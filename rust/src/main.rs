//! `memsgd` — the experiment launcher.
//!
//! ```text
//! memsgd table1 [--scale 20]
//! memsgd table2
//! memsgd figure2 --dataset epsilon [--scale 20] [--epochs 2] [--out results/]
//! memsgd figure3 --dataset epsilon [--scale 20] [--epochs 2] [--gamma0 1.0]
//! memsgd figure4 --dataset epsilon [--workers 1,2,4,8,12,16,20,24] [--threads]
//! memsgd figure5 --dataset rcv1   [--scale 40]
//! memsgd bitsloss --k 100 [--scale 100] [--steps 10000]  # composition payoff
//! memsgd e2e     [--steps 200] [--k 100]      # transformer through PJRT
//! memsgd train   --method memsgd:top_k:1 [--topology shared] ...  # ad-hoc run
//! memsgd info                                  # runtime / artifact status
//! ```
//!
//! Every figure subcommand prints the regenerated series and writes the
//! JSON records under `--out` (default `results/`).

use anyhow::{bail, Result};

use memsgd::coordinator::train::{self, TrainConfig};
use memsgd::coordinator::{FailurePolicy, FaultSpec, GossipGraph, LocalUpdate, MethodSpec, Topology};
use memsgd::experiments::{self, Which};
use memsgd::metrics::{self, summary_table, RunRecord};
use memsgd::optim::Schedule;
use memsgd::sim::network::NetworkModel;
use memsgd::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("table1") => cmd_table1(args),
        Some("table2") => cmd_table2(args),
        Some("figure2") => cmd_figure2(args),
        Some("figure3") => cmd_figure3(args),
        Some("figure4") => cmd_figure4(args),
        Some("figure5") => cmd_figure5(args),
        Some("figure6") => cmd_figure6(args),
        Some("bitsloss") => cmd_bitsloss(args),
        Some("section22") => cmd_section22(args),
        Some("theory") => cmd_theory(args),
        Some("async") => cmd_async(args),
        Some("e2e") => cmd_e2e(args),
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("worker") => cmd_worker(args),
        Some("ring") => cmd_ring(args),
        Some("bench-gate") => cmd_bench_gate(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand '{other}' (see --help in README)"),
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
memsgd — Sparsified SGD with Memory (Stich, Cordonnier, Jaggi; NIPS 2018)

subcommands:
  table1    dataset statistics (paper Table 1)
  table2    theoretical stepsize parameters (paper Table 2)
  figure2   Mem-SGD convergence, top-k/rand-k vs SGD (paper Figure 2)
  figure3   Mem-SGD vs QSGD in iterations and bits (paper Figure 3)
  figure4   multicore speedup: threads + DES model (paper Figure 4)
  figure5   gamma0 grid search (paper Figure 5)
  figure6   time-to-accuracy on 1GbE/10GbE/100Gb links (extension)
  bitsloss  bits on the wire to a shared target loss: top_k:K vs the
            composed qsgd:16(top_k:K) vs adaptive:K (--k K, extension)
  section22 variance blow-up of unbiased sparsification (paper §2.2)
  theory    Lemma 3.2 memory envelope on a live run
  async     async vs sync parameter server under a network model
  e2e       transformer LM through the PJRT artifacts (full stack)
  train     one ad-hoc run (--method memsgd:top_k:100 — compressor
            specs compose: memsgd:qsgd:16(top_k:100) quantizes the
            kept coordinates, memsgd:adaptive:100 keeps ~100 coords
            with Wangni probabilities; --epochs, --dataset, --topology
            sequential|shared|ps-sync|ps-async|all-reduce|gossip,
            --workers-count N, --gossip-graph complete|ring,
            --batch B, --local-steps H, --wire,
            --wire-transport loopback|tcp, ...)
  serve     cluster parameter server: bind --listen ADDR, accept exactly
            --nodes N workers over TCP, run a ps-sync|ps-async job
            across OS processes (same flags as train minus --topology
            sequential/shared), print the record + a final: line;
            --io poll|threads picks the socket-multiplexing backend
            (poll(2) event loop, default on unix | reader threads)
  worker    cluster worker: dial --connect ADDR (bounded retries via
            --retries), handshake, run the assigned wire protocol;
            --expect-method/--expect-dim/--expect-batch/
            --expect-local-steps pin what the server must be running
  ring      one node of the server-free all-reduce ring: bind --listen
            ADDR, dial the successor --next ADDR, run the ring protocol
            peer-to-peer (no server process); --node I --nodes N place
            this process in the ring, node 0 prints the final: line
  bench-gate  CI perf gate: compare a fresh hot-path bench JSON against
            the committed baseline (--baseline BENCH_hot_path.json,
            --fresh run.json); exits nonzero on >25% normalized median
            regression or a broken sparse-speedup invariant
  info      artifact / runtime status

common options: --dataset epsilon|rcv1  --scale N  --seed N  --out DIR
local-update schedule (train, figure6): --batch B (minibatch size),
  --local-steps H (local steps between syncs; ~H-fold fewer bits)
wire mode (train, ps-sync/ps-async only): --wire runs real server/worker
  threads exchanging Elias-coded updates over an in-process channel;
  trajectories are bit-identical to the simulated engines, and the
  record gains wire_* extras with the bytes that actually crossed.
  --wire-transport tcp moves the same threads onto localhost kernel
  sockets (loopback = the in-process default)
cluster mode: memsgd serve --listen 127.0.0.1:7070 --nodes 2 ... plus
  one memsgd worker --connect 127.0.0.1:7070 per node runs the same
  protocol across separate OS processes, bit-identical to --wire
  (see README 'Cluster quickstart'); all-reduce has no server — launch
  one memsgd ring process per node instead
failure injection (train, serve, worker, ring): --fault-plan
  none|kill:K:SEED|drop:K:SEED|corrupt:K:SEED|delay:K:MS:SEED draws a
  deterministic per-node fault schedule from SEED — the same spec
  replays bit-for-bit in the simulator and on the wire (on worker/ring
  the plan wraps that process's own sockets; on train/serve it wraps
  the server side)
failure policies (train, serve): --failure-policy
  fail-fast (default: first fault aborts the run) |
  drop-round[:QUORUM] (ps topologies: fold the survivors, scale by the
  live count, lost mass re-enters via error feedback) |
  wait-rejoin:SECS (ps-sync serve: hold the round open for a
  reconnecting worker; pair with worker --resume)
checkpointed server (serve, ps-sync): --checkpoint PATH
  [--checkpoint-every N] snapshots model+round+liveness every N rounds;
  restarting the same command resumes mid-run, workers re-sync from a
  model SNAPSHOT frame";

fn out_dir(args: &Args) -> String {
    args.get_str("out", "results")
}

fn finish(args: &Args, name: &str, records: &[RunRecord]) -> Result<()> {
    println!("\n{}", summary_table(records));
    let path = format!("{}/{}.json", out_dir(args), name);
    metrics::write_records(&path, records)?;
    println!("records -> {path}");
    args.finish()
}

fn cmd_table1(args: &Args) -> Result<()> {
    let scale = args.get("scale", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    println!("Table 1 — dataset statistics (surrogates at scale {scale}):\n");
    println!("{}", experiments::table1(scale, seed));
    args.finish()
}

fn cmd_table2(args: &Args) -> Result<()> {
    println!("Table 2 — theoretical stepsizes:\n");
    println!("{}", experiments::table2());
    args.finish()
}

fn cmd_figure2(args: &Args) -> Result<()> {
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let epochs = args.get("epochs", 2usize)?;
    let evals = args.get("evals", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    println!(
        "Figure 2 — Mem-SGD convergence on {} (scale {scale}, {epochs} epochs)",
        which.name()
    );
    let records = experiments::figure2(which, scale, epochs, evals, seed)?;
    print_curves(&records);
    finish(args, &format!("figure2_{}", which.name()), &records)
}

fn cmd_figure3(args: &Args) -> Result<()> {
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let epochs = args.get("epochs", 2usize)?;
    let evals = args.get("evals", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    let gamma0 = args.opt_str("gamma0").map(|s| s.parse::<f64>()).transpose()?;
    println!(
        "Figure 3 — Mem-SGD vs QSGD on {} (scale {scale}, {epochs} epochs, gamma0 {:?})",
        which.name(),
        gamma0
    );
    let records = experiments::figure3(which, scale, epochs, evals, gamma0, seed)?;
    print_curves(&records);
    println!("\ncommunication at equal iteration count:");
    for r in &records {
        println!(
            "  {:<28} {:>12} total",
            r.method,
            metrics::fmt_bits(r.total_bits)
        );
    }
    finish(args, &format!("figure3_{}", which.name()), &records)
}

fn cmd_figure4(args: &Args) -> Result<()> {
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let seed = args.get("seed", 1u64)?;
    let workers = args.get_list("workers", &[1usize, 2, 4, 8, 12, 16, 20, 24])?;
    println!("Figure 4 — multicore speedup on {} (DES model)\n", which.name());
    let series = experiments::figure4_sim(which, &workers, seed);
    println!("{}", experiments::sim_table(&series));
    println!("collision/lost-update counts at max workers:");
    for s in &series {
        if let Some(p) = s.points.last() {
            println!("  {:<24} lost {:>6} updates", s.method, p.lost_updates);
        }
    }

    if args.flag("threads") {
        let scale = args.get("scale", 100usize)?;
        let steps = args.get("steps", 40_000usize)?;
        let tw: Vec<usize> = workers.iter().copied().filter(|&w| w <= 8).collect();
        println!("\nthreaded Algorithm 2 (fixed total budget {steps}, final-iterate loss):");
        let recs = experiments::figure4_threads(which, scale, steps, &tw, seed)?;
        println!("{}", summary_table(&recs));
        metrics::write_records(
            format!("{}/figure4_threads_{}.json", out_dir(args), which.name()),
            &recs,
        )?;
    }
    args.finish()
}

fn cmd_figure5(args: &Args) -> Result<()> {
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 40usize)?;
    let steps = args.get("steps", 10_000usize)?;
    let seed = args.get("seed", 1u64)?;
    println!(
        "Figure 5 — gamma0 grid search on {} (scale {scale}, {steps} steps per cell)\n",
        which.name()
    );
    let res = experiments::figure5(which, scale, steps, seed)?;
    println!("{}", res.table());
    let records: Vec<RunRecord> = res.cells.iter().map(|c| c.record.clone()).collect();
    metrics::write_records(
        format!("{}/figure5_{}.json", out_dir(args), which.name()),
        &records,
    )?;
    args.finish()
}

fn cmd_figure6(args: &Args) -> Result<()> {
    use memsgd::experiments::extensions;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let rounds = args.get("rounds", 2_000usize)?;
    let workers = args.get("workers-count", 8usize)?;
    let seed = args.get("seed", 1u64)?;
    let local = LocalUpdate::new(args.get("batch", 1usize)?, args.get("local-steps", 1usize)?)?;
    println!(
        "Figure 6 (extension) — time-to-accuracy on real link profiles, {} (scale {scale}, \
         B={} H={})\n",
        which.name(),
        local.batch,
        local.sync_every
    );
    let res = extensions::figure6_network(which, scale, rounds, workers, local, seed)?;
    println!("{}", res.table());
    let mut obj = Vec::new();
    for c in &res.cells {
        obj.push(memsgd::util::json::Json::obj(vec![
            ("method", memsgd::util::json::Json::str(&c.method)),
            ("network", memsgd::util::json::Json::str(&c.network)),
            (
                "seconds_to_target",
                memsgd::util::json::Json::Num(c.seconds_to_target.unwrap_or(f64::NAN)),
            ),
            ("comm_fraction", memsgd::util::json::Json::Num(c.comm_fraction)),
            ("final_loss", memsgd::util::json::Json::Num(c.final_loss)),
        ]));
    }
    let path = format!("{}/figure6_{}.json", out_dir(args), which.name());
    std::fs::create_dir_all(out_dir(args))?;
    std::fs::write(&path, memsgd::util::json::Json::Arr(obj).to_string_pretty())?;
    println!("wrote {path}");
    args.finish()
}

fn cmd_bitsloss(args: &Args) -> Result<()> {
    use memsgd::experiments::extensions;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let steps = args.get("steps", 10_000usize)?;
    let k = args.get("k", 100usize)?;
    let seed = args.get("seed", 1u64)?;
    println!(
        "bits-vs-loss (extension) — top_k:{k} vs qsgd:16(top_k:{k}) vs adaptive:{k} \
         on {} (scale {scale}, {steps} steps)\n",
        which.name()
    );
    let res = extensions::bits_vs_loss(which, scale, steps, k, seed)?;
    println!("{}", res.table());
    let mut obj = Vec::new();
    for c in &res.cells {
        obj.push(memsgd::util::json::Json::obj(vec![
            ("method", memsgd::util::json::Json::str(&c.method)),
            ("final_loss", memsgd::util::json::Json::Num(c.final_loss)),
            ("total_bits", memsgd::util::json::Json::Num(c.total_bits as f64)),
            (
                "bits_to_target",
                memsgd::util::json::Json::Num(
                    c.bits_to_target.map(|b| b as f64).unwrap_or(f64::NAN),
                ),
            ),
            ("bits_per_step", memsgd::util::json::Json::Num(c.bits_per_step)),
        ]));
    }
    let path = format!("{}/bitsloss_{}.json", out_dir(args), which.name());
    std::fs::create_dir_all(out_dir(args))?;
    std::fs::write(&path, memsgd::util::json::Json::Arr(obj).to_string_pretty())?;
    println!("wrote {path}");
    args.finish()
}

fn cmd_section22(args: &Args) -> Result<()> {
    use memsgd::experiments::extensions;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let steps = args.get("steps", 20_000usize)?;
    let seed = args.get("seed", 1u64)?;
    println!("Section 2.2 — variance blow-up of unbiased sparsification\n");
    let res = extensions::section22(which, scale, steps, seed)?;
    println!("estimator variance at x₀ (d/k predicted blow-up: {:.0}×):", res.predicted_blowup);
    for (name, v) in &res.variances {
        println!("  {name:<32} {v:.4}");
    }
    println!();
    print_curves(&res.records);
    finish(args, &format!("section22_{}", which.name()), &res.records)
}

fn cmd_theory(args: &Args) -> Result<()> {
    use memsgd::experiments::extensions;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 200usize)?;
    let steps = args.get("steps", 20_000usize)?;
    let seed = args.get("seed", 1u64)?;
    let spec = args.get_str("spec", "top_k:1");
    println!("Lemma 3.2 — measured ‖m_t‖² vs the theoretical envelope ({spec})\n");
    let tr = extensions::memory_trace(which, scale, steps, &spec, seed)?;
    println!("G² estimate {:.4}, shift a = {:.0}", tr.g_sq, tr.shift);
    println!("{:>8} {:>14} {:>14} {:>8}", "t", "measured", "bound", "ratio");
    for p in tr.points.iter().step_by((tr.points.len() / 15).max(1)) {
        println!(
            "{:>8} {:>14.4e} {:>14.4e} {:>8.4}",
            p.t,
            p.measured,
            p.bound,
            p.measured / p.bound
        );
    }
    println!("\nmax measured/bound ratio: {:.4} (Lemma 3.2 holds iff ≤ 1)", tr.max_ratio);
    args.finish()
}

fn cmd_async(args: &Args) -> Result<()> {
    use memsgd::experiments::extensions;
    use memsgd::sim::network::NetworkModel;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let updates = args.get("updates", 20_000usize)?;
    let workers = args.get("workers-count", 8usize)?;
    let seed = args.get("seed", 1u64)?;
    let net = match args.get_str("network", "1g").as_str() {
        "1g" => NetworkModel::eth_1g(),
        "10g" => NetworkModel::eth_10g(),
        "100g" => NetworkModel::ib_100g(),
        other => bail!("unknown network '{other}' (1g|10g|100g)"),
    };
    println!(
        "async vs sync parameter server on {} ({} workers, {})\n",
        which.name(),
        workers,
        net.name
    );
    let recs = extensions::async_compare(which, scale, updates, workers, net, seed)?;
    println!("{}", summary_table(&recs));
    println!("simulated wall-clock:");
    for r in &recs {
        println!(
            "  {:<44} {:>10.3}s  staleness mean {:>6.2}",
            r.method,
            r.extra.get("sim_seconds").copied().unwrap_or(f64::NAN),
            r.extra.get("mean_staleness").copied().unwrap_or(0.0),
        );
    }
    finish(args, &format!("async_{}", which.name()), &recs)
}

fn cmd_e2e(args: &Args) -> Result<()> {
    use memsgd::runtime::pjrt::PjrtRuntime;
    use memsgd::runtime::transformer::TransformerBackend;

    let steps = args.get("steps", 200usize)?;
    let k = args.get("k", 100usize)?;
    let eta = args.get("eta", 0.1f64)?;
    let evals = args.get("evals", 10usize)?;
    let seed = args.get("seed", 1u64)?;
    let n_batches = args.get("batches", 16usize)?;

    println!("e2e — Mem-SGD(top_{k}) on the ~1M-param transformer via PJRT artifacts");
    let mut rt = PjrtRuntime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    let mut backend = TransformerBackend::new(&mut rt, n_batches, 2, seed)?;
    let p = backend.rt.meta.param_count;
    println!(
        "model: {} params, vocab {}, {} layers — Mem-SGD compresses {p} -> {k} coords/step",
        p, backend.rt.meta.vocab, backend.rt.meta.n_layers
    );

    let cfg = TrainConfig {
        method: format!("memsgd:top_k:{k}"),
        schedule: Schedule::constant(eta),
        steps,
        eval_points: evals,
        average: false, // LM: evaluate the live iterate
        seed,
        lam: Some(0.0),
        local: LocalUpdate::default(),
    };
    // Mem-SGD starts from x0 = 0; shift to the artifact's init by
    // training the *delta* is wrong — instead run the loop manually from
    // the init params (the coordinator API is exercised by logreg).
    let record = run_transformer_memsgd(&mut backend, &cfg)?;
    println!("\n{}", summary_table(std::slice::from_ref(&record)));
    print_curves(std::slice::from_ref(&record));
    metrics::write_records(format!("{}/e2e_transformer.json", out_dir(args)), &[record])?;
    args.finish()
}

/// Mem-SGD over the transformer backend, starting from the artifact's
/// initial parameters (not zero — a zero LM has no gradient signal).
fn run_transformer_memsgd(
    backend: &mut memsgd::runtime::transformer::TransformerBackend<'_>,
    cfg: &TrainConfig,
) -> Result<RunRecord> {
    use memsgd::compress::from_spec;
    use memsgd::metrics::LossPoint;
    use memsgd::models::GradBackend;
    use memsgd::optim::MemSgd;
    use memsgd::util::prng::Prng;
    use std::time::Instant;

    let comp_spec = cfg
        .method
        .strip_prefix("memsgd:")
        .ok_or_else(|| anyhow::anyhow!("e2e expects a memsgd:* method"))?;
    let mut opt = MemSgd::new(backend.initial_params(), from_spec(comp_spec)?);
    let mut rng = Prng::new(cfg.seed);
    let n = backend.n();
    let d = backend.dim();
    let mut grad = vec![0.0f32; d];
    let eval_every = (cfg.steps / cfg.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: format!("memsgd({comp_spec}) transformer"),
        dataset: "markov-lm".into(),
        schedule: cfg.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let loss0 = backend.full_loss(&opt.x);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: loss0 });
    println!("step {:>5}  eval loss {loss0:.4}", 0);
    for t in 0..cfg.steps {
        let i = rng.below(n);
        backend.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, cfg.schedule.eta(t), &mut rng);
        if (t + 1) % eval_every == 0 || t + 1 == cfg.steps {
            let loss = backend.full_loss(&opt.x);
            println!(
                "step {:>5}  eval loss {loss:.4}  train loss {:.4}  bits {}",
                t + 1,
                backend.last_train_loss,
                metrics::fmt_bits(opt.bits_sent)
            );
            record.curve.push(LossPoint { t: t + 1, bits: opt.bits_sent, loss });
        }
    }
    record.steps = cfg.steps;
    record.total_bits = opt.bits_sent;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    Ok(record)
}

fn cmd_train(args: &Args) -> Result<()> {
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    // The CLI is the parse edge: one typed MethodSpec from here on.
    let method = MethodSpec::parse(&args.get_str("method", "memsgd:top_k:1"))?;
    let epochs = args.get("epochs", 1usize)?;
    let gamma = args.get("gamma", 2.0f64)?;
    let evals = args.get("evals", 10usize)?;
    let workers = args.get("workers-count", 4usize)?;
    // The strict parse edge for the local-update schedule: zero and
    // overflowing --batch/--local-steps are rejected here, not deep
    // inside a driver.
    let local = LocalUpdate::new(args.get("batch", 1usize)?, args.get("local-steps", 1usize)?)?;
    let data = experiments::dataset(which, scale, seed);
    let steps = epochs * data.n();
    let schedule =
        method.paper_schedule(data.d(), data.n(), gamma, which.shift_multiplier(), None);

    // --checkpoint PATH [--checkpoint-every N] [--resume]: periodic state
    // persistence + bit-identical resume (memsgd:* methods, sequential).
    if let Some(path) = args.opt_str("checkpoint") {
        let cfg = TrainConfig {
            method: method.spec_string(),
            schedule,
            steps,
            eval_points: evals,
            seed,
            local,
            ..TrainConfig::default()
        };
        let policy = train::CheckpointPolicy {
            path: path.into(),
            every: args.get("checkpoint-every", 1_000usize)?,
            resume: args.flag("resume"),
        };
        let rec = train::run_resumable(&data, &cfg, &policy)?;
        println!(
            "checkpoint -> {} (resumed from step {})",
            policy.path.display(),
            rec.extra.get("resumed_from").copied().unwrap_or(0.0) as usize
        );
        print_curves(std::slice::from_ref(&rec));
        return finish(args, "train", std::slice::from_ref(&rec));
    }

    // --failure-policy / --fault-plan: deterministic fault injection
    // and the policy that absorbs it. Parsed here (the CLI is the parse
    // edge); the policy × topology matrix is validated by the
    // experiment itself.
    let policy = FailurePolicy::parse(&args.get_str("failure-policy", "fail-fast"))?;
    let faults = FaultSpec::parse(&args.get_str("fault-plan", "none"))?;

    // --topology sequential|shared|ps-sync|ps-async|all-reduce|gossip
    // [--workers-count N]: the same method/schedule on any coordination
    // fabric. Unknown strings are rejected here with the full menu —
    // never silently defaulted.
    let topology = match args.get_str("topology", "sequential").as_str() {
        "sequential" | "seq" => Topology::Sequential,
        "shared" | "shared-memory" => Topology::SharedMemory { workers },
        "ps-sync" | "ps" | "sync" => Topology::ParamServerSync { nodes: workers },
        "ps-async" | "async" => {
            let net = match args.get_str("network", "1g").as_str() {
                "1g" => NetworkModel::eth_1g(),
                "10g" => NetworkModel::eth_10g(),
                "100g" => NetworkModel::ib_100g(),
                other => bail!("unknown network '{other}' (1g|10g|100g)"),
            };
            Topology::ParamServerAsync { nodes: workers, net }
        }
        "all-reduce" | "allreduce" | "ring" => Topology::AllReduce { nodes: workers },
        "gossip" => {
            // --gossip-graph complete|ring: who may pair with whom each
            // round (complete = any node, ring = adjacent nodes only).
            let graph = match args.get_str("gossip-graph", "complete").as_str() {
                "complete" | "full" => GossipGraph::Complete,
                "ring" => GossipGraph::Ring,
                other => bail!("unknown gossip graph '{other}' (complete|ring)"),
            };
            Topology::Gossip { nodes: workers, graph }
        }
        other => bail!(
            "unknown topology '{other}' \
             (sequential|shared|ps-sync|ps-async|all-reduce|gossip)"
        ),
    };
    // --wire: run the parameter-server topologies on the threaded
    // message-passing runtime (real Elias-coded bytes over an
    // in-process channel) instead of the single-threaded simulation.
    // --wire-transport loopback|tcp picks the fabric (tcp = localhost
    // kernel sockets; implies --wire).
    let transport = args.opt_str("wire-transport");
    let wire = args.flag("wire") || transport.is_some();
    let mut exp = experiments::experiment_on(&data, None)
        .method(method)
        .schedule(schedule)
        .topology(topology)
        .steps(steps)
        .eval_points(evals)
        .seed(seed)
        .local_update(local)
        .wire(wire)
        .failure_policy(policy);
    if let Some(spec) = faults {
        exp = exp.fault_plan(spec);
    }
    if let Some(t) = transport {
        use memsgd::coordinator::net::TcpTransport;
        use memsgd::coordinator::transport::Loopback;
        exp = match t.as_str() {
            "loopback" => exp.wire_transport(Box::new(Loopback)),
            "tcp" => exp.wire_transport(Box::new(TcpTransport)),
            other => bail!("unknown wire transport '{other}' (loopback|tcp)"),
        };
    }
    let rec = exp.run()?;
    if wire {
        let wex = |key: &str| rec.extra.get(key).copied().unwrap_or(0.0) as u64;
        println!(
            "wire: {} payload bits up, {} down, {} frame bits on the channel \
             (accounted: {} total)",
            metrics::fmt_bits(wex("wire_upload_payload_bits")),
            metrics::fmt_bits(wex("wire_broadcast_payload_bits")),
            metrics::fmt_bits(wex("wire_frame_bits")),
            metrics::fmt_bits(rec.total_bits),
        );
    }
    print_curves(std::slice::from_ref(&rec));
    print_final_line(&rec);
    finish(args, "train", std::slice::from_ref(&rec))
}

/// The machine-diffable one-line summary. The CI `cluster-smoke` job
/// compares this line between a multi-process `serve` run and the
/// equivalent simulated `train` run — bit-identical trajectories make
/// the lines equal, so keep the format stable.
fn print_final_line(rec: &RunRecord) {
    println!(
        "final: method={} loss={:.6} total_bits={} steps={}",
        rec.method,
        rec.final_loss(),
        rec.total_bits,
        rec.steps
    );
}

/// `memsgd serve` — the cluster parameter server. Mirrors `cmd_train`'s
/// experiment flags, but instead of running worker threads it binds
/// `--listen`, waits for `--nodes` TCP workers, and runs the shared
/// server-protocol half against their sockets.
fn cmd_serve(args: &Args) -> Result<()> {
    use memsgd::coordinator::cluster::{ClusterServer, IoBackend, RunConfig};
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    let method = MethodSpec::parse(&args.get_str("method", "memsgd:top_k:1"))?;
    let epochs = args.get("epochs", 1usize)?;
    let gamma = args.get("gamma", 2.0f64)?;
    let evals = args.get("evals", 10usize)?;
    let nodes = args.get("nodes", 2usize)?;
    let local = LocalUpdate::new(args.get("batch", 1usize)?, args.get("local-steps", 1usize)?)?;
    let listen = args.get_str("listen", "127.0.0.1:7070");
    let topology = args.get_str("topology", "ps-sync");
    let network = args.get_str("network", "1g");
    let failure_policy = FailurePolicy::parse(&args.get_str("failure-policy", "fail-fast"))?;
    let fault_plan = FaultSpec::parse(&args.get_str("fault-plan", "none"))?;
    let out = out_dir(args);
    // Derive steps/schedule from the dataset *shape* — `bind` builds the
    // actual data once, and every worker rebuilds it from the config.
    let (n, dim) = experiments::dataset_shape(which, scale);
    let steps = epochs * n;
    let schedule = method.paper_schedule(dim, n, gamma, which.shift_multiplier(), None);
    let cfg = RunConfig {
        dataset: which.name().into(),
        scale,
        seed,
        method: method.spec_string(),
        schedule,
        steps,
        eval_points: evals,
        nodes,
        local,
        topology,
        network,
        dim,
        failure_policy,
        fault_plan,
        start_round: 0,
    };
    // --io poll|threads: the server's socket-multiplexing backend
    // (default: poll(2) event loop on unix, reader threads elsewhere).
    let io = match args.opt_str("io") {
        Some(s) => IoBackend::parse(&s)?,
        None => IoBackend::platform_default(),
    };
    let mut server = ClusterServer::bind_with_io(&listen, cfg, io)?;
    // --checkpoint PATH [--checkpoint-every N]: periodic cluster
    // checkpoints (model + round + liveness). If PATH already holds one,
    // the run resumes from its round and every worker opens on a model
    // SNAPSHOT instead of round 0.
    if let Some(path) = args.opt_str("checkpoint") {
        let every = args.get("checkpoint-every", 10usize)?;
        server = server.with_checkpoint(path.into(), every)?;
        if server.start_round() > 0 {
            println!("checkpoint found — resuming from round {}", server.start_round());
        }
    }
    println!(
        "serving on {} [io={}] — waiting for {nodes} worker(s) (connect with \
         `memsgd worker --connect <addr>`)",
        server.local_addr()?,
        io.name()
    );
    // Reject unknown flags before blocking on the accept loop.
    args.finish()?;
    let rec = server.run()?;
    print_curves(std::slice::from_ref(&rec));
    println!("\n{}", summary_table(std::slice::from_ref(&rec)));
    print_final_line(&rec);
    let path = format!("{out}/serve.json");
    metrics::write_records(&path, std::slice::from_ref(&rec))?;
    println!("records -> {path}");
    Ok(())
}

/// `memsgd worker` — one cluster worker process. Dials the server with
/// bounded-backoff retries, handshakes, and runs whatever job the
/// server's config describes; the `--expect-*` flags let a deployment
/// pin the method/dim/local-update it believes the server is running.
fn cmd_worker(args: &Args) -> Result<()> {
    use memsgd::coordinator::cluster::run_worker;
    use memsgd::coordinator::net::{Backoff, Hello};
    let addr = args.get_str("connect", "127.0.0.1:7070");
    let attempts = args.get("retries", 8u32)?;
    let mut expect = Hello::any();
    if let Some(m) = args.opt_str("expect-method") {
        // Canonicalize so `--expect-method memsgd:top_k:01` and the
        // server's spec string compare equal.
        expect.method = MethodSpec::parse(&m)?.spec_string();
    }
    expect.dim = args.get("expect-dim", 0usize)?;
    expect.batch = args.get("expect-batch", 0usize)?;
    expect.sync_every = args.get("expect-local-steps", 0usize)?;
    // --resume: announce this process replaces a dead worker — the
    // server (under wait-rejoin) re-syncs it from a model SNAPSHOT.
    // --fault-plan: deterministic faults on THIS worker's own socket
    // (the server side is wrapped by `serve --fault-plan`, never both).
    let resume = args.flag("resume");
    let fault_plan = FaultSpec::parse(&args.get_str("fault-plan", "none"))?;
    args.finish()?;
    let backoff = Backoff { attempts, ..Backoff::default() };
    let (node, bits) = run_worker(&addr, &expect, &backoff, resume, fault_plan.as_ref())?;
    println!("worker {node} done: {bits} accounted upload bits");
    Ok(())
}

/// `memsgd ring` — one node of the server-free all-reduce ring. Every
/// process binds `--listen`, dials its successor `--next`, and speaks
/// the ring reduce/gather protocol peer-to-peer; there is no server.
/// Node 0 doubles as the driver: it owns the `RunRecord` and prints the
/// same `final:` line CI diffs against the simulated twin
/// (`train --topology all-reduce`).
fn cmd_ring(args: &Args) -> Result<()> {
    use memsgd::coordinator::cluster::{RingNodeProcess, RunConfig};
    use memsgd::coordinator::net::Backoff;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let seed = args.get("seed", 1u64)?;
    let method = MethodSpec::parse(&args.get_str("method", "memsgd:top_k:1"))?;
    let epochs = args.get("epochs", 1usize)?;
    let gamma = args.get("gamma", 2.0f64)?;
    let evals = args.get("evals", 10usize)?;
    let nodes = args.get("nodes", 2usize)?;
    let node = args.get("node", 0usize)?;
    let local = LocalUpdate::new(args.get("batch", 1usize)?, args.get("local-steps", 1usize)?)?;
    let listen = args.get_str("listen", "127.0.0.1:7080");
    let next = args.get_str("next", "127.0.0.1:7080");
    let attempts = args.get("retries", 8u32)?;
    let out = out_dir(args);
    // Same derivation as `serve`: steps/schedule come from the dataset
    // *shape*; every ring process rebuilds the data from the config, so
    // all nodes must be launched with identical experiment flags.
    let (n, dim) = experiments::dataset_shape(which, scale);
    let steps = epochs * n;
    let schedule = method.paper_schedule(dim, n, gamma, which.shift_multiplier(), None);
    let cfg = RunConfig {
        dataset: which.name().into(),
        scale,
        seed,
        method: method.spec_string(),
        schedule,
        steps,
        eval_points: evals,
        nodes,
        local,
        topology: "all-reduce".into(),
        network: "1g".into(),
        dim,
        failure_policy: FailurePolicy::FailFast,
        fault_plan: None,
        start_round: 0,
    };
    // --fault-plan wraps this node's inbound ring edge; every hop is
    // load-bearing, so injected faults are fail-fast by construction.
    let fault_plan = FaultSpec::parse(&args.get_str("fault-plan", "none"))?;
    let ring = RingNodeProcess::bind(&listen, cfg, node)?;
    println!(
        "ring node {node}/{nodes} on {} — dialing successor {next}",
        ring.local_addr()?
    );
    // Reject unknown flags before blocking on the handshake.
    args.finish()?;
    let backoff = Backoff { attempts, ..Backoff::default() };
    match ring.run(&next, &backoff, fault_plan.as_ref())? {
        Some(rec) => {
            print_curves(std::slice::from_ref(&rec));
            println!("\n{}", summary_table(std::slice::from_ref(&rec)));
            print_final_line(&rec);
            let path = format!("{out}/ring.json");
            metrics::write_records(&path, std::slice::from_ref(&rec))?;
            println!("records -> {path}");
        }
        None => println!("ring node {node} done"),
    }
    Ok(())
}

/// The CI performance gate (`.github/workflows/ci.yml`, `bench-gate`
/// job): compare a fresh hot-path bench JSON against the committed
/// baseline. Policy and comparison live in `util::gate` (unit-tested,
/// including the injected-2×-slowdown canary); this wrapper only does
/// I/O and turns failures into a nonzero exit.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let baseline_path = args.get_str("baseline", "BENCH_hot_path.json");
    let fresh_path = args.get_str("fresh", "fresh.json");
    args.finish()?;
    // Canonicalize so aliases (./x vs x, symlinks) cannot sneak a file
    // past the self-comparison guard.
    let same_file = match (
        std::fs::canonicalize(&baseline_path),
        std::fs::canonicalize(&fresh_path),
    ) {
        (Ok(a), Ok(b)) => a == b,
        _ => baseline_path == fresh_path,
    };
    if same_file {
        bail!(
            "--baseline '{baseline_path}' and --fresh '{fresh_path}' are the same file: \
             comparing a file to itself always passes; point --fresh at a fresh-rows-only \
             file (e.g. one written via MEMSGD_BENCH_JSON=fresh.json cargo bench --bench \
             hot_path)"
        );
    }
    let read = |path: &str| -> Result<Vec<memsgd::util::gate::GateRow>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        memsgd::util::gate::parse_rows(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e:#}"))
    };
    let baseline = read(&baseline_path)?;
    let fresh = read(&fresh_path)?;
    let cfg = memsgd::util::gate::hot_path_config();
    let report = memsgd::util::gate::compare(&baseline, &fresh, &cfg);
    println!("bench-gate: {} (baseline) vs {} (fresh)\n", baseline_path, fresh_path);
    for line in &report.lines {
        println!("{line}");
    }
    for warning in &report.warnings {
        println!("warn: {warning}");
    }
    if !report.passed() {
        for failure in &report.failures {
            eprintln!("FAIL: {failure}");
        }
        bail!("{} perf regression(s) beyond tolerance", report.failures.len());
    }
    println!("\nbench-gate passed ({} case(s) compared)", report.lines.len());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("artifacts dir: {}", memsgd::runtime::default_artifacts_dir().display());
    if memsgd::runtime::artifacts_available() {
        let m = memsgd::runtime::manifest::Manifest::load(
            memsgd::runtime::default_artifacts_dir(),
        )?;
        println!("manifest: {} entries", m.entries.len());
        for e in &m.entries {
            println!(
                "  {:<28} {} inputs -> {} outputs",
                e.name,
                e.inputs.len(),
                e.outputs.len()
            );
        }
        let mut rt = memsgd::runtime::pjrt::PjrtRuntime::open_default()?;
        rt.warmup("logreg_grad_b64_d512")?;
        println!("PJRT platform: {} (compile OK)", rt.platform());
    } else {
        println!("artifacts NOT built — run `make artifacts`");
    }
    args.finish()
}

/// ASCII sketch of each record's loss curve (terminal-friendly Figure 2).
fn print_curves(records: &[RunRecord]) {
    for r in records {
        if r.curve.len() < 2 {
            continue;
        }
        let min = r.best_loss();
        let max = r.curve.iter().map(|p| p.loss).fold(f64::MIN, f64::max);
        let span = (max - min).max(1e-12);
        let bars: String = r
            .curve
            .iter()
            .map(|p| {
                let level = ((p.loss - min) / span * 7.0).round() as usize;
                char::from_u32(0x2581 + level.min(7) as u32).unwrap()
            })
            .collect();
        println!("{:<36} {bars}  [{max:.4} → {:.4}]", r.method, r.final_loss());
    }
}
