//! Ridge (L2-regularized least-squares) regression:
//!
//! `f(x) = (1/2n) Σ (⟨a_i, x⟩ − b_i)² + (λ/2)‖x‖²`,
//! `∇f_i(x) = (⟨a_i, x⟩ − b_i)·a_i + λ·x`.
//!
//! An extension workload with a *closed-form* optimum
//! `x* = (AᵀA/n + λI)⁻¹ Aᵀb/n`, which makes it the anchor for exact
//! convergence tests: Mem-SGD must drive `‖x − x*‖` down on a problem
//! where `x*` is known to machine precision.

use super::GradBackend;
use crate::compress::{SparseMerge, SparseVec};
use crate::data::{Dataset, Features};

/// Least-squares model over a dataset (labels used as real targets).
///
/// As with [`super::LogisticModel`], the per-sample gradient `r_i·a_i +
/// λ·x` is a scaled feature row exactly when `λ = 0` — that case opts
/// into the sparse gradient pipeline; nonzero `λ` falls back to the
/// dense path.
#[derive(Clone)]
pub struct LeastSquaresModel<'a> {
    pub data: &'a Dataset,
    pub lam: f64,
    /// Real-valued targets; defaults to the dataset's ±1 labels.
    pub targets: Vec<f32>,
    /// Coordinate-merge scratch for the batched sparse emission.
    merge: SparseMerge,
    /// Dense scratch for the `λ ≠ 0` sparse-emission fallback.
    scratch: Vec<f32>,
}

impl<'a> LeastSquaresModel<'a> {
    pub fn new(data: &'a Dataset, lam: f64) -> Self {
        LeastSquaresModel {
            targets: data.labels.clone(),
            data,
            lam,
            merge: SparseMerge::new(),
            scratch: Vec::new(),
        }
    }

    /// Residual `⟨a_i, x⟩ − b_i`.
    #[inline]
    pub fn residual(&self, x: &[f32], i: usize) -> f32 {
        self.data.dot_row(i, x) - self.targets[i]
    }

    /// Closed-form optimum via normal equations (dense Gaussian
    /// elimination with partial pivoting; fine for test-sized d).
    pub fn solve_exact(&self) -> Vec<f32> {
        let d = self.data.d();
        let n = self.data.n();
        // H = AᵀA/n + λI, g = Aᵀb/n.
        let mut h = vec![0.0f64; d * d];
        let mut g = vec![0.0f64; d];
        let mut row = vec![0.0f32; d];
        for i in 0..n {
            row.iter_mut().for_each(|r| *r = 0.0);
            self.data.add_scaled_row(i, 1.0, &mut row);
            for p in 0..d {
                if row[p] == 0.0 {
                    continue;
                }
                g[p] += row[p] as f64 * self.targets[i] as f64 / n as f64;
                for q in 0..d {
                    h[p * d + q] += row[p] as f64 * row[q] as f64 / n as f64;
                }
            }
        }
        for p in 0..d {
            h[p * d + p] += self.lam;
        }
        solve_dense(&mut h, &mut g, d);
        g.iter().map(|&v| v as f32).collect()
    }
}

/// In-place Gaussian elimination with partial pivoting: solves `H·x = g`,
/// leaving the solution in `g`.
fn solve_dense(h: &mut [f64], g: &mut [f64], d: usize) {
    for col in 0..d {
        // pivot
        let mut best = col;
        for r in col + 1..d {
            if h[r * d + col].abs() > h[best * d + col].abs() {
                best = r;
            }
        }
        if best != col {
            for q in 0..d {
                h.swap(col * d + q, best * d + q);
            }
            g.swap(col, best);
        }
        let piv = h[col * d + col];
        assert!(piv.abs() > 1e-12, "singular normal matrix");
        for r in col + 1..d {
            let f = h[r * d + col] / piv;
            if f == 0.0 {
                continue;
            }
            for q in col..d {
                h[r * d + q] -= f * h[col * d + q];
            }
            g[r] -= f * g[col];
        }
    }
    for col in (0..d).rev() {
        let mut acc = g[col];
        for q in col + 1..d {
            acc -= h[col * d + q] * g[q];
        }
        g[col] = acc / h[col * d + col];
    }
}

impl GradBackend for LeastSquaresModel<'_> {
    fn dim(&self) -> usize {
        self.data.d()
    }

    fn n(&self) -> usize {
        self.data.n()
    }

    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
        let r = self.residual(x, i);
        let lam = self.lam as f32;
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = lam * xi;
        }
        self.data.add_scaled_row(i, r, out);
    }

    fn sample_grad_batch(&mut self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), x.len());
        debug_assert!(!idx.is_empty(), "empty minibatch");
        let lam = self.lam as f32;
        let inv_b = 1.0 / idx.len() as f32;
        // out = λ·x once, then += (r_i/B)·a_i per sample (dense or CSR
        // rows) — allocation-free; B = 1 matches sample_grad bit for bit.
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = lam * xi;
        }
        for &i in idx {
            let r = self.residual(x, i);
            self.data.add_scaled_row(i, r * inv_b, out);
        }
    }

    /// The gradient is truly sparse only without the dense `λ·x` term
    /// (and, as for [`super::LogisticModel`], only CSR storage benefits).
    fn supports_sparse_grad(&self) -> bool {
        self.lam == 0.0 && matches!(self.data.features, Features::Csr { .. })
    }

    /// Exact sparse emission (`λ = 0`: `∇f_i = r_i·a_i`, one row pass
    /// through the shared core `models::push_scaled_row`); `λ ≠ 0`
    /// densifies through the reusable scratch, staying exact.
    fn sample_grad_sparse(&mut self, x: &[f32], i: usize, out: &mut SparseVec) {
        if self.lam != 0.0 {
            let mut tmp = std::mem::take(&mut self.scratch);
            tmp.resize(x.len(), 0.0);
            self.sample_grad(x, i, &mut tmp);
            super::gather_nonzeros(&tmp, out);
            self.scratch = tmp;
            return;
        }
        super::push_scaled_row(self.data, i, self.residual(x, i), out);
    }

    /// Batched exact sparse emission through the reusable
    /// [`SparseMerge`] (shared core `models::merge_scaled_row`) —
    /// mirrors [`GradBackend::sample_grad_batch`]'s per-sample
    /// `(r_i/B)·a_i` accumulation in dense FP order.
    fn sample_grad_batch_sparse(&mut self, x: &[f32], idx: &[usize], out: &mut SparseVec) {
        debug_assert!(!idx.is_empty(), "empty minibatch");
        if idx.len() == 1 {
            self.sample_grad_sparse(x, idx[0], out);
            return;
        }
        if self.lam != 0.0 {
            let mut tmp = std::mem::take(&mut self.scratch);
            tmp.resize(x.len(), 0.0);
            self.sample_grad_batch(x, idx, &mut tmp);
            super::gather_nonzeros(&tmp, out);
            self.scratch = tmp;
            return;
        }
        let inv_b = 1.0 / idx.len() as f32;
        let mut merge = std::mem::take(&mut self.merge);
        merge.begin(self.data.d(), out);
        for &i in idx {
            let scaled = self.residual(x, i) * inv_b;
            super::merge_scaled_row(&mut merge, self.data, i, scaled, out);
        }
        merge.finish(out);
        self.merge = merge;
    }

    fn full_loss(&mut self, x: &[f32]) -> f64 {
        let n = self.n();
        let mut acc = 0.0f64;
        for i in 0..n {
            let r = self.residual(x, i) as f64;
            acc += 0.5 * r * r;
        }
        acc / n as f64 + 0.5 * self.lam * crate::util::stats::l2_norm_sq(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::prng::Prng;

    #[test]
    fn exact_solution_zeroes_the_gradient() {
        let ds = synthetic::epsilon_like(60, 8, 2);
        let mut m = LeastSquaresModel::new(&ds, 0.1);
        let xstar = m.solve_exact();
        let mut grad = vec![0.0f32; 8];
        m.full_grad(&xstar, &mut grad);
        let gn = crate::util::stats::l2_norm(&grad);
        assert!(gn < 1e-4, "‖∇f(x*)‖ = {gn}");
    }

    #[test]
    fn exact_solution_is_a_minimum() {
        let ds = synthetic::epsilon_like(60, 6, 3);
        let mut m = LeastSquaresModel::new(&ds, 0.05);
        let xstar = m.solve_exact();
        let fstar = m.full_loss(&xstar);
        let mut rng = Prng::new(4);
        for _ in 0..20 {
            let xp: Vec<f32> = xstar.iter().map(|&v| v + 0.1 * rng.normal_f32()).collect();
            assert!(m.full_loss(&xp) >= fstar - 1e-9);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = synthetic::epsilon_like(30, 5, 7);
        let mut m = LeastSquaresModel::new(&ds, 0.2);
        let x = vec![0.3f32, -0.1, 0.5, 0.0, -0.4];
        let mut grad = vec![0.0f32; 5];
        m.full_grad(&x, &mut grad);
        let eps = 1e-3f32;
        for j in 0..5 {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (m.full_loss(&xp) - m.full_loss(&xm)) / (2.0 * eps as f64);
            assert!((fd - grad[j] as f64).abs() < 2e-3, "j={j}");
        }
    }

    #[test]
    fn batch_gradient_matches_sample_mean_and_b1_exactly() {
        let ds = synthetic::epsilon_like(40, 6, 8);
        let mut m = LeastSquaresModel::new(&ds, 0.15);
        let x = vec![0.2f32, -0.3, 0.1, 0.4, -0.2, 0.05];
        let mut single = vec![0.0f32; 6];
        let mut batched = vec![0.0f32; 6];
        m.sample_grad(&x, 5, &mut single);
        m.sample_grad_batch(&x, &[5], &mut batched);
        assert_eq!(single, batched, "B=1 must be bit-for-bit");

        let idx = [1usize, 5, 9, 13];
        m.sample_grad_batch(&x, &idx, &mut batched);
        let mut mean = vec![0.0f32; 6];
        let mut tmp = vec![0.0f32; 6];
        for &i in &idx {
            m.sample_grad(&x, i, &mut tmp);
            for (a, &t) in mean.iter_mut().zip(&tmp) {
                *a += t / idx.len() as f32;
            }
        }
        crate::util::check::ensure_allclose(&batched, &mean, 1e-5, 1e-6, "batch mean").unwrap();
    }

    #[test]
    fn sparse_grad_matches_dense_for_both_lambda_regimes() {
        let ds = synthetic::rcv1_like(50, 24, 0.25, 6);
        let d = ds.d();
        let x: Vec<f32> = (0..d).map(|j| 0.1 * (j as f32).sin()).collect();
        let mut dense = vec![0.0f32; d];
        let mut sparse = crate::compress::SparseVec::new(d);
        for lam in [0.0f64, 0.2] {
            let mut m = LeastSquaresModel::new(&ds, lam);
            // rcv1_like data is CSR, so support hinges on λ alone here.
            assert_eq!(m.supports_sparse_grad(), lam == 0.0);
            for i in [0usize, 13, 49] {
                m.sample_grad(&x, i, &mut dense);
                m.sample_grad_sparse(&x, i, &mut sparse);
                assert_eq!(sparse.to_dense(), dense, "lam={lam} sample {i}");
            }
            let idx = [5usize, 20, 5, 31];
            m.sample_grad_batch(&x, &idx, &mut dense);
            m.sample_grad_batch_sparse(&x, &idx, &mut sparse);
            assert_eq!(sparse.to_dense(), dense, "lam={lam} batch");
        }
    }

    #[test]
    fn solver_handles_diagonal_system() {
        // Identity features: x* = targets/(1 + λn/n)... verify directly on
        // a hand-built diagonal case: A = I (n = d), b arbitrary.
        let d = 4;
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let ds = Dataset::dense("eye", eye, d, vec![1.0, -1.0, 1.0, -1.0]);
        let lam = 0.25;
        let m = LeastSquaresModel::new(&ds, lam);
        let xstar = m.solve_exact();
        // H = I/n + λI = (1/4 + 1/4) I, g = b/4 ⇒ x* = b/2.
        for (x, b) in xstar.iter().zip(&ds.labels) {
            assert!((x - b * 0.5).abs() < 1e-5, "{x} vs {}", b * 0.5);
        }
    }
}
