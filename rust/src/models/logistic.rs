//! L2-regularized logistic regression (the paper's Section 4 objective):
//!
//! `f(x) = (1/n) Σ log(1 + exp(−b_i·⟨a_i, x⟩)) + (λ/2)‖x‖²`.
//!
//! Per-sample gradient: `∇f_i(x) = coef·a_i + λ·x` with
//! `coef = −b_i·σ(−b_i·⟨a_i, x⟩)`. Both dense and CSR feature rows are
//! supported through [`Dataset`]'s row views; this is the native Rust
//! gradient backend the figure drivers run on (the PJRT/Pallas backend
//! computes the identical quantity from the AOT artifact and is
//! cross-checked in the integration suite).

use super::{log1p_exp, sigmoid, GradBackend};
use crate::compress::{SparseMerge, SparseVec};
use crate::data::{Dataset, Features};

/// Logistic regression over a dataset with L2 strength `lam`.
///
/// `Clone` is cheap (a borrow, a scalar, and empty/small scratch) — the
/// shared-memory topology engine clones one model per worker thread.
///
/// With `lam == 0` the per-sample gradient is exactly `coef·a_i`, a
/// scaled copy of one feature row, so the model opts into the sparse
/// gradient pipeline ([`GradBackend::supports_sparse_grad`]); any
/// nonzero `λ` adds the dense `λ·x` term and the engines fall back to
/// the dense path (the sparse emissions below stay exact either way via
/// an internal densifying fallback).
#[derive(Clone)]
pub struct LogisticModel<'a> {
    pub data: &'a Dataset,
    pub lam: f64,
    /// Coordinate-merge scratch for the batched sparse emission.
    merge: SparseMerge,
    /// Dense scratch for the `λ ≠ 0` sparse-emission fallback.
    scratch: Vec<f32>,
}

impl<'a> LogisticModel<'a> {
    /// Paper convention: `λ = 1/n` (Section 4.1, following [31]).
    pub fn with_paper_lambda(data: &'a Dataset) -> Self {
        let lam = 1.0 / data.n() as f64;
        Self::new(data, lam)
    }

    pub fn new(data: &'a Dataset, lam: f64) -> Self {
        LogisticModel {
            data,
            lam,
            merge: SparseMerge::new(),
            scratch: Vec::new(),
        }
    }

    /// Margin `⟨a_i, x⟩`.
    #[inline]
    pub fn margin(&self, x: &[f32], i: usize) -> f32 {
        self.data.dot_row(i, x)
    }

    /// The scalar gradient coefficient `coef = −b_i·σ(−b_i·z_i)` so that
    /// `∇f_i = coef·a_i + λx`. Exposed for the sparse-aware parallel path.
    #[inline]
    pub fn grad_coef(&self, x: &[f32], i: usize) -> f32 {
        let y = self.data.label(i);
        let z = self.margin(x, i);
        -y * sigmoid(-y * z)
    }

    /// Loss of one sample (without regularizer).
    #[inline]
    pub fn sample_data_loss(&self, x: &[f32], i: usize) -> f32 {
        let y = self.data.label(i);
        log1p_exp(-y * self.margin(x, i))
    }

    /// Estimate of the paper's `G² ≥ E‖∇f_i(x)‖²` at `x` (Monte Carlo
    /// over `m` samples) — used by theory-validation tests.
    pub fn g_squared_estimate(&mut self, x: &[f32], m: usize, seed: u64) -> f64 {
        let mut rng = crate::util::prng::Prng::new(seed);
        let mut out = vec![0.0f32; self.dim()];
        let mut acc = 0.0f64;
        for _ in 0..m {
            let i = rng.below(self.n());
            self.sample_grad(x, i, &mut out);
            acc += crate::util::stats::l2_norm_sq(&out);
        }
        acc / m as f64
    }
}

impl GradBackend for LogisticModel<'_> {
    fn dim(&self) -> usize {
        self.data.d()
    }

    fn n(&self) -> usize {
        self.data.n()
    }

    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), x.len());
        let coef = self.grad_coef(x, i);
        let lam = self.lam as f32;
        // out = λ·x, then += coef·a_i (sparse rows touch few entries).
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = lam * xi;
        }
        self.data.add_scaled_row(i, coef, out);
    }

    fn sample_grad_batch(&mut self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), x.len());
        debug_assert!(!idx.is_empty(), "empty minibatch");
        let lam = self.lam as f32;
        let inv_b = 1.0 / idx.len() as f32;
        // The regularizer appears once in the mean, so out = λ·x, then
        // += (coef_i/B)·a_i per sample: one pass, no scratch, O(Σ nnz)
        // whether the rows are dense or CSR. With B = 1, `coef·1.0`
        // is exact, so this is sample_grad bit for bit.
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = lam * xi;
        }
        for &i in idx {
            let coef = self.grad_coef(x, i);
            self.data.add_scaled_row(i, coef * inv_b, out);
        }
    }

    /// The gradient is truly sparse only without the dense `λ·x` term,
    /// and the pipeline only pays off when the feature rows themselves
    /// are sparse — dense-storage datasets would emit `nnz = d` entries
    /// plus merge bookkeeping, strictly worse than the dense path.
    fn supports_sparse_grad(&self) -> bool {
        self.lam == 0.0 && matches!(self.data.features, Features::Csr { .. })
    }

    /// Exact sparse emission: with `λ = 0`, `∇f_i = coef·a_i` — one pass
    /// over the feature row, `O(nnz)`, allocation-free (shared core
    /// `models::push_scaled_row`). With `λ ≠ 0` (dense gradient) this
    /// falls back to densify-and-gather through the reusable scratch,
    /// staying exact.
    fn sample_grad_sparse(&mut self, x: &[f32], i: usize, out: &mut SparseVec) {
        if self.lam != 0.0 {
            let mut tmp = std::mem::take(&mut self.scratch);
            tmp.resize(x.len(), 0.0);
            self.sample_grad(x, i, &mut tmp);
            super::gather_nonzeros(&tmp, out);
            self.scratch = tmp;
            return;
        }
        super::push_scaled_row(self.data, i, self.grad_coef(x, i), out);
    }

    /// Batched exact sparse emission: per sample the scaled coefficient
    /// `coef_i/B` multiplies the row entries in dense-path order, and
    /// repeated coordinates merge in arrival order through the reusable
    /// [`SparseMerge`] (shared core `models::merge_scaled_row`) —
    /// bit-identical values to [`GradBackend::sample_grad_batch`] at
    /// every stored coordinate.
    fn sample_grad_batch_sparse(&mut self, x: &[f32], idx: &[usize], out: &mut SparseVec) {
        debug_assert!(!idx.is_empty(), "empty minibatch");
        if idx.len() == 1 {
            // `coef·(1/1)` is exact, but skip the merge entirely.
            self.sample_grad_sparse(x, idx[0], out);
            return;
        }
        if self.lam != 0.0 {
            let mut tmp = std::mem::take(&mut self.scratch);
            tmp.resize(x.len(), 0.0);
            self.sample_grad_batch(x, idx, &mut tmp);
            super::gather_nonzeros(&tmp, out);
            self.scratch = tmp;
            return;
        }
        let inv_b = 1.0 / idx.len() as f32;
        let mut merge = std::mem::take(&mut self.merge);
        merge.begin(self.data.d(), out);
        for &i in idx {
            let scaled = self.grad_coef(x, i) * inv_b;
            super::merge_scaled_row(&mut merge, self.data, i, scaled, out);
        }
        merge.finish(out);
        self.merge = merge;
    }

    fn full_loss(&mut self, x: &[f32]) -> f64 {
        let n = self.n();
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += self.sample_data_loss(x, i) as f64;
        }
        acc / n as f64 + 0.5 * self.lam * crate::util::stats::l2_norm_sq(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::check::ensure_allclose;
    use crate::util::prng::Prng;

    fn tiny() -> Dataset {
        Dataset::dense(
            "tiny",
            vec![1.0, 0.0, /*r1*/ 0.0, 1.0, /*r2*/ 1.0, 1.0],
            2,
            vec![1.0, -1.0, 1.0],
        )
    }

    #[test]
    fn grad_at_zero_closed_form() {
        // At x = 0: σ = 1/2, coef_i = −b_i/2; ∇f_i = −(b_i/2)a_i.
        let ds = tiny();
        let mut m = LogisticModel::new(&ds, 0.0);
        let mut out = vec![0.0f32; 2];
        m.sample_grad(&[0.0, 0.0], 0, &mut out);
        assert_eq!(out, vec![-0.5, 0.0]);
        m.sample_grad(&[0.0, 0.0], 1, &mut out);
        assert_eq!(out, vec![0.0, 0.5]);
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let ds = tiny();
        let mut m = LogisticModel::new(&ds, 0.0);
        let loss = m.full_loss(&[0.0, 0.0]);
        assert!((loss - (2.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn regularizer_contributions() {
        let ds = tiny();
        let lam = 0.5;
        let mut m = LogisticModel::new(&ds, lam);
        let x = vec![2.0f32, -1.0];
        let mut m0 = LogisticModel::new(&ds, 0.0);
        let base = m0.full_loss(&x);
        let reg = 0.5 * lam * 5.0;
        assert!((m.full_loss(&x) - base - reg).abs() < 1e-6);

        let mut g = vec![0.0f32; 2];
        let mut g0 = vec![0.0f32; 2];
        m.sample_grad(&x, 2, &mut g);
        m0.sample_grad(&x, 2, &mut g0);
        let diff: Vec<f32> = g.iter().zip(&g0).map(|(a, b)| a - b).collect();
        ensure_allclose(&diff, &[1.0, -0.5], 1e-5, 1e-6, "lam*x").unwrap();
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = synthetic::epsilon_like(40, 12, 5);
        let mut m = LogisticModel::new(&ds, 0.03);
        let mut rng = Prng::new(1);
        let x: Vec<f32> = (0..12).map(|_| 0.2 * rng.normal_f32()).collect();
        let mut grad = vec![0.0f32; 12];
        m.full_grad(&x, &mut grad);
        let eps = 1e-3f32;
        for j in 0..12 {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (m.full_loss(&xp) - m.full_loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (fd - grad[j] as f64).abs() < 2e-3,
                "coord {j}: fd={fd} analytic={}",
                grad[j]
            );
        }
    }

    #[test]
    fn sparse_and_dense_agree() {
        // Same logical matrix as dense and as CSR must give identical
        // margins, losses and gradients.
        let dense = Dataset::dense(
            "dense",
            vec![0.5, 0.0, 1.5, /*r*/ 0.0, 2.0, 0.0],
            3,
            vec![1.0, -1.0],
        );
        let sparse = Dataset::csr(
            "sparse",
            vec![0, 2, 3],
            vec![0, 2, 1],
            vec![0.5, 1.5, 2.0],
            3,
            vec![1.0, -1.0],
        );
        let mut md = LogisticModel::new(&dense, 0.1);
        let mut ms = LogisticModel::new(&sparse, 0.1);
        let x = vec![0.3f32, -0.7, 0.9];
        assert!((md.full_loss(&x) - ms.full_loss(&x)).abs() < 1e-7);
        let mut gd = vec![0.0f32; 3];
        let mut gs = vec![0.0f32; 3];
        for i in 0..2 {
            md.sample_grad(&x, i, &mut gd);
            ms.sample_grad(&x, i, &mut gs);
            ensure_allclose(&gd, &gs, 1e-6, 1e-7, "grad").unwrap();
        }
    }

    #[test]
    fn batch_of_one_is_sample_grad_bit_for_bit() {
        for ds in [synthetic::epsilon_like(60, 12, 4), synthetic::rcv1_like(60, 24, 0.2, 4)] {
            let mut m = LogisticModel::with_paper_lambda(&ds);
            let d = ds.d();
            let mut rng = Prng::new(2);
            let x: Vec<f32> = (0..d).map(|_| 0.4 * rng.normal_f32()).collect();
            let mut single = vec![0.0f32; d];
            let mut batched = vec![0.0f32; d];
            for i in [0usize, 7, 59] {
                m.sample_grad(&x, i, &mut single);
                m.sample_grad_batch(&x, &[i], &mut batched);
                assert_eq!(single, batched, "{} sample {i}", ds.name);
            }
        }
    }

    #[test]
    fn batch_gradient_is_the_sample_mean() {
        for ds in [synthetic::epsilon_like(50, 10, 6), synthetic::rcv1_like(50, 20, 0.3, 6)] {
            let mut m = LogisticModel::new(&ds, 0.07);
            let d = ds.d();
            let x: Vec<f32> = (0..d).map(|j| 0.1 * (j as f32 + 1.0).sin()).collect();
            let idx = [3usize, 11, 11, 42, 7]; // repeats allowed
            let mut batched = vec![0.0f32; d];
            m.sample_grad_batch(&x, &idx, &mut batched);
            let mut mean = vec![0.0f32; d];
            let mut tmp = vec![0.0f32; d];
            for &i in &idx {
                m.sample_grad(&x, i, &mut tmp);
                for (a, &t) in mean.iter_mut().zip(&tmp) {
                    *a += t / idx.len() as f32;
                }
            }
            ensure_allclose(&batched, &mean, 1e-5, 1e-6, &ds.name).unwrap();
        }
    }

    #[test]
    fn sparse_grad_matches_dense_bit_for_bit_at_lam_zero() {
        for ds in [synthetic::rcv1_like(60, 48, 0.15, 3), synthetic::epsilon_like(40, 12, 3)] {
            let mut m = LogisticModel::new(&ds, 0.0);
            // Only CSR storage opts into the engine's sparse path, but
            // the emissions themselves are exact for dense rows too.
            let is_csr = matches!(ds.features, crate::data::Features::Csr { .. });
            assert_eq!(m.supports_sparse_grad(), is_csr, "{}", ds.name);
            let d = ds.d();
            let mut rng = Prng::new(5);
            let x: Vec<f32> = (0..d).map(|_| 0.3 * rng.normal_f32()).collect();
            let mut dense = vec![0.0f32; d];
            let mut sparse = crate::compress::SparseVec::new(d);
            for i in [0usize, 17, 39] {
                m.sample_grad(&x, i, &mut dense);
                m.sample_grad_sparse(&x, i, &mut sparse);
                assert_eq!(sparse.to_dense(), dense, "{} sample {i}", ds.name);
            }
            // Batched, with repeated samples (exercises the merge).
            let idx = [3usize, 11, 3, 28, 11];
            m.sample_grad_batch(&x, &idx, &mut dense);
            m.sample_grad_batch_sparse(&x, &idx, &mut sparse);
            assert_eq!(sparse.to_dense(), dense, "{} batch", ds.name);
        }
    }

    #[test]
    fn sparse_grad_falls_back_exactly_at_nonzero_lam() {
        let ds = synthetic::rcv1_like(50, 32, 0.2, 4);
        let mut m = LogisticModel::with_paper_lambda(&ds);
        assert!(!m.supports_sparse_grad(), "λ ≠ 0 gradients are dense");
        let d = ds.d();
        let x: Vec<f32> = (0..d).map(|j| 0.05 * (j as f32 + 1.0).cos()).collect();
        let mut dense = vec![0.0f32; d];
        let mut sparse = crate::compress::SparseVec::new(d);
        m.sample_grad(&x, 7, &mut dense);
        m.sample_grad_sparse(&x, 7, &mut sparse);
        assert_eq!(sparse.to_dense(), dense);
        m.sample_grad_batch(&x, &[1, 9, 9, 30], &mut dense);
        m.sample_grad_batch_sparse(&x, &[1, 9, 9, 30], &mut sparse);
        assert_eq!(sparse.to_dense(), dense);
    }

    #[test]
    fn sparse_batch_buffers_stop_growing_after_warmup() {
        // Same protocol as top_k.rs::reuses_buffers_without_allocation_growth:
        // one warm-up call, then capacities must stay put.
        let ds = synthetic::rcv1_like(80, 64, 0.2, 7);
        let mut m = LogisticModel::new(&ds, 0.0);
        let d = ds.d();
        let x = vec![0.02f32; d];
        let mut out = crate::compress::SparseVec::new(d);
        let mut rng = Prng::new(9);
        let idx: Vec<usize> = (0..16).map(|_| rng.below(80)).collect();
        m.sample_grad_batch_sparse(&x, &idx, &mut out);
        let cap = (out.idx.capacity(), out.val.capacity());
        for round in 0..100 {
            let idx: Vec<usize> = (0..16).map(|_| rng.below(80)).collect();
            m.sample_grad_batch_sparse(&x, &idx, &mut out);
            assert_eq!((out.idx.capacity(), out.val.capacity()), cap, "round {round}");
        }
    }

    #[test]
    fn paper_lambda_is_one_over_n() {
        let ds = synthetic::epsilon_like(250, 8, 0);
        let m = LogisticModel::with_paper_lambda(&ds);
        assert!((m.lam - 1.0 / 250.0).abs() < 1e-12);
    }

    #[test]
    fn g_squared_estimate_is_positive_and_bounded() {
        let ds = synthetic::epsilon_like(100, 16, 3);
        let mut m = LogisticModel::with_paper_lambda(&ds);
        let g2 = m.g_squared_estimate(&vec![0.0; 16], 200, 1);
        // rows are unit-norm, coef ∈ [−1, 1] ⇒ ‖∇f_i‖ ≤ 1 + λ‖x‖ = 1.
        assert!(g2 > 0.0 && g2 <= 1.0 + 1e-6, "g2={g2}");
    }
}
