//! Model layer: objective functions and gradient backends.
//!
//! The coordinator is generic over a [`GradBackend`] — anything that can
//! produce per-sample stochastic gradients and evaluate the full
//! objective:
//!
//! * [`logistic::LogisticModel`] — the paper's workload, computed
//!   natively in Rust (dense and CSR paths). This is the backend the
//!   figure drivers use: the paper runs 10⁵–10⁶ *per-sample* iterations,
//!   where a PJRT dispatch per iteration would measure dispatch overhead
//!   rather than the algorithm (DESIGN.md §2, hot-path split).
//! * [`linear::LeastSquaresModel`] — ridge regression, an extension
//!   workload with a closed-form optimum used by convergence tests.
//! * `runtime::PjrtBackend` — the same logistic gradients executed from
//!   the AOT HLO artifacts (whose innards are the L1 Pallas kernels);
//!   cross-checked against the native backend to ≤1e-4 relative error in
//!   the integration suite.

pub mod linear;
pub mod logistic;

pub use linear::LeastSquaresModel;
pub use logistic::LogisticModel;

/// A source of per-sample gradients and objective values.
///
/// `&mut self` lets implementations keep reusable scratch (the PJRT
/// backend owns device buffers; native backends need nothing).
pub trait GradBackend {
    /// Feature dimension.
    fn dim(&self) -> usize;

    /// Number of samples.
    fn n(&self) -> usize;

    /// Write `∇f_i(x)` (including the regularizer) densely into `out`.
    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]);

    /// Write the minibatch gradient `(1/B)·Σ_{i∈idx} ∇f_i(x)` densely
    /// into `out` (`B = idx.len()`, must be ≥ 1) — the batched hot path
    /// of the local-update schedule.
    ///
    /// Contract pinned by `tests/local_update_equivalence.rs`: with
    /// `idx.len() == 1` the result is **bit-for-bit** identical to
    /// [`GradBackend::sample_grad`]. The default implementation averages
    /// `sample_grad` through a temporary (fine for remote backends like
    /// PJRT where dispatch dominates); the native models override it with
    /// a single-pass, allocation-free accumulation over their dense or
    /// CSR rows.
    fn sample_grad_batch(&mut self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        debug_assert!(!idx.is_empty(), "empty minibatch");
        if idx.len() == 1 {
            self.sample_grad(x, idx[0], out);
            return;
        }
        let d = self.dim();
        let inv_b = 1.0 / idx.len() as f32;
        let mut tmp = vec![0.0f32; d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for &i in idx {
            self.sample_grad(x, i, &mut tmp);
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += inv_b * t;
            }
        }
    }

    /// Full objective `f(x)`.
    fn full_loss(&mut self, x: &[f32]) -> f64;

    /// Full-batch gradient (defaults to averaging sample gradients; used
    /// by tests and the L-smoothness estimator).
    fn full_grad(&mut self, x: &[f32], out: &mut [f32]) {
        let d = self.dim();
        let n = self.n();
        let mut tmp = vec![0.0f32; d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..n {
            self.sample_grad(x, i, &mut tmp);
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += t / n as f32;
            }
        }
    }
}

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `log(1 + exp(z))`.
#[inline]
pub fn log1p_exp(z: f32) -> f32 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) <= 1.0 && sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(-100.0) < 1e-3);
        for &z in &[-5.0f32, -1.0, 0.3, 2.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn log1p_exp_stable() {
        assert!((log1p_exp(0.0) - (2.0f32).ln()).abs() < 1e-6);
        assert!((log1p_exp(100.0) - 100.0).abs() < 1e-4);
        assert!(log1p_exp(-100.0) >= 0.0 && log1p_exp(-100.0) < 1e-6);
        // matches naive formula in the safe range
        for &z in &[-3.0f32, -0.5, 0.5, 3.0] {
            let naive = (1.0 + z.exp()).ln();
            assert!((log1p_exp(z) - naive).abs() < 1e-6);
        }
    }
}
