//! Model layer: objective functions and gradient backends.
//!
//! The coordinator is generic over a [`GradBackend`] — anything that can
//! produce per-sample stochastic gradients and evaluate the full
//! objective:
//!
//! * [`logistic::LogisticModel`] — the paper's workload, computed
//!   natively in Rust (dense and CSR paths). This is the backend the
//!   figure drivers use: the paper runs 10⁵–10⁶ *per-sample* iterations,
//!   where a PJRT dispatch per iteration would measure dispatch overhead
//!   rather than the algorithm (DESIGN.md §2, hot-path split).
//! * [`linear::LeastSquaresModel`] — ridge regression, an extension
//!   workload with a closed-form optimum used by convergence tests.
//! * `runtime::PjrtBackend` — the same logistic gradients executed from
//!   the AOT HLO artifacts (whose innards are the L1 Pallas kernels);
//!   cross-checked against the native backend to ≤1e-4 relative error in
//!   the integration suite.

pub mod linear;
pub mod logistic;

pub use linear::LeastSquaresModel;
pub use logistic::LogisticModel;

use crate::compress::SparseVec;

/// A source of per-sample gradients and objective values.
///
/// `&mut self` lets implementations keep reusable scratch (the PJRT
/// backend owns device buffers; native backends keep the sparse-merge
/// accumulator of the sparse gradient pipeline).
///
/// ## The sparse gradient pipeline
///
/// On the paper's sparse workloads (RCV1: d = 47 236, ~73 nonzeros per
/// row) a stochastic gradient without L2 regularization is a scaled copy
/// of one sparse row — materializing it densely wastes a factor of
/// `d/nnz`. Backends that can emit such gradients exactly advertise it
/// through [`GradBackend::supports_sparse_grad`] and the topology
/// engines then run the whole local phase in `O(nnz)` per local step
/// (see `coordinator::experiment`). The contract is strict: the sparse
/// emission must hold the **same floating-point values** the dense
/// [`GradBackend::sample_grad`] would produce at its nonzero
/// coordinates, with exact zeros everywhere else, so dense and sparse
/// trajectories are bit-identical (`tests/sparse_pipeline.rs`). The
/// default implementations are densifying shims — correct for every
/// backend, allocating and `O(d)`, there so remote backends (PJRT) and
/// downstream implementors keep compiling without opting in.
pub trait GradBackend {
    /// Feature dimension.
    fn dim(&self) -> usize;

    /// Number of samples.
    fn n(&self) -> usize;

    /// Write `∇f_i(x)` (including the regularizer) densely into `out`.
    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]);

    /// Write the minibatch gradient `(1/B)·Σ_{i∈idx} ∇f_i(x)` densely
    /// into `out` (`B = idx.len()`, must be ≥ 1) — the batched hot path
    /// of the local-update schedule.
    ///
    /// Contract pinned by `tests/local_update_equivalence.rs`: with
    /// `idx.len() == 1` the result is **bit-for-bit** identical to
    /// [`GradBackend::sample_grad`]. The default implementation averages
    /// `sample_grad` through a temporary (fine for remote backends like
    /// PJRT where dispatch dominates); the native models override it with
    /// a single-pass, allocation-free accumulation over their dense or
    /// CSR rows.
    fn sample_grad_batch(&mut self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        debug_assert!(!idx.is_empty(), "empty minibatch");
        if idx.len() == 1 {
            self.sample_grad(x, idx[0], out);
            return;
        }
        let d = self.dim();
        let inv_b = 1.0 / idx.len() as f32;
        let mut tmp = vec![0.0f32; d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for &i in idx {
            self.sample_grad(x, i, &mut tmp);
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += inv_b * t;
            }
        }
    }

    /// Whether this backend's stochastic gradients are genuinely sparse
    /// **and** [`GradBackend::sample_grad_sparse`] /
    /// [`GradBackend::sample_grad_batch_sparse`] emit them in `O(nnz)`
    /// without densifying. The topology engines consult this once per
    /// local phase to pick the sparse path; the default is `false`
    /// (remote backends, dense-storage datasets — where `nnz = d` makes
    /// the pipeline pure overhead — and L2-regularized models, whose
    /// `λ·x` term makes every gradient dense).
    fn supports_sparse_grad(&self) -> bool {
        false
    }

    /// Write `∇f_i(x)` into `out` as a sparse vector.
    ///
    /// Exactness contract: for every coordinate `j` stored in `out`,
    /// `out[j]` is **bit-identical** to what [`GradBackend::sample_grad`]
    /// writes at `j`, and every omitted coordinate's dense value is an
    /// exact zero. Indices are unique; duplicate contributions must be
    /// merged by the implementation (in dense accumulation order).
    ///
    /// The default is a densifying shim — it calls `sample_grad` through
    /// a temporary and gathers the nonzeros, so it is exact but `O(d)`
    /// and allocating; native models override it allocation-free.
    fn sample_grad_sparse(&mut self, x: &[f32], i: usize, out: &mut SparseVec) {
        let d = self.dim();
        let mut tmp = vec![0.0f32; d];
        self.sample_grad(x, i, &mut tmp);
        gather_nonzeros(&tmp, out);
    }

    /// Sparse counterpart of [`GradBackend::sample_grad_batch`]: the
    /// minibatch mean `(1/B)·Σ_{i∈idx} ∇f_i(x)` as a merged sparse
    /// vector, same exactness contract as
    /// [`GradBackend::sample_grad_sparse`] (values bit-identical to the
    /// dense batch path at stored coordinates, exact zeros elsewhere,
    /// unique indices). Default: densifying shim over
    /// [`GradBackend::sample_grad_batch`].
    fn sample_grad_batch_sparse(&mut self, x: &[f32], idx: &[usize], out: &mut SparseVec) {
        debug_assert!(!idx.is_empty(), "empty minibatch");
        if idx.len() == 1 {
            self.sample_grad_sparse(x, idx[0], out);
            return;
        }
        let d = self.dim();
        let mut tmp = vec![0.0f32; d];
        self.sample_grad_batch(x, idx, &mut tmp);
        gather_nonzeros(&tmp, out);
    }

    /// Full objective `f(x)`.
    fn full_loss(&mut self, x: &[f32]) -> f64;

    /// Full-batch gradient (defaults to averaging sample gradients; used
    /// by tests and the L-smoothness estimator).
    fn full_grad(&mut self, x: &[f32], out: &mut [f32]) {
        let d = self.dim();
        let n = self.n();
        let mut tmp = vec![0.0f32; d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..n {
            self.sample_grad(x, i, &mut tmp);
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += t / n as f32;
            }
        }
    }
}

/// Gather the nonzeros of a dense vector into a reusable [`SparseVec`]
/// (the densifying-shim tail shared by the default trait methods).
fn gather_nonzeros(dense: &[f32], out: &mut SparseVec) {
    out.clear(dense.len());
    for (j, &g) in dense.iter().enumerate() {
        if g != 0.0 {
            out.push(j as u32, g);
        }
    }
}

/// Exact single-sample sparse emission shared by the native models:
/// `out = coef·a_i` — each stored value is the literal product
/// `coef * v`, matching the dense path's
/// [`Dataset::add_scaled_row`](crate::data::Dataset::add_scaled_row)
/// contribution bit for bit (the `λ = 0` dense gradient is `±0 +
/// coef·v`, numerically equal). Assumes rows carry unique column
/// indices (standard CSR).
fn push_scaled_row(data: &crate::data::Dataset, i: usize, coef: f32, out: &mut SparseVec) {
    out.clear(data.d());
    match data.row(i) {
        crate::data::RowView::Dense(row) => {
            for (j, &v) in row.iter().enumerate() {
                out.push(j as u32, coef * v);
            }
        }
        crate::data::RowView::Sparse { idx, val } => {
            for (&j, &v) in idx.iter().zip(val) {
                out.push(j, coef * v);
            }
        }
    }
}

/// Exact batched-emission core shared by the native models: merge
/// `scaled·a_i` into an in-progress coordinate merge, adding per-entry
/// contributions `scaled * v` in row order — the same FP sequence the
/// dense minibatch accumulation applies at each coordinate.
fn merge_scaled_row(
    merge: &mut crate::compress::SparseMerge,
    data: &crate::data::Dataset,
    i: usize,
    scaled: f32,
    out: &mut SparseVec,
) {
    match data.row(i) {
        crate::data::RowView::Dense(row) => {
            for (j, &v) in row.iter().enumerate() {
                merge.add(out, j as u32, scaled * v);
            }
        }
        crate::data::RowView::Sparse { idx, val } => {
            for (&j, &v) in idx.iter().zip(val) {
                merge.add(out, j, scaled * v);
            }
        }
    }
}

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `log(1 + exp(z))`.
#[inline]
pub fn log1p_exp(z: f32) -> f32 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal dense backend relying on every default trait method.
    struct Quadratic {
        d: usize,
    }

    impl GradBackend for Quadratic {
        fn dim(&self) -> usize {
            self.d
        }
        fn n(&self) -> usize {
            3
        }
        fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
            for (j, o) in out.iter_mut().enumerate() {
                *o = if j % 2 == 0 { (i as f32 + 1.0) * x[j] } else { 0.0 };
            }
        }
        fn full_loss(&mut self, _x: &[f32]) -> f64 {
            0.0
        }
    }

    #[test]
    fn default_sparse_shim_gathers_exact_nonzeros() {
        let mut b = Quadratic { d: 6 };
        let x = vec![1.0f32, 2.0, -3.0, 4.0, 5.0, -6.0];
        let mut dense = vec![0.0f32; 6];
        let mut sparse = crate::compress::SparseVec::new(6);
        b.sample_grad(&x, 1, &mut dense);
        b.sample_grad_sparse(&x, 1, &mut sparse);
        assert!(!b.supports_sparse_grad(), "shim backends stay opted out");
        assert_eq!(sparse.to_dense(), dense);
        assert_eq!(sparse.nnz(), 3); // only the even coordinates

        b.sample_grad_batch(&x, &[0, 2], &mut dense);
        b.sample_grad_batch_sparse(&x, &[0, 2], &mut sparse);
        assert_eq!(sparse.to_dense(), dense);
        // B = 1 routes through the per-sample emission.
        b.sample_grad(&x, 2, &mut dense);
        b.sample_grad_batch_sparse(&x, &[2], &mut sparse);
        assert_eq!(sparse.to_dense(), dense);
    }

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) <= 1.0 && sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(-100.0) < 1e-3);
        for &z in &[-5.0f32, -1.0, 0.3, 2.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn log1p_exp_stable() {
        assert!((log1p_exp(0.0) - (2.0f32).ln()).abs() < 1e-6);
        assert!((log1p_exp(100.0) - 100.0).abs() < 1e-4);
        assert!(log1p_exp(-100.0) >= 0.0 && log1p_exp(-100.0) < 1e-6);
        // matches naive formula in the safe range
        for &z in &[-3.0f32, -0.5, 0.5, 3.0] {
            let naive = (1.0 + z.exp()).ln();
            assert!((log1p_exp(z) - naive).abs() < 1e-6);
        }
    }
}
