//! Top-k sparsification (Definition 2.2): keep the k coordinates of
//! largest magnitude. Deterministic, and the strongest k-contraction of
//! the family: `‖x − top_k(x)‖² ≤ (1 − k/d)‖x‖²` holds *pointwise*, not
//! just in expectation (Lemma A.1 via `‖x − top_k(x)‖ ≤ ‖x − rand_k(x)‖`).
//!
//! Selection ties break toward the lowest index (the `util::select`
//! contract), which makes the dense scan and the active-set scan
//! ([`Compressor::compress_active`]) select the **same** coordinate set
//! — the bit-identity hinge of the dimension-free sync path.

use super::{ActiveView, Compressor, Update};
use crate::util::prng::Prng;
use crate::util::select;

/// Keep the `k` largest-|x| coordinates.
#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
    /// Reusable index scratch — the hot loop never allocates.
    scratch: Vec<u32>,
    /// Reusable selection heap (§Perf iteration 6).
    heap: Vec<u64>,
    /// Active-scan scratch: the nonzero subset of the touched set.
    nz: Vec<u32>,
    /// Active-scan scratch: sorted touched indices for zero-padding.
    sorted: Vec<u32>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "top_k requires k >= 1");
        TopK {
            k,
            scratch: Vec::new(),
            heap: Vec::new(),
            nz: Vec::new(),
            sorted: Vec::new(),
        }
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top_{}", self.k)
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(self.k.min(d) as f64)
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let k = self.k.min(d);
        let sp = out.sparse_mut(d);
        select::top_k_indices_with_heap(x, k, &mut self.heap, &mut self.scratch);
        for &i in &self.scratch {
            sp.push(i, x[i as usize]);
        }
        sp.encoded_bits()
    }

    fn supports_active_scan(&self) -> bool {
        true
    }

    /// `O(touched)` top-k: since every untouched coordinate is an exact
    /// zero, the selection runs over the touched set only. When the
    /// touched set holds fewer than `k` nonzero coordinates, the dense
    /// scan would fill the remaining slots with zero-magnitude
    /// coordinates — lowest indices first, per the tie rule — so this
    /// path pads with exactly those coordinates (same index set, same
    /// `k·(32 + ⌈log₂ d⌉)` wire bits).
    fn compress_active(
        &mut self,
        v: ActiveView<'_>,
        _rng: &mut Prng,
        out: &mut Update,
    ) -> Option<u64> {
        let d = v.dim();
        let k = self.k.min(d);
        let sp = out.sparse_mut(d);
        self.nz.clear();
        for &j in v.touched {
            if v.vals[j as usize] != 0.0 {
                self.nz.push(j);
            }
        }
        if self.nz.len() >= k {
            // Every nonzero of the represented dense vector is in `nz`,
            // so top-k over `nz` equals the dense top-k (zeros can never
            // enter a selection that k nonzeros already fill).
            select::top_k_in_subset(v.vals, &self.nz, k, &mut self.heap, &mut self.scratch);
            for &i in &self.scratch {
                sp.push(i, v.vals[i as usize]);
            }
        } else {
            // All nonzeros are selected; pad with the lowest-index
            // zero-magnitude coordinates — touched-with-zero entries keep
            // their stored (±0.0) value, untouched entries are exact
            // zeros — replicating the dense tie-broken fill bit for bit.
            for &i in &self.nz {
                sp.push(i, v.vals[i as usize]);
            }
            let mut need = k - self.nz.len();
            v.for_each_dense(&mut self.sorted, |j, val| {
                if val == 0.0 {
                    sp.push(j, val);
                    need -= 1;
                }
                need > 0
            });
        }
        Some(sp.encoded_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn compress_dense(x: &[f32], k: usize) -> Vec<f32> {
        let mut c = TopK::new(k);
        let mut rng = Prng::new(0);
        let mut out = Update::new_sparse(x.len());
        c.compress(x, &mut rng, &mut out);
        out.to_dense(x.len())
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let x = vec![0.1f32, -5.0, 2.0, 0.0, 3.0];
        assert_eq!(compress_dense(&x, 2), vec![0.0, -5.0, 0.0, 0.0, 3.0]);
        assert_eq!(compress_dense(&x, 1), vec![0.0, -5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn k_geq_d_is_identity() {
        let x = vec![1.0f32, -2.0, 3.0];
        assert_eq!(compress_dense(&x, 3), x);
        assert_eq!(compress_dense(&x, 10), x);
    }

    #[test]
    fn ties_break_to_lowest_indices() {
        // The documented selection rule, pinned at the operator level.
        let x = vec![2.0f32, -2.0, 2.0, 2.0];
        assert_eq!(compress_dense(&x, 2), vec![2.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn contraction_property_pointwise() {
        // Definition 2.1 holds for every x, deterministically.
        let mut rng = Prng::new(42);
        for _ in 0..100 {
            let d = 1 + rng.below(200);
            let k = 1 + rng.below(d);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let compressed = compress_dense(&x, k);
            let resid: Vec<f32> = x.iter().zip(&compressed).map(|(a, b)| a - b).collect();
            let lhs = stats::l2_norm_sq(&resid);
            let rhs = (1.0 - k as f64 / d as f64) * stats::l2_norm_sq(&x);
            assert!(lhs <= rhs + 1e-9, "d={d} k={k}: {lhs} > {rhs}");
        }
    }

    #[test]
    fn bit_cost_is_footnote5() {
        let mut c = TopK::new(10);
        let mut rng = Prng::new(0);
        let mut out = Update::new_sparse(47236);
        let x: Vec<f32> = (0..47236).map(|i| i as f32).collect();
        let bits = c.compress(&x, &mut rng, &mut out);
        assert_eq!(bits, 10 * (32 + 16));
    }

    #[test]
    fn reuses_buffers_without_allocation_growth() {
        let mut c = TopK::new(5);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(100);
        let x: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        c.compress(&x, &mut rng, &mut out);
        let cap = match &out {
            Update::Sparse(s) => (s.idx.capacity(), s.val.capacity()),
            _ => unreachable!(),
        };
        for _ in 0..10 {
            c.compress(&x, &mut rng, &mut out);
        }
        match &out {
            Update::Sparse(s) => {
                assert_eq!((s.idx.capacity(), s.val.capacity()), cap);
                assert_eq!(s.nnz(), 5);
            }
            _ => unreachable!(),
        }
    }

    /// Build an [`ActiveView`] over `x`'s nonzeros plus the listed
    /// touched-but-zero coordinates, shuffled (the active path must not
    /// depend on visit order).
    fn view_support(x: &[f32], extra_zero: &[u32], rng: &mut Prng) -> Vec<u32> {
        let mut touched: Vec<u32> = (0..x.len() as u32)
            .filter(|&j| x[j as usize] != 0.0)
            .collect();
        touched.extend_from_slice(extra_zero);
        rng.shuffle(&mut touched);
        touched
    }

    fn assert_active_matches_dense(x: &[f32], touched: &[u32], k: usize, what: &str) {
        let d = x.len();
        let mut rng = Prng::new(0);
        let mut dense_c = TopK::new(k);
        let mut active_c = TopK::new(k);
        let mut dense_out = Update::new_sparse(d);
        let mut active_out = Update::new_sparse(d);
        let bits_dense = dense_c.compress(x, &mut rng, &mut dense_out);
        let bits_active = active_c
            .compress_active(ActiveView { vals: x, touched }, &mut rng, &mut active_out)
            .expect("top-k supports the active scan");
        assert_eq!(bits_dense, bits_active, "{what}: bits");
        assert_eq!(dense_out.nnz(), active_out.nnz(), "{what}: nnz");
        assert_eq!(dense_out.to_dense(d), active_out.to_dense(d), "{what}: values");
        // The padded index *set* must also match (zero-valued entries are
        // invisible in to_dense but still cost wire bits / server slots).
        let idx_set = |u: &Update| -> Vec<u32> {
            match u {
                Update::Sparse(s) => {
                    let mut i = s.idx.clone();
                    i.sort_unstable();
                    i
                }
                _ => unreachable!(),
            }
        };
        assert_eq!(idx_set(&dense_out), idx_set(&active_out), "{what}: index set");
    }

    #[test]
    fn active_scan_matches_dense_scan() {
        let mut rng = Prng::new(7);
        for trial in 0..200 {
            let d = 4 + rng.below(120);
            let nnz = rng.below(d.min(20));
            let mut x = vec![0.0f32; d];
            for _ in 0..nnz {
                let j = rng.below(d);
                // Quantized values force magnitude ties.
                x[j] = (1 + rng.below(3)) as f32 * if rng.below(2) == 0 { 0.5 } else { -0.5 };
            }
            let extra: Vec<u32> = (0..rng.below(3))
                .map(|_| rng.below(d) as u32)
                .filter(|&j| x[j as usize] == 0.0)
                .collect();
            let mut dedup = extra.clone();
            dedup.sort_unstable();
            dedup.dedup();
            let touched = view_support(&x, &dedup, &mut rng);
            for k in [1usize, 2, 1 + rng.below(d)] {
                assert_active_matches_dense(&x, &touched, k, &format!("trial={trial} k={k}"));
            }
        }
    }

    #[test]
    fn active_scan_pads_like_the_dense_scan_when_nonzeros_run_out() {
        // 2 nonzeros, k = 5: the dense scan fills with the lowest-index
        // zeros; the active scan must produce the same index set and the
        // same bit cost.
        let mut x = vec![0.0f32; 12];
        x[7] = 3.0;
        x[4] = -1.0;
        let mut rng = Prng::new(9);
        let touched = view_support(&x, &[9], &mut rng); // 9 touched-but-zero
        assert_active_matches_dense(&x, &touched, 5, "padded");
        // All-zero vector: k pads alone.
        let z = vec![0.0f32; 6];
        assert_active_matches_dense(&z, &[2, 5], 3, "all-zero");
        assert_active_matches_dense(&z, &[], 3, "empty view");
    }

    #[test]
    fn active_scan_handles_k_saturation() {
        let x = vec![1.0f32, 0.0, -2.0, 0.5];
        let mut rng = Prng::new(11);
        let touched = view_support(&x, &[], &mut rng);
        assert_active_matches_dense(&x, &touched, 4, "k = d");
        assert_active_matches_dense(&x, &touched, 9, "k > d");
    }
}
