//! Top-k sparsification (Definition 2.2): keep the k coordinates of
//! largest magnitude. Deterministic, and the strongest k-contraction of
//! the family: `‖x − top_k(x)‖² ≤ (1 − k/d)‖x‖²` holds *pointwise*, not
//! just in expectation (Lemma A.1 via `‖x − top_k(x)‖ ≤ ‖x − rand_k(x)‖`).

use super::{Compressor, Update};
use crate::util::prng::Prng;
use crate::util::select;

/// Keep the `k` largest-|x| coordinates.
#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
    /// Reusable index scratch — the hot loop never allocates.
    scratch: Vec<u32>,
    /// Reusable selection heap (§Perf iteration 6).
    heap: Vec<(u32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "top_k requires k >= 1");
        TopK {
            k,
            scratch: Vec::new(),
            heap: Vec::new(),
        }
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top_{}", self.k)
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(self.k.min(d) as f64)
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let k = self.k.min(d);
        let sp = match out {
            Update::Sparse(s) => s,
            other => {
                *other = Update::new_sparse(d);
                match other {
                    Update::Sparse(s) => s,
                    _ => unreachable!(),
                }
            }
        };
        sp.clear(d);
        select::top_k_indices_with_heap(x, k, &mut self.heap, &mut self.scratch);
        for &i in &self.scratch {
            sp.push(i, x[i as usize]);
        }
        sp.encoded_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn compress_dense(x: &[f32], k: usize) -> Vec<f32> {
        let mut c = TopK::new(k);
        let mut rng = Prng::new(0);
        let mut out = Update::new_sparse(x.len());
        c.compress(x, &mut rng, &mut out);
        out.to_dense(x.len())
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let x = vec![0.1f32, -5.0, 2.0, 0.0, 3.0];
        assert_eq!(compress_dense(&x, 2), vec![0.0, -5.0, 0.0, 0.0, 3.0]);
        assert_eq!(compress_dense(&x, 1), vec![0.0, -5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn k_geq_d_is_identity() {
        let x = vec![1.0f32, -2.0, 3.0];
        assert_eq!(compress_dense(&x, 3), x);
        assert_eq!(compress_dense(&x, 10), x);
    }

    #[test]
    fn contraction_property_pointwise() {
        // Definition 2.1 holds for every x, deterministically.
        let mut rng = Prng::new(42);
        for _ in 0..100 {
            let d = 1 + rng.below(200);
            let k = 1 + rng.below(d);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let compressed = compress_dense(&x, k);
            let resid: Vec<f32> = x.iter().zip(&compressed).map(|(a, b)| a - b).collect();
            let lhs = stats::l2_norm_sq(&resid);
            let rhs = (1.0 - k as f64 / d as f64) * stats::l2_norm_sq(&x);
            assert!(lhs <= rhs + 1e-9, "d={d} k={k}: {lhs} > {rhs}");
        }
    }

    #[test]
    fn bit_cost_is_footnote5() {
        let mut c = TopK::new(10);
        let mut rng = Prng::new(0);
        let mut out = Update::new_sparse(47236);
        let x: Vec<f32> = (0..47236).map(|i| i as f32).collect();
        let bits = c.compress(&x, &mut rng, &mut out);
        assert_eq!(bits, 10 * (32 + 16));
    }

    #[test]
    fn reuses_buffers_without_allocation_growth() {
        let mut c = TopK::new(5);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(100);
        let x: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        c.compress(&x, &mut rng, &mut out);
        let cap = match &out {
            Update::Sparse(s) => (s.idx.capacity(), s.val.capacity()),
            _ => unreachable!(),
        };
        for _ in 0..10 {
            c.compress(&x, &mut rng, &mut out);
        }
        match &out {
            Update::Sparse(s) => {
                assert_eq!((s.idx.capacity(), s.val.capacity()), cap);
                assert_eq!(s.nnz(), 5);
            }
            _ => unreachable!(),
        }
    }
}
