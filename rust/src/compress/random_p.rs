//! Ultra-sparsification (Remark 2.3): transmit *less than one coordinate
//! per iteration on average*. With probability `p = k ∈ (0, 1]` emit one
//! uniformly random coordinate, otherwise emit nothing. Then
//!
//! `E‖x − comp(x)‖² = (1−p)‖x‖² + p(1 − 1/d)‖x‖² = (1 − p/d)‖x‖²`,
//!
//! i.e. Definition 2.1 holds with parameter `k = p < 1`. The theory
//! (Theorem 2.4) still applies — with shift `a = O(d/p)` — which the
//! ultra-sparsification ablation bench exercises.

use super::{Compressor, Update};
use crate::util::prng::Prng;

/// With probability `p` keep one random coordinate; else keep nothing.
#[derive(Clone, Debug)]
pub struct RandomP {
    pub p: f64,
}

impl RandomP {
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "random_p requires p in (0, 1], got {p}");
        RandomP { p }
    }
}

impl Compressor for RandomP {
    fn name(&self) -> String {
        format!("random_p_{}", self.p)
    }

    fn contraction_k(&self, _d: usize) -> Option<f64> {
        Some(self.p)
    }

    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let sp = out.sparse_mut(d);
        if rng.bernoulli(self.p) {
            let i = rng.below(d) as u32;
            sp.push(i, x[i as usize]);
        }
        sp.encoded_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn emits_at_most_one_coordinate() {
        let x = vec![1.0f32; 16];
        let mut c = RandomP::new(0.5);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(16);
        for _ in 0..100 {
            c.compress(&x, &mut rng, &mut out);
            assert!(out.nnz() <= 1);
        }
    }

    #[test]
    fn emission_rate_matches_p() {
        let x = vec![1.0f32; 8];
        let mut c = RandomP::new(0.3);
        let mut rng = Prng::new(2);
        let mut out = Update::new_sparse(8);
        let trials = 50_000;
        let mut emitted = 0usize;
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            emitted += out.nnz();
        }
        let rate = emitted as f64 / trials as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn contraction_with_fractional_k() {
        // E‖x − comp(x)‖² = (1 − p/d)‖x‖², exactly. Monte Carlo check.
        let d = 16;
        let p = 0.5;
        let mut rng = Prng::new(5);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let norm_sq = stats::l2_norm_sq(&x);
        let mut c = RandomP::new(p);
        let mut out = Update::new_sparse(d);
        let trials = 100_000;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            let dense = out.to_dense(d);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += stats::l2_norm_sq(&resid);
        }
        let mean = acc / trials as f64;
        let expected = (1.0 - p / d as f64) * norm_sq;
        assert!(
            (mean - expected).abs() / expected < 0.01,
            "mean={mean} expected={expected}"
        );
    }

    #[test]
    fn p_one_always_emits() {
        let x = vec![2.0f32; 4];
        let mut c = RandomP::new(1.0);
        let mut rng = Prng::new(8);
        let mut out = Update::new_sparse(4);
        for _ in 0..50 {
            c.compress(&x, &mut rng, &mut out);
            assert_eq!(out.nnz(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "random_p requires p in (0, 1]")]
    fn rejects_bad_p() {
        RandomP::new(0.0);
    }
}
