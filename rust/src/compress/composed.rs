//! Composed compression: QSGD quantization applied to the values an
//! inner sparsifier keeps — the Qsparse-local-SGD operator family of
//! Basu et al. (NeurIPS 2019).
//!
//! The inner stage selects coordinates (top-k, rand-k, random-p,
//! block-top-k, threshold, adaptive); the outer stage quantizes the
//! kept values to `s` levels against the ℓ₂ norm of the *kept* vector,
//! with Alistarh et al.'s unbiased stochastic rounding. The wire then
//! carries one norm scalar plus, per kept coordinate, an index, a sign
//! bit, and a level in `0..=s` — far below the 32-bit raw value the
//! plain sparsifiers pay (`TAG_COMPOSED` in [`super::elias`]).
//!
//! Contraction algebra (Qsparse Lemma 1): if the inner stage is a
//! `k`-contraction and the quantizer has relative variance bound
//! `ω = min(m/s², √m/s)` on its `m ≈ ⌈k⌉`-dimensional input, the
//! composition is a `(1 − ω)·k`-contraction — see
//! [`composed_contraction`]. A quantizer too coarse for the inner
//! sparsity (`ω ≥ 1`) voids the guarantee and the operator reports
//! `None`, running memory-free like plain QSGD.
//!
//! Zero levels keep their index on the wire (as exact `+0.0` values):
//! the kept-coordinate set — and therefore the accounted bit count —
//! stays the deterministic choice of the inner stage, and server
//! aggregation slots match the plain sparsifier's exactly.

use super::{elias, Compressor, Update};
use crate::util::prng::Prng;
use crate::util::stats;

/// QSGD with `levels` applied to the output of `inner` (a
/// sparse-emitting operator — enforced at the spec parse edge by
/// [`super::CompressorSpec::parse`]).
pub struct Composed {
    pub levels: u32,
    inner: Box<dyn Compressor>,
    /// Inner stage's output (always `Update::Sparse` for valid inners).
    inner_out: Update,
    /// Quantization-order scratch: entry ranks sorted by index.
    order: Vec<u32>,
    /// Wire scratch of the last compression: sorted indices, signed
    /// levels, kept-vector norm, and dimension — what
    /// [`Compressor::encode_payload`] frames natively. Disabled (never
    /// matching) when `levels` exceeds the payload's i32 level range.
    wire_idx: Vec<u32>,
    wire_levels: Vec<i32>,
    wire_norm: f32,
    wire_dim: usize,
}

/// Product-form contraction of quantization ∘ sparsification (Qsparse
/// Lemma 1): inner `k`-contraction, outer `s`-level QSGD with variance
/// bound `ω = min(m/s², √m/s)` evaluated at the effective dimension
/// `m = ⌈k⌉` clamped to `[1, d]`. Returns `(1 − ω)·k`, or `None` when
/// `ω ≥ 1` (no contraction guarantee survives the quantizer).
pub fn composed_contraction(levels: u32, inner_k: f64, d: usize) -> Option<f64> {
    let m = (inner_k.ceil().max(1.0) as usize).min(d.max(1)) as f64;
    let s = levels as f64;
    let omega = (m / (s * s)).min(m.sqrt() / s);
    if omega >= 1.0 {
        return None;
    }
    Some((1.0 - omega) * inner_k)
}

impl Composed {
    pub fn new(levels: u32, inner: Box<dyn Compressor>) -> Self {
        assert!(levels >= 1, "composed quantizer requires at least one level");
        Composed {
            levels,
            inner,
            inner_out: Update::new_sparse(0),
            order: Vec::new(),
            wire_idx: Vec::new(),
            wire_levels: Vec::new(),
            wire_norm: 0.0,
            wire_dim: usize::MAX,
        }
    }

    /// Whether `update` is exactly the dequantization of the stored wire
    /// scratch — the mirror of `elias::decode_payload`'s composed arm,
    /// so a `true` guarantees the framed payload decodes back to
    /// `update` bit for bit.
    fn scratch_matches(&self, update: &Update) -> bool {
        let Update::Sparse(sp) = update else { return false };
        if sp.dim != self.wire_dim || sp.nnz() != self.wire_idx.len() {
            return false;
        }
        let sf = self.levels as f32;
        sp.idx.iter().zip(&sp.val).zip(self.wire_idx.iter().zip(&self.wire_levels)).all(
            |((&i, &v), (&wi, &wl))| {
                let want = if wl == 0 {
                    0.0f32
                } else {
                    let sgn = if wl < 0 { -1.0f32 } else { 1.0 };
                    self.wire_norm * sgn * (wl.unsigned_abs() as f32 / sf)
                };
                i == wi && want.to_bits() == v.to_bits()
            },
        )
    }

    /// Accounted wire cost: one norm scalar plus, per kept entry, a
    /// footnote-5 index, a sign bit, and a fixed-width level in `0..=s`
    /// (`⌊log₂ s⌋ + 1` bits) — the composed analogue of
    /// `SparseVec::encoded_bits`.
    fn accounted_bits(&self, nnz: u64, d: usize) -> u64 {
        let level_bits = (32 - self.levels.leading_zeros()) as u64;
        32 + nnz * (super::sparse::index_bits(d) + 1 + level_bits)
    }
}

impl Compressor for Composed {
    fn name(&self) -> String {
        format!(
            "qsgd_{}({})",
            super::qsgd::level_suffix(self.levels),
            self.inner.name()
        )
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        composed_contraction(self.levels, self.inner.contraction_k(d)?, d)
    }

    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        self.inner.compress(x, rng, &mut self.inner_out);
        let s = match &self.inner_out {
            Update::Sparse(s) => s,
            Update::Dense(_) => unreachable!("composed inner stages emit sparse updates"),
        };
        // Canonical ascending-index order for quantization and the wire:
        // fixes the rng draw sequence regardless of the inner stage's
        // emission order.
        self.order.clear();
        self.order.extend(0..s.nnz() as u32);
        self.order.sort_unstable_by_key(|&r| s.idx[r as usize]);
        let norm = stats::l2_norm(&s.val) as f32;
        let track_wire = self.levels <= i32::MAX as u32;
        self.wire_idx.clear();
        self.wire_levels.clear();
        self.wire_norm = norm;
        self.wire_dim = if track_wire { d } else { usize::MAX };
        let sl = self.levels as f32;
        let sp = out.sparse_mut(d);
        for &rank in &self.order {
            let i = s.idx[rank as usize];
            let v = s.val[rank as usize];
            let (level, value) = if norm == 0.0 || v == 0.0 {
                // Zero-valued padding entries keep their slot, exactly
                // +0.0 — same convention as the QSGD zero level.
                (0i32, 0.0f32)
            } else {
                let u = v.abs() / norm * sl; // in [0, s]
                let l = u.floor();
                let p = u - l;
                let lv = l + if rng.bernoulli(p as f64) { 1.0 } else { 0.0 };
                if lv == 0.0 {
                    (0, 0.0)
                } else {
                    let mag = if track_wire { lv as i32 } else { 0 };
                    let mag = if v < 0.0 { -mag } else { mag };
                    (mag, norm * v.signum() * (lv / sl))
                }
            };
            sp.push(i, value);
            if track_wire {
                self.wire_idx.push(i);
                self.wire_levels.push(level);
            }
        }
        self.accounted_bits(sp.nnz() as u64, d)
    }

    /// Frame the native `(norm, sorted indices, signed levels)` stream
    /// when `update` is verifiably the last compression this operator
    /// produced; otherwise fall back to the generic codec (always exact).
    fn encode_payload(&self, update: &Update, w: &mut elias::BitWriter) -> u64 {
        if self.scratch_matches(update) {
            elias::encode_payload_composed(
                self.levels,
                self.wire_norm,
                &self.wire_idx,
                &self.wire_levels,
                self.wire_dim,
                w,
            )
        } else {
            elias::encode_payload_update(update, w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::elias::{decode_payload, BitReader, BitWriter};
    use crate::compress::sparse::index_bits;
    use crate::compress::{from_spec, TopK};

    fn composed(levels: u32, k: usize) -> Composed {
        Composed::new(levels, Box::new(TopK::new(k)))
    }

    #[test]
    fn keeps_the_inner_selection_with_quantized_values() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, 0.05, -0.4];
        let mut c = composed(16, 2);
        let mut rng = Prng::new(3);
        let mut out = Update::new_sparse(x.len());
        c.compress(&x, &mut rng, &mut out);
        let Update::Sparse(s) = &out else { panic!("sparse expected") };
        // Top-2 selection survives, index-sorted.
        assert_eq!(s.idx, vec![1, 3]);
        // Values sit on the quantization grid of the kept-vector norm.
        let norm = stats::l2_norm(&[-5.0f32, 3.0]) as f32;
        for (&v, &xv) in s.val.iter().zip(&[-5.0f32, 3.0]) {
            let level = v.abs() / norm * 16.0;
            assert!((level - level.round()).abs() < 1e-4, "v={v} level={level}");
            assert!(v == 0.0 || v.signum() == xv.signum());
        }
    }

    #[test]
    fn accounted_bits_are_deterministic_and_below_plain_topk() {
        let d = 47_236usize;
        let mut rng = Prng::new(5);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut c = composed(16, 100);
        let mut out = Update::new_sparse(d);
        let bits = c.compress(&x, &mut rng, &mut out);
        // 32-bit norm + 100·(16-bit index + sign + 5-bit level).
        assert_eq!(bits, 32 + 100 * (index_bits(d) + 1 + 5));
        let plain = 100 * (32 + index_bits(d));
        assert!(bits < plain, "composed {bits} >= plain top-k {plain}");
    }

    #[test]
    fn unbiased_given_the_inner_selection() {
        // Conditioned on top-k keeping a fixed coordinate set, the
        // quantized values must average to the kept values.
        let x = vec![4.0f32, -3.0, 0.0, 0.01, 2.0];
        let mut c = composed(4, 3);
        let mut rng = Prng::new(11);
        let mut out = Update::new_sparse(x.len());
        let trials = 30_000;
        let mut acc = vec![0.0f64; x.len()];
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            if let Update::Sparse(s) = &out {
                for (&i, &v) in s.idx.iter().zip(&s.val) {
                    acc[i as usize] += v as f64;
                }
            }
        }
        for &j in &[0usize, 1, 4] {
            let mean = acc[j] / trials as f64;
            assert!(
                (mean - x[j] as f64).abs() < 0.05 * x[j].abs() as f64 + 0.02,
                "coord {j}: mean={mean} x={}",
                x[j]
            );
        }
    }

    #[test]
    fn contraction_is_the_lemma_1_product() {
        // qsgd:16(top_k:100) at d = 47236: m = 100,
        // ω = min(100/256, 10/16) = 0.390625 → k_eff = 60.9375.
        let c = composed(16, 100);
        let k = c.contraction_k(47_236).unwrap();
        assert!((k - (1.0 - 0.390625) * 100.0).abs() < 1e-9, "k = {k}");
        // A 1-level quantizer on a wide selection voids the guarantee.
        assert_eq!(composed(1, 100).contraction_k(47_236), None);
        // k > d clamps through the inner operator's own cap.
        assert_eq!(
            composed(16, 3).contraction_k(2),
            composed_contraction(16, 2.0, 2)
        );
    }

    #[test]
    fn native_payload_roundtrips_bitwise() {
        let d = 500usize;
        let mut rng = Prng::new(17);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut c = composed(16, 20);
        let mut out = Update::new_sparse(d);
        c.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        let bits = c.encode_payload(&out, &mut w);
        // The native frame beats the generic 32-bit-value sparse frame.
        let mut generic = BitWriter::new();
        let generic_bits = elias::encode_payload_update(&out, &mut generic);
        assert!(bits < generic_bits, "native {bits} >= generic {generic_bits}");
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, d).unwrap();
        assert_eq!(r.consumed(), bits);
        let want: Vec<u32> = out.to_dense(d).iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = back.to_dense(d).iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        // Sparse entry sets (incl. zero-level padding) survive exactly.
        let (Update::Sparse(a), Update::Sparse(b)) = (&out, &back) else {
            panic!("kind changed through the codec");
        };
        assert_eq!(a.idx, b.idx);
        // A foreign update still round-trips via the generic fallback.
        let foreign = Update::new_sparse(d);
        let mut w = BitWriter::new();
        let bits = c.encode_payload(&foreign, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, d).unwrap();
        assert_eq!(r.consumed(), bits);
        assert_eq!(back.to_dense(d), foreign.to_dense(d));
    }

    #[test]
    fn zero_vector_sends_padding_only() {
        let mut c = composed(16, 4);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(32);
        c.compress(&[0.0f32; 32], &mut rng, &mut out);
        // top-k on a zero vector keeps nothing; the composed frame is
        // just the norm scalar.
        assert_eq!(out.nnz(), 0);
    }

    #[test]
    fn spec_parsing_and_name() {
        let c = from_spec("qsgd:16(top_k:100)").unwrap();
        assert_eq!(c.name(), "qsgd_4bit(top_100)");
        let c = from_spec("qsgd:6(rand_k:3)").unwrap();
        assert_eq!(c.name(), "qsgd_s6(rand_3)");
    }
}
