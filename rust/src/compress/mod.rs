//! Gradient compression operators (paper Section 2).
//!
//! The paper's algorithmic primitive is a *k-contraction* (Definition 2.1):
//! an operator `comp: R^d -> R^d` with
//! `E‖x − comp(x)‖² ≤ (1 − k/d)·‖x‖²`. This module provides:
//!
//! * [`top_k::TopK`] — keep the k largest-magnitude coordinates
//!   (Definition 2.2; deterministic; the paper's best performer).
//! * [`rand_k::RandK`] — keep k uniformly random coordinates
//!   (Definition 2.2; a k-contraction in expectation).
//! * [`random_p::RandomP`] — ultra-sparsification (Remark 2.3): with
//!   probability `k ∈ (0, 1]` emit one random coordinate, else nothing;
//!   still a k-contraction, with *less than one* coordinate per step.
//! * [`qsgd::Qsgd`] — the QSGD random quantizer of Alistarh et al. 2017,
//!   the paper's Section 4.3 baseline (unbiased, *not* a contraction for
//!   small `s`, used without memory).
//! * [`sign::SignSgd`] — the 1Bit-SGD operator of Seide et al. [32]
//!   (where the error-feedback idea originates): sign + mean-|x| scale,
//!   a data-dependent contraction with guaranteed `k ≥ 1`.
//! * [`threshold::Threshold`] — Aji & Heafield's [1] relative-threshold
//!   sparsification with adaptive cardinality.
//! * [`identity`] — `comp = id` (vanilla SGD baseline; a d-contraction).
//!
//! Every operator implements [`Compressor`], producing a reusable
//! [`Update`] and reporting the exact number of bits the update costs on
//! the wire (the currency of Figures 3 and the communication claims).

pub mod block_top_k;
pub mod elias;
pub mod qsgd;
pub mod rand_k;
pub mod random_p;
pub mod sign;
pub mod sparse;
pub mod threshold;
pub mod top_k;

use anyhow::{bail, Result};

pub use block_top_k::BlockTopK;
pub use qsgd::Qsgd;
pub use rand_k::RandK;
pub use random_p::RandomP;
pub use sign::SignSgd;
pub use sparse::SparseVec;
pub use threshold::Threshold;
pub use top_k::TopK;

use crate::util::prng::Prng;

/// A compressed gradient update, reusable across iterations.
#[derive(Clone, Debug)]
pub enum Update {
    /// Sparse coordinate list (top-k, rand-k, random-p).
    Sparse(SparseVec),
    /// Dense vector (identity, QSGD quantization).
    Dense(Vec<f32>),
}

impl Update {
    /// An empty update with `dim` capacity hint.
    pub fn new_sparse(dim: usize) -> Update {
        Update::Sparse(SparseVec::new(dim))
    }

    pub fn new_dense(dim: usize) -> Update {
        Update::Dense(vec![0.0; dim])
    }

    /// `x -= update` — the parameter step of Algorithm 1 line 5.
    pub fn sub_from(&self, x: &mut [f32]) {
        match self {
            Update::Sparse(s) => s.sub_from(x),
            Update::Dense(g) => {
                debug_assert_eq!(g.len(), x.len());
                for (xi, gi) in x.iter_mut().zip(g) {
                    *xi -= gi;
                }
            }
        }
    }

    /// Densify (test / metrics helper; allocates).
    pub fn to_dense(&self, dim: usize) -> Vec<f32> {
        match self {
            Update::Sparse(s) => {
                debug_assert_eq!(s.dim, dim);
                s.to_dense()
            }
            Update::Dense(g) => {
                debug_assert_eq!(g.len(), dim);
                g.clone()
            }
        }
    }

    /// Number of nonzero coordinates actually stored.
    pub fn nnz(&self) -> usize {
        match self {
            Update::Sparse(s) => s.nnz(),
            Update::Dense(g) => g.iter().filter(|&&v| v != 0.0).count(),
        }
    }
}

/// A gradient compression operator.
///
/// `compress` takes `&mut self` so implementations can keep reusable
/// scratch buffers (the top-k index array, QSGD's norm accumulator) —
/// the hot loop must not allocate. Each parallel worker owns its own
/// compressor instance.
pub trait Compressor: Send {
    /// Human-readable name used in metric records and plots.
    fn name(&self) -> String;

    /// The contraction parameter `k` of Definition 2.1 as a function of
    /// the dimension, or `None` when the operator is not a k-contraction
    /// (QSGD). Used by theory checks (stepsize shift `a = O(d/k)`).
    fn contraction_k(&self, d: usize) -> Option<f64>;

    /// Compress `x` into `out`, returning the wire cost in bits.
    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64;
}

/// The identity "compressor" — vanilla SGD's dense transmission.
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(d as f64) // exact: ‖x − x‖² = 0 ≤ (1 − d/d)‖x‖²
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        match out {
            Update::Dense(g) => {
                g.clear();
                g.extend_from_slice(x);
            }
            other => *other = Update::Dense(x.to_vec()),
        }
        32 * x.len() as u64
    }
}

/// Parse a compressor spec string: `top_k:1`, `rand_k:10`, `random_p:0.5`,
/// `qsgd:16` (levels), `qsgd:16:71` (levels + effective sparsity-aware
/// dimension, Appendix B), or `identity`.
pub fn from_spec(spec: &str) -> Result<Box<dyn Compressor>> {
    let mut parts = spec.split(':');
    let kind = parts.next().unwrap_or_default();
    let arg = parts.next();
    let arg2 = parts.next();
    let parse_usize = |s: Option<&str>, what: &str| -> Result<usize> {
        match s {
            Some(v) => Ok(v.parse::<usize>()?),
            None => bail!("{what} requires an argument, e.g. '{what}:1'"),
        }
    };
    Ok(match kind {
        "identity" | "none" | "sgd" => Box::new(Identity),
        "top_k" | "topk" | "top" => Box::new(TopK::new(parse_usize(arg, "top_k")?)),
        "rand_k" | "randk" | "rand" => Box::new(RandK::new(parse_usize(arg, "rand_k")?)),
        "random_p" | "ultra" => {
            let p: f64 = match arg {
                Some(v) => v.parse()?,
                None => bail!("random_p requires a probability, e.g. 'random_p:0.5'"),
            };
            Box::new(RandomP::new(p))
        }
        "qsgd" => {
            let levels = parse_usize(arg, "qsgd")? as u32;
            let eff = match arg2 {
                Some(v) => Some(v.parse::<usize>()?),
                None => None,
            };
            Box::new(Qsgd::with_effective_dim(levels, eff))
        }
        "block_top_k" | "block" => Box::new(BlockTopK::new(parse_usize(arg, "block_top_k")?)),
        "sign" | "1bit" => Box::new(SignSgd::new()),
        "threshold" | "thresh" => {
            let tau: f32 = match arg {
                Some(v) => v.parse()?,
                None => bail!("threshold requires tau, e.g. 'threshold:0.25'"),
            };
            Box::new(Threshold::new(tau))
        }
        other => bail!("unknown compressor spec '{other}' (full spec: '{spec}')"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_exact() {
        let x = vec![1.0f32, -2.0, 3.0];
        let mut rng = Prng::new(0);
        let mut out = Update::new_dense(3);
        let mut c = Identity;
        let bits = c.compress(&x, &mut rng, &mut out);
        assert_eq!(bits, 96);
        assert_eq!(out.to_dense(3), x);
        assert_eq!(c.contraction_k(3), Some(3.0));
    }

    #[test]
    fn update_sub_from_dense_and_sparse() {
        let mut x = vec![5.0f32; 4];
        Update::Dense(vec![1.0, 0.0, 0.0, 2.0]).sub_from(&mut x);
        assert_eq!(x, vec![4.0, 5.0, 5.0, 3.0]);
        Update::Sparse(SparseVec::from_parts(4, vec![1], vec![1.0])).sub_from(&mut x);
        assert_eq!(x, vec![4.0, 4.0, 5.0, 3.0]);
    }

    #[test]
    fn update_nnz() {
        assert_eq!(Update::Dense(vec![0.0, 1.0, 0.0]).nnz(), 1);
        assert_eq!(
            Update::Sparse(SparseVec::from_parts(4, vec![0, 1], vec![1.0, 2.0])).nnz(),
            2
        );
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(from_spec("top_k:3").unwrap().name(), "top_3");
        assert_eq!(from_spec("rand_k:10").unwrap().name(), "rand_10");
        assert_eq!(from_spec("random_p:0.25").unwrap().name(), "random_p_0.25");
        assert_eq!(from_spec("qsgd:16").unwrap().name(), "qsgd_4bit");
        assert_eq!(from_spec("identity").unwrap().name(), "identity");
        assert!(from_spec("nope").is_err());
        assert!(from_spec("top_k").is_err());
        assert!(from_spec("top_k:x").is_err());
    }
}
