//! Gradient compression operators (paper Section 2).
//!
//! The paper's algorithmic primitive is a *k-contraction* (Definition 2.1):
//! an operator `comp: R^d -> R^d` with
//! `E‖x − comp(x)‖² ≤ (1 − k/d)·‖x‖²`. This module provides:
//!
//! * [`top_k::TopK`] — keep the k largest-magnitude coordinates
//!   (Definition 2.2; deterministic; the paper's best performer).
//! * [`rand_k::RandK`] — keep k uniformly random coordinates
//!   (Definition 2.2; a k-contraction in expectation).
//! * [`random_p::RandomP`] — ultra-sparsification (Remark 2.3): with
//!   probability `k ∈ (0, 1]` emit one random coordinate, else nothing;
//!   still a k-contraction, with *less than one* coordinate per step.
//! * [`qsgd::Qsgd`] — the QSGD random quantizer of Alistarh et al. 2017,
//!   the paper's Section 4.3 baseline (unbiased, *not* a contraction for
//!   small `s`, used without memory).
//! * [`sign::SignSgd`] — the 1Bit-SGD operator of Seide et al. [32]
//!   (where the error-feedback idea originates): sign + mean-|x| scale,
//!   a data-dependent contraction with guaranteed `k ≥ 1`.
//! * [`threshold::Threshold`] — Aji & Heafield's [1] relative-threshold
//!   sparsification with adaptive cardinality.
//! * [`adaptive::AdaptiveSparse`] — Wangni et al.'s unbiased adaptive
//!   sparsifier: keep coordinate `i` with probability `min(1, c·|x_i|)`
//!   where `c` solves for an expected budget, rescaling kept values by
//!   `1/p_i`.
//! * [`composed::Composed`] — quantization ∘ sparsification in the style
//!   of Qsparse-local-SGD (Basu et al.): QSGD levels over a sparsifier's
//!   kept values, with the Lemma 1 product-form contraction. Spec grammar
//!   `qsgd:16(top_k:100)`.
//! * [`identity`] — `comp = id` (vanilla SGD baseline; a d-contraction).
//!
//! Every operator implements [`Compressor`], producing a reusable
//! [`Update`] and reporting the exact number of bits the update costs on
//! the wire (the currency of Figures 3 and the communication claims).

pub mod active;
pub mod adaptive;
pub mod block_top_k;
pub mod composed;
pub mod elias;
pub mod qsgd;
pub mod rand_k;
pub mod random_p;
pub mod sign;
pub mod sparse;
pub mod threshold;
pub mod top_k;

use anyhow::{bail, Result};

pub use active::{ActiveIndex, ActiveView};
pub use adaptive::AdaptiveSparse;
pub use block_top_k::BlockTopK;
pub use composed::{composed_contraction, Composed};
pub use qsgd::Qsgd;
pub use rand_k::RandK;
pub use random_p::RandomP;
pub use sign::SignSgd;
pub use sparse::{SparseMerge, SparseVec};
pub use threshold::Threshold;
pub use top_k::TopK;

use crate::util::prng::Prng;

/// A compressed gradient update, reusable across iterations.
#[derive(Clone, Debug)]
pub enum Update {
    /// Sparse coordinate list (top-k, rand-k, random-p).
    Sparse(SparseVec),
    /// Dense vector (identity, QSGD quantization).
    Dense(Vec<f32>),
}

impl Update {
    /// An empty update with `dim` capacity hint.
    pub fn new_sparse(dim: usize) -> Update {
        Update::Sparse(SparseVec::new(dim))
    }

    pub fn new_dense(dim: usize) -> Update {
        Update::Dense(vec![0.0; dim])
    }

    /// `x -= update` — the parameter step of Algorithm 1 line 5.
    pub fn sub_from(&self, x: &mut [f32]) {
        match self {
            Update::Sparse(s) => s.sub_from(x),
            Update::Dense(g) => {
                debug_assert_eq!(g.len(), x.len());
                for (xi, gi) in x.iter_mut().zip(g) {
                    *xi -= gi;
                }
            }
        }
    }

    /// `x -= scale·update` — how a parameter-server replica applies an
    /// aggregated broadcast (`scale = 1/nodes`). Sparse payloads apply
    /// in stored order; the wire path stores them index-sorted, which
    /// mirrors the server's own sorted fold.
    pub fn sub_scaled_from(&self, scale: f32, x: &mut [f32]) {
        match self {
            Update::Sparse(s) => {
                for (&i, &v) in s.idx.iter().zip(&s.val) {
                    x[i as usize] -= v * scale;
                }
            }
            Update::Dense(g) => {
                debug_assert_eq!(g.len(), x.len());
                for (xi, &gi) in x.iter_mut().zip(g) {
                    *xi -= gi * scale;
                }
            }
        }
    }

    /// Densify (test / metrics helper; allocates).
    pub fn to_dense(&self, dim: usize) -> Vec<f32> {
        match self {
            Update::Sparse(s) => {
                debug_assert_eq!(s.dim, dim);
                s.to_dense()
            }
            Update::Dense(g) => {
                debug_assert_eq!(g.len(), dim);
                g.clone()
            }
        }
    }

    /// Number of nonzero coordinates actually stored.
    pub fn nnz(&self) -> usize {
        match self {
            Update::Sparse(s) => s.nnz(),
            Update::Dense(g) => g.iter().filter(|&&v| v != 0.0).count(),
        }
    }

    /// Coerce into the sparse representation (replacing a dense payload
    /// if needed) and reset it for dimension `dim` — the shared entry of
    /// every sparse compressor's emit path. When already sparse, the
    /// existing allocation is reused (hot loops stay allocation-free).
    pub fn sparse_mut(&mut self, dim: usize) -> &mut SparseVec {
        if !matches!(self, Update::Sparse(_)) {
            *self = Update::new_sparse(dim);
        }
        match self {
            Update::Sparse(s) => {
                s.clear(dim);
                s
            }
            _ => unreachable!(),
        }
    }
}

/// A gradient compression operator.
///
/// `compress` takes `&mut self` so implementations can keep reusable
/// scratch buffers (the top-k index array, QSGD's norm accumulator) —
/// the hot loop must not allocate. Each parallel worker owns its own
/// compressor instance.
pub trait Compressor: Send {
    /// Human-readable name used in metric records and plots.
    fn name(&self) -> String;

    /// The contraction parameter `k` of Definition 2.1 as a function of
    /// the dimension, or `None` when the operator is not a k-contraction
    /// (QSGD). Used by theory checks (stepsize shift `a = O(d/k)`).
    fn contraction_k(&self, d: usize) -> Option<f64>;

    /// Compress `x` into `out`, returning the wire cost in bits.
    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64;

    /// Whether [`Compressor::compress_active`] is implemented — i.e. the
    /// operator's scan can run over an active-set vector in `O(touched)`
    /// instead of `O(d)`. Consulted by the sparse entry points of
    /// [`crate::optim::ErrorFeedbackStep`] and [`crate::optim::MemSgd`]
    /// on each step to pick the dimension-free route; it should be an
    /// inherent property of the operator (constant over its lifetime),
    /// not a function of mutable state.
    fn supports_active_scan(&self) -> bool {
        false
    }

    /// `O(touched)` compression of an active-set vector.
    ///
    /// Contract: must produce **exactly** the update (same coordinate
    /// set, same values, same wire bits) that [`Compressor::compress`]
    /// would produce on the dense vector `v` represents — `vals[j]` at
    /// every `j` in `touched`, an exact zero everywhere else. Selection
    /// ties are resolved toward the lowest index on both paths
    /// (`util::select`), which is what makes the two scans agree.
    ///
    /// Returns `None` iff the operator has no active scan
    /// ([`Compressor::supports_active_scan`] is `false`); callers that
    /// checked the capability first may `expect` the `Some`.
    fn compress_active(
        &mut self,
        _v: ActiveView<'_>,
        _rng: &mut Prng,
        _out: &mut Update,
    ) -> Option<u64> {
        None
    }

    /// Serialize an update this operator produced into its typed wire
    /// payload (framing tag + Elias-coded body) — the bits the threaded
    /// parameter-server engines actually put on a channel. Returns the
    /// payload bit count.
    ///
    /// Contract: [`elias::decode_payload`] on the written bits must
    /// reconstruct `update` **bit for bit** — every f32 value,
    /// including zero-valued padding coordinates and signed zeros —
    /// regardless of which update is passed (operators that frame from
    /// internal scratch, like QSGD's level stream, verify the scratch
    /// against `update` and fall back to the generic codec on any
    /// mismatch). The default frames generically: sparse list →
    /// [`elias::encode_payload_sparse`], dense →
    /// [`elias::encode_payload_dense`].
    fn encode_payload(&self, update: &Update, w: &mut elias::BitWriter) -> u64 {
        elias::encode_payload_update(update, w)
    }
}

/// The identity "compressor" — vanilla SGD's dense transmission.
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(d as f64) // exact: ‖x − x‖² = 0 ≤ (1 − d/d)‖x‖²
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        match out {
            Update::Dense(g) => {
                g.clear();
                g.extend_from_slice(x);
            }
            other => *other = Update::Dense(x.to_vec()),
        }
        32 * x.len() as u64
    }
}

/// A **typed** compression-operator specification: the parsed form of the
/// spec strings (`top_k:1`, `qsgd:16:71`, ...) that the CLI and config
/// files use. Operator parameters live here as numbers, so everything
/// downstream of the parse edge ([`CompressorSpec::parse`]) is infallible
/// — no `expect()` on user input deep inside a driver.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorSpec {
    /// `comp = id` — vanilla dense transmission.
    Identity,
    /// Keep the `k` largest-magnitude coordinates (Definition 2.2).
    TopK { k: usize },
    /// Keep `k` uniformly random coordinates (Definition 2.2).
    RandK { k: usize },
    /// Ultra-sparsification (Remark 2.3): one random coordinate with
    /// probability `p`, nothing otherwise.
    RandomP { p: f64 },
    /// Contiguous-block top-k (cache-friendly variant).
    BlockTopK { k: usize },
    /// 1Bit-SGD sign + mean-magnitude operator.
    Sign,
    /// Relative-threshold sparsification with cutoff `tau`.
    Threshold { tau: f32 },
    /// QSGD random quantizer: `levels`, optional sparsity-aware effective
    /// dimension for the Appendix-B bit accounting.
    Qsgd { levels: u32, eff: Option<usize> },
    /// Wangni et al. adaptive unbiased sparsifier with expected budget.
    Adaptive { budget: usize },
    /// Quantization ∘ sparsification (`qsgd:16(top_k:100)`): QSGD with
    /// `levels` applied to the kept values of the `inner` sparsifier.
    Composed { levels: u32, inner: Box<CompressorSpec> },
}

impl CompressorSpec {
    /// Parse a spec string. **Strict**: every `:`-separated component
    /// must be consumed — `top_k:1:junk` is an error, not a silently
    /// truncated `top_k:1`.
    ///
    /// Composition grammar: `qsgd:<levels>(<inner>)` — the outer must be
    /// a bare quantizer, the inner a sparsifier that emits a coordinate
    /// list, and nesting is rejected (one quantization layer suffices;
    /// the Lemma 1 algebra below is for a single product).
    pub fn parse(spec: &str) -> Result<CompressorSpec> {
        // The paren branch runs before the `:`-split so inner specs keep
        // their own colons (`qsgd:16(top_k:100)`).
        if let Some(open) = spec.find('(') {
            if !spec.ends_with(')') || spec.len() == open + 1 {
                bail!("composed spec '{spec}' must end with ')'");
            }
            let inner_str = &spec[open + 1..spec.len() - 1];
            if inner_str.contains('(') {
                bail!("nested composition in '{spec}' is not supported");
            }
            let levels = match CompressorSpec::parse(&spec[..open])? {
                CompressorSpec::Qsgd { levels, eff: None } => levels,
                CompressorSpec::Qsgd { eff: Some(_), .. } => bail!(
                    "composed outer in '{spec}' must not override the effective \
                     dimension — bits are accounted from the inner selection"
                ),
                other => bail!(
                    "composed outer must be a quantizer (qsgd:<levels>), got '{}' in '{spec}'",
                    other.spec_string()
                ),
            };
            let inner = CompressorSpec::parse(inner_str)?;
            if !inner.composable_inner() {
                bail!(
                    "composed inner must be a sparsifier emitting a coordinate \
                     list, got '{}' in '{spec}'",
                    inner.spec_string()
                );
            }
            return Ok(CompressorSpec::Composed { levels, inner: Box::new(inner) });
        }
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or_default();
        let arg = parts.next();
        let arg2 = parts.next();
        if let Some(extra) = parts.next() {
            bail!("trailing component '{extra}' in compressor spec '{spec}'");
        }
        let no_arg2 = |what: &str| -> Result<()> {
            match arg2 {
                Some(extra) => bail!("trailing component '{extra}' in {what} spec '{spec}'"),
                None => Ok(()),
            }
        };
        let parse_k = |s: Option<&str>, what: &str| -> Result<usize> {
            let k = match s {
                Some(v) => v
                    .parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("{what} argument '{v}': {e}"))?,
                None => bail!("{what} requires an argument, e.g. '{what}:1'"),
            };
            if k == 0 {
                bail!("{what} requires k >= 1");
            }
            Ok(k)
        };
        Ok(match kind {
            "identity" | "none" | "sgd" => {
                if let Some(extra) = arg {
                    bail!("trailing component '{extra}' in compressor spec '{spec}'");
                }
                CompressorSpec::Identity
            }
            "top_k" | "topk" | "top" => {
                no_arg2("top_k")?;
                CompressorSpec::TopK { k: parse_k(arg, "top_k")? }
            }
            "rand_k" | "randk" | "rand" => {
                no_arg2("rand_k")?;
                CompressorSpec::RandK { k: parse_k(arg, "rand_k")? }
            }
            "random_p" | "ultra" => {
                no_arg2("random_p")?;
                let p: f64 = match arg {
                    Some(v) => v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("random_p argument '{v}': {e}"))?,
                    None => bail!("random_p requires a probability, e.g. 'random_p:0.5'"),
                };
                if !(p > 0.0 && p <= 1.0) {
                    bail!("random_p requires p in (0, 1], got {p}");
                }
                CompressorSpec::RandomP { p }
            }
            "qsgd" => {
                // `as u32` here would silently truncate: `qsgd:4294967297`
                // used to parse as levels = 1.
                let raw = parse_k(arg, "qsgd")?;
                let levels = u32::try_from(raw).map_err(|_| {
                    anyhow::anyhow!(
                        "qsgd level count {raw} exceeds u32 range (max {})",
                        u32::MAX
                    )
                })?;
                let eff = match arg2 {
                    Some(v) => Some(
                        v.parse::<usize>()
                            .map_err(|e| anyhow::anyhow!("qsgd effective dim '{v}': {e}"))?,
                    ),
                    None => None,
                };
                CompressorSpec::Qsgd { levels, eff }
            }
            "block_top_k" | "block" => {
                no_arg2("block_top_k")?;
                CompressorSpec::BlockTopK { k: parse_k(arg, "block_top_k")? }
            }
            "adaptive" => {
                no_arg2("adaptive")?;
                CompressorSpec::Adaptive { budget: parse_k(arg, "adaptive")? }
            }
            "sign" | "1bit" => {
                if let Some(extra) = arg {
                    bail!("trailing component '{extra}' in sign spec '{spec}'");
                }
                CompressorSpec::Sign
            }
            "threshold" | "thresh" => {
                no_arg2("threshold")?;
                let tau: f32 = match arg {
                    Some(v) => v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("threshold argument '{v}': {e}"))?,
                    None => bail!("threshold requires tau, e.g. 'threshold:0.25'"),
                };
                if !(tau > 0.0 && tau <= 1.0) {
                    bail!("threshold requires tau in (0, 1], got {tau}");
                }
                CompressorSpec::Threshold { tau }
            }
            other => bail!("unknown compressor spec '{other}' (full spec: '{spec}')"),
        })
    }

    /// Whether this spec emits a sparse coordinate list that a quantizer
    /// can stack on — the legal inner position of `qsgd:s(inner)`.
    /// Dense emitters (identity, qsgd, sign) are excluded: the composed
    /// wire frame codes a coordinate list.
    fn composable_inner(&self) -> bool {
        matches!(
            self,
            CompressorSpec::TopK { .. }
                | CompressorSpec::RandK { .. }
                | CompressorSpec::RandomP { .. }
                | CompressorSpec::BlockTopK { .. }
                | CompressorSpec::Threshold { .. }
                | CompressorSpec::Adaptive { .. }
        )
    }

    /// Instantiate the operator. Infallible: every variant holds
    /// already-validated parameters.
    pub fn build(&self) -> Box<dyn Compressor> {
        match self {
            CompressorSpec::Identity => Box::new(Identity),
            CompressorSpec::TopK { k } => Box::new(TopK::new(*k)),
            CompressorSpec::RandK { k } => Box::new(RandK::new(*k)),
            CompressorSpec::RandomP { p } => Box::new(RandomP::new(*p)),
            CompressorSpec::BlockTopK { k } => Box::new(BlockTopK::new(*k)),
            CompressorSpec::Sign => Box::new(SignSgd::new()),
            CompressorSpec::Threshold { tau } => Box::new(Threshold::new(*tau)),
            CompressorSpec::Qsgd { levels, eff } => {
                Box::new(Qsgd::with_effective_dim(*levels, *eff))
            }
            CompressorSpec::Adaptive { budget } => Box::new(AdaptiveSparse::new(*budget)),
            CompressorSpec::Composed { levels, inner } => {
                debug_assert!(inner.composable_inner(), "parse edge admits sparsifiers only");
                Box::new(Composed::new(*levels, inner.build()))
            }
        }
    }

    /// The operator's display name. Mirrors each [`Compressor::name`]
    /// without building the operator (asserted against the built
    /// operator in the tests below).
    pub fn name(&self) -> String {
        match self {
            CompressorSpec::Identity => "identity".into(),
            CompressorSpec::TopK { k } => format!("top_{k}"),
            CompressorSpec::RandK { k } => format!("rand_{k}"),
            CompressorSpec::RandomP { p } => format!("random_p_{p}"),
            CompressorSpec::BlockTopK { k } => format!("block_top_{k}"),
            CompressorSpec::Sign => "sign_1bit".into(),
            CompressorSpec::Threshold { tau } => format!("threshold_{tau}"),
            CompressorSpec::Qsgd { levels, .. } => {
                format!("qsgd_{}", qsgd::level_suffix(*levels))
            }
            CompressorSpec::Adaptive { budget } => format!("adaptive_{budget}"),
            CompressorSpec::Composed { levels, inner } => {
                format!("qsgd_{}({})", qsgd::level_suffix(*levels), inner.name())
            }
        }
    }

    /// Contraction parameter `k` of Definition 2.1 (None for QSGD).
    /// Mirrors each [`Compressor::contraction_k`] without building the
    /// operator (asserted against the built operator in the tests below).
    pub fn contraction_k(&self, d: usize) -> Option<f64> {
        match self {
            CompressorSpec::Identity => Some(d as f64),
            CompressorSpec::TopK { k } | CompressorSpec::RandK { k } => Some((*k).min(d) as f64),
            CompressorSpec::RandomP { p } => Some(*p),
            CompressorSpec::BlockTopK { k } => {
                if d == 0 {
                    return Some(*k as f64);
                }
                let b = d.div_ceil((*k).min(d));
                Some(d as f64 / b as f64)
            }
            CompressorSpec::Sign | CompressorSpec::Threshold { .. } => Some(1.0),
            CompressorSpec::Qsgd { .. } => None,
            CompressorSpec::Adaptive { budget } => Some((*budget).min(d) as f64),
            CompressorSpec::Composed { levels, inner } => {
                composed_contraction(*levels, inner.contraction_k(d)?, d)
            }
        }
    }

    /// Canonical spec string — parses back to `self`.
    pub fn spec_string(&self) -> String {
        match self {
            CompressorSpec::Identity => "identity".into(),
            CompressorSpec::TopK { k } => format!("top_k:{k}"),
            CompressorSpec::RandK { k } => format!("rand_k:{k}"),
            CompressorSpec::RandomP { p } => format!("random_p:{p}"),
            CompressorSpec::BlockTopK { k } => format!("block_top_k:{k}"),
            CompressorSpec::Sign => "sign".into(),
            CompressorSpec::Threshold { tau } => format!("threshold:{tau}"),
            CompressorSpec::Qsgd { levels, eff } => match eff {
                Some(e) => format!("qsgd:{levels}:{e}"),
                None => format!("qsgd:{levels}"),
            },
            CompressorSpec::Adaptive { budget } => format!("adaptive:{budget}"),
            CompressorSpec::Composed { levels, inner } => {
                format!("qsgd:{levels}({})", inner.spec_string())
            }
        }
    }
}

/// Parse a compressor spec string: `top_k:1`, `rand_k:10`, `random_p:0.5`,
/// `qsgd:16` (levels), `qsgd:16:71` (levels + effective sparsity-aware
/// dimension, Appendix B), `adaptive:100` (Wangni expected budget),
/// `qsgd:16(top_k:100)` (quantization ∘ sparsification), or `identity`.
///
/// Thin shim over [`CompressorSpec::parse`] + [`CompressorSpec::build`];
/// kept for call sites that go straight from a string to an operator.
/// Unconsumed spec components are rejected.
pub fn from_spec(spec: &str) -> Result<Box<dyn Compressor>> {
    Ok(CompressorSpec::parse(spec)?.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_exact() {
        let x = vec![1.0f32, -2.0, 3.0];
        let mut rng = Prng::new(0);
        let mut out = Update::new_dense(3);
        let mut c = Identity;
        let bits = c.compress(&x, &mut rng, &mut out);
        assert_eq!(bits, 96);
        assert_eq!(out.to_dense(3), x);
        assert_eq!(c.contraction_k(3), Some(3.0));
    }

    #[test]
    fn update_sub_from_dense_and_sparse() {
        let mut x = vec![5.0f32; 4];
        Update::Dense(vec![1.0, 0.0, 0.0, 2.0]).sub_from(&mut x);
        assert_eq!(x, vec![4.0, 5.0, 5.0, 3.0]);
        Update::Sparse(SparseVec::from_parts(4, vec![1], vec![1.0])).sub_from(&mut x);
        assert_eq!(x, vec![4.0, 4.0, 5.0, 3.0]);
    }

    #[test]
    fn update_sub_scaled_from_dense_and_sparse() {
        let mut x = vec![4.0f32; 4];
        Update::Dense(vec![2.0, 0.0, 4.0, 8.0]).sub_scaled_from(0.5, &mut x);
        assert_eq!(x, vec![3.0, 4.0, 2.0, 0.0]);
        Update::Sparse(SparseVec::from_parts(4, vec![3], vec![2.0])).sub_scaled_from(0.5, &mut x);
        assert_eq!(x, vec![3.0, 4.0, 2.0, -1.0]);
    }

    #[test]
    fn update_nnz() {
        assert_eq!(Update::Dense(vec![0.0, 1.0, 0.0]).nnz(), 1);
        assert_eq!(
            Update::Sparse(SparseVec::from_parts(4, vec![0, 1], vec![1.0, 2.0])).nnz(),
            2
        );
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(from_spec("top_k:3").unwrap().name(), "top_3");
        assert_eq!(from_spec("rand_k:10").unwrap().name(), "rand_10");
        assert_eq!(from_spec("random_p:0.25").unwrap().name(), "random_p_0.25");
        assert_eq!(from_spec("qsgd:16").unwrap().name(), "qsgd_4bit");
        assert_eq!(from_spec("identity").unwrap().name(), "identity");
        assert_eq!(from_spec("adaptive:100").unwrap().name(), "adaptive_100");
        assert_eq!(
            from_spec("qsgd:16(top_k:100)").unwrap().name(),
            "qsgd_4bit(top_100)"
        );
        assert!(from_spec("nope").is_err());
        assert!(from_spec("top_k").is_err());
        assert!(from_spec("top_k:x").is_err());
    }

    #[test]
    fn composed_grammar_is_strict() {
        // The outer must be a bare quantizer (no eff-dim override)...
        assert!(from_spec("top_k:3(rand_k:1)").is_err());
        assert!(from_spec("qsgd:16:71(top_k:1)").is_err());
        // ...the inner must emit a coordinate list...
        assert!(from_spec("qsgd:16(qsgd:8)").is_err());
        assert!(from_spec("qsgd:16(identity)").is_err());
        assert!(from_spec("qsgd:16(sign)").is_err());
        // ...and no nesting, trailing junk, or unbalanced parens.
        assert!(from_spec("qsgd:16(qsgd:8(top_k:1))").is_err());
        assert!(from_spec("qsgd:16(top_k:1)x").is_err());
        assert!(from_spec("qsgd:16(top_k:1").is_err());
        assert!(from_spec("qsgd:16()").is_err());
        assert!(from_spec("top_k:1)").is_err());
        // Every composable sparsifier is accepted inside.
        for inner in ["top_k:3", "rand_k:3", "random_p:0.5", "block_top_k:4", "threshold:0.25", "adaptive:3"] {
            assert!(from_spec(&format!("qsgd:16({inner})")).is_ok(), "{inner}");
        }
    }

    #[test]
    fn qsgd_levels_beyond_u32_are_rejected_not_truncated() {
        // 2^32 + 1 used to truncate to levels = 1 via `as u32`.
        let err = from_spec("qsgd:4294967297").unwrap_err();
        assert!(
            format!("{err:#}").contains("exceeds u32 range"),
            "unexpected error: {err:#}"
        );
        assert!(from_spec("qsgd:4294967296").is_err());
        // The largest representable level count still parses.
        assert_eq!(
            from_spec("qsgd:4294967295").unwrap().name(),
            "qsgd_s4294967295"
        );
    }

    #[test]
    fn qsgd_names_distinguish_non_power_of_two_levels() {
        // `log2().round()` used to name both of these `qsgd_3bit`,
        // colliding their metric-record keys.
        assert_eq!(from_spec("qsgd:6").unwrap().name(), "qsgd_s6");
        assert_eq!(from_spec("qsgd:8").unwrap().name(), "qsgd_3bit");
        assert_ne!(
            CompressorSpec::parse("qsgd:6").unwrap().name(),
            CompressorSpec::parse("qsgd:8").unwrap().name()
        );
    }

    #[test]
    fn spec_parsing_rejects_trailing_components() {
        // Every unconsumed part is an error, not silently ignored.
        assert!(from_spec("top_k:1:junk").is_err());
        assert!(from_spec("rand_k:2:9").is_err());
        assert!(from_spec("identity:1").is_err());
        assert!(from_spec("sign:3").is_err());
        assert!(from_spec("random_p:0.5:x").is_err());
        assert!(from_spec("threshold:0.25:x").is_err());
        assert!(from_spec("qsgd:16:71:zz").is_err());
        assert!(from_spec("adaptive:3:j").is_err());
        assert!(from_spec("qsgd:16(top_k:1:j)").is_err());
        // ...while fully-consumed specs still parse.
        assert!(from_spec("qsgd:16:71").is_ok());
    }

    #[test]
    fn spec_parsing_rejects_out_of_range_params() {
        assert!(from_spec("top_k:0").is_err());
        assert!(from_spec("rand_k:0").is_err());
        assert!(from_spec("random_p:0").is_err());
        assert!(from_spec("random_p:1.5").is_err());
        assert!(from_spec("threshold:0").is_err());
        assert!(from_spec("threshold:2").is_err());
        assert!(from_spec("qsgd:0").is_err());
        assert!(from_spec("adaptive:0").is_err());
        assert!(from_spec("qsgd:0(top_k:1)").is_err());
        assert!(from_spec("qsgd:16(top_k:0)").is_err());
    }

    #[test]
    fn typed_spec_round_trips() {
        for spec in [
            "identity",
            "top_k:3",
            "rand_k:10",
            "random_p:0.25",
            "block_top_k:4",
            "sign",
            "threshold:0.25",
            "qsgd:16",
            "qsgd:16:71",
            "adaptive:100",
            "qsgd:16(top_k:100)",
            "qsgd:6(rand_k:3)",
        ] {
            let parsed = CompressorSpec::parse(spec).unwrap();
            assert_eq!(
                CompressorSpec::parse(&parsed.spec_string()).unwrap(),
                parsed,
                "{spec}"
            );
        }
        // Typed parameters are held directly — no re-parse needed.
        assert_eq!(
            CompressorSpec::parse("top_k:3").unwrap(),
            CompressorSpec::TopK { k: 3 }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd:16:71").unwrap(),
            CompressorSpec::Qsgd { levels: 16, eff: Some(71) }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd:16(top_k:100)").unwrap(),
            CompressorSpec::Composed {
                levels: 16,
                inner: Box::new(CompressorSpec::TopK { k: 100 })
            }
        );
        assert_eq!(
            CompressorSpec::parse("adaptive:100").unwrap(),
            CompressorSpec::Adaptive { budget: 100 }
        );
        assert_eq!(CompressorSpec::TopK { k: 3 }.contraction_k(100), Some(3.0));
        assert_eq!(CompressorSpec::Qsgd { levels: 16, eff: None }.contraction_k(100), None);
    }

    #[test]
    fn typed_spec_mirrors_built_operator() {
        // name()/contraction_k() are hand-mirrored (no boxing on the
        // naming path); this pins them to the operators' own answers.
        for spec in [
            "identity",
            "top_k:3",
            "top_k:200", // k > d: operator caps at d
            "rand_k:10",
            "random_p:0.25",
            "block_top_k:4",
            "block_top_k:7", // d % k != 0: ceil-block contraction
            "sign",
            "threshold:0.25",
            "qsgd:16",
            "qsgd:16:71",
            "qsgd:6", // non-power-of-two levels: exact `s6` naming
            "adaptive:3",
            "adaptive:100",
            "qsgd:16(top_k:3)",
            "qsgd:6(rand_k:3)",
            "qsgd:1(top_k:3)", // ω ≥ 1: composed contraction is None
            "qsgd:16(adaptive:3)",
        ] {
            let typed = CompressorSpec::parse(spec).unwrap();
            let built = typed.build();
            assert_eq!(typed.name(), built.name(), "{spec}");
            for d in [1usize, 5, 64, 100] {
                assert_eq!(
                    typed.contraction_k(d),
                    built.contraction_k(d),
                    "{spec} at d={d}"
                );
            }
        }
    }
}
