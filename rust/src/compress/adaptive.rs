//! Adaptive unbiased sparsification — Wangni et al. (NeurIPS 2018),
//! "Gradient Sparsification for Communication-Efficient Distributed
//! Optimization": keep coordinate `i` with probability
//! `p_i = min(1, c·|x_i|)`, where `c` solves `Σ p_i = budget`, and
//! rescale kept values by `1/p_i`.
//!
//! The estimator is *unbiased* (`E[comp(x)] = x`) with variance
//! `Σ x_i²·(1/p_i − 1)` — the probability profile is the one that
//! minimizes that variance under the expected-sparsity constraint
//! (Wangni et al., §3.2), so large coordinates are kept almost surely
//! while small ones are dropped (and amplified on the rare keep) to
//! stay honest in expectation.
//!
//! Unlike rand-k, the *expected* number of kept coordinates is `budget`
//! but the realized cardinality varies per draw; unlike top-k the
//! operator is unbiased, so it composes with averaging without a
//! systematic bias term. `contraction_k()` reports the in-expectation
//! kept count `budget.min(d)` — the Definition 2.1 inequality itself is
//! **not** guaranteed by the 1/p rescaling (a flat vector has variance
//! `‖x‖²·(d/k − 1)`), which the property suite checks against the
//! closed-form variance instead.

use super::{Compressor, Update};
use crate::util::prng::Prng;

/// Wangni-style adaptive sparsifier with expected budget `k`.
#[derive(Clone, Debug)]
pub struct AdaptiveSparse {
    pub budget: usize,
    /// Solve scratch: nonzero magnitudes, sorted descending.
    mags: Vec<f64>,
}

impl AdaptiveSparse {
    pub fn new(budget: usize) -> Self {
        assert!(budget >= 1, "adaptive requires budget >= 1");
        AdaptiveSparse { budget, mags: Vec::new() }
    }

    /// Solve for the probability scale `c` of `p_i = min(1, c·|x_i|)`
    /// with `Σ p_i = budget`: sort the nonzero magnitudes descending and
    /// clamp the largest `t` to probability one, where `t` is the
    /// smallest count for which `c = (budget − t)/Σ_{i>t} a_i` leaves
    /// every unclamped `c·a_i ≤ 1` (Wangni et al., Algorithm 2).
    ///
    /// Returns `f64::INFINITY` when `budget` covers every nonzero (all
    /// probabilities clamp to one — the operator is exact) and `0.0` on
    /// the zero vector.
    fn solve_scale(&mut self, x: &[f32]) -> f64 {
        self.mags.clear();
        for &v in x {
            if v != 0.0 {
                self.mags.push(v.abs() as f64);
            }
        }
        let m = self.mags.len();
        if m == 0 {
            return 0.0;
        }
        if m <= self.budget {
            return f64::INFINITY;
        }
        self.mags.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
        let k = self.budget as f64;
        let mut tail: f64 = self.mags.iter().sum();
        let mut t = 0usize;
        let mut c = k / tail;
        // Clamp one magnitude per round; `c` hits 0 at t = budget < m at
        // the latest, so the loop always exits with `t < m`.
        while c * self.mags[t] > 1.0 {
            tail -= self.mags[t];
            t += 1;
            debug_assert!(t < m, "more clamped entries than the budget");
            c = (k - t as f64) / tail;
        }
        c
    }

    /// Per-coordinate keep probabilities for `x` (zeros get 0) — the
    /// closed-form side of the variance property checked in
    /// `tests/proptest_invariants.rs`.
    pub fn keep_probabilities(&mut self, x: &[f32], out: &mut Vec<f64>) {
        let c = self.solve_scale(x);
        out.clear();
        out.extend(x.iter().map(|&v| {
            if v == 0.0 {
                0.0
            } else {
                (c * v.abs() as f64).min(1.0)
            }
        }));
    }
}

impl Compressor for AdaptiveSparse {
    fn name(&self) -> String {
        format!("adaptive_{}", self.budget)
    }

    /// In-expectation kept count `budget.min(d)` — the analogue of
    /// rand-k's `k`, reported so the stepsize-shift heuristics have a
    /// sparsity scale to work with. See the module docs: the 1/p
    /// rescaling means the Definition 2.1 *inequality* is not implied.
    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(self.budget.min(d) as f64)
    }

    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let c = self.solve_scale(x);
        let sp = out.sparse_mut(d);
        for (i, &v) in x.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let p = (c * v.abs() as f64).min(1.0);
            if rng.bernoulli(p) {
                // Clamped coordinates (p = 1) ship exactly; the rest are
                // amplified by 1/p so the estimator stays unbiased.
                let val = if p >= 1.0 { v } else { (v as f64 / p) as f32 };
                sp.push(i as u32, val);
            }
        }
        sp.encoded_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn keep_all_when_budget_covers_nonzeros() {
        let x = vec![0.0f32, 1.0, -2.0, 0.0, 0.5];
        let mut c = AdaptiveSparse::new(3);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(x.len());
        c.compress(&x, &mut rng, &mut out);
        // Exactly the nonzeros, unscaled (p = 1 everywhere).
        assert_eq!(out.to_dense(x.len()), x);
    }

    #[test]
    fn zero_vector_sends_nothing() {
        let mut c = AdaptiveSparse::new(4);
        let mut rng = Prng::new(2);
        let mut out = Update::new_sparse(16);
        let bits = c.compress(&[0.0; 16], &mut rng, &mut out);
        assert_eq!(out.nnz(), 0);
        assert_eq!(bits, 0);
    }

    #[test]
    fn probabilities_sum_to_budget_and_respect_clamps() {
        let x = vec![10.0f32, -3.0, 1.0, 0.5, 0.0, 0.25, -0.125, 0.0625];
        let mut c = AdaptiveSparse::new(3);
        let mut p = Vec::new();
        c.keep_probabilities(&x, &mut p);
        assert_eq!(p.len(), x.len());
        let sum: f64 = p.iter().sum();
        assert!((sum - 3.0).abs() < 1e-9, "sum(p) = {sum}");
        assert!(p.iter().all(|&pi| (0.0..=1.0).contains(&pi)));
        // The dominant coordinate clamps to certainty; zeros get 0.
        assert_eq!(p[0], 1.0);
        assert_eq!(p[4], 0.0);
        // Unclamped probabilities are proportional to magnitude.
        assert!((p[2] / p[3] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unbiased_and_budget_in_expectation() {
        let mut rng = Prng::new(7);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let budget = 6;
        let mut c = AdaptiveSparse::new(budget);
        let mut out = Update::new_sparse(x.len());
        let trials = 20_000;
        let mut acc = vec![0.0f64; x.len()];
        let mut nnz_acc = 0usize;
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            nnz_acc += out.nnz();
            if let Update::Sparse(s) = &out {
                for (&i, &v) in s.idx.iter().zip(&s.val) {
                    acc[i as usize] += v as f64;
                }
            }
        }
        let norm = stats::l2_norm(&x);
        for (j, (&xj, &aj)) in x.iter().zip(&acc).enumerate() {
            let mean = aj / trials as f64;
            assert!(
                (mean - xj as f64).abs() < 0.05 * norm,
                "coord {j}: mean={mean} x={xj}"
            );
        }
        let mean_nnz = nnz_acc as f64 / trials as f64;
        assert!(
            (mean_nnz - budget as f64).abs() < 0.1,
            "E[nnz] = {mean_nnz}, budget = {budget}"
        );
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(
            crate::compress::from_spec("adaptive:100").unwrap().name(),
            "adaptive_100"
        );
        assert!(crate::compress::from_spec("adaptive").is_err());
        assert!(crate::compress::from_spec("adaptive:0").is_err());
    }

    #[test]
    #[should_panic(expected = "budget >= 1")]
    fn rejects_zero_budget() {
        AdaptiveSparse::new(0);
    }
}
