//! Rand-k sparsification (Definition 2.2): keep k uniformly random
//! coordinates (a uniform draw from the `(d choose k)` subsets). A
//! k-contraction in expectation: `E‖x − rand_k(x)‖² = (1 − k/d)‖x‖²`
//! with *equality* (Lemma A.1, eq. 19).

use super::{Compressor, Update};
use crate::util::prng::Prng;

/// Keep `k` uniformly random coordinates.
#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
    scratch: Vec<u32>,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "rand_k requires k >= 1");
        RandK {
            k,
            scratch: Vec::new(),
        }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand_{}", self.k)
    }

    fn contraction_k(&self, d: usize) -> Option<f64> {
        Some(self.k.min(d) as f64)
    }

    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let k = self.k.min(d);
        let sp = out.sparse_mut(d);
        rng.sample_distinct(d, k, &mut self.scratch);
        for &i in &self.scratch {
            sp.push(i, x[i as usize]);
        }
        sp.encoded_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn output_is_a_masked_copy() {
        let x: Vec<f32> = (0..50).map(|i| i as f32 + 1.0).collect();
        let mut c = RandK::new(5);
        let mut rng = Prng::new(3);
        let mut out = Update::new_sparse(50);
        c.compress(&x, &mut rng, &mut out);
        match &out {
            Update::Sparse(s) => {
                assert_eq!(s.nnz(), 5);
                for (&i, &v) in s.idx.iter().zip(&s.val) {
                    assert_eq!(v, x[i as usize]);
                }
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn contraction_holds_in_expectation() {
        // E‖x − rand_k(x)‖² = (1 − k/d)‖x‖² exactly; check the Monte Carlo
        // mean lands within a few standard errors.
        let d = 64;
        let k = 8;
        let mut rng = Prng::new(7);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let norm_sq = stats::l2_norm_sq(&x);
        let trials = 20_000;
        let mut c = RandK::new(k);
        let mut out = Update::new_sparse(d);
        let mut acc = 0.0f64;
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            let dense = out.to_dense(d);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += stats::l2_norm_sq(&resid);
        }
        let mean = acc / trials as f64;
        let expected = (1.0 - k as f64 / d as f64) * norm_sq;
        assert!(
            (mean - expected).abs() / expected < 0.02,
            "mean={mean} expected={expected}"
        );
    }

    #[test]
    fn every_coordinate_eventually_selected() {
        let d = 30;
        let x = vec![1.0f32; d];
        let mut c = RandK::new(2);
        let mut rng = Prng::new(9);
        let mut out = Update::new_sparse(d);
        let mut seen = vec![false; d];
        for _ in 0..2_000 {
            c.compress(&x, &mut rng, &mut out);
            if let Update::Sparse(s) = &out {
                for &i in &s.idx {
                    seen[i as usize] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "some coordinate was never selected");
    }

    #[test]
    fn selection_is_roughly_uniform() {
        let d = 20;
        let x = vec![1.0f32; d];
        let mut c = RandK::new(1);
        let mut rng = Prng::new(11);
        let mut out = Update::new_sparse(d);
        let mut counts = vec![0usize; d];
        let trials = 40_000;
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            if let Update::Sparse(s) = &out {
                counts[s.idx[0] as usize] += 1;
            }
        }
        let expected = trials / d;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expected as f64).abs() < expected as f64 * 0.15,
                "coordinate {i}: {c} vs {expected}"
            );
        }
    }

    #[test]
    fn k_geq_d_keeps_everything() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut c = RandK::new(10);
        let mut rng = Prng::new(13);
        let mut out = Update::new_sparse(3);
        c.compress(&x, &mut rng, &mut out);
        let mut dense = out.to_dense(3);
        dense.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(dense, x);
    }
}
