//! Exact wire encodings: a real bitstream with Elias-γ / Elias-δ integer
//! codes, plus encoders for the two payload families the paper transmits
//! — and the **typed payload framing** the threaded parameter-server
//! engines put on an actual channel.
//!
//! The paper accounts bits with closed-form *estimates* (Appendix B:
//! `3s(s+√d)+32` for QSGD-with-Elias; `k(32 + log d)` for sparse
//! updates, footnote 5). This module makes the accounting exact: it
//! serializes updates into a byte buffer and reports the measured bit
//! count, so `benches/figure3_qsgd.rs` can cross-check the formulas the
//! figures rely on and the distributed engines can charge the network
//! model with real message sizes.
//!
//! Wire formats:
//! * **Sparse update** ([`encode_sparse`]): `γ(nnz+1)`, then the sorted
//!   index deltas `γ(Δᵢ+1)` interleaved with raw 32-bit IEEE values.
//! * **QSGD payload** ([`encode_qsgd`]): 32-bit norm, then for each
//!   nonzero level: `γ(index-delta+1)`, sign bit, `γ(level)` — the
//!   encoding of Alistarh et al. §3.2.
//!
//! ## Payload framing
//!
//! [`decode_payload`] / the `encode_payload_*` family frame one
//! compressed [`Update`] as a self-describing bitstream: a γ-coded tag
//! selecting the body codec, then the body. Every
//! [`super::Compressor`] has a frame (the trait's
//! [`super::Compressor::encode_payload`] picks it), and decoding
//! reconstructs the update **bit for bit** — every f32 value including
//! zero-valued padding coordinates and signed zeros — which is what
//! lets the threaded engines stay on the simulated engines' exact
//! trajectories while shipping real bytes
//! (`tests/wire_protocol.rs`).
//!
//! | tag | body | producers |
//! |---|---|---|
//! | [`TAG_SPARSE`] | [`encode_sparse`] | top-k, rand-k, random-p, block-top-k, threshold, unbiased rand-k |
//! | [`TAG_DENSE_RAW`] | `γ(d+1)`, `d` raw f32 | identity; dense fallback |
//! | [`TAG_DENSE_NZ`] | `γ(d+1)`, [`encode_sparse`] of the bitwise-nonzero entries | dense vectors that are mostly `+0.0` |
//! | [`TAG_SIGN`] | `γ(d+1)`, f32 scale, `d` sign bits (omitted at scale 0) | 1Bit-SGD sign compression |
//! | [`TAG_QSGD`] | `γ(d+1)`, `γ(s)`, [`encode_qsgd`] | QSGD quantization |
//! | [`TAG_COMPOSED`] | `γ(d+1)`, `γ(s)`, f32 norm, `γ(nnz+1)`, per entry `γ(Δ+1)`, sign bit, `γ(level+1)` | quantization ∘ sparsification ([`super::Composed`]) |
//!
//! The generic dense encoder chooses `TAG_DENSE_NZ` vs `TAG_DENSE_RAW`
//! by exact bit cost, so the choice is a deterministic function of the
//! payload content.
//!
//! All decoders are **total**: truncated, corrupted, or adversarial
//! byte streams return descriptive errors — no panics, no unbounded
//! allocation from a hostile `nnz`/index/level field (property-tested
//! in `tests/proptest_invariants.rs`).

use anyhow::{bail, Result};

use super::sparse::SparseVec;
use super::Update;

/// Append-only bit buffer (MSB-first within each byte).
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the final byte (0 = byte boundary).
    fill: u32,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    /// Total bits written so far.
    pub fn bits(&self) -> u64 {
        if self.fill == 0 {
            (self.buf.len() as u64) * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.fill as u64
        }
    }

    /// Reset for reuse, keeping the allocation.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.fill = 0;
    }

    /// Finished payload, zero-padded to a byte boundary.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Write one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        if self.fill == 0 {
            self.buf.push(0);
        }
        if bit {
            let last = self.buf.len() - 1;
            self.buf[last] |= 1 << (7 - self.fill);
        }
        self.fill = (self.fill + 1) % 8;
    }

    /// Write the low `n` bits of `v`, most significant first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Elias-γ code of `v ≥ 1`: `⌊log₂ v⌋` zeros, then `v`'s binary form.
    /// Costs `2⌊log₂ v⌋ + 1` bits.
    pub fn put_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1, "elias-gamma is defined for v >= 1");
        let nbits = 64 - v.leading_zeros(); // position of the MSB, >= 1
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(v, nbits);
    }

    /// Elias-δ code of `v ≥ 1`: γ(length) then the mantissa. Shorter than
    /// γ for large `v`; used for the index of the first nonzero in very
    /// high-dimensional sparse payloads.
    pub fn put_delta(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        self.put_gamma(nbits as u64);
        if nbits > 1 {
            // mantissa without the implicit leading 1
            self.put_bits(v & ((1u64 << (nbits - 1)) - 1), nbits - 1);
        }
    }

    /// Raw IEEE-754 single.
    pub fn put_f32(&mut self, v: f32) {
        self.put_bits(v.to_bits() as u64, 32);
    }
}

/// Bit cursor over an encoded payload.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, pos: 0 }
    }

    /// Bits consumed so far.
    pub fn consumed(&self) -> u64 {
        self.pos
    }

    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.buf.len() {
            bail!("bitstream exhausted at bit {}", self.pos);
        }
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    pub fn get_bits(&mut self, n: u32) -> Result<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Ok(v)
    }

    pub fn get_gamma(&mut self) -> Result<u64> {
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros > 63 {
                bail!("malformed gamma code (>63 leading zeros)");
            }
        }
        // We already consumed the leading 1 of the binary form.
        let rest = self.get_bits(zeros)?;
        Ok((1u64 << zeros) | rest)
    }

    pub fn get_delta(&mut self) -> Result<u64> {
        let nbits = self.get_gamma()?;
        if nbits == 0 || nbits > 64 {
            bail!("malformed delta code (length {nbits})");
        }
        if nbits == 1 {
            return Ok(1);
        }
        let mantissa = self.get_bits(nbits as u32 - 1)?;
        Ok((1u64 << (nbits - 1)) | mantissa)
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_bits(32)? as u32))
    }
}

/// Encode a sparse update; returns the exact payload bit count.
/// Indices are sorted and delta-coded (`γ(Δ+1)`), values are raw f32.
pub fn encode_sparse(s: &SparseVec, w: &mut BitWriter) -> u64 {
    let before = w.bits();
    let mut order: Vec<usize> = (0..s.nnz()).collect();
    order.sort_unstable_by_key(|&i| s.idx[i]);
    w.put_gamma(s.nnz() as u64 + 1);
    let mut prev = 0u64;
    for (rank, &j) in order.iter().enumerate() {
        let i = s.idx[j] as u64;
        let delta = if rank == 0 { i } else { i - prev - 1 };
        prev = i;
        w.put_gamma(delta + 1);
        w.put_f32(s.val[j]);
    }
    w.bits() - before
}

/// Decode a sparse update produced by [`encode_sparse`].
///
/// Total on arbitrary input: a hostile `nnz` field is rejected before
/// any allocation (valid payloads have strictly increasing indices
/// below `dim`, so `nnz ≤ dim` always), and index arithmetic is
/// checked — truncation and corruption produce descriptive errors,
/// never panics.
pub fn decode_sparse(r: &mut BitReader<'_>, dim: usize) -> Result<SparseVec> {
    let nnz = r.get_gamma()? - 1;
    if nnz > dim as u64 {
        bail!("decoded nnz {nnz} exceeds dimension {dim}");
    }
    let mut out = SparseVec::new(dim);
    let mut prev = 0u64;
    for rank in 0..nnz {
        let delta = r.get_gamma()? - 1;
        let i = if rank == 0 {
            delta
        } else {
            match prev.checked_add(1).and_then(|p| p.checked_add(delta)) {
                Some(i) => i,
                None => bail!("decoded index overflows (Δ {delta} after {prev})"),
            }
        };
        prev = i;
        if i >= dim as u64 {
            bail!("decoded index {i} out of dimension {dim}");
        }
        let v = r.get_f32()?;
        out.push(i as u32, v);
    }
    Ok(out)
}

/// Encode a QSGD quantization `(‖x‖, sign·level per coordinate)` with the
/// Elias scheme of Alistarh et al. §3.2; returns the exact bit count.
/// Zero levels are skipped via index deltas.
pub fn encode_qsgd(norm: f32, levels: &[i32], w: &mut BitWriter) -> u64 {
    let before = w.bits();
    w.put_f32(norm);
    let nnz = levels.iter().filter(|&&l| l != 0).count();
    w.put_gamma(nnz as u64 + 1);
    let mut prev = 0u64;
    let mut first = true;
    for (i, &l) in levels.iter().enumerate() {
        if l == 0 {
            continue;
        }
        let i = i as u64;
        let delta = if first { i } else { i - prev - 1 };
        first = false;
        prev = i;
        w.put_gamma(delta + 1);
        w.put_bit(l < 0);
        w.put_gamma(l.unsigned_abs() as u64);
    }
    w.bits() - before
}

/// Decode a QSGD payload back into `(norm, levels)`.
///
/// Total on arbitrary input, like [`decode_sparse`]: hostile `nnz` is
/// rejected before work proportional to it, index arithmetic is
/// checked, and a level magnitude beyond `i32::MAX` is a descriptive
/// error rather than a silent truncation.
pub fn decode_qsgd(r: &mut BitReader<'_>, dim: usize) -> Result<(f32, Vec<i32>)> {
    let norm = r.get_f32()?;
    let nnz = r.get_gamma()? - 1;
    if nnz > dim as u64 {
        bail!("decoded nnz {nnz} exceeds dimension {dim}");
    }
    let mut levels = vec![0i32; dim];
    let mut prev = 0u64;
    for rank in 0..nnz {
        let delta = r.get_gamma()? - 1;
        let i = if rank == 0 {
            delta
        } else {
            match prev.checked_add(1).and_then(|p| p.checked_add(delta)) {
                Some(i) => i,
                None => bail!("decoded index overflows (Δ {delta} after {prev})"),
            }
        };
        prev = i;
        if i >= dim as u64 {
            bail!("decoded index {i} out of dimension {dim}");
        }
        let neg = r.get_bit()?;
        let mag = r.get_gamma()?;
        if mag > i32::MAX as u64 {
            bail!("decoded level magnitude {mag} out of i32 range");
        }
        let mag = mag as i32;
        levels[i as usize] = if neg { -mag } else { mag };
    }
    Ok((norm, levels))
}

/// Bits of the γ code of `v` (`2⌊log₂ v⌋ + 1`), without encoding.
pub fn gamma_bits(v: u64) -> u64 {
    debug_assert!(v >= 1);
    2 * (63 - v.leading_zeros() as u64) + 1
}

// ---------------------------------------------------------------------------
// Payload framing (see the module docs for the tag table)
// ---------------------------------------------------------------------------

/// Frame tag: sparse coordinate list ([`encode_sparse`] body).
pub const TAG_SPARSE: u64 = 1;
/// Frame tag: dense vector as `d` raw f32s.
pub const TAG_DENSE_RAW: u64 = 2;
/// Frame tag: dense vector as the sparse list of its bitwise-nonzero
/// entries (entries whose IEEE bits are not `+0.0`; `-0.0` is stored
/// explicitly, so the round-trip is exact for every dense vector).
pub const TAG_DENSE_NZ: u64 = 3;
/// Frame tag: sign compression — one f32 scale plus `d` sign bits.
pub const TAG_SIGN: u64 = 4;
/// Frame tag: QSGD quantization — `γ(s)` then an [`encode_qsgd`] body.
pub const TAG_QSGD: u64 = 5;
/// Frame tag: composed quantization ∘ sparsification — a sparse index
/// list whose values are `s`-level quantizations of the kept vector
/// (norm scalar + sign/level per entry). Zero levels keep their index
/// (decoded as exact `+0.0`), so the kept-coordinate set round-trips.
pub const TAG_COMPOSED: u64 = 6;

/// Frame a sparse update: `γ(TAG_SPARSE)` + [`encode_sparse`].
/// Returns the payload bit count (tag included).
pub fn encode_payload_sparse(s: &SparseVec, w: &mut BitWriter) -> u64 {
    let before = w.bits();
    w.put_gamma(TAG_SPARSE);
    encode_sparse(s, w);
    w.bits() - before
}

/// Frame a dense vector, choosing `TAG_DENSE_NZ` vs `TAG_DENSE_RAW` by
/// exact bit cost (a deterministic function of the content). The
/// nonzero-coded form stores every entry whose IEEE bits differ from
/// `+0.0` — including `-0.0` — so either form decodes back bit for bit.
pub fn encode_payload_dense(g: &[f32], w: &mut BitWriter) -> u64 {
    let before = w.bits();
    let d = g.len() as u64;
    // Exact cost of the nonzero-coded body (indices ascend, so the
    // deltas here are exactly what the encoder below writes).
    let mut nnz = 0u64;
    let mut nz_body = 0u64;
    let mut prev = 0u64;
    let mut first = true;
    for (i, &v) in g.iter().enumerate() {
        if v.to_bits() == 0 {
            continue;
        }
        let i = i as u64;
        let delta = if first { i } else { i - prev - 1 };
        first = false;
        prev = i;
        nz_body += gamma_bits(delta + 1) + 32;
        nnz += 1;
    }
    nz_body += gamma_bits(nnz + 1);
    if nz_body < 32 * d {
        w.put_gamma(TAG_DENSE_NZ);
        w.put_gamma(d + 1);
        w.put_gamma(nnz + 1);
        let mut prev = 0u64;
        let mut first = true;
        for (i, &v) in g.iter().enumerate() {
            if v.to_bits() == 0 {
                continue;
            }
            let i = i as u64;
            let delta = if first { i } else { i - prev - 1 };
            first = false;
            prev = i;
            w.put_gamma(delta + 1);
            w.put_f32(v);
        }
    } else {
        w.put_gamma(TAG_DENSE_RAW);
        w.put_gamma(d + 1);
        for &v in g {
            w.put_f32(v);
        }
    }
    w.bits() - before
}

/// Frame a sign-compressed dense vector: `γ(TAG_SIGN)`, `γ(d+1)`, the
/// f32 scale, then (when the scale is positive) one sign bit per
/// coordinate. Precondition (checked by the [`super::SignSgd`] caller):
/// every entry is bitwise `±scale`, or every entry is bitwise `+0.0`.
pub fn encode_payload_sign(g: &[f32], scale: f32, w: &mut BitWriter) -> u64 {
    let before = w.bits();
    w.put_gamma(TAG_SIGN);
    w.put_gamma(g.len() as u64 + 1);
    w.put_f32(scale);
    if scale > 0.0 {
        for &v in g {
            w.put_bit(v < 0.0);
        }
    }
    w.bits() - before
}

/// Frame a QSGD quantization: `γ(TAG_QSGD)`, `γ(d+1)`, `γ(s)`, then an
/// [`encode_qsgd`] body. The decoder dequantizes with the compressor's
/// literal expression `norm · sign · (level / s)`, so the payload
/// reconstructs the transmitted dense update bit for bit.
pub fn encode_payload_qsgd(s: u32, norm: f32, levels: &[i32], w: &mut BitWriter) -> u64 {
    debug_assert!(s >= 1);
    let before = w.bits();
    w.put_gamma(TAG_QSGD);
    w.put_gamma(levels.len() as u64 + 1);
    w.put_gamma(s as u64);
    encode_qsgd(norm, levels, w);
    w.bits() - before
}

/// Frame a composed quantization-∘-sparsification payload:
/// `γ(TAG_COMPOSED)`, `γ(d+1)`, `γ(s)`, the f32 kept-vector norm,
/// `γ(nnz+1)`, then per entry (indices strictly ascending): `γ(Δ+1)`,
/// one sign bit, `γ(|level|+1)` with `level ∈ 0..=s`. The decoder
/// dequantizes with the compressor's literal expression
/// `norm · sign · (level / s)` (zero levels become exact `+0.0`), so
/// the payload reconstructs the transmitted sparse update bit for bit.
pub fn encode_payload_composed(
    s: u32,
    norm: f32,
    idx: &[u32],
    levels: &[i32],
    dim: usize,
    w: &mut BitWriter,
) -> u64 {
    debug_assert!(s >= 1);
    debug_assert_eq!(idx.len(), levels.len());
    debug_assert!(idx.windows(2).all(|p| p[0] < p[1]), "indices must ascend");
    let before = w.bits();
    w.put_gamma(TAG_COMPOSED);
    w.put_gamma(dim as u64 + 1);
    w.put_gamma(s as u64);
    w.put_f32(norm);
    w.put_gamma(idx.len() as u64 + 1);
    let mut prev = 0u64;
    for (rank, (&i, &l)) in idx.iter().zip(levels).enumerate() {
        let i = i as u64;
        let delta = if rank == 0 { i } else { i - prev - 1 };
        prev = i;
        w.put_gamma(delta + 1);
        w.put_bit(l < 0);
        w.put_gamma(l.unsigned_abs() as u64 + 1);
    }
    w.bits() - before
}

/// Frame any [`Update`] through the generic codecs — the default of
/// [`super::Compressor::encode_payload`].
pub fn encode_payload_update(update: &Update, w: &mut BitWriter) -> u64 {
    match update {
        Update::Sparse(s) => encode_payload_sparse(s, w),
        Update::Dense(g) => encode_payload_dense(g, w),
    }
}

/// Read and validate the framed dimension field against the dimension
/// the caller expects.
fn expect_dim(r: &mut BitReader<'_>, dim: usize) -> Result<()> {
    let d = r.get_gamma()? - 1;
    if d != dim as u64 {
        bail!("payload dimension {d} does not match expected {dim}");
    }
    Ok(())
}

/// Decode one framed payload back into the exact [`Update`] it encoded.
///
/// Total on arbitrary input: unknown tags, dimension mismatches,
/// truncation, and hostile counts all return descriptive errors (the
/// robustness suite in `tests/proptest_invariants.rs` fuzzes this
/// entry point alongside the raw body decoders).
pub fn decode_payload(r: &mut BitReader<'_>, dim: usize) -> Result<Update> {
    match r.get_gamma()? {
        TAG_SPARSE => Ok(Update::Sparse(decode_sparse(r, dim)?)),
        TAG_DENSE_RAW => {
            expect_dim(r, dim)?;
            let mut g = vec![0.0f32; dim];
            for gi in g.iter_mut() {
                *gi = r.get_f32()?;
            }
            Ok(Update::Dense(g))
        }
        TAG_DENSE_NZ => {
            expect_dim(r, dim)?;
            let s = decode_sparse(r, dim)?;
            let mut g = vec![0.0f32; dim];
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                g[i as usize] = v;
            }
            Ok(Update::Dense(g))
        }
        TAG_SIGN => {
            expect_dim(r, dim)?;
            let scale = r.get_f32()?;
            let mut g = vec![0.0f32; dim];
            if scale > 0.0 {
                for gi in g.iter_mut() {
                    *gi = if r.get_bit()? { -scale } else { scale };
                }
            }
            Ok(Update::Dense(g))
        }
        TAG_QSGD => {
            expect_dim(r, dim)?;
            let s = r.get_gamma()?;
            if s > i32::MAX as u64 {
                bail!("decoded QSGD level count {s} out of range");
            }
            let sf = s as f32;
            let (norm, levels) = decode_qsgd(r, dim)?;
            let mut g = vec![0.0f32; dim];
            for (gi, &l) in g.iter_mut().zip(&levels) {
                if l != 0 {
                    let sgn = if l < 0 { -1.0f32 } else { 1.0 };
                    // The compressor's literal dequantization expression.
                    *gi = norm * sgn * (l.unsigned_abs() as f32 / sf);
                }
            }
            Ok(Update::Dense(g))
        }
        TAG_COMPOSED => {
            expect_dim(r, dim)?;
            let s = r.get_gamma()?;
            if s > i32::MAX as u64 {
                bail!("decoded composed level count {s} out of range");
            }
            let sf = s as f32;
            let norm = r.get_f32()?;
            let nnz = r.get_gamma()? - 1;
            if nnz > dim as u64 {
                bail!("decoded nnz {nnz} exceeds dimension {dim}");
            }
            let mut out = SparseVec::new(dim);
            let mut prev = 0u64;
            for rank in 0..nnz {
                let delta = r.get_gamma()? - 1;
                let i = if rank == 0 {
                    delta
                } else {
                    match prev.checked_add(1).and_then(|p| p.checked_add(delta)) {
                        Some(i) => i,
                        None => bail!("decoded index overflows (Δ {delta} after {prev})"),
                    }
                };
                prev = i;
                if i >= dim as u64 {
                    bail!("decoded index {i} out of dimension {dim}");
                }
                let neg = r.get_bit()?;
                let mag = r.get_gamma()? - 1;
                if mag > i32::MAX as u64 {
                    bail!("decoded level magnitude {mag} out of i32 range");
                }
                let v = if mag == 0 {
                    // Zero levels are exact +0.0 — the padding slots of
                    // the inner sparsifier's selection.
                    0.0f32
                } else {
                    let sgn = if neg { -1.0f32 } else { 1.0 };
                    // The compressor's literal dequantization expression.
                    norm * sgn * (mag as u32 as f32 / sf)
                };
                out.push(i as u32, v);
            }
            Ok(Update::Sparse(out))
        }
        other => bail!("unknown payload tag {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn gamma_roundtrip_small_and_large() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 4, 7, 8, 100, 1 << 20, (1 << 40) + 12345];
        for &v in &vals {
            w.put_gamma(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        for &v in &vals {
            assert_eq!(r.get_gamma().unwrap(), v);
        }
    }

    #[test]
    fn delta_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 17, 1000, 1 << 33];
        for &v in &vals {
            w.put_delta(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        for &v in &vals {
            assert_eq!(r.get_delta().unwrap(), v);
        }
    }

    #[test]
    fn gamma_bit_cost_formula() {
        let mut w = BitWriter::new();
        for v in 1..300u64 {
            let before = w.bits();
            w.put_gamma(v);
            assert_eq!(w.bits() - before, gamma_bits(v), "v={v}");
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        let vals = [0.0f32, -0.0, 1.5, -3.25e-12, f32::MAX, f32::MIN_POSITIVE];
        let mut w = BitWriter::new();
        w.put_bit(true); // unaligned on purpose
        for &v in &vals {
            w.put_f32(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        r.get_bit().unwrap();
        for &v in &vals {
            assert_eq!(r.get_f32().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn sparse_roundtrip_random() {
        let mut rng = Prng::new(7);
        for trial in 0..50 {
            let dim = 1 + rng.below(5000);
            let nnz = rng.below(dim.min(64) + 1);
            let mut idx = Vec::new();
            rng.sample_distinct(dim, nnz, &mut idx);
            let mut s = SparseVec::new(dim);
            for &i in &idx {
                s.push(i, rng.normal_f32());
            }
            let mut w = BitWriter::new();
            let bits = encode_sparse(&s, &mut w);
            assert!(bits >= 1);
            let mut r = BitReader::new(w.as_bytes());
            let back = decode_sparse(&mut r, dim).unwrap();
            assert_eq!(r.consumed(), bits, "trial {trial}");
            // Compare as dense (encoder sorts indices).
            assert_eq!(back.to_dense(), s.to_dense(), "trial {trial}");
        }
    }

    #[test]
    fn sparse_empty_and_full() {
        let mut w = BitWriter::new();
        let empty = SparseVec::new(10);
        encode_sparse(&empty, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(decode_sparse(&mut r, 10).unwrap().nnz(), 0);

        let mut full = SparseVec::new(4);
        for i in 0..4 {
            full.push(i, i as f32 + 0.5);
        }
        let mut w = BitWriter::new();
        encode_sparse(&full, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(
            decode_sparse(&mut r, 4).unwrap().to_dense(),
            full.to_dense()
        );
    }

    #[test]
    fn qsgd_roundtrip() {
        let mut rng = Prng::new(9);
        for _ in 0..30 {
            let dim = 1 + rng.below(2000);
            let levels: Vec<i32> = (0..dim)
                .map(|_| {
                    if rng.bernoulli(0.05) {
                        let m = 1 + rng.below(15) as i32;
                        if rng.bernoulli(0.5) {
                            -m
                        } else {
                            m
                        }
                    } else {
                        0
                    }
                })
                .collect();
            let norm = rng.f32() * 10.0;
            let mut w = BitWriter::new();
            let bits = encode_qsgd(norm, &levels, &mut w);
            let mut r = BitReader::new(w.as_bytes());
            let (n2, l2) = decode_qsgd(&mut r, dim).unwrap();
            assert_eq!(r.consumed(), bits);
            assert_eq!(n2.to_bits(), norm.to_bits());
            assert_eq!(l2, levels);
        }
    }

    #[test]
    fn top1_payload_is_tiny() {
        // The paper's headline: top-1 on d=2000 costs ~(32 + log d) bits,
        // three orders of magnitude below the 64'000-bit dense gradient.
        let mut s = SparseVec::new(2000);
        s.push(1234, -0.7);
        let mut w = BitWriter::new();
        let bits = encode_sparse(&s, &mut w);
        assert!(bits < 64, "top-1 payload should be <64 bits, got {bits}");
        assert!((2000 * 32) as u64 / bits > 900);
    }

    #[test]
    fn truncated_stream_errors_cleanly() {
        let mut s = SparseVec::new(100);
        for i in 0..10 {
            s.push(i * 7, 1.0);
        }
        let mut w = BitWriter::new();
        encode_sparse(&s, &mut w);
        let bytes = w.as_bytes();
        let cut = &bytes[..bytes.len() / 2];
        let mut r = BitReader::new(cut);
        assert!(decode_sparse(&mut r, 100).is_err());
    }

    #[test]
    fn writer_reuse_clears_state() {
        let mut w = BitWriter::new();
        w.put_gamma(77);
        w.clear();
        assert_eq!(w.bits(), 0);
        w.put_gamma(5);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(r.get_gamma().unwrap(), 5);
    }

    #[test]
    fn hostile_nnz_is_rejected_before_allocation() {
        // γ(2^40) as the nnz field: must bail on the count check, not
        // loop/allocate its way to stream exhaustion.
        let mut w = BitWriter::new();
        w.put_gamma(1u64 << 40);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_sparse(&mut r, 100).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds dimension"), "{err:#}");
        let mut w = BitWriter::new();
        w.put_f32(1.0);
        w.put_gamma(1u64 << 40);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_qsgd(&mut r, 100).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds dimension"), "{err:#}");
    }

    #[test]
    fn hostile_level_magnitude_is_rejected() {
        // norm, nnz=1, index delta, sign, then a γ level beyond i32.
        let mut w = BitWriter::new();
        w.put_f32(1.0);
        w.put_gamma(2); // nnz = 1
        w.put_gamma(1); // index 0
        w.put_bit(false);
        w.put_gamma(1u64 << 40);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_qsgd(&mut r, 4).unwrap_err();
        assert!(format!("{err:#}").contains("out of i32 range"), "{err:#}");
    }

    fn roundtrip_payload(update: &Update, dim: usize) -> (Update, u64) {
        let mut w = BitWriter::new();
        let bits = encode_payload_update(update, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, dim).unwrap();
        assert_eq!(r.consumed(), bits, "consumed == produced");
        (back, bits)
    }

    fn bits_of(update: &Update, dim: usize) -> Vec<u32> {
        update.to_dense(dim).iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn payload_sparse_roundtrips_including_zero_valued_entries() {
        // Zero-valued padding coordinates (top-k tie padding) must
        // survive: they cost wire bits and occupy server slots.
        let mut s = SparseVec::new(50);
        s.push(3, -1.5);
        s.push(17, 0.0);
        s.push(40, f32::MIN_POSITIVE);
        let u = Update::Sparse(s);
        let (back, _) = roundtrip_payload(&u, 50);
        match (&u, &back) {
            (Update::Sparse(a), Update::Sparse(b)) => {
                // Encoder sorts; index/value multisets must agree exactly.
                let mut want: Vec<(u32, u32)> =
                    a.idx.iter().zip(&a.val).map(|(&i, &v)| (i, v.to_bits())).collect();
                want.sort_unstable();
                let got: Vec<(u32, u32)> =
                    b.idx.iter().zip(&b.val).map(|(&i, &v)| (i, v.to_bits())).collect();
                assert_eq!(got, want);
            }
            _ => panic!("kind changed through the codec"),
        }
    }

    #[test]
    fn payload_dense_roundtrips_signed_zeros_bitwise() {
        let g = vec![0.0f32, -0.0, 1.25, 0.0, -3.5e-20, 0.0, 0.0, 0.0];
        let u = Update::Dense(g);
        let (back, _) = roundtrip_payload(&u, 8);
        assert_eq!(bits_of(&back, 8), bits_of(&u, 8));
        assert!(matches!(back, Update::Dense(_)));
    }

    #[test]
    fn payload_dense_picks_the_cheaper_form() {
        // Mostly-zero: nonzero-coded beats raw.
        let mut g = vec![0.0f32; 1000];
        g[7] = 1.0;
        let mut w = BitWriter::new();
        let bits = encode_payload_dense(&g, &mut w);
        assert!(bits < 32 * 1000, "nz-coded: {bits}");
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(r.get_gamma().unwrap(), TAG_DENSE_NZ);
        // Fully dense: raw wins (nz coding would add index overhead).
        let g: Vec<f32> = (0..100).map(|i| i as f32 + 0.5).collect();
        let mut w = BitWriter::new();
        let bits = encode_payload_dense(&g, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(r.get_gamma().unwrap(), TAG_DENSE_RAW);
        assert_eq!(bits, gamma_bits(TAG_DENSE_RAW) + gamma_bits(101) + 32 * 100);
    }

    #[test]
    fn payload_sign_roundtrips_bitwise() {
        let scale = 0.375f32;
        let g = vec![scale, -scale, scale, scale, -scale];
        let mut w = BitWriter::new();
        let bits = encode_payload_sign(&g, scale, &mut w);
        // Exactly the accounted d + 32 plus the frame header.
        assert_eq!(bits, gamma_bits(TAG_SIGN) + gamma_bits(6) + 32 + 5);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 5).unwrap();
        assert_eq!(bits_of(&back, 5), bits_of(&Update::Dense(g), 5));
        // Zero scale: no sign bits on the wire, all-+0.0 back.
        let mut w = BitWriter::new();
        let bits = encode_payload_sign(&[0.0; 4], 0.0, &mut w);
        assert_eq!(bits, gamma_bits(TAG_SIGN) + gamma_bits(5) + 32);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 4).unwrap();
        assert_eq!(bits_of(&back, 4), vec![0u32; 4]);
    }

    #[test]
    fn payload_qsgd_roundtrips_the_dequantized_update_bitwise() {
        let s = 16u32;
        let norm = 2.7182817f32;
        let levels = vec![0i32, 3, -1, 0, 16, -7, 0, 0];
        let sf = s as f32;
        let g: Vec<f32> = levels
            .iter()
            .map(|&l| {
                if l == 0 {
                    0.0
                } else {
                    let sgn = if l < 0 { -1.0f32 } else { 1.0 };
                    norm * sgn * (l.unsigned_abs() as f32 / sf)
                }
            })
            .collect();
        let mut w = BitWriter::new();
        let bits = encode_payload_qsgd(s, norm, &levels, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 8).unwrap();
        assert_eq!(r.consumed(), bits);
        assert_eq!(bits_of(&back, 8), bits_of(&Update::Dense(g), 8));
    }

    #[test]
    fn payload_composed_roundtrips_the_dequantized_update_bitwise() {
        let s = 16u32;
        let norm = 1.7320508f32;
        // Includes a zero level: its index must survive as exact +0.0.
        let idx = vec![3u32, 17, 40, 44];
        let levels = vec![5i32, 0, -16, 1];
        let sf = s as f32;
        let mut want = SparseVec::new(50);
        for (&i, &l) in idx.iter().zip(&levels) {
            let v = if l == 0 {
                0.0
            } else {
                let sgn = if l < 0 { -1.0f32 } else { 1.0 };
                norm * sgn * (l.unsigned_abs() as f32 / sf)
            };
            want.push(i, v);
        }
        let mut w = BitWriter::new();
        let bits = encode_payload_composed(s, norm, &idx, &levels, 50, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 50).unwrap();
        assert_eq!(r.consumed(), bits);
        let Update::Sparse(b) = &back else { panic!("sparse expected") };
        assert_eq!(b.idx, want.idx, "index set (incl. the zero-level slot)");
        let want_bits: Vec<u32> = want.val.iter().map(|v| v.to_bits()).collect();
        let got_bits: Vec<u32> = b.val.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn composed_hostile_fields_are_rejected() {
        // Hostile nnz: bail before allocation.
        let mut w = BitWriter::new();
        w.put_gamma(TAG_COMPOSED);
        w.put_gamma(101); // d = 100
        w.put_gamma(16);
        w.put_f32(1.0);
        w.put_gamma(1u64 << 40);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_payload(&mut r, 100).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds dimension"), "{err:#}");
        // Hostile level magnitude: beyond i32 is a descriptive error.
        let mut w = BitWriter::new();
        w.put_gamma(TAG_COMPOSED);
        w.put_gamma(101);
        w.put_gamma(16);
        w.put_f32(1.0);
        w.put_gamma(2); // nnz = 1
        w.put_gamma(1); // index 0
        w.put_bit(false);
        w.put_gamma((1u64 << 40) + 1);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_payload(&mut r, 100).unwrap_err();
        assert!(format!("{err:#}").contains("out of i32 range"), "{err:#}");
        // Hostile level count: s beyond i32 is refused up front.
        let mut w = BitWriter::new();
        w.put_gamma(TAG_COMPOSED);
        w.put_gamma(101);
        w.put_gamma(1u64 << 40);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_payload(&mut r, 100).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn payload_decode_rejects_dimension_mismatch_and_unknown_tag() {
        let mut w = BitWriter::new();
        encode_payload_dense(&[1.0f32; 8], &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_payload(&mut r, 9).unwrap_err();
        assert!(format!("{err:#}").contains("does not match"), "{err:#}");
        let mut w = BitWriter::new();
        w.put_gamma(99);
        let mut r = BitReader::new(w.as_bytes());
        let err = decode_payload(&mut r, 4).unwrap_err();
        assert!(format!("{err:#}").contains("unknown payload tag"), "{err:#}");
    }
}
