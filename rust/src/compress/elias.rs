//! Exact wire encodings: a real bitstream with Elias-γ / Elias-δ integer
//! codes, plus encoders for the two payload families the paper transmits.
//!
//! The paper accounts bits with closed-form *estimates* (Appendix B:
//! `3s(s+√d)+32` for QSGD-with-Elias; `k(32 + log d)` for sparse
//! updates, footnote 5). This module makes the accounting exact: it
//! serializes updates into a byte buffer and reports the measured bit
//! count, so `benches/figure3_qsgd.rs` can cross-check the formulas the
//! figures rely on and the distributed simulator can charge the network
//! model with real message sizes.
//!
//! Wire formats:
//! * **Sparse update** ([`encode_sparse`]): `γ(nnz+1)`, then the sorted
//!   index deltas `γ(Δᵢ+1)` interleaved with raw 32-bit IEEE values.
//! * **QSGD payload** ([`encode_qsgd`]): 32-bit norm, then for each
//!   nonzero level: `γ(index-delta+1)`, sign bit, `γ(level)` — the
//!   encoding of Alistarh et al. §3.2.

use anyhow::{bail, Result};

use super::sparse::SparseVec;

/// Append-only bit buffer (MSB-first within each byte).
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the final byte (0 = byte boundary).
    fill: u32,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    /// Total bits written so far.
    pub fn bits(&self) -> u64 {
        if self.fill == 0 {
            (self.buf.len() as u64) * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.fill as u64
        }
    }

    /// Reset for reuse, keeping the allocation.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.fill = 0;
    }

    /// Finished payload, zero-padded to a byte boundary.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Write one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        if self.fill == 0 {
            self.buf.push(0);
        }
        if bit {
            let last = self.buf.len() - 1;
            self.buf[last] |= 1 << (7 - self.fill);
        }
        self.fill = (self.fill + 1) % 8;
    }

    /// Write the low `n` bits of `v`, most significant first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Elias-γ code of `v ≥ 1`: `⌊log₂ v⌋` zeros, then `v`'s binary form.
    /// Costs `2⌊log₂ v⌋ + 1` bits.
    pub fn put_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1, "elias-gamma is defined for v >= 1");
        let nbits = 64 - v.leading_zeros(); // position of the MSB, >= 1
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(v, nbits);
    }

    /// Elias-δ code of `v ≥ 1`: γ(length) then the mantissa. Shorter than
    /// γ for large `v`; used for the index of the first nonzero in very
    /// high-dimensional sparse payloads.
    pub fn put_delta(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        self.put_gamma(nbits as u64);
        if nbits > 1 {
            // mantissa without the implicit leading 1
            self.put_bits(v & ((1u64 << (nbits - 1)) - 1), nbits - 1);
        }
    }

    /// Raw IEEE-754 single.
    pub fn put_f32(&mut self, v: f32) {
        self.put_bits(v.to_bits() as u64, 32);
    }
}

/// Bit cursor over an encoded payload.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, pos: 0 }
    }

    /// Bits consumed so far.
    pub fn consumed(&self) -> u64 {
        self.pos
    }

    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.buf.len() {
            bail!("bitstream exhausted at bit {}", self.pos);
        }
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    pub fn get_bits(&mut self, n: u32) -> Result<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Ok(v)
    }

    pub fn get_gamma(&mut self) -> Result<u64> {
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros > 63 {
                bail!("malformed gamma code (>63 leading zeros)");
            }
        }
        // We already consumed the leading 1 of the binary form.
        let rest = self.get_bits(zeros)?;
        Ok((1u64 << zeros) | rest)
    }

    pub fn get_delta(&mut self) -> Result<u64> {
        let nbits = self.get_gamma()?;
        if nbits == 0 || nbits > 64 {
            bail!("malformed delta code (length {nbits})");
        }
        if nbits == 1 {
            return Ok(1);
        }
        let mantissa = self.get_bits(nbits as u32 - 1)?;
        Ok((1u64 << (nbits - 1)) | mantissa)
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_bits(32)? as u32))
    }
}

/// Encode a sparse update; returns the exact payload bit count.
/// Indices are sorted and delta-coded (`γ(Δ+1)`), values are raw f32.
pub fn encode_sparse(s: &SparseVec, w: &mut BitWriter) -> u64 {
    let before = w.bits();
    let mut order: Vec<usize> = (0..s.nnz()).collect();
    order.sort_unstable_by_key(|&i| s.idx[i]);
    w.put_gamma(s.nnz() as u64 + 1);
    let mut prev = 0u64;
    for (rank, &j) in order.iter().enumerate() {
        let i = s.idx[j] as u64;
        let delta = if rank == 0 { i } else { i - prev - 1 };
        prev = i;
        w.put_gamma(delta + 1);
        w.put_f32(s.val[j]);
    }
    w.bits() - before
}

/// Decode a sparse update produced by [`encode_sparse`].
pub fn decode_sparse(r: &mut BitReader<'_>, dim: usize) -> Result<SparseVec> {
    let nnz = r.get_gamma()? - 1;
    let mut out = SparseVec::new(dim);
    let mut prev = 0u64;
    for rank in 0..nnz {
        let delta = r.get_gamma()? - 1;
        let i = if rank == 0 { delta } else { prev + 1 + delta };
        prev = i;
        if i as usize >= dim {
            bail!("decoded index {i} out of dimension {dim}");
        }
        let v = r.get_f32()?;
        out.push(i as u32, v);
    }
    Ok(out)
}

/// Encode a QSGD quantization `(‖x‖, sign·level per coordinate)` with the
/// Elias scheme of Alistarh et al. §3.2; returns the exact bit count.
/// Zero levels are skipped via index deltas.
pub fn encode_qsgd(norm: f32, levels: &[i32], w: &mut BitWriter) -> u64 {
    let before = w.bits();
    w.put_f32(norm);
    let nnz = levels.iter().filter(|&&l| l != 0).count();
    w.put_gamma(nnz as u64 + 1);
    let mut prev = 0u64;
    let mut first = true;
    for (i, &l) in levels.iter().enumerate() {
        if l == 0 {
            continue;
        }
        let i = i as u64;
        let delta = if first { i } else { i - prev - 1 };
        first = false;
        prev = i;
        w.put_gamma(delta + 1);
        w.put_bit(l < 0);
        w.put_gamma(l.unsigned_abs() as u64);
    }
    w.bits() - before
}

/// Decode a QSGD payload back into `(norm, levels)`.
pub fn decode_qsgd(r: &mut BitReader<'_>, dim: usize) -> Result<(f32, Vec<i32>)> {
    let norm = r.get_f32()?;
    let nnz = r.get_gamma()? - 1;
    let mut levels = vec![0i32; dim];
    let mut prev = 0u64;
    for rank in 0..nnz {
        let delta = r.get_gamma()? - 1;
        let i = if rank == 0 { delta } else { prev + 1 + delta };
        prev = i;
        if i as usize >= dim {
            bail!("decoded index {i} out of dimension {dim}");
        }
        let neg = r.get_bit()?;
        let mag = r.get_gamma()? as i32;
        levels[i as usize] = if neg { -mag } else { mag };
    }
    Ok((norm, levels))
}

/// Bits of the γ code of `v` (`2⌊log₂ v⌋ + 1`), without encoding.
pub fn gamma_bits(v: u64) -> u64 {
    debug_assert!(v >= 1);
    2 * (63 - v.leading_zeros() as u64) + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn gamma_roundtrip_small_and_large() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 4, 7, 8, 100, 1 << 20, (1 << 40) + 12345];
        for &v in &vals {
            w.put_gamma(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        for &v in &vals {
            assert_eq!(r.get_gamma().unwrap(), v);
        }
    }

    #[test]
    fn delta_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 17, 1000, 1 << 33];
        for &v in &vals {
            w.put_delta(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        for &v in &vals {
            assert_eq!(r.get_delta().unwrap(), v);
        }
    }

    #[test]
    fn gamma_bit_cost_formula() {
        let mut w = BitWriter::new();
        for v in 1..300u64 {
            let before = w.bits();
            w.put_gamma(v);
            assert_eq!(w.bits() - before, gamma_bits(v), "v={v}");
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        let vals = [0.0f32, -0.0, 1.5, -3.25e-12, f32::MAX, f32::MIN_POSITIVE];
        let mut w = BitWriter::new();
        w.put_bit(true); // unaligned on purpose
        for &v in &vals {
            w.put_f32(v);
        }
        let mut r = BitReader::new(w.as_bytes());
        r.get_bit().unwrap();
        for &v in &vals {
            assert_eq!(r.get_f32().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn sparse_roundtrip_random() {
        let mut rng = Prng::new(7);
        for trial in 0..50 {
            let dim = 1 + rng.below(5000);
            let nnz = rng.below(dim.min(64) + 1);
            let mut idx = Vec::new();
            rng.sample_distinct(dim, nnz, &mut idx);
            let mut s = SparseVec::new(dim);
            for &i in &idx {
                s.push(i, rng.normal_f32());
            }
            let mut w = BitWriter::new();
            let bits = encode_sparse(&s, &mut w);
            assert!(bits >= 1);
            let mut r = BitReader::new(w.as_bytes());
            let back = decode_sparse(&mut r, dim).unwrap();
            assert_eq!(r.consumed(), bits, "trial {trial}");
            // Compare as dense (encoder sorts indices).
            assert_eq!(back.to_dense(), s.to_dense(), "trial {trial}");
        }
    }

    #[test]
    fn sparse_empty_and_full() {
        let mut w = BitWriter::new();
        let empty = SparseVec::new(10);
        encode_sparse(&empty, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(decode_sparse(&mut r, 10).unwrap().nnz(), 0);

        let mut full = SparseVec::new(4);
        for i in 0..4 {
            full.push(i, i as f32 + 0.5);
        }
        let mut w = BitWriter::new();
        encode_sparse(&full, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(
            decode_sparse(&mut r, 4).unwrap().to_dense(),
            full.to_dense()
        );
    }

    #[test]
    fn qsgd_roundtrip() {
        let mut rng = Prng::new(9);
        for _ in 0..30 {
            let dim = 1 + rng.below(2000);
            let levels: Vec<i32> = (0..dim)
                .map(|_| {
                    if rng.bernoulli(0.05) {
                        let m = 1 + rng.below(15) as i32;
                        if rng.bernoulli(0.5) {
                            -m
                        } else {
                            m
                        }
                    } else {
                        0
                    }
                })
                .collect();
            let norm = rng.f32() * 10.0;
            let mut w = BitWriter::new();
            let bits = encode_qsgd(norm, &levels, &mut w);
            let mut r = BitReader::new(w.as_bytes());
            let (n2, l2) = decode_qsgd(&mut r, dim).unwrap();
            assert_eq!(r.consumed(), bits);
            assert_eq!(n2.to_bits(), norm.to_bits());
            assert_eq!(l2, levels);
        }
    }

    #[test]
    fn top1_payload_is_tiny() {
        // The paper's headline: top-1 on d=2000 costs ~(32 + log d) bits,
        // three orders of magnitude below the 64'000-bit dense gradient.
        let mut s = SparseVec::new(2000);
        s.push(1234, -0.7);
        let mut w = BitWriter::new();
        let bits = encode_sparse(&s, &mut w);
        assert!(bits < 64, "top-1 payload should be <64 bits, got {bits}");
        assert!((2000 * 32) as u64 / bits > 900);
    }

    #[test]
    fn truncated_stream_errors_cleanly() {
        let mut s = SparseVec::new(100);
        for i in 0..10 {
            s.push(i * 7, 1.0);
        }
        let mut w = BitWriter::new();
        encode_sparse(&s, &mut w);
        let bytes = w.as_bytes();
        let cut = &bytes[..bytes.len() / 2];
        let mut r = BitReader::new(cut);
        assert!(decode_sparse(&mut r, 100).is_err());
    }

    #[test]
    fn writer_reuse_clears_state() {
        let mut w = BitWriter::new();
        w.put_gamma(77);
        w.clear();
        assert_eq!(w.bits(), 0);
        w.put_gamma(5);
        let mut r = BitReader::new(w.as_bytes());
        assert_eq!(r.get_gamma().unwrap(), 5);
    }
}
