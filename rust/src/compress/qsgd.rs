//! QSGD random quantization (Alistarh et al., NIPS 2017) — the paper's
//! Section 4.3 baseline. *Unbiased* stochastic quantization to `s` levels:
//!
//! `Q_s(x)_i = ‖x‖₂ · sgn(x_i) · ξ_i(x, s)`,
//!
//! where `ξ_i = (l+1)/s` with probability `|x_i|/‖x‖·s − l` and `l/s`
//! otherwise, for `l = ⌊|x_i|/‖x‖·s⌋`. `E[Q_s(x)] = x`, so QSGD needs no
//! error memory — that is exactly the contrast the paper draws.
//!
//! Bit accounting follows Appendix B:
//! `min( (log₂ s + 1)·d ,  3s(s + √d) + 32 )` bits per gradient — the
//! first term is the naïve sign+level encoding, the second the Elias
//! estimate of [3, Theorem 3.2]. For sparse datasets the effective
//! dimension can be overridden (`d ≈ 71` for RCV1), again as in Appendix B.

use super::{elias, Compressor, Update};
use crate::util::prng::Prng;
use crate::util::stats;

/// Canonical display suffix for a QSGD level count: `4bit` style for
/// powers of two, exact `s6` style otherwise. `log2().round()` here used
/// to name both `qsgd:6` and `qsgd:8` as `qsgd_3bit`, colliding their
/// metric-record keys. Shared by [`Qsgd::name`],
/// [`super::CompressorSpec::name`], and the method-level mirror in
/// `coordinator::config` so the three sites cannot drift.
pub fn level_suffix(levels: u32) -> String {
    if levels.is_power_of_two() {
        format!("{}bit", levels.trailing_zeros())
    } else {
        format!("s{levels}")
    }
}

/// QSGD quantizer with `levels = s` and optional sparsity-aware effective
/// dimension for the bit accounting.
#[derive(Clone, Debug)]
pub struct Qsgd {
    pub levels: u32,
    pub effective_dim: Option<usize>,
    /// Wire scratch: the signed levels and norm of the last
    /// quantization, kept so [`Compressor::encode_payload`] can frame
    /// the native `(norm, levels)` stream instead of a dense f32 dump.
    /// Empty until the first `compress` (or when `levels` exceeds the
    /// payload's i32 range — then the generic dense codec is used).
    wire_levels: Vec<i32>,
    wire_norm: f32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        Self::with_effective_dim(levels, None)
    }

    pub fn with_effective_dim(levels: u32, effective_dim: Option<usize>) -> Self {
        assert!(levels >= 1, "qsgd requires at least one level");
        Qsgd {
            levels,
            effective_dim,
            wire_levels: Vec::new(),
            wire_norm: 0.0,
        }
    }

    /// Whether `update` is exactly the dequantization of the stored
    /// wire scratch — the mirror of `elias::decode_payload`'s QSGD arm,
    /// so a `true` here guarantees the framed payload decodes back to
    /// `update` bit for bit.
    fn scratch_matches(&self, update: &Update) -> bool {
        let Update::Dense(g) = update else { return false };
        if g.len() != self.wire_levels.len() {
            return false;
        }
        let sf = self.levels as f32;
        g.iter().zip(&self.wire_levels).all(|(&v, &l)| {
            let want = if l == 0 {
                0.0f32
            } else {
                let sgn = if l < 0 { -1.0f32 } else { 1.0 };
                self.wire_norm * sgn * (l.unsigned_abs() as f32 / sf)
            };
            want.to_bits() == v.to_bits()
        })
    }

    /// Number of bits QSGD pays to transmit one `d`-dimensional gradient
    /// (Appendix B formula).
    pub fn bits_for_dim(&self, d: usize) -> u64 {
        let d = self.effective_dim.unwrap_or(d) as f64;
        let s = self.levels as f64;
        let naive = (s.log2() + 1.0) * d;
        let elias = 3.0 * s * (s + d.sqrt()) + 32.0;
        naive.min(elias).ceil() as u64
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd_{}", level_suffix(self.levels))
    }

    /// QSGD is unbiased but not a k-contraction in the sense of
    /// Definition 2.1 for small `s` (its relative variance bound is
    /// `min(d/s², √d/s)`), so it reports `None` and is run without memory.
    fn contraction_k(&self, _d: usize) -> Option<f64> {
        None
    }

    fn compress(&mut self, x: &[f32], rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let g = match out {
            Update::Dense(g) => g,
            other => {
                *other = Update::new_dense(d);
                match other {
                    Update::Dense(g) => g,
                    _ => unreachable!(),
                }
            }
        };
        g.clear();
        g.resize(d, 0.0);
        // Maintain the wire scratch alongside the quantization (skipped
        // when `s` exceeds the payload's i32 level range — the generic
        // dense codec takes over in encode_payload).
        let track_wire = self.levels <= i32::MAX as u32;
        self.wire_levels.clear();
        if track_wire {
            self.wire_levels.resize(d, 0);
        }
        let norm = stats::l2_norm(x) as f32;
        self.wire_norm = norm;
        if norm == 0.0 {
            return self.bits_for_dim(d);
        }
        let s = self.levels as f32;
        for (i, (gi, &xi)) in g.iter_mut().zip(x).enumerate() {
            let u = xi.abs() / norm * s; // in [0, s]
            let l = u.floor();
            let p = u - l;
            let level = l + if rng.bernoulli(p as f64) { 1.0 } else { 0.0 };
            // A zero level is an exact +0.0 on the wire (and here), not
            // a signed zero — what makes the payload round-trip exact.
            if level == 0.0 {
                continue;
            }
            *gi = norm * xi.signum() * (level / s);
            if track_wire {
                let mag = level as i32;
                self.wire_levels[i] = if xi < 0.0 { -mag } else { mag };
            }
        }
        self.bits_for_dim(d)
    }

    /// Frame the native `(norm, signed levels)` stream of Alistarh et
    /// al. §3.2 when `update` is verifiably the last quantization this
    /// operator produced; otherwise fall back to the generic dense
    /// codec (which is always exact).
    fn encode_payload(&self, update: &Update, w: &mut elias::BitWriter) -> u64 {
        if self.scratch_matches(update) {
            elias::encode_payload_qsgd(self.levels, self.wire_norm, &self.wire_levels, w)
        } else {
            elias::encode_payload_update(update, w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quantize(x: &[f32], s: u32, seed: u64) -> Vec<f32> {
        let mut c = Qsgd::new(s);
        let mut rng = Prng::new(seed);
        let mut out = Update::new_dense(x.len());
        c.compress(x, &mut rng, &mut out);
        out.to_dense(x.len())
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        let x = vec![0.3f32, -0.7, 0.05, 0.0, 1.2];
        let trials = 40_000;
        let mut acc = vec![0.0f64; x.len()];
        let mut c = Qsgd::new(4);
        let mut rng = Prng::new(1);
        let mut out = Update::new_dense(x.len());
        for _ in 0..trials {
            c.compress(&x, &mut rng, &mut out);
            if let Update::Dense(g) = &out {
                for (a, &v) in acc.iter_mut().zip(g) {
                    *a += v as f64;
                }
            }
        }
        for (i, (&xi, &ai)) in x.iter().zip(&acc).enumerate() {
            let mean = ai / trials as f64;
            assert!(
                (mean - xi as f64).abs() < 0.02,
                "coord {i}: mean={mean} x={xi}"
            );
        }
    }

    #[test]
    fn levels_are_on_the_grid() {
        let x = vec![0.5f32, -1.0, 0.25, 0.8];
        let s = 8u32;
        let norm = stats::l2_norm(&x) as f32;
        let q = quantize(&x, s, 3);
        for (&qi, &xi) in q.iter().zip(&x) {
            let level = qi.abs() / norm * s as f32;
            assert!(
                (level - level.round()).abs() < 1e-4,
                "qi={qi} level={level}"
            );
            assert!(qi == 0.0 || qi.signum() == xi.signum());
        }
    }

    #[test]
    fn zero_vector_stays_zero() {
        let q = quantize(&[0.0f32; 7], 4, 5);
        assert!(q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bit_formula_appendix_b() {
        // naive: (log2 s + 1) d; elias: 3s(s + sqrt(d)) + 32.
        let q = Qsgd::new(16); // 4-bit
        // d = 2000: naive = 5*2000 = 10000; elias = 48*(16+44.7)+32 ≈ 2947 → elias wins.
        let d = 2000;
        let elias = (3.0 * 16.0 * (16.0 + (d as f64).sqrt()) + 32.0).ceil() as u64;
        assert_eq!(q.bits_for_dim(d), elias);
        // tiny d: naive wins. d=4: naive = 5*4=20; elias = 48*18+32=896.
        assert_eq!(q.bits_for_dim(4), 20);
    }

    #[test]
    fn effective_dim_override() {
        // RCV1 sparsity-aware accounting: d_eff ≈ 71 (Appendix B).
        let q = Qsgd::with_effective_dim(4, Some(71));
        let full = Qsgd::new(4);
        assert!(q.bits_for_dim(47236) < full.bits_for_dim(47236));
        assert_eq!(q.bits_for_dim(47236), q.bits_for_dim(123));
    }

    #[test]
    fn variance_shrinks_with_levels() {
        let mut rng = Prng::new(9);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let var_of = |s: u32| {
            let mut c = Qsgd::new(s);
            let mut r = Prng::new(11);
            let mut out = Update::new_dense(x.len());
            let trials = 3_000;
            let mut acc = 0.0f64;
            for _ in 0..trials {
                c.compress(&x, &mut r, &mut out);
                let g = out.to_dense(x.len());
                let diff: Vec<f32> = g.iter().zip(&x).map(|(a, b)| a - b).collect();
                acc += stats::l2_norm_sq(&diff);
            }
            acc / trials as f64
        };
        let v4 = var_of(4);
        let v64 = var_of(64);
        assert!(v64 < v4 / 4.0, "v4={v4} v64={v64}");
    }

    #[test]
    fn name_encodes_bit_width() {
        assert_eq!(Qsgd::new(4).name(), "qsgd_2bit");
        assert_eq!(Qsgd::new(16).name(), "qsgd_4bit");
        assert_eq!(Qsgd::new(256).name(), "qsgd_8bit");
        // Non-powers of two get exact names instead of colliding with
        // the nearest power (both 6 and 8 used to round to "3bit").
        assert_eq!(Qsgd::new(6).name(), "qsgd_s6");
        assert_ne!(Qsgd::new(6).name(), Qsgd::new(8).name());
        assert_eq!(Qsgd::new(1).name(), "qsgd_0bit");
    }

    #[test]
    fn zero_levels_are_unsigned_zeros() {
        // Negative coordinates quantized to level 0 must come out as
        // exact +0.0 (not -0.0): the wire payload skips zero levels, so
        // a signed zero could never round-trip.
        let mut c = Qsgd::new(2); // coarse: most small coords hit level 0
        let mut rng = Prng::new(13);
        let mut out = Update::new_dense(64);
        let x: Vec<f32> = (0..64).map(|i| if i == 0 { 100.0 } else { -1e-6 }).collect();
        c.compress(&x, &mut rng, &mut out);
        if let Update::Dense(g) = &out {
            assert!(g.iter().filter(|v| v.to_bits() == 0).count() > 32, "zeros expected");
            assert!(g.iter().all(|v| v.to_bits() != (-0.0f32).to_bits()), "-0.0 leaked");
        }
    }

    #[test]
    fn native_payload_roundtrips_the_quantization_bitwise() {
        use crate::compress::elias::{decode_payload, BitReader, BitWriter};
        let mut c = Qsgd::new(16);
        let mut rng = Prng::new(21);
        let mut out = Update::new_dense(200);
        let x: Vec<f32> = (0..200).map(|i| ((i * 7 % 23) as f32 - 11.0) * 0.1).collect();
        c.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        let bits = c.encode_payload(&out, &mut w);
        // The native frame beats a raw dense dump by a wide margin.
        assert!(bits < 32 * 200, "native frame not engaged: {bits} bits");
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 200).unwrap();
        assert_eq!(r.consumed(), bits);
        let want: Vec<u32> = out.to_dense(200).iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = back.to_dense(200).iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        // A foreign update (not this operator's last quantization) must
        // still round-trip — via the generic fallback.
        let foreign = Update::Dense(vec![0.123f32; 200]);
        let mut w = BitWriter::new();
        let bits = c.encode_payload(&foreign, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 200).unwrap();
        assert_eq!(r.consumed(), bits);
        assert_eq!(back.to_dense(200), foreign.to_dense(200));
    }
}
