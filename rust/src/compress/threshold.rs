//! Relative-threshold sparsification — the Aji & Heafield [1] family:
//! keep every coordinate whose magnitude is at least `τ·max_j |x_j|`.
//!
//! Unlike top-k the *cardinality is adaptive*: flat vectors transmit
//! many coordinates, peaked vectors few. It is a k-contraction with
//! guaranteed `k ≥ 1` (the max always survives, and dropping entries
//! below the max removes at most `(1 − 1/d)` of the energy — Lemma A.1's
//! top-1 argument), and typically much more.

use super::{ActiveView, Compressor, Update};
use crate::util::prng::Prng;

/// Keep coordinates with `|x_i| ≥ tau·max|x|`, `tau ∈ (0, 1]`.
#[derive(Clone, Debug)]
pub struct Threshold {
    pub tau: f32,
    /// Active-scan scratch (the pathological cut-underflow branch only).
    sorted: Vec<u32>,
}

impl Threshold {
    pub fn new(tau: f32) -> Self {
        assert!(tau > 0.0 && tau <= 1.0, "tau must be in (0,1], got {tau}");
        Threshold { tau, sorted: Vec::new() }
    }
}

impl Compressor for Threshold {
    fn name(&self) -> String {
        format!("threshold_{}", self.tau)
    }

    /// Guaranteed contraction: at least the argmax coordinate survives,
    /// so the top-1 bound applies pointwise.
    fn contraction_k(&self, _d: usize) -> Option<f64> {
        Some(1.0)
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let sp = out.sparse_mut(d);
        let max = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if max == 0.0 {
            return sp.encoded_bits();
        }
        let cut = self.tau * max;
        for (i, &v) in x.iter().enumerate() {
            if v.abs() >= cut {
                sp.push(i as u32, v);
            }
        }
        sp.encoded_bits()
    }

    fn supports_active_scan(&self) -> bool {
        true
    }

    /// `O(touched)` threshold scan: the max (hence the cut) lives on the
    /// touched set (untouched coordinates are exact zeros and never set
    /// the max beyond the fold's 0.0 floor), and with `cut > 0` every
    /// kept coordinate is nonzero, i.e. touched — so scanning the
    /// touched set alone reproduces the dense emission exactly.
    fn compress_active(
        &mut self,
        v: ActiveView<'_>,
        _rng: &mut Prng,
        out: &mut Update,
    ) -> Option<u64> {
        let d = v.dim();
        let sp = out.sparse_mut(d);
        let mut max = 0.0f32;
        for &j in v.touched {
            max = max.max(v.vals[j as usize].abs());
        }
        if max == 0.0 {
            return Some(sp.encoded_bits());
        }
        let cut = self.tau * max;
        if cut > 0.0 {
            for &j in v.touched {
                let val = v.vals[j as usize];
                if val.abs() >= cut {
                    sp.push(j, val);
                }
            }
            return Some(sp.encoded_bits());
        }
        // τ·max underflowed to zero (subnormal max): `|v_j| ≥ 0` holds at
        // every coordinate, so the dense scan keeps all d of them.
        // Replicate exactly — O(d), unreachable outside adversarial
        // subnormal inputs.
        v.for_each_dense(&mut self.sorted, |j, val| {
            sp.push(j, val);
            true
        });
        Some(sp.encoded_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn compress(x: &[f32], tau: f32) -> (Vec<f32>, usize) {
        let mut c = Threshold::new(tau);
        let mut rng = Prng::new(0);
        let mut out = Update::new_sparse(x.len());
        c.compress(x, &mut rng, &mut out);
        (out.to_dense(x.len()), out.nnz())
    }

    #[test]
    fn keeps_everything_above_cut() {
        let x = vec![1.0f32, -0.5, 0.05, 0.49, -1.0];
        let (dense, nnz) = compress(&x, 0.5);
        assert_eq!(dense, vec![1.0, -0.5, 0.0, 0.0, -1.0]);
        assert_eq!(nnz, 3);
    }

    #[test]
    fn tau_one_keeps_only_maxima() {
        let x = vec![1.0f32, -2.0, 2.0];
        let (dense, nnz) = compress(&x, 1.0);
        assert_eq!(dense, vec![0.0, -2.0, 2.0]);
        assert_eq!(nnz, 2);
    }

    #[test]
    fn adaptivity_flat_vs_peaked() {
        let flat = vec![1.0f32; 64];
        let mut peaked = vec![0.01f32; 64];
        peaked[7] = 10.0;
        assert_eq!(compress(&flat, 0.5).1, 64);
        assert_eq!(compress(&peaked, 0.5).1, 1);
    }

    #[test]
    fn zero_vector_empty() {
        assert_eq!(compress(&[0.0; 9], 0.3).1, 0);
    }

    #[test]
    fn contraction_top1_bound_pointwise() {
        let mut rng = Prng::new(9);
        for _ in 0..100 {
            let d = 1 + rng.below(128);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let (dense, _) = compress(&x, 0.9);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            let bound = (1.0 - 1.0 / d as f64) * stats::l2_norm_sq(&x);
            assert!(stats::l2_norm_sq(&resid) <= bound + 1e-6);
        }
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(
            crate::compress::from_spec("threshold:0.25").unwrap().name(),
            "threshold_0.25"
        );
        assert!(crate::compress::from_spec("threshold").is_err());
    }

    #[test]
    #[should_panic(expected = "tau must be in (0,1]")]
    fn rejects_bad_tau() {
        Threshold::new(0.0);
    }

    fn assert_active_matches_dense(x: &[f32], touched: &[u32], tau: f32, what: &str) {
        use crate::compress::ActiveView;
        let d = x.len();
        let mut rng = crate::util::prng::Prng::new(0);
        let mut dense_c = Threshold::new(tau);
        let mut active_c = Threshold::new(tau);
        let mut dense_out = Update::new_sparse(d);
        let mut active_out = Update::new_sparse(d);
        let bits_dense = dense_c.compress(x, &mut rng, &mut dense_out);
        let bits_active = active_c
            .compress_active(ActiveView { vals: x, touched }, &mut rng, &mut active_out)
            .expect("threshold supports the active scan");
        assert_eq!(bits_dense, bits_active, "{what}: bits");
        assert_eq!(dense_out.nnz(), active_out.nnz(), "{what}: nnz");
        assert_eq!(dense_out.to_dense(d), active_out.to_dense(d), "{what}: values");
    }

    #[test]
    fn active_scan_matches_dense_scan() {
        let mut rng = crate::util::prng::Prng::new(8);
        for trial in 0..200 {
            let d = 4 + rng.below(120);
            let nnz = rng.below(d.min(24));
            let mut x = vec![0.0f32; d];
            let mut touched: Vec<u32> = Vec::new();
            for _ in 0..nnz {
                let j = rng.below(d);
                if x[j] == 0.0 {
                    x[j] = rng.normal_f32();
                    touched.push(j as u32);
                }
            }
            // A touched-but-zero coordinate must not disturb the cut.
            if let Some(j) = (0..d).find(|&j| x[j] == 0.0) {
                touched.push(j as u32);
            }
            rng.shuffle(&mut touched);
            for tau in [0.1f32, 0.5, 0.9, 1.0] {
                assert_active_matches_dense(&x, &touched, tau, &format!("trial={trial} tau={tau}"));
            }
        }
    }

    #[test]
    fn active_scan_handles_all_zero_views() {
        let z = vec![0.0f32; 9];
        assert_active_matches_dense(&z, &[], 0.5, "empty view");
        assert_active_matches_dense(&z, &[3, 7], 0.5, "touched-but-zero view");
    }
}
