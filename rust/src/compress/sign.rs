//! Scaled sign compression — the 1Bit-SGD lineage (Seide et al. [32],
//! Strom [36]) that *introduced* the error-feedback mechanism this paper
//! analyzes. The operator transmits one sign per coordinate plus one
//! scale:
//!
//! `comp(x) = (‖x‖₁ / d) · sign(x)`.
//!
//! It is a k-contraction (Definition 2.1) with a **data-dependent**
//! parameter: `‖x − comp(x)‖² = ‖x‖² − ‖x‖₁²/d`, so property (4) holds
//! with `k = ‖x‖₁² / ‖x‖₂²  ∈ [1, d]`. The guaranteed worst case is
//! `k = 1` (Cauchy–Schwarz gives `‖x‖₁ ≥ ‖x‖₂`); for isotropic Gaussian
//! vectors the typical value is `(2/π)·d ≈ 0.64·d`, i.e. near-identity
//! contraction at 1/32 of the bits.

use super::{elias, Compressor, Update};
use crate::util::prng::Prng;

/// `(‖x‖₁/d)·sign(x)` with 1 bit per coordinate + 32 bits of scale.
#[derive(Clone, Debug, Default)]
pub struct SignSgd;

impl SignSgd {
    pub fn new() -> Self {
        SignSgd
    }
}

impl Compressor for SignSgd {
    fn name(&self) -> String {
        "sign_1bit".into()
    }

    /// The provable worst-case contraction parameter (see module docs);
    /// the stepsize shift derived from it (`a ∝ d/k = d`) is therefore
    /// conservative, exactly like top-1's.
    fn contraction_k(&self, _d: usize) -> Option<f64> {
        Some(1.0)
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let g = match out {
            Update::Dense(g) => g,
            other => {
                *other = Update::new_dense(d);
                match other {
                    Update::Dense(g) => g,
                    _ => unreachable!(),
                }
            }
        };
        g.clear();
        g.resize(d, 0.0);
        let l1: f64 = x.iter().map(|&v| v.abs() as f64).sum();
        let scale = (l1 / d as f64) as f32;
        if scale > 0.0 {
            for (gi, &xi) in g.iter_mut().zip(x) {
                // sign(0) = +1 here; a zero coordinate contributes scale,
                // which the error memory corrects next round.
                *gi = if xi < 0.0 { -scale } else { scale };
            }
        }
        d as u64 + 32
    }

    /// Frame the native scale + sign-bitmask stream — the wire payload
    /// costs exactly the accounted `d + 32` bits plus the frame header.
    /// Verifies `update` really has the `±scale` structure this operator
    /// emits (all entries bitwise `±|g[0]|`, or all bitwise `+0.0`) and
    /// falls back to the generic dense codec otherwise, so the
    /// decode-exactly contract holds for any input.
    fn encode_payload(&self, update: &Update, w: &mut elias::BitWriter) -> u64 {
        let Update::Dense(g) = update else {
            return elias::encode_payload_update(update, w);
        };
        let scale = g.first().map(|v| v.abs()).unwrap_or(0.0);
        let structured = if scale > 0.0 {
            let (p, n) = (scale.to_bits(), (-scale).to_bits());
            g.iter().all(|v| v.to_bits() == p || v.to_bits() == n)
        } else {
            g.iter().all(|v| v.to_bits() == 0)
        };
        if structured {
            elias::encode_payload_sign(g, scale, w)
        } else {
            elias::encode_payload_update(update, w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn compress(x: &[f32]) -> Vec<f32> {
        let mut c = SignSgd::new();
        let mut rng = Prng::new(0);
        let mut out = Update::new_dense(x.len());
        c.compress(x, &mut rng, &mut out);
        out.to_dense(x.len())
    }

    #[test]
    fn magnitude_is_mean_abs() {
        let x = vec![3.0f32, -1.0, 0.0, 2.0];
        let got = compress(&x);
        let scale = 6.0 / 4.0;
        assert_eq!(got, vec![scale, -scale, scale, scale]);
    }

    #[test]
    fn zero_maps_to_zero() {
        assert_eq!(compress(&[0.0; 5]), vec![0.0; 5]);
    }

    #[test]
    fn residual_identity_holds() {
        // ‖x − comp(x)‖² = ‖x‖² − ‖x‖₁²/d, exactly.
        let mut rng = Prng::new(3);
        for _ in 0..50 {
            let d = 1 + rng.below(200);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let c = compress(&x);
            let resid: Vec<f32> = x.iter().zip(&c).map(|(a, b)| a - b).collect();
            let l1: f64 = x.iter().map(|&v| v.abs() as f64).sum();
            let want = stats::l2_norm_sq(&x) - l1 * l1 / d as f64;
            let got = stats::l2_norm_sq(&resid);
            assert!((got - want).abs() < 1e-3 * want.abs().max(1.0), "{got} vs {want}");
        }
    }

    #[test]
    fn contraction_with_guaranteed_k1() {
        // (1 − 1/d)‖x‖² bound must hold for every x.
        let mut rng = Prng::new(5);
        for _ in 0..50 {
            let d = 2 + rng.below(100);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 5.0).collect();
            let c = compress(&x);
            let resid: Vec<f32> = x.iter().zip(&c).map(|(a, b)| a - b).collect();
            let bound = (1.0 - 1.0 / d as f64) * stats::l2_norm_sq(&x);
            assert!(stats::l2_norm_sq(&resid) <= bound + 1e-6);
        }
    }

    #[test]
    fn gaussian_vectors_contract_near_two_over_pi_d() {
        // Typical-case contraction ≈ (2/π)·d for isotropic inputs.
        let mut rng = Prng::new(7);
        let d = 2_000;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let c = compress(&x);
        let resid: Vec<f32> = x.iter().zip(&c).map(|(a, b)| a - b).collect();
        let k_emp = (1.0 - stats::l2_norm_sq(&resid) / stats::l2_norm_sq(&x)) * d as f64;
        let expect = 2.0 / std::f64::consts::PI * d as f64;
        assert!(
            (k_emp - expect).abs() < 0.1 * expect,
            "empirical k {k_emp} vs (2/π)d {expect}"
        );
    }

    #[test]
    fn bit_cost_one_bit_per_coordinate() {
        let mut c = SignSgd::new();
        let mut rng = Prng::new(0);
        let mut out = Update::new_dense(2_000);
        let bits = c.compress(&vec![1.0f32; 2_000], &mut rng, &mut out);
        assert_eq!(bits, 2_000 + 32);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(crate::compress::from_spec("sign").unwrap().name(), "sign_1bit");
    }

    #[test]
    fn native_payload_costs_accounted_bits_plus_header() {
        use crate::compress::elias::{decode_payload, gamma_bits, BitReader, BitWriter, TAG_SIGN};
        let mut c = SignSgd::new();
        let mut rng = Prng::new(0);
        let mut out = Update::new_dense(300);
        let x: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) * 0.01).collect();
        let accounted = c.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        let wire = c.encode_payload(&out, &mut w);
        // Wire = accounted (d + 32) + frame header (tag + γ(d+1)) exactly.
        assert_eq!(wire, accounted + gamma_bits(TAG_SIGN) + gamma_bits(301));
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 300).unwrap();
        assert_eq!(r.consumed(), wire);
        let want: Vec<u32> = out.to_dense(300).iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = back.to_dense(300).iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        // Unstructured dense input falls back to the generic codec but
        // still round-trips exactly.
        let foreign = Update::Dense(vec![1.0f32, 2.0, 3.0]);
        let mut w = BitWriter::new();
        let bits = c.encode_payload(&foreign, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, 3).unwrap();
        assert_eq!(r.consumed(), bits);
        assert_eq!(back.to_dense(3), foreign.to_dense(3));
    }
}
