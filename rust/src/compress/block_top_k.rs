//! Block-wise top-1 sparsification — the layer-local variant used by the
//! deep-learning compression schemes the paper cites ([8, 20]): partition
//! `[d]` into `k` contiguous blocks and keep the largest-magnitude
//! coordinate of *each* block.
//!
//! Why it matters here: it is a k-contraction (per block of size `b`,
//! keeping the max drops at most `(1 − 1/b)` of the block's mass, and the
//! blocks tile the vector), so Theorem 2.4 applies verbatim — but unlike
//! global top-k it needs no selection structure across the full vector,
//! making it O(d) with a single pass and trivially shardable across
//! workers that own disjoint blocks. The ablation bench compares it
//! against global top-k on the heavy-tailed RCV1-like gradients where the
//! two genuinely differ.

use super::{Compressor, Update};
use crate::util::prng::Prng;

/// Keep the max-|·| coordinate of each of `k` contiguous blocks.
#[derive(Clone, Debug)]
pub struct BlockTopK {
    pub k: usize,
}

impl BlockTopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "block_top_k requires k >= 1");
        BlockTopK { k }
    }
}

impl Compressor for BlockTopK {
    fn name(&self) -> String {
        format!("block_top_{}", self.k)
    }

    /// Per block of size `bᵢ`, keeping the max keeps at least `1/bᵢ` of
    /// the block mass; the worst block size is `⌈d/k⌉`, so the operator
    /// is a `d/⌈d/k⌉`-contraction (= `k` when `k | d`).
    fn contraction_k(&self, d: usize) -> Option<f64> {
        if d == 0 {
            return Some(self.k as f64);
        }
        let b = d.div_ceil(self.k.min(d));
        Some(d as f64 / b as f64)
    }

    fn compress(&mut self, x: &[f32], _rng: &mut Prng, out: &mut Update) -> u64 {
        let d = x.len();
        let k = self.k.min(d.max(1));
        let s = out.sparse_mut(d);
        if d == 0 {
            return 0;
        }
        let block = d.div_ceil(k);
        let mut start = 0usize;
        while start < d {
            let end = (start + block).min(d);
            let mut best = start;
            let mut best_mag = x[start].abs();
            for (off, &v) in x[start + 1..end].iter().enumerate() {
                let mag = v.abs();
                if mag > best_mag {
                    best_mag = mag;
                    best = start + 1 + off;
                }
            }
            if x[best] != 0.0 {
                s.push(best as u32, x[best]);
            }
            start = end;
        }
        // Same accounting as top-k/rand-k/threshold (footnote 5): one
        // site for the formula instead of a hand-rolled float log.
        s.encoded_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::top_k::TopK;
    use crate::util::stats;

    fn run(x: &[f32], k: usize) -> Vec<f32> {
        let mut c = BlockTopK::new(k);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(x.len());
        c.compress(x, &mut rng, &mut out);
        out.to_dense(x.len())
    }

    #[test]
    fn one_entry_per_block() {
        let x = vec![1.0f32, -3.0, 0.5, 2.0, -0.1, 0.2, 4.0, -4.5];
        let y = run(&x, 4); // blocks of 2
        assert_eq!(y, vec![0.0, -3.0, 0.0, 2.0, 0.0, 0.2, 0.0, -4.5]);
    }

    #[test]
    fn uneven_blocks_cover_everything() {
        // d=7, k=3 → blocks of size ⌈7/3⌉=3: [0..3), [3..6), [6..7).
        let x = vec![0.0f32, 0.0, 1.0, 0.0, -2.0, 0.0, 3.0];
        let y = run(&x, 3);
        assert_eq!(y, vec![0.0, 0.0, 1.0, 0.0, -2.0, 0.0, 3.0]);
    }

    #[test]
    fn k_one_equals_global_top_one() {
        let mut rng = Prng::new(5);
        let x: Vec<f32> = (0..257).map(|_| rng.normal_f32()).collect();
        let blocked = run(&x, 1);
        let mut t = TopK::new(1);
        let mut out = Update::new_sparse(x.len());
        t.compress(&x, &mut rng, &mut out);
        assert_eq!(blocked, out.to_dense(x.len()));
    }

    #[test]
    fn contraction_property_holds() {
        // ‖x − comp(x)‖² ≤ (1 − k'/d)‖x‖² with k' = contraction_k.
        let mut rng = Prng::new(11);
        for &(d, k) in &[(16usize, 4usize), (100, 7), (2000, 10), (5, 5)] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let y = run(&x, k);
            let resid: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a - b).collect();
            let kk = BlockTopK::new(k).contraction_k(d).unwrap();
            let bound = (1.0 - kk / d as f64) * stats::l2_norm_sq(&x);
            assert!(
                stats::l2_norm_sq(&resid) <= bound + 1e-6,
                "d={d} k={k}: {} > {}",
                stats::l2_norm_sq(&resid),
                bound
            );
        }
    }

    #[test]
    fn zero_vector_sends_nothing() {
        let x = vec![0.0f32; 64];
        let mut c = BlockTopK::new(8);
        let mut rng = Prng::new(1);
        let mut out = Update::new_sparse(64);
        c.compress(&x, &mut rng, &mut out);
        assert_eq!(out.nnz(), 0);
    }

    #[test]
    fn k_larger_than_d_keeps_all_nonzeros() {
        let x = vec![1.0f32, 0.0, -2.0];
        let y = run(&x, 10);
        assert_eq!(y, x);
    }

    #[test]
    fn bit_accounting_matches_encoded_bits() {
        // The compressor must charge exactly SparseVec::encoded_bits —
        // the hand-rolled `log2().ceil()` it replaced agreed at d ≥ 2
        // but overcharged one bit per entry at d = 1.
        for &d in &[1usize, 2, 47_236] {
            let x: Vec<f32> = (0..d).map(|i| (i % 5) as f32 - 2.0).collect();
            let mut c = BlockTopK::new(3);
            let mut rng = Prng::new(7);
            let mut out = Update::new_sparse(d);
            let bits = c.compress(&x, &mut rng, &mut out);
            let Update::Sparse(s) = &out else { panic!("sparse expected") };
            assert_eq!(bits, s.encoded_bits(), "d={d}");
        }
    }
}
