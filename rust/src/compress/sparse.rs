//! Sparse update vectors: the wire format of sparsified SGD — and the
//! kernels of the sparse gradient pipeline.
//!
//! A [`SparseVec`] is a `(index, value)` pair list over a fixed dimension,
//! with all hot-path operations (apply, residual, norms, axpy, the fused
//! local step) allocation-free. Buffers are reused across iterations via
//! [`SparseVec::clear`].
//!
//! [`SparseMerge`] is the coordinate-merge accumulator behind
//! `GradBackend::sample_grad_batch_sparse`: it folds scattered
//! `(coordinate, contribution)` pairs into a [`SparseVec`] with unique
//! indices in `O(contributions)` — first touch appends, later touches
//! add **in arrival order**, which is exactly the floating-point
//! operation order of the dense minibatch accumulation. That invariant
//! is what lets the sparse pipeline reproduce the dense trajectories bit
//! for bit (`tests/sparse_pipeline.rs`).
//!
//! The generation-stamped membership structures of the dimension-free
//! sync path ([`super::active::ActiveIndex`] /
//! [`super::active::ActiveView`]) live in the sibling
//! [`super::active`] module; they play the same role for the error
//! memory and the phase accumulator that [`SparseMerge`] plays for
//! minibatch gradients.

/// A sparse vector: parallel `idx`/`val` arrays over dimension `dim`.
/// Indices are unique but not necessarily sorted (top-k emits them in
/// selection order; sort only when encoding determinism matters).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
    pub dim: usize,
}

impl SparseVec {
    /// Empty sparse vector of dimension `dim`.
    pub fn new(dim: usize) -> Self {
        SparseVec {
            idx: Vec::new(),
            val: Vec::new(),
            dim,
        }
    }

    /// Build from parallel arrays (debug-asserts index bounds).
    pub fn from_parts(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len());
        debug_assert!(idx.iter().all(|&i| (i as usize) < dim));
        SparseVec { idx, val, dim }
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Reset for reuse (keeps capacity, may change dimension).
    #[inline]
    pub fn clear(&mut self, dim: usize) {
        self.idx.clear();
        self.val.clear();
        self.dim = dim;
    }

    /// Append one entry.
    #[inline]
    pub fn push(&mut self, i: u32, v: f32) {
        debug_assert!((i as usize) < self.dim);
        self.idx.push(i);
        self.val.push(v);
    }

    /// `x -= self` (the parameter update of Algorithm 1, line 5).
    #[inline]
    pub fn sub_from(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            x[i as usize] -= v;
        }
    }

    /// `x += self`.
    #[inline]
    pub fn add_to(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            x[i as usize] += v;
        }
    }

    /// Sparse axpy: `x += alpha·self`, touching only stored coordinates.
    #[inline]
    pub fn axpy_to(&self, alpha: f32, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            x[i as usize] += alpha * v;
        }
    }

    /// Fused local-update step: for every stored entry `(j, g)` compute
    /// `step = eta·g`, then `acc[j] += step` and `x[j] -= step` — the
    /// `O(nnz)` inner loop of the sparse local phase. Per touched
    /// coordinate this is the *same floating-point operation order* as
    /// the dense phase loop (`step = η·g; acc += step; x_loc -= step`),
    /// so a sparse gradient with the dense gradient's nonzero values
    /// produces bit-identical `acc`/`x` (`tests/sparse_pipeline.rs`).
    #[inline]
    pub fn local_step(&self, eta: f32, acc: &mut [f32], x: &mut [f32]) {
        debug_assert_eq!(acc.len(), self.dim);
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &g) in self.idx.iter().zip(&self.val) {
            let step = eta * g;
            let j = i as usize;
            acc[j] += step;
            x[j] -= step;
        }
    }

    /// Squared L2 norm.
    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Densify into a fresh vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.add_to(&mut out);
        out
    }

    /// Exact wire size in bits with the paper's footnote-5 encoding: each
    /// entry costs one f32 value (32 bits) plus `ceil(log2 d)` index bits.
    pub fn encoded_bits(&self) -> u64 {
        let index_bits = index_bits(self.dim);
        self.nnz() as u64 * (32 + index_bits)
    }
}

/// Reusable coordinate-merge accumulator: builds a [`SparseVec`] with
/// unique indices from scattered, possibly repeated `(coordinate,
/// contribution)` pairs in `O(contributions)` time.
///
/// The position table is `O(d)` **memory** but is written only at
/// touched slots, reset via the output's index list in
/// [`SparseMerge::finish`], and grown only on first use (or a dimension
/// increase) — after warm-up a merge allocates nothing.
///
/// Usage (the minibatch-gradient pattern):
///
/// ```
/// use memsgd::compress::sparse::{SparseMerge, SparseVec};
/// let mut merge = SparseMerge::new();
/// let mut out = SparseVec::new(8);
/// merge.begin(8, &mut out);
/// merge.add(&mut out, 3, 1.0);
/// merge.add(&mut out, 5, -2.0);
/// merge.add(&mut out, 3, 0.5); // merged: 1.0 + 0.5, in arrival order
/// merge.finish(&out);
/// assert_eq!(out.idx, vec![3, 5]);
/// assert_eq!(out.val, vec![1.5, -2.0]);
/// ```
#[derive(Clone, Debug, Default)]
pub struct SparseMerge {
    /// `pos[j]` = index of coordinate `j` in the output being built, or
    /// `u32::MAX` when untouched by the current merge.
    pos: Vec<u32>,
}

impl SparseMerge {
    pub fn new() -> SparseMerge {
        SparseMerge { pos: Vec::new() }
    }

    /// Start a merge over dimension `d`: clears `out` (keeping its
    /// capacity) and grows the position table if `d` exceeds any
    /// previously seen dimension.
    pub fn begin(&mut self, d: usize, out: &mut SparseVec) {
        if self.pos.len() < d {
            self.pos.resize(d, u32::MAX);
        }
        out.clear(d);
    }

    /// Merge contribution `c` into coordinate `j`: the first touch
    /// appends a new entry, later touches add onto it — additions happen
    /// in arrival order, matching the dense accumulation's FP order.
    #[inline]
    pub fn add(&mut self, out: &mut SparseVec, j: u32, c: f32) {
        let slot = &mut self.pos[j as usize];
        if *slot == u32::MAX {
            *slot = out.idx.len() as u32;
            out.push(j, c);
        } else {
            out.val[*slot as usize] += c;
        }
    }

    /// End the merge: resets the touched position slots (via `out`'s
    /// index list, `O(nnz)`) so the table is clean for the next merge.
    /// Must be called with the same `out` the merge built.
    pub fn finish(&mut self, out: &SparseVec) {
        for &j in &out.idx {
            self.pos[j as usize] = u32::MAX;
        }
    }
}

/// Bits to address one coordinate of a `dim`-dimensional vector.
pub fn index_bits(dim: usize) -> u64 {
    if dim <= 1 {
        0
    } else {
        (usize::BITS - (dim - 1).leading_zeros()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_and_residual() {
        let g = SparseVec::from_parts(5, vec![1, 3], vec![2.0, -1.0]);
        let mut x = vec![10.0f32; 5];
        g.sub_from(&mut x);
        assert_eq!(x, vec![10.0, 8.0, 10.0, 11.0, 10.0]);
        g.add_to(&mut x);
        assert_eq!(x, vec![10.0f32; 5]);
    }

    #[test]
    fn dense_round_trip() {
        let g = SparseVec::from_parts(4, vec![0, 2], vec![1.5, -2.5]);
        assert_eq!(g.to_dense(), vec![1.5, 0.0, -2.5, 0.0]);
        assert_eq!(g.norm_sq(), 1.5f64 * 1.5 + 2.5 * 2.5);
        assert_eq!(g.nnz(), 2);
    }

    #[test]
    fn clear_reuses_buffers() {
        let mut g = SparseVec::from_parts(4, vec![0], vec![1.0]);
        let cap = g.idx.capacity();
        g.clear(8);
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.dim, 8);
        assert!(g.idx.capacity() >= cap);
    }

    #[test]
    fn index_bits_formula() {
        assert_eq!(index_bits(1), 0);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(4), 2);
        assert_eq!(index_bits(2000), 11);
        assert_eq!(index_bits(47236), 16);
    }

    #[test]
    fn axpy_touches_only_stored_coordinates() {
        let g = SparseVec::from_parts(5, vec![1, 3], vec![2.0, -1.0]);
        let mut x = vec![1.0f32; 5];
        g.axpy_to(0.5, &mut x);
        assert_eq!(x, vec![1.0, 2.0, 1.0, 0.5, 1.0]);
    }

    #[test]
    fn local_step_matches_dense_loop_bitwise() {
        // The fused kernel must reproduce the dense phase loop exactly
        // on the touched coordinates and leave the rest alone.
        let d = 6;
        let g_dense = vec![0.0f32, 0.3, 0.0, -1.7, 0.0, 2.2];
        let g = SparseVec::from_parts(d, vec![1, 3, 5], vec![0.3, -1.7, 2.2]);
        let eta = 0.37f32;

        let mut acc_d = vec![0.5f32; d];
        let mut x_d = vec![1.0f32; d];
        for ((a, xl), &gv) in acc_d.iter_mut().zip(x_d.iter_mut()).zip(&g_dense) {
            let step = eta * gv;
            *a += step;
            *xl -= step;
        }

        let mut acc_s = vec![0.5f32; d];
        let mut x_s = vec![1.0f32; d];
        g.local_step(eta, &mut acc_s, &mut x_s);
        assert_eq!(acc_d, acc_s);
        assert_eq!(x_d, x_s);
    }

    #[test]
    fn merge_accumulates_in_arrival_order() {
        let mut merge = SparseMerge::new();
        let mut out = SparseVec::new(10);
        merge.begin(10, &mut out);
        for &(j, c) in &[(7u32, 1.0f32), (2, 2.0), (7, 3.0), (9, -1.0), (2, 0.25)] {
            merge.add(&mut out, j, c);
        }
        merge.finish(&out);
        assert_eq!(out.idx, vec![7, 2, 9]); // first-touch order
        assert_eq!(out.val, vec![4.0, 2.25, -1.0]);
        // The table is clean: a second merge starts fresh.
        merge.begin(10, &mut out);
        merge.add(&mut out, 7, 5.0);
        merge.finish(&out);
        assert_eq!(out.idx, vec![7]);
        assert_eq!(out.val, vec![5.0]);
    }

    #[test]
    fn merge_reuses_buffers_without_allocation_growth() {
        let mut merge = SparseMerge::new();
        let mut out = SparseVec::new(64);
        // Warm-up pass touching the widest pattern.
        merge.begin(64, &mut out);
        for j in 0..32u32 {
            merge.add(&mut out, j * 2, 1.0);
        }
        merge.finish(&out);
        let cap = (out.idx.capacity(), out.val.capacity());
        for round in 0..50u32 {
            merge.begin(64, &mut out);
            for j in 0..32u32 {
                merge.add(&mut out, (j * 2 + round) % 64, 1.0);
            }
            merge.finish(&out);
            assert_eq!((out.idx.capacity(), out.val.capacity()), cap, "round {round}");
        }
    }

    #[test]
    fn merge_handles_dimension_growth() {
        let mut merge = SparseMerge::new();
        let mut out = SparseVec::new(4);
        merge.begin(4, &mut out);
        merge.add(&mut out, 3, 1.0);
        merge.finish(&out);
        merge.begin(16, &mut out);
        merge.add(&mut out, 15, 2.0);
        merge.finish(&out);
        assert_eq!(out.dim, 16);
        assert_eq!(out.idx, vec![15]);
    }

    #[test]
    fn encoded_bits_matches_footnote5() {
        // top_10 on RCV1 (d=47236): 10 * (32 + 16) = 480 bits.
        let mut g = SparseVec::new(47236);
        for i in 0..10 {
            g.push(i, 1.0);
        }
        assert_eq!(g.encoded_bits(), 480);
    }
}
