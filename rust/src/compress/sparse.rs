//! Sparse update vectors: the wire format of sparsified SGD.
//!
//! A [`SparseVec`] is a `(index, value)` pair list over a fixed dimension,
//! with all hot-path operations (apply, residual, norms) allocation-free.
//! Buffers are reused across iterations via [`SparseVec::clear`].

/// A sparse vector: parallel `idx`/`val` arrays over dimension `dim`.
/// Indices are unique but not necessarily sorted (top-k emits them in
/// selection order; sort only when encoding determinism matters).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
    pub dim: usize,
}

impl SparseVec {
    /// Empty sparse vector of dimension `dim`.
    pub fn new(dim: usize) -> Self {
        SparseVec {
            idx: Vec::new(),
            val: Vec::new(),
            dim,
        }
    }

    /// Build from parallel arrays (debug-asserts index bounds).
    pub fn from_parts(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len());
        debug_assert!(idx.iter().all(|&i| (i as usize) < dim));
        SparseVec { idx, val, dim }
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Reset for reuse (keeps capacity, may change dimension).
    #[inline]
    pub fn clear(&mut self, dim: usize) {
        self.idx.clear();
        self.val.clear();
        self.dim = dim;
    }

    /// Append one entry.
    #[inline]
    pub fn push(&mut self, i: u32, v: f32) {
        debug_assert!((i as usize) < self.dim);
        self.idx.push(i);
        self.val.push(v);
    }

    /// `x -= self` (the parameter update of Algorithm 1, line 5).
    #[inline]
    pub fn sub_from(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            x[i as usize] -= v;
        }
    }

    /// `x += self`.
    #[inline]
    pub fn add_to(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            x[i as usize] += v;
        }
    }

    /// Squared L2 norm.
    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Densify into a fresh vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.add_to(&mut out);
        out
    }

    /// Exact wire size in bits with the paper's footnote-5 encoding: each
    /// entry costs one f32 value (32 bits) plus `ceil(log2 d)` index bits.
    pub fn encoded_bits(&self) -> u64 {
        let index_bits = index_bits(self.dim);
        self.nnz() as u64 * (32 + index_bits)
    }
}

/// Bits to address one coordinate of a `dim`-dimensional vector.
pub fn index_bits(dim: usize) -> u64 {
    if dim <= 1 {
        0
    } else {
        (usize::BITS - (dim - 1).leading_zeros()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_and_residual() {
        let g = SparseVec::from_parts(5, vec![1, 3], vec![2.0, -1.0]);
        let mut x = vec![10.0f32; 5];
        g.sub_from(&mut x);
        assert_eq!(x, vec![10.0, 8.0, 10.0, 11.0, 10.0]);
        g.add_to(&mut x);
        assert_eq!(x, vec![10.0f32; 5]);
    }

    #[test]
    fn dense_round_trip() {
        let g = SparseVec::from_parts(4, vec![0, 2], vec![1.5, -2.5]);
        assert_eq!(g.to_dense(), vec![1.5, 0.0, -2.5, 0.0]);
        assert_eq!(g.norm_sq(), 1.5f64 * 1.5 + 2.5 * 2.5);
        assert_eq!(g.nnz(), 2);
    }

    #[test]
    fn clear_reuses_buffers() {
        let mut g = SparseVec::from_parts(4, vec![0], vec![1.0]);
        let cap = g.idx.capacity();
        g.clear(8);
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.dim, 8);
        assert!(g.idx.capacity() >= cap);
    }

    #[test]
    fn index_bits_formula() {
        assert_eq!(index_bits(1), 0);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(4), 2);
        assert_eq!(index_bits(2000), 11);
        assert_eq!(index_bits(47236), 16);
    }

    #[test]
    fn encoded_bits_matches_footnote5() {
        // top_10 on RCV1 (d=47236): 10 * (32 + 16) = 480 bits.
        let mut g = SparseVec::new(47236);
        for i in 0..10 {
            g.push(i, 1.0);
        }
        assert_eq!(g.encoded_bits(), 480);
    }
}
