//! Generation-stamped active-set bookkeeping — the backbone of the
//! dimension-free sync path.
//!
//! On sparse workloads the error memory `m` of the Mem-SGD recursion
//! stays concentrated on the coordinates the gradients actually touch
//! (Alistarh et al., Wangni et al.), so the per-sync `v = m + η·g` build
//! and compressor scan only need to visit `support(m) ∪ support(g)` —
//! every other coordinate of `v` is an exact zero. The structures here
//! make that visit list explicit while keeping **dense value storage**,
//! so the floating-point expressions evaluated at touched coordinates
//! are literally the dense path's expressions (bit-for-bit trajectories,
//! pinned by `tests/sparse_pipeline.rs`):
//!
//! * [`ActiveIndex`] — a membership set over `0..d` with `O(1)` clears
//!   (generation stamps) and an insertion-ordered index list. `O(d)`
//!   memory, written only at touched slots — the same trade
//!   [`super::sparse::SparseMerge`] makes.
//! * [`ActiveView`] — a borrowed (dense values, touched indices) pair:
//!   the read-side contract of [`super::Compressor::compress_active`].
//!   Values are only valid at the listed indices; every unlisted index
//!   represents an exact zero.

/// Membership index over `0..d`: generation-stamped marks plus an
/// insertion-ordered list of the indices inserted since the last clear.
///
/// [`ActiveIndex::clear`] is `O(1)` (a generation bump), so per-phase /
/// per-step resets never pay `O(d)`. The stamp table is `O(d)` memory,
/// grown only on first use or a dimension increase ([`ActiveIndex::grow`]).
#[derive(Clone, Debug)]
pub struct ActiveIndex {
    /// `stamp[j] == gen` ⇔ `j` is currently a member.
    stamp: Vec<u32>,
    /// Current generation; always ≥ 1 once the table exists, so stale
    /// zero-initialized stamps can never read as members.
    gen: u32,
    /// Members in insertion order (unique).
    touched: Vec<u32>,
}

impl ActiveIndex {
    pub fn new() -> ActiveIndex {
        ActiveIndex { stamp: Vec::new(), gen: 1, touched: Vec::new() }
    }

    /// Ensure the stamp table covers dimension `d` (no-op when already
    /// large enough). Must be called before inserting indices `< d`.
    pub fn grow(&mut self, d: usize) {
        if self.stamp.len() < d {
            self.stamp.resize(d, 0);
        }
        if self.gen == 0 {
            self.gen = 1;
        }
    }

    /// Drop all members in `O(1)` (generation bump; the rare wrap-around
    /// pays one `O(d)` stamp reset every `u32::MAX` clears).
    pub fn clear(&mut self) {
        self.touched.clear();
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            self.stamp.fill(0);
            self.gen = 1;
        }
    }

    /// Insert `j`; returns `true` on first insertion since the last
    /// clear, `false` if `j` was already a member.
    #[inline]
    pub fn insert(&mut self, j: u32) -> bool {
        let slot = &mut self.stamp[j as usize];
        if *slot == self.gen {
            false
        } else {
            *slot = self.gen;
            self.touched.push(j);
            true
        }
    }

    /// Whether `j` is currently a member.
    #[inline]
    pub fn contains(&self, j: u32) -> bool {
        self.stamp[j as usize] == self.gen
    }

    /// Members in insertion order (unique indices).
    #[inline]
    pub fn touched(&self) -> &[u32] {
        &self.touched
    }

    /// Number of members.
    #[inline]
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// Test-only: force the generation counter (exercises wrap-around).
    #[cfg(test)]
    fn force_gen(&mut self, gen: u32) {
        self.gen = gen;
    }
}

impl Default for ActiveIndex {
    fn default() -> ActiveIndex {
        ActiveIndex::new()
    }
}

/// A borrowed active-set vector: dense value backing plus the list of
/// live indices.
///
/// Contract: `vals` has the full dimension (`vals.len() == d`); entries
/// are **only meaningful at the indices listed in `touched`** (anything
/// else may be stale scratch), `touched` holds unique indices, and every
/// index *not* listed represents an exact zero of the vector the view
/// describes. [`super::Compressor::compress_active`] consumes this shape.
#[derive(Clone, Copy)]
pub struct ActiveView<'a> {
    pub vals: &'a [f32],
    pub touched: &'a [u32],
}

impl ActiveView<'_> {
    /// Dimension of the viewed vector.
    #[inline]
    pub fn dim(&self) -> usize {
        self.vals.len()
    }

    /// Densify (test helper; allocates). Unlisted indices are zeros.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.vals.len()];
        for &j in self.touched {
            out[j as usize] = self.vals[j as usize];
        }
        out
    }

    /// Walk every coordinate `0..dim` **in ascending index order** —
    /// the dense scan's visit order — calling `visit(j, value)` with the
    /// stored value at touched coordinates and an exact `0.0` elsewhere;
    /// the visitor returns `false` to stop early. `sorted` is reusable
    /// scratch for the sorted touched list (`O(touched·log touched)` +
    /// `O(visited)`).
    ///
    /// This is the one shared implementation of the "replicate the dense
    /// scan over conceptual zeros" fallback that the `compress_active`
    /// impls need when they must emit (or tie-break through)
    /// zero-magnitude coordinates exactly as the dense pass would.
    pub fn for_each_dense<F: FnMut(u32, f32) -> bool>(&self, sorted: &mut Vec<u32>, mut visit: F) {
        sorted.clear();
        sorted.extend_from_slice(self.touched);
        sorted.sort_unstable();
        let mut p = 0usize;
        for j in 0..self.vals.len() as u32 {
            let val = if p < sorted.len() && sorted[p] == j {
                p += 1;
                self.vals[j as usize]
            } else {
                0.0
            };
            if !visit(j, val) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership_and_first_touch_order() {
        let mut idx = ActiveIndex::new();
        idx.grow(10);
        assert!(idx.is_empty());
        assert!(idx.insert(7));
        assert!(idx.insert(2));
        assert!(!idx.insert(7), "second insert reports existing membership");
        assert!(idx.insert(9));
        assert!(idx.contains(7) && idx.contains(2) && idx.contains(9));
        assert!(!idx.contains(0));
        assert_eq!(idx.touched(), &[7, 2, 9]);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn clear_resets_membership_without_touching_all_slots() {
        let mut idx = ActiveIndex::new();
        idx.grow(8);
        idx.insert(3);
        idx.insert(5);
        idx.clear();
        assert!(idx.is_empty());
        assert!(!idx.contains(3));
        assert!(!idx.contains(5));
        assert!(idx.insert(3));
        assert_eq!(idx.touched(), &[3]);
    }

    #[test]
    fn reuse_does_not_grow_allocations() {
        let mut idx = ActiveIndex::new();
        idx.grow(64);
        for j in 0..32u32 {
            idx.insert(j * 2);
        }
        idx.clear();
        let cap = (idx.stamp.capacity(), idx.touched.capacity());
        for round in 0..100u32 {
            for j in 0..32u32 {
                idx.insert((j * 2 + round) % 64);
            }
            idx.clear();
            assert_eq!((idx.stamp.capacity(), idx.touched.capacity()), cap, "round {round}");
        }
    }

    #[test]
    fn grow_extends_dimension() {
        let mut idx = ActiveIndex::new();
        idx.grow(4);
        idx.insert(3);
        idx.grow(16);
        idx.insert(15);
        assert!(idx.contains(3) && idx.contains(15));
        assert_eq!(idx.touched(), &[3, 15]);
    }

    #[test]
    fn generation_wraparound_stays_correct() {
        // A stale stamp from a pre-wrap generation must never read as a
        // member after the wrap resets the table.
        let mut idx = ActiveIndex::new();
        idx.grow(4);
        idx.insert(1); // stamp[1] = 1
        idx.force_gen(u32::MAX);
        idx.insert(2); // stamp[2] = u32::MAX
        idx.clear(); // wraps: stamps reset, gen = 1 again
        assert!(idx.is_empty());
        assert!(!idx.contains(1), "pre-wrap stamp must not alias the new generation");
        assert!(!idx.contains(2));
        assert!(idx.insert(1));
        assert_eq!(idx.touched(), &[1]);
    }

    #[test]
    fn view_densifies_with_exact_zeros_elsewhere() {
        let vals = vec![9.0f32, 1.5, 9.0, -2.5, 9.0]; // 9.0s are stale scratch
        let touched = vec![3u32, 1];
        let view = ActiveView { vals: &vals, touched: &touched };
        assert_eq!(view.dim(), 5);
        assert_eq!(view.to_dense(), vec![0.0, 1.5, 0.0, -2.5, 0.0]);
    }

    #[test]
    fn dense_walk_visits_every_coordinate_in_order() {
        let vals = vec![9.0f32, 1.5, 9.0, -2.5, 9.0];
        let touched = vec![3u32, 1]; // deliberately unsorted
        let view = ActiveView { vals: &vals, touched: &touched };
        let mut sorted = Vec::new();
        let mut seen = Vec::new();
        view.for_each_dense(&mut sorted, |j, val| {
            seen.push((j, val));
            true
        });
        assert_eq!(
            seen,
            vec![(0, 0.0), (1, 1.5), (2, 0.0), (3, -2.5), (4, 0.0)],
            "stale entries read as exact zeros, touched ones as stored"
        );
        // Early stop.
        let mut count = 0;
        view.for_each_dense(&mut sorted, |_, _| {
            count += 1;
            count < 2
        });
        assert_eq!(count, 2);
    }
}
