//! # memsgd — Sparsified SGD with Memory
//!
//! A production-quality reproduction of *"Sparsified SGD with Memory"*
//! (Stich, Cordonnier, Jaggi — NIPS 2018) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   gradient compression (top-k / rand-k / ultra-sparsification / QSGD),
//!   error-feedback memory, worker orchestration, stepsize schedules,
//!   weighted iterate averaging, and communication accounting.
//! * **Layer 2 (python/compile/model.py)** — JAX forward/backward graphs
//!   (logistic regression, small transformer) lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots, verified against pure-jnp oracles, lowered inside the same
//!   HLO artifacts.
//!
//! Python never runs on the training hot path: the Rust binary loads the
//! AOT artifacts through PJRT ([`runtime`]) and drives every experiment in
//! the paper ([`coordinator`], [`sim`], [`grid`]).
//!
//! ## The experiment API
//!
//! Training runs are built with one typed builder, generic over the
//! gradient backend and the coordination fabric:
//!
//! ```no_run
//! use memsgd::coordinator::{Experiment, MethodSpec, Topology};
//! use memsgd::models::LogisticModel;
//! use memsgd::optim::Schedule;
//! # fn main() -> anyhow::Result<()> {
//! let data = memsgd::data::synthetic::epsilon_like(20_000, 2_000, 1);
//! let record = Experiment::new(LogisticModel::new(&data, 1.0 / 20_000.0))
//!     .dataset(&data.name)
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.05))
//!     .topology(Topology::SharedMemory { workers: 8 })
//!     .steps(100_000)
//!     .eval_points(20)
//!     .seed(1)
//!     .run()?;
//! println!("{}: {:.4} after {}", record.method, record.final_loss(), record.steps);
//! # Ok(())
//! # }
//! ```
//!
//! All four topologies (sequential, lock-free shared memory, sync and
//! async parameter server) execute the same
//! [`optim::ErrorFeedbackStep`]; see [`coordinator`] for the topology
//! table and the migration guide from the deprecated string-spec
//! drivers.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`compress`] | k-contraction operators + QSGD baseline + exact Elias wire encodings |
//! | [`optim`] | Mem-SGD (Alg. 1), SGD baselines, stepsizes, averaging, Theorem-2.4 bounds |
//! | [`models`] | logistic loss/gradient backends (native + PJRT) |
//! | [`data`] | dense/CSR datasets, synthetic generators, LIBSVM parser |
//! | [`coordinator`] | `Experiment` builder + generic engines for all four topologies (sequential, shared-memory, sync/async parameter server), checkpoints |
//! | [`runtime`] | PJRT artifact registry: load HLO text, compile, execute |
//! | [`sim`] | discrete-event multicore model (Figure 4) + network cost model (Figure 6) |
//! | [`grid`] | learning-rate grid search (Figure 5) |
//! | [`experiments`] | one driver per paper table/figure + extensions |
//! | [`metrics`] | run records, JSON/CSV emission |
//! | [`util`] | in-tree PRNG / JSON / CLI / bench / property-check |

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod grid;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod util;
