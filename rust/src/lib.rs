//! # memsgd — Sparsified SGD with Memory
//!
//! A production-quality reproduction of *"Sparsified SGD with Memory"*
//! (Stich, Cordonnier, Jaggi — NIPS 2018) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   gradient compression (top-k / rand-k / ultra-sparsification / QSGD),
//!   error-feedback memory, worker orchestration, stepsize schedules,
//!   weighted iterate averaging, and communication accounting.
//! * **Layer 2 (python/compile/model.py)** — JAX forward/backward graphs
//!   (logistic regression, small transformer) lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots, verified against pure-jnp oracles, lowered inside the same
//!   HLO artifacts.
//!
//! Python never runs on the training hot path: the Rust binary loads the
//! AOT artifacts through PJRT ([`runtime`]) and drives every experiment in
//! the paper ([`coordinator`], [`sim`], [`grid`]).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`compress`] | k-contraction operators + QSGD baseline + exact Elias wire encodings |
//! | [`optim`] | Mem-SGD (Alg. 1), SGD baselines, stepsizes, averaging, Theorem-2.4 bounds |
//! | [`models`] | logistic loss/gradient backends (native + PJRT) |
//! | [`data`] | dense/CSR datasets, synthetic generators, LIBSVM parser |
//! | [`coordinator`] | sequential driver, Algorithm 2 shared-memory parallel, sync/async parameter server, checkpoints |
//! | [`runtime`] | PJRT artifact registry: load HLO text, compile, execute |
//! | [`sim`] | discrete-event multicore model (Figure 4) + network cost model (Figure 6) |
//! | [`grid`] | learning-rate grid search (Figure 5) |
//! | [`experiments`] | one driver per paper table/figure + extensions |
//! | [`metrics`] | run records, JSON/CSV emission |
//! | [`util`] | in-tree PRNG / JSON / CLI / bench / property-check |

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod grid;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod util;
