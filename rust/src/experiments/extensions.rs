//! Extension experiments beyond the paper's printed figures:
//!
//! * [`section22`] — the §2.2 motivating story as a measured ablation:
//!   unbiased scaled rand-k suffers the `d/k` variance blow-up; the same
//!   operator with memory does not.
//! * [`memory_trace`] — Lemma 3.2 validated on a live run: measured
//!   `‖m_t‖²` against the `η_t²·(4α/(α−4))·(d/k)²·G²` envelope.
//! * [`figure6_network`] — the figure the paper argues for but never
//!   plots: time-to-accuracy of the distributed methods priced on real
//!   link profiles (1GbE / 10GbE / 100Gb-IB).
//! * [`async_compare`] — synchronous vs asynchronous parameter server
//!   under the same network model (the §1.1 "best of both worlds" claim).
//! * [`bits_vs_loss`] — the composition payoff: `qsgd:16(top_k:k)` and
//!   `adaptive:k` against plain `top_k:k`, bits on the wire until a
//!   shared target loss (the figure-6-style evidence that stacking a
//!   quantizer on the sparsifier buys bits at equal loss).

use anyhow::Result;

use super::{dataset, experiment_on, Which};
use crate::compress::CompressorSpec;
use crate::coordinator::config::{LocalUpdate, MethodSpec};
use crate::coordinator::experiment::Topology;
use crate::metrics::RunRecord;
use crate::models::{GradBackend, LogisticModel};
use crate::optim::theory::TheoryParams;
use crate::optim::{MemSgd, Schedule};
use crate::sim::network::{ComputeModel, NetworkModel};
use crate::util::prng::Prng;
use crate::{compress, util::stats};

// ---------------------------------------------------------------------------
// §2.2 — variance blow-up of unbiased sparsification
// ---------------------------------------------------------------------------

/// Estimator variances measured at `x = 0` plus full convergence runs.
pub struct Section22Result {
    /// `(method, empirical E‖g − ∇f‖²)` at the initial iterate.
    pub variances: Vec<(String, f64)>,
    /// Convergence runs under a shared constant stepsize.
    pub records: Vec<RunRecord>,
    /// The `d/k` factor the section predicts for the unbiased scheme.
    pub predicted_blowup: f64,
}

/// Reproduce §2.2: the unbiased estimator `(d/k)·rand_k(∇f_i)` has
/// variance `≈ (d/k)·G²` (measured), needs `d/k` more iterations, while
/// Mem-SGD with the *same* rand-k operator matches vanilla SGD.
pub fn section22(
    which: Which,
    scale: usize,
    steps: usize,
    seed: u64,
) -> Result<Section22Result> {
    let data = dataset(which, scale, seed);
    let n = data.n();
    let d = data.d();
    let k = which.ks()[0];
    let lam = 1.0 / n as f64;

    // --- (1) Estimator variance at x = 0, Monte-Carlo over samples + operator noise.
    let mut model = LogisticModel::new(&data, lam);
    let x0 = vec![0.0f32; d];
    let mut full = vec![0.0f32; d];
    model.full_grad(&x0, &mut full);
    let trials = 2_000.min(n * 4);
    let mut grad = vec![0.0f32; d];
    let mut rng = Prng::new(seed ^ 0x522);

    let mut var_of = |mode: &str| -> Result<f64> {
        let mut comp = match mode {
            "sgd" => None,
            m => Some(compress::from_spec(m)?),
        };
        let scale_up = match mode {
            "sgd" => 1.0f32,
            _ => (d as f32) / (k as f32),
        };
        let mut out = compress::Update::new_sparse(d);
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let i = rng.below(n);
            model.sample_grad(&x0, i, &mut grad);
            let est: Vec<f32> = match &mut comp {
                None => grad.clone(),
                Some(c) => {
                    c.compress(&grad, &mut rng, &mut out);
                    out.to_dense(d).iter().map(|&v| v * scale_up).collect()
                }
            };
            let diff: Vec<f32> = est.iter().zip(&full).map(|(a, b)| a - b).collect();
            acc += stats::l2_norm_sq(&diff);
        }
        Ok(acc / trials as f64)
    };

    let variances = vec![
        ("sgd (full gradient sample)".to_string(), var_of("sgd")?),
        (
            format!("(d/k)·rand_{k} unbiased"),
            var_of(&format!("rand_k:{k}"))?,
        ),
    ];

    // --- (2) Convergence under one shared schedule: the paper's §4.4
    // constant stepsize. SGD settles at its (small) noise floor; the
    // unbiased scheme's floor is d/k times higher — the §2.2 story.
    let mut records = Vec::new();
    for method in [
        MethodSpec::Sgd,
        MethodSpec::SgdUnbiasedRandK { k }, // (d/k)-scaled, no memory — eq. (6)
        MethodSpec::mem_rand_k(k),          // same operator, with memory
        MethodSpec::mem_top_k(k),
    ] {
        records.push(
            experiment_on(&data, Some(lam))
                .method(method)
                .schedule(Schedule::constant(0.05))
                .steps(steps)
                .eval_points(20)
                .average(false)
                .seed(seed ^ 0x22)
                .run()?,
        );
    }

    Ok(Section22Result {
        variances,
        records,
        predicted_blowup: d as f64 / k as f64,
    })
}

// ---------------------------------------------------------------------------
// Lemma 3.2 — memory-norm envelope on a live run
// ---------------------------------------------------------------------------

/// One point of the memory trace.
#[derive(Clone, Copy, Debug)]
pub struct MemoryPoint {
    pub t: usize,
    /// Measured `‖m_t‖²`.
    pub measured: f64,
    /// Lemma 3.2 bound `η_t²·(4α/(α−4))·(d/k)²·G²` at this `t`.
    pub bound: f64,
}

/// Trace of a run plus the violation summary.
pub struct MemoryTrace {
    pub method: String,
    pub points: Vec<MemoryPoint>,
    /// max over t of measured/bound (Lemma 3.2 holds in expectation; a
    /// single trajectory should still sit well below 1).
    pub max_ratio: f64,
    pub g_sq: f64,
    pub shift: f64,
}

/// Run Mem-SGD with the Theorem-2.4 stepsizes and record `‖m_t‖²`
/// against the Lemma 3.2 envelope. `alpha = 5` per Remark 2.6.
pub fn memory_trace(
    which: Which,
    scale: usize,
    steps: usize,
    spec: &str,
    seed: u64,
) -> Result<MemoryTrace> {
    let data = dataset(which, scale, seed);
    let n = data.n();
    let d = data.d();
    let lam = 1.0 / n as f64;
    let mut model = LogisticModel::new(&data, lam);

    let comp = compress::from_spec(spec)?;
    let k = comp
        .contraction_k(d)
        .ok_or_else(|| anyhow::anyhow!("{spec} is not a contraction"))?;
    let alpha = 5.0;
    let g_sq = model.g_squared_estimate(&vec![0.0f32; d], 512.min(n), seed ^ 0x65);
    let params = TheoryParams {
        d,
        k,
        g_sq,
        mu: lam,
        ell: 0.25 * 4.0 + lam, // L ≤ max_i‖a_i‖²/4 + λ; features are ~unit-norm rows ×4 slack
        x0_dist_sq: 0.0,
        alpha,
    };
    // Paper stepsize η_t = 8/(μ(a+t)) with the Remark-2.5 shift.
    let a = params.remark_shift().max(params.min_shift());
    let mut opt = MemSgd::new(vec![0.0f32; d], comp);
    let mut rng = Prng::new(seed ^ 0x3A2);
    let mut grad = vec![0.0f32; d];

    let eval_every = (steps / 60).max(1);
    let mut points = Vec::new();
    let mut max_ratio = 0.0f64;
    for t in 0..steps {
        let eta = 8.0 / (lam * (a + t as f64));
        let i = rng.below(n);
        model.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, eta, &mut rng);
        if t % eval_every == 0 || t + 1 == steps {
            let measured = opt.memory_norm_sq();
            let bound = params.memory_bound(a, t + 1);
            if bound > 0.0 {
                max_ratio = max_ratio.max(measured / bound);
            }
            points.push(MemoryPoint {
                t: t + 1,
                measured,
                bound,
            });
        }
    }
    Ok(MemoryTrace {
        method: spec.to_string(),
        points,
        max_ratio,
        g_sq,
        shift: a,
    })
}

// ---------------------------------------------------------------------------
// Figure 6 (extension) — time-to-accuracy on real link profiles
// ---------------------------------------------------------------------------

/// One priced cell of the network ablation.
#[derive(Clone, Debug)]
pub struct NetworkCell {
    pub method: String,
    pub network: String,
    /// Rounds until the target loss (None = never reached).
    pub rounds_to_target: Option<usize>,
    /// Simulated seconds until the target loss on this link.
    pub seconds_to_target: Option<f64>,
    /// Fraction of round time spent on the wire.
    pub comm_fraction: f64,
    pub final_loss: f64,
}

pub struct NetworkResult {
    pub target_loss: f64,
    pub workers: usize,
    pub cells: Vec<NetworkCell>,
}

impl NetworkResult {
    pub fn table(&self) -> String {
        let mut out = format!(
            "time-to-loss≤{:.4} with W={} (synchronous PS rounds)\n{:<22} {:>10} {:>12} {:>12} {:>10}\n",
            self.target_loss, self.workers, "method", "network", "rounds", "seconds", "comm%"
        );
        for c in &self.cells {
            out.push_str(&format!(
                "{:<22} {:>10} {:>12} {:>12} {:>9.1}%\n",
                c.method,
                c.network,
                c.rounds_to_target
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "—".into()),
                c.seconds_to_target
                    .map(|s| format!("{s:.3}"))
                    .unwrap_or_else(|| "—".into()),
                100.0 * c.comm_fraction,
            ));
        }
        out
    }
}

/// Price synchronous distributed runs (top-k / QSGD / dense) on the three
/// link presets. Convergence is *measured* (real runs); only time is
/// modeled. The target is the dense baseline's final loss + 2%.
///
/// `local` is the local-update schedule: each round now performs
/// `sync_every` local steps of `batch`-sample minibatches per worker
/// before the compressed exchange, so the same gradient work takes
/// `H`-fold fewer (compute-heavier) rounds — the time-to-accuracy lever
/// the `figure6` CLI exposes as `--batch` / `--local-steps`.
pub fn figure6_network(
    which: Which,
    scale: usize,
    rounds: usize,
    workers: usize,
    local: LocalUpdate,
    seed: u64,
) -> Result<NetworkResult> {
    local.validate()?;
    let data = dataset(which, scale, seed);
    let n = data.n();
    let _ = data.d();
    let k0 = which.ks()[0];
    let h = local.sync_every;
    let eta = Schedule::constant(0.5);
    let comps = vec![
        CompressorSpec::TopK { k: k0 },
        CompressorSpec::Qsgd { levels: 16, eff: None },
        CompressorSpec::Identity,
    ];
    let methods: Vec<String> = comps.iter().map(|c| c.spec_string()).collect();

    // Real convergence runs (one per method, network-independent). The
    // step budget is checked: a validate-passing but huge H must error,
    // not wrap around to an arbitrary budget.
    let steps = rounds
        .checked_mul(workers.max(1))
        .and_then(|v| v.checked_mul(h))
        .ok_or_else(|| {
            anyhow::anyhow!("rounds x workers x sync_every overflows the step budget")
        })?;
    let mut runs = Vec::new();
    for comp in &comps {
        runs.push(
            experiment_on(&data, None)
                .method(MethodSpec::mem(comp.clone()))
                .schedule(eta.clone())
                .topology(Topology::ParamServerSync { nodes: workers })
                .steps(steps)
                .eval_points(40)
                .seed(seed ^ 0xF6)
                .local_update(local)
                .run()?,
        );
    }
    let target = runs
        .last()
        .map(|r| r.final_loss() * 1.02)
        .unwrap_or(f64::NAN);

    // Mean coordinates touched per gradient — prices compute; one round
    // of compute is a full local phase (H steps × B samples).
    let mean_coords = data.nnz() as f64 / n as f64;
    let compute = ComputeModel::new(1e-9, mean_coords.max(1.0));
    let compute_s = compute.phase_s(local.batch, local.sync_every);

    let mut cells = Vec::new();
    for (m, rec) in methods.iter().zip(&runs) {
        // Average per-round message sizes from the exact accounting.
        let up_per_round = rec.extra["upload_bits"] / rounds as f64;
        let down_per_round = rec.extra["broadcast_bits"] / rounds as f64;
        for net in NetworkModel::presets() {
            let round_s = net.round_s(up_per_round as u64, down_per_round as u64, compute_s);
            let comm_s = round_s - compute_s;
            // The ParamServerSync curve's `t` is the server-round index
            // already — no per-worker rescaling.
            let rounds_to = rec.iterations_to(target);
            cells.push(NetworkCell {
                method: format!("dist({m})"),
                network: net.name.clone(),
                rounds_to_target: rounds_to,
                seconds_to_target: rounds_to.map(|r| r as f64 * round_s),
                comm_fraction: comm_s / round_s,
                final_loss: rec.final_loss(),
            });
        }
    }
    Ok(NetworkResult {
        target_loss: target,
        workers,
        cells,
    })
}

// ---------------------------------------------------------------------------
// Composition payoff — bits on the wire until a shared target loss
// ---------------------------------------------------------------------------

/// One method of the bits-vs-loss comparison.
#[derive(Clone, Debug)]
pub struct BitsLossCell {
    pub method: String,
    pub final_loss: f64,
    /// Total accounted bits over the whole run.
    pub total_bits: u64,
    /// Accounted bits until the shared target loss (None = not reached).
    pub bits_to_target: Option<u64>,
    /// Mean accounted bits per communicated update.
    pub bits_per_step: f64,
}

pub struct BitsLossResult {
    pub target_loss: f64,
    pub cells: Vec<BitsLossCell>,
}

impl BitsLossResult {
    pub fn table(&self) -> String {
        let mut out = format!(
            "bits to loss≤{:.4}\n{:<26} {:>12} {:>14} {:>14} {:>12}\n",
            self.target_loss, "method", "final loss", "bits→target", "total bits", "bits/step"
        );
        for c in &self.cells {
            out.push_str(&format!(
                "{:<26} {:>12.5} {:>14} {:>14} {:>12.1}\n",
                c.method,
                c.final_loss,
                c.bits_to_target
                    .map(crate::metrics::fmt_bits)
                    .unwrap_or_else(|| "—".into()),
                crate::metrics::fmt_bits(c.total_bits),
                c.bits_per_step,
            ));
        }
        out
    }
}

/// The composition payoff, measured: run `top_k:k`, `qsgd:16(top_k:k)`,
/// and `adaptive:k` through the same schedule and seed, and price each
/// by accounted bits until the plain sparsifier's final loss + 5% — the
/// figure-6-style evidence that quantizing the kept values (22 vs 48
/// bits per kept coordinate at RCV1 scale) buys wire bits at equal loss.
pub fn bits_vs_loss(
    which: Which,
    scale: usize,
    steps: usize,
    k: usize,
    seed: u64,
) -> Result<BitsLossResult> {
    if k == 0 {
        anyhow::bail!("bits_vs_loss requires k >= 1");
    }
    let data = dataset(which, scale, seed);
    let specs = [
        format!("top_k:{k}"),
        format!("qsgd:16(top_k:{k})"),
        format!("adaptive:{k}"),
    ];
    let mut runs = Vec::new();
    for spec in &specs {
        let comp = CompressorSpec::parse(spec)?;
        runs.push(
            experiment_on(&data, None)
                .method(MethodSpec::mem(comp))
                .schedule(Schedule::constant(0.5))
                .steps(steps)
                .eval_points(40)
                .average(false)
                .seed(seed ^ 0xB1)
                .run()?,
        );
    }
    // The plain sparsifier anchors the target: composition must reach
    // *its* quality band, cheaper. The band is 5% (vs figure 6's 2%):
    // the s=16 quantizer and the 1/p rescaling sit at a slightly
    // higher noise floor by design — that is the trade being measured.
    let target = runs[0].final_loss() * 1.05;
    let cells = runs
        .iter()
        .map(|rec| BitsLossCell {
            method: rec.method.clone(),
            final_loss: rec.final_loss(),
            total_bits: rec.total_bits,
            bits_to_target: rec.bits_to(target),
            bits_per_step: rec.total_bits as f64 / rec.steps.max(1) as f64,
        })
        .collect();
    Ok(BitsLossResult {
        target_loss: target,
        cells,
    })
}

// ---------------------------------------------------------------------------
// Async vs sync parameter server
// ---------------------------------------------------------------------------

/// Sync-vs-async comparison on one network: same total gradient budget,
/// report simulated seconds + staleness.
pub fn async_compare(
    which: Which,
    scale: usize,
    updates: usize,
    workers: usize,
    net: NetworkModel,
    seed: u64,
) -> Result<Vec<RunRecord>> {
    let data = dataset(which, scale, seed);
    let n = data.n();
    let k0 = which.ks()[0];
    let mean_coords = (data.nnz() as f64 / n as f64).max(1.0);
    let compute = ComputeModel::new(1e-9, mean_coords);
    let mut records = Vec::new();
    for comp in [CompressorSpec::TopK { k: k0 }, CompressorSpec::Identity] {
        let rec = experiment_on(&data, None)
            .method(MethodSpec::mem(comp.clone()))
            .schedule(Schedule::constant(0.5))
            .topology(Topology::ParamServerAsync { nodes: workers, net: net.clone() })
            .compute(compute.clone())
            .hetero(0.5)
            .steps(updates)
            .eval_points(20)
            .seed(seed ^ 0xA5)
            .run()?;
        records.push(rec);

        // Synchronous twin with the same budget, priced on the same link.
        let rounds = updates / workers.max(1);
        let mut sync = experiment_on(&data, None)
            .method(MethodSpec::mem(comp))
            .schedule(Schedule::constant(0.5))
            .topology(Topology::ParamServerSync { nodes: workers })
            .steps(rounds * workers.max(1))
            .eval_points(20)
            .seed(seed ^ 0xA5)
            .run()?;
        let up = sync.extra["upload_bits"] / rounds.max(1) as f64;
        let down = sync.extra["broadcast_bits"] / rounds.max(1) as f64;
        // Straggler: synchronous rounds wait for the slowest worker
        // (same ×(1+hetero) spread as the async fleet).
        let mut strag = compute.clone();
        strag.straggler_factor = 1.5;
        let round_s = net.round_s(up as u64, down as u64, strag.round_s(1));
        sync.extra
            .insert("sim_seconds".into(), round_s * rounds as f64);
        sync.method = format!("sync_{}", sync.method);
        records.push(sync);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn section22_variance_blowup_is_near_d_over_k() {
        // Small synthetic instance: d=64, k=1 → predicted 64× blow-up.
        let res = section22(Which::Epsilon, 4_000, 4_000, 3).unwrap();
        let base = res.variances[0].1;
        let blown = res.variances[1].1;
        let ratio = blown / base.max(1e-12);
        // rand-k keeps k coords of d: E‖(d/k)rand_k(g)‖² = (d/k)E‖g‖², so
        // the *excess* variance is ≈ d/k × the gradient's second moment.
        // Accept a generous band (the full-gradient reference subtracts ∇f).
        assert!(
            ratio > res.predicted_blowup / 4.0,
            "ratio {ratio} vs predicted {}",
            res.predicted_blowup
        );
        // And the memory variant must beat the unbiased one at equal budget.
        let unbiased = &res.records[1];
        let with_mem = &res.records[2];
        assert!(
            with_mem.final_loss() < unbiased.final_loss() + 1e-9,
            "mem {} vs unbiased {}",
            with_mem.final_loss(),
            unbiased.final_loss()
        );
    }

    #[test]
    fn memory_trace_respects_lemma32_envelope() {
        let tr = memory_trace(Which::Epsilon, 4_000, 3_000, "top_k:1", 5).unwrap();
        assert!(!tr.points.is_empty());
        assert!(
            tr.max_ratio <= 1.0,
            "measured memory exceeded the Lemma 3.2 bound: ratio {}",
            tr.max_ratio
        );
        // The envelope must not be vacuous either — the trajectory should
        // come within a few orders of magnitude at some point.
        assert!(tr.max_ratio > 1e-8, "bound is vacuous: {}", tr.max_ratio);
    }

    #[test]
    fn network_ablation_orders_methods_on_slow_links() {
        let res =
            figure6_network(Which::Epsilon, 4_000, 600, 4, LocalUpdate::default(), 7).unwrap();
        // On 1GbE, dense must spend a larger comm fraction than top-k.
        let frac = |m: &str, net: &str| {
            res.cells
                .iter()
                .find(|c| c.method.contains(m) && c.network == net)
                .map(|c| c.comm_fraction)
                .unwrap()
        };
        assert!(frac("identity", "1GbE") > frac("top_k", "1GbE"));
        // QSGD sits between.
        assert!(frac("qsgd", "1GbE") > frac("top_k", "1GbE"));
    }

    #[test]
    fn network_ablation_local_steps_shift_time_to_compute() {
        // H = 4 local steps per round: the same per-round message now
        // amortizes 4x the compute, so the dense method's comm fraction
        // on 1GbE must drop relative to H = 1.
        let h1 = figure6_network(Which::Epsilon, 4_000, 300, 4, LocalUpdate::default(), 7)
            .unwrap();
        let h4 =
            figure6_network(Which::Epsilon, 4_000, 300, 4, LocalUpdate::new(1, 4).unwrap(), 7)
                .unwrap();
        let frac = |res: &NetworkResult, m: &str| {
            res.cells
                .iter()
                .find(|c| c.method.contains(m) && c.network == "1GbE")
                .map(|c| c.comm_fraction)
                .unwrap()
        };
        assert!(frac(&h4, "identity") < frac(&h1, "identity"));
        assert!(frac(&h4, "top_k") < frac(&h1, "top_k"));
        // And the schedule is rejected strictly at the driver edge too.
        assert!(figure6_network(
            Which::Epsilon,
            4_000,
            50,
            2,
            LocalUpdate { batch: 0, sync_every: 1 },
            7
        )
        .is_err());
    }

    #[test]
    fn bits_vs_loss_composition_buys_bits_at_equal_loss() {
        let res = bits_vs_loss(Which::Epsilon, 4_000, 4_000, 3, 11).unwrap();
        assert_eq!(res.cells.len(), 3);
        let cell = |m: &str| res.cells.iter().find(|c| c.method.contains(m)).unwrap();
        let plain = cell("top_3");
        let composed = cell("qsgd_4bit(top_3)");
        // The composed operator pays fewer bits per communicated update
        // than the plain sparsifier it wraps...
        assert!(
            composed.bits_per_step < plain.bits_per_step,
            "composed {} >= plain {}",
            composed.bits_per_step,
            plain.bits_per_step
        );
        // ...while reaching the plain operator's target loss band — and
        // doing so within fewer total bits.
        assert!(
            composed.bits_to_target.is_some(),
            "composed never reached the plain target"
        );
        assert!(composed.bits_to_target.unwrap() <= plain.bits_to_target.unwrap());
        // The adaptive operator converges to the same band too.
        assert!(
            cell("adaptive_3").final_loss < res.target_loss * 1.5,
            "adaptive diverged: {} vs {}",
            cell("adaptive_3").final_loss,
            res.target_loss
        );
        // The report renders.
        assert!(res.table().contains("bits/step"));
    }

    #[test]
    fn async_compare_produces_paired_records() {
        let recs = async_compare(
            Which::Epsilon,
            4_000,
            2_000,
            4,
            NetworkModel::eth_1g(),
            9,
        )
        .unwrap();
        assert_eq!(recs.len(), 4);
        // Every record carries a simulated time.
        for r in &recs {
            assert!(r.extra.contains_key("sim_seconds"), "{}", r.method);
        }
        // Sparse async beats dense async in simulated time on 1GbE.
        let t = |m: &str| {
            recs.iter()
                .find(|r| r.method.starts_with("async") && r.method.contains(m))
                .map(|r| r.extra["sim_seconds"])
                .unwrap()
        };
        assert!(t("top_k") < t("identity"));
    }
}
