//! Mem-SGD — Algorithm 1 of the paper.
//!
//! Per iteration, with error memory `m_t` (initialized to 0):
//!
//! ```text
//! g_t     ← comp_k(m_t + η_t ∇f_{i_t}(x_t))     // compressed transmission
//! x_{t+1} ← x_t − g_t
//! m_{t+1} ← m_t + η_t ∇f_{i_t}(x_t) − g_t        // suppressed residual
//! ```
//!
//! Note the stepsize multiplies the gradient **when it enters the
//! memory**, not when coordinates are later retrieved — this detail is
//! load-bearing for the analysis (Section 2.3) and is asserted by the
//! unit tests below.
//!
//! The implementation is allocation-free per step: the combined vector
//! `v = m + ηg` is built in a scratch buffer, the compressor writes into
//! a reusable [`Update`], and the memory update reuses `v` (`m = v − g`).
//!
//! The memory `m` is private (read it through [`MemSgd::memory`], load a
//! checkpoint through [`MemSgd::set_memory`]): the sparse step tracks
//! `support(m)` incrementally for the `O(touched)` active path
//! (`optim::error_feedback`), and an untracked external write would
//! silently corrupt that bookkeeping.

use crate::compress::{ActiveIndex, Compressor, Update};
use crate::util::prng::Prng;
use crate::util::stats;

/// Mem-SGD optimizer state (Algorithm 1).
pub struct MemSgd {
    /// Current iterate `x_t`.
    pub x: Vec<f32>,
    /// Error memory `m_t` (dense storage; support tracked for the
    /// active sparse path).
    m: Vec<f32>,
    /// Scratch: `v = m + η ∇f`. On the active path only the coordinates
    /// built in the last step are meaningful.
    v: Vec<f32>,
    /// Reusable compressed update.
    update: Update,
    compressor: Box<dyn Compressor>,
    /// Active-set bookkeeping for [`MemSgd::step_sparse`].
    m_support: ActiveIndex,
    v_support: ActiveIndex,
    /// Whether `m_support` equals `support(m)` exactly (dense steps and
    /// [`MemSgd::set_memory`] invalidate; the next sparse step rebuilds).
    support_valid: bool,
    /// Cumulative communication cost (bits of every transmitted g_t).
    pub bits_sent: u64,
    /// Iterations taken.
    pub t: usize,
}

impl MemSgd {
    /// Start from `x0` with the given compression operator.
    pub fn new(x0: Vec<f32>, compressor: Box<dyn Compressor>) -> Self {
        let d = x0.len();
        MemSgd {
            x: x0,
            m: vec![0.0; d],
            v: vec![0.0; d],
            update: Update::new_sparse(d),
            compressor,
            m_support: ActiveIndex::new(),
            v_support: ActiveIndex::new(),
            support_valid: true, // m = 0: the empty support is exact
            bits_sent: 0,
            t: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    pub fn compressor_name(&self) -> String {
        self.compressor.name()
    }

    /// Contraction parameter `k` of the configured operator (None for
    /// non-contractions); used to derive the paper's stepsize shift.
    pub fn contraction_k(&self) -> Option<f64> {
        self.compressor.contraction_k(self.x.len())
    }

    /// Current error memory `m_t` (read-only dense view).
    pub fn memory(&self) -> &[f32] {
        &self.m
    }

    /// Overwrite the error memory (checkpoint restore). Panics when the
    /// length differs from the iterate's dimension; invalidates the
    /// incremental support tracking (rebuilt on the next sparse step).
    pub fn set_memory(&mut self, m: &[f32]) {
        self.m.copy_from_slice(m);
        self.support_valid = false;
    }

    /// One Algorithm-1 iteration given the stochastic gradient
    /// `grad = ∇f_{i_t}(x_t)` and stepsize `eta`. Returns the transmitted
    /// update (for communication tracing / the parallel driver).
    ///
    /// The recursion core (lines 4 and 6) is the crate-wide shared
    /// [`error_feedback::apply`](super::error_feedback::apply); this
    /// wrapper only applies the update to the iterate (line 5) and keeps
    /// the counters.
    pub fn step(&mut self, grad: &[f32], eta: f64, rng: &mut Prng) -> &Update {
        debug_assert_eq!(grad.len(), self.x.len());
        // v = m + η ∇f; g = comp_k(v); m ← v − g  (lines 4 and 6).
        self.support_valid = false;
        self.bits_sent += super::error_feedback::apply(
            self.compressor.as_mut(),
            &mut self.m,
            &mut self.v,
            grad,
            eta as f32,
            rng,
            &mut self.update,
        );
        // x ← x − g  (line 5).
        self.update.sub_from(&mut self.x);
        self.t += 1;
        &self.update
    }

    /// [`MemSgd::step`] for a **sparse** stochastic gradient — the same
    /// recursion, bit-identical trajectory, without materializing the
    /// gradient densely. With an active-scan compressor (top-k,
    /// threshold) the whole iteration runs in `O(touched)` over
    /// `support(m) ∪ support(g)` via the shared
    /// [`error_feedback::active_apply_grad`](super::error_feedback) core;
    /// other operators take the `O(d)`
    /// [`error_feedback::apply_sparse`](super::error_feedback::apply_sparse)
    /// fallback.
    pub fn step_sparse(
        &mut self,
        grad: &crate::compress::SparseVec,
        eta: f64,
        rng: &mut Prng,
    ) -> &Update {
        debug_assert_eq!(grad.dim, self.x.len());
        let bits = if self.compressor.supports_active_scan() {
            super::error_feedback::ensure_support_tracking(
                &self.m,
                &mut self.m_support,
                &mut self.v_support,
                &mut self.support_valid,
            );
            super::error_feedback::active_apply_grad(
                self.compressor.as_mut(),
                &mut self.m,
                &mut self.v,
                &mut self.m_support,
                &mut self.v_support,
                grad,
                eta as f32,
                rng,
                &mut self.update,
            )
        } else {
            self.support_valid = false;
            super::error_feedback::apply_sparse(
                self.compressor.as_mut(),
                &mut self.m,
                &mut self.v,
                grad,
                eta as f32,
                rng,
                &mut self.update,
            )
        };
        self.bits_sent += bits;
        self.update.sub_from(&mut self.x);
        self.t += 1;
        &self.update
    }

    /// `‖m_t‖²` — the quantity Lemma 3.2 bounds.
    pub fn memory_norm_sq(&self) -> f64 {
        stats::l2_norm_sq(&self.m)
    }

    /// The perturbed ("virtual") iterate of the proof's eq. (11)–(12):
    /// the point uncompressed SGD *would* be at had nothing been
    /// suppressed. From `m_t = Σ η_j∇f_j − Σ g_j` and `x_t = x₀ − Σ g_j`,
    /// it is `x̃_t = x₀ − Σ η_j∇f_j = x_t − m_t` (the paper's eq. 12 up to
    /// its sign convention for `m`). Exposed for the theory suite.
    pub fn virtual_iterate(&self) -> Vec<f32> {
        self.x.iter().zip(&self.m).map(|(&x, &m)| x - m).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{from_spec, Identity, TopK};
    use crate::util::check::ensure_allclose;

    fn grad_const(d: usize, v: f32) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn identity_compressor_reduces_to_vanilla_sgd() {
        let d = 8;
        let mut opt = MemSgd::new(vec![1.0; d], Box::new(Identity));
        let mut rng = Prng::new(0);
        let g = grad_const(d, 2.0);
        opt.step(&g, 0.1, &mut rng);
        // x = 1 − 0.1·2 = 0.8, memory stays zero.
        ensure_allclose(&opt.x, &vec![0.8; d], 1e-6, 1e-7, "x").unwrap();
        assert!(opt.memory_norm_sq() < 1e-12);
    }

    #[test]
    fn memory_accumulates_suppressed_coordinates() {
        // d=2, top-1, gradient [10, 1]: the small coordinate accumulates
        // in memory until it dominates, then gets flushed.
        let mut opt = MemSgd::new(vec![0.0, 0.0], Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        let g = vec![10.0f32, 1.0];
        opt.step(&g, 1.0, &mut rng);
        // v = [10, 1] → g = [10, 0]; x = [-10, 0]; m = [0, 1].
        assert_eq!(opt.x, vec![-10.0, 0.0]);
        assert_eq!(opt.memory(), &[0.0, 1.0]);
        // Now feed zero gradients: memory [0,1] dominates → coordinate 1
        // is flushed on the next step.
        opt.step(&[0.0, 0.0], 1.0, &mut rng);
        assert_eq!(opt.x, vec![-10.0, -1.0]);
        assert_eq!(opt.memory(), &[0.0, 0.0]);
    }

    #[test]
    fn stepsize_applied_at_memory_entry_not_retrieval() {
        // Gradient enters memory scaled by η_t of *that* step; later
        // retrieval must not rescale by the retrieval step's η.
        let mut opt = MemSgd::new(vec![0.0, 0.0], Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        opt.step(&[10.0, 1.0], 0.5, &mut rng); // m = [0, 0.5]
        assert_eq!(opt.memory(), &[0.0, 0.5]);
        // Retrieval step with a very different η: transmitted coordinate
        // must be exactly 0.5 (the stored value), not 0.5·η'.
        opt.step(&[0.0, 0.0], 100.0, &mut rng);
        assert_eq!(opt.x, vec![-5.0, -0.5]);
    }

    #[test]
    fn conservation_x_minus_m_tracks_virtual_iterate() {
        // Invariant (12): x_t − m_t equals the uncompressed-SGD
        // trajectory x0 − Σ η_j ∇f_j, no matter what the compressor drops.
        let d = 32;
        let mut opt = MemSgd::new(vec![0.5; d], from_spec("top_k:3").unwrap());
        let mut rng = Prng::new(7);
        let mut virt = vec![0.5f32; d];
        let mut g = vec![0.0f32; d];
        for t in 0..200 {
            for (j, gj) in g.iter_mut().enumerate() {
                *gj = ((t * 31 + j * 7) % 13) as f32 / 13.0 - 0.5;
            }
            let eta = 1.0 / (t as f64 + 10.0);
            for (v, &gj) in virt.iter_mut().zip(&g) {
                *v -= (eta as f32) * gj;
            }
            opt.step(&g, eta, &mut rng);
            ensure_allclose(&opt.virtual_iterate(), &virt, 1e-4, 1e-5, "virtual").unwrap();
        }
    }

    #[test]
    fn step_sparse_tracks_step_bit_for_bit() {
        // top_k runs the active path, rand_k the dense fallback — both
        // must replay the dense step exactly.
        for spec in ["top_k:2", "threshold:0.25", "rand_k:2"] {
            let d = 10;
            let mut dense_opt = MemSgd::new(vec![0.2; d], from_spec(spec).unwrap());
            let mut sparse_opt = MemSgd::new(vec![0.2; d], from_spec(spec).unwrap());
            let mut rng_a = Prng::new(2);
            let mut rng_b = Prng::new(2);
            for t in 0..40usize {
                let mut g = vec![0.0f32; d];
                let mut sg = crate::compress::SparseVec::new(d);
                for j in [0usize, 3, 7, 9] {
                    let val = ((t * 13 + j * 5) % 17) as f32 / 17.0 - 0.3;
                    g[j] = val;
                    sg.push(j as u32, val);
                }
                dense_opt.step(&g, 0.05, &mut rng_a);
                sparse_opt.step_sparse(&sg, 0.05, &mut rng_b);
                assert_eq!(dense_opt.x, sparse_opt.x, "{spec} t={t}");
                assert_eq!(dense_opt.memory(), sparse_opt.memory(), "{spec} t={t}");
                assert_eq!(dense_opt.bits_sent, sparse_opt.bits_sent, "{spec} t={t}");
            }
        }
    }

    #[test]
    fn set_memory_reaches_the_sparse_path() {
        // A checkpoint-style memory load must be visible to the next
        // sparse step (the support is rebuilt, not trusted stale).
        let d = 6;
        let mut a = MemSgd::new(vec![0.0; d], from_spec("top_k:1").unwrap());
        let mut b = MemSgd::new(vec![0.0; d], from_spec("top_k:1").unwrap());
        let loaded = vec![0.0f32, 3.0, 0.0, -1.5, 0.0, 0.25];
        a.set_memory(&loaded);
        b.set_memory(&loaded);
        let mut rng_a = Prng::new(4);
        let mut rng_b = Prng::new(4);
        let g = vec![0.0f32, 0.0, 0.5, 0.0, 0.0, 0.0];
        let sg = crate::compress::SparseVec::from_parts(d, vec![2], vec![0.5]);
        a.step(&g, 1.0, &mut rng_a);
        b.step_sparse(&sg, 1.0, &mut rng_b);
        assert_eq!(a.x, b.x);
        assert_eq!(a.memory(), b.memory());
        // The loaded residual (coordinate 1) was the top-1 and flushed.
        assert_eq!(b.x[1], -3.0);
    }

    #[test]
    fn bits_accumulate() {
        let d = 100;
        let mut opt = MemSgd::new(vec![0.0; d], from_spec("top_k:2").unwrap());
        let mut rng = Prng::new(1);
        let g = grad_const(d, 1.0);
        for _ in 0..10 {
            opt.step(&g, 0.1, &mut rng);
        }
        // top-2 on d=100: 2·(32+7) = 78 bits per step.
        assert_eq!(opt.bits_sent, 10 * 78);
        assert_eq!(opt.t, 10);
    }

    #[test]
    fn rand_k_also_maintains_conservation() {
        let d = 16;
        let mut opt = MemSgd::new(vec![0.0; d], from_spec("rand_k:2").unwrap());
        let mut rng = Prng::new(3);
        let g = grad_const(d, 1.0);
        for _ in 0..50 {
            opt.step(&g, 0.01, &mut rng);
        }
        // virtual iterate = −Σ η g = −50·0.01·1 = −0.5 in every coordinate
        let virt = opt.virtual_iterate();
        ensure_allclose(&virt, &vec![-0.5; d], 1e-5, 1e-6, "virtual").unwrap();
    }
}
