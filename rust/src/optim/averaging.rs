//! Quadratically-weighted iterate averaging (Theorem 2.4).
//!
//! The convergence guarantee holds for the weighted average
//! `x̄_T = (1/S_T) Σ w_t x_t` with `w_t = (a + t)²` and
//! `S_T = Σ w_t`. Storing all iterates is impossible at d = 47k and
//! T = 10⁶, so the average is maintained **streaming**:
//!
//! `x̄ ← x̄ · (S_{t}/S_{t+1}) + x_t · (w_t/S_{t+1})`.
//!
//! The accumulator is f64 to keep the long sum well-conditioned.

/// Streaming weighted average with weights `w_t = (a + t)²`.
#[derive(Clone, Debug)]
pub struct WeightedAverage {
    shift: f64,
    acc: Vec<f64>,
    sum_w: f64,
    t: usize,
}

impl WeightedAverage {
    /// New averager over dimension `dim` with shift `a` (Theorem 2.4 uses
    /// the same `a` as the stepsize schedule).
    pub fn new(dim: usize, shift: f64) -> Self {
        assert!(shift >= 1.0, "averaging shift must be >= 1, got {shift}");
        WeightedAverage {
            shift,
            acc: vec![0.0; dim],
            sum_w: 0.0,
            t: 0,
        }
    }

    /// Weight applied to iterate `t`.
    #[inline]
    pub fn weight(&self, t: usize) -> f64 {
        let w = self.shift + t as f64;
        w * w
    }

    /// Fold in the iterate of step `t` (must be called with consecutive
    /// t = 0, 1, 2, ... — asserted in debug builds).
    pub fn update(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.acc.len());
        let w = self.weight(self.t);
        self.sum_w += w;
        let scale_old = 1.0 - w / self.sum_w;
        let scale_new = w / self.sum_w;
        for (a, &xi) in self.acc.iter_mut().zip(x) {
            *a = *a * scale_old + xi as f64 * scale_new;
        }
        self.t += 1;
    }

    /// Number of folded iterates.
    pub fn count(&self) -> usize {
        self.t
    }

    /// Total weight `S_T`.
    pub fn total_weight(&self) -> f64 {
        self.sum_w
    }

    /// Current average as f32 (empty-average returns zeros).
    pub fn average(&self) -> Vec<f32> {
        self.acc.iter().map(|&a| a as f32).collect()
    }

    /// Current average written into `out`.
    pub fn write_average(&self, out: &mut [f32]) {
        for (o, &a) in out.iter_mut().zip(&self.acc) {
            *o = a as f32;
        }
    }

    /// Raw state `(shift, acc, sum_w, t)`, for checkpointing.
    pub fn state(&self) -> (f64, &[f64], f64, usize) {
        (self.shift, &self.acc, self.sum_w, self.t)
    }

    /// Rebuild from a checkpointed state (inverse of [`Self::state`]).
    pub fn from_state(shift: f64, acc: Vec<f64>, sum_w: f64, t: usize) -> Self {
        assert!(shift >= 1.0 && sum_w >= 0.0);
        WeightedAverage {
            shift,
            acc,
            sum_w,
            t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Brute-force reference: store everything, average at the end.
    fn brute(iterates: &[Vec<f32>], shift: f64) -> Vec<f64> {
        let d = iterates[0].len();
        let mut acc = vec![0.0f64; d];
        let mut sum_w = 0.0;
        for (t, x) in iterates.iter().enumerate() {
            let w = (shift + t as f64).powi(2);
            sum_w += w;
            for (a, &xi) in acc.iter_mut().zip(x) {
                *a += w * xi as f64;
            }
        }
        acc.iter().map(|a| a / sum_w).collect()
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Prng::new(4);
        for &shift in &[1.0, 10.0, 2000.0] {
            let d = 17;
            let iterates: Vec<Vec<f32>> = (0..57)
                .map(|_| (0..d).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut avg = WeightedAverage::new(d, shift);
            for x in &iterates {
                avg.update(x);
            }
            let got = avg.average();
            let want = brute(&iterates, shift);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-5, "shift={shift}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn single_iterate_is_identity() {
        let mut avg = WeightedAverage::new(3, 5.0);
        avg.update(&[1.0, -2.0, 3.0]);
        assert_eq!(avg.average(), vec![1.0, -2.0, 3.0]);
        assert_eq!(avg.count(), 1);
    }

    #[test]
    fn recent_iterates_weigh_more() {
        // With quadratic weights, later iterates dominate: average of
        // 0,0,...,0,1 must exceed 1/T.
        let t_total = 100;
        let mut avg = WeightedAverage::new(1, 1.0);
        for t in 0..t_total {
            let v = if t == t_total - 1 { 1.0 } else { 0.0 };
            avg.update(&[v]);
        }
        let a = avg.average()[0];
        assert!(a > 1.0 / t_total as f32 * 2.0, "a={a}");
    }

    #[test]
    fn total_weight_matches_lemma_3_3() {
        // S_T = T/6 (2T² + 6aT − 3T + 6a² − 6a + 1) ≥ T³/3.
        let a = 7.0;
        let t_total = 50usize;
        let mut avg = WeightedAverage::new(1, a);
        for _ in 0..t_total {
            avg.update(&[0.0]);
        }
        let t = t_total as f64;
        let closed = t / 6.0 * (2.0 * t * t + 6.0 * a * t - 3.0 * t + 6.0 * a * a - 6.0 * a + 1.0);
        assert!((avg.total_weight() - closed).abs() / closed < 1e-12);
        assert!(avg.total_weight() >= t * t * t / 3.0);
    }

    #[test]
    fn empty_average_is_zero() {
        let avg = WeightedAverage::new(4, 1.0);
        assert_eq!(avg.average(), vec![0.0; 4]);
        assert_eq!(avg.total_weight(), 0.0);
    }
}
