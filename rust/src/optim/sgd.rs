//! SGD baselines (no error memory).
//!
//! * vanilla SGD — `x ← x − η ∇f_i` (the scikit-learn baseline role).
//! * unbiased rand-k SGD — `x ← x − η (d/k)·rand_k(∇f_i)` (Section 2.2's
//!   motivating example: unbiased but with a d/k variance blow-up).
//! * QSGD — `x ← x − η Q_s(∇f_i)` (Alistarh et al. 2017; the Section 4.3
//!   baseline: unbiased quantization, *no* memory).
//!
//! All variants share one struct: a compressor applied to the *gradient*
//! (not to memory+gradient), an optional unbiasing scale, and the same
//! bit accounting as Mem-SGD so communication plots are comparable.

use crate::compress::{Compressor, Identity, Update};
use crate::util::prng::Prng;

/// SGD with optional (unbiased) gradient compression.
pub struct Sgd {
    /// Current iterate.
    pub x: Vec<f32>,
    compressor: Box<dyn Compressor>,
    /// Multiply the compressed gradient by this factor (e.g. d/k to
    /// unbias rand-k; 1.0 for QSGD which is already unbiased).
    pub scale: f32,
    update: Update,
    scaled: Vec<f32>,
    /// Cumulative transmitted bits.
    pub bits_sent: u64,
    /// Iterations taken.
    pub t: usize,
}

impl Sgd {
    /// Vanilla SGD (dense transmission).
    pub fn vanilla(x0: Vec<f32>) -> Self {
        Self::with_compressor(x0, Box::new(Identity), 1.0)
    }

    /// Unbiased rand-k SGD of Section 2.2: scale = d/k.
    pub fn unbiased_rand_k(x0: Vec<f32>, k: usize) -> Self {
        let d = x0.len();
        let scale = d as f32 / k as f32;
        Self::with_compressor(x0, Box::new(crate::compress::RandK::new(k)), scale)
    }

    /// QSGD baseline with `levels = s` quantization levels.
    pub fn qsgd(x0: Vec<f32>, levels: u32, effective_dim: Option<usize>) -> Self {
        Self::with_compressor(
            x0,
            Box::new(crate::compress::Qsgd::with_effective_dim(levels, effective_dim)),
            1.0,
        )
    }

    pub fn with_compressor(x0: Vec<f32>, compressor: Box<dyn Compressor>, scale: f32) -> Self {
        let d = x0.len();
        Sgd {
            x: x0,
            compressor,
            scale,
            update: Update::new_sparse(d),
            scaled: vec![0.0; d],
            bits_sent: 0,
            t: 0,
        }
    }

    pub fn name(&self) -> String {
        if self.scale != 1.0 {
            format!("sgd_unbiased_{}", self.compressor.name())
        } else {
            format!("sgd_{}", self.compressor.name())
        }
    }

    /// One step: `x ← x − η·scale·comp(∇f)`.
    pub fn step(&mut self, grad: &[f32], eta: f64, rng: &mut Prng) {
        debug_assert_eq!(grad.len(), self.x.len());
        self.bits_sent += self.compressor.compress(grad, rng, &mut self.update);
        let factor = (eta as f32) * self.scale;
        match &self.update {
            Update::Sparse(s) => {
                for (&i, &v) in s.idx.iter().zip(&s.val) {
                    self.x[i as usize] -= factor * v;
                }
            }
            Update::Dense(g) => {
                for (xi, &gi) in self.x.iter_mut().zip(g) {
                    *xi -= factor * gi;
                }
            }
        }
        let _ = &self.scaled; // reserved for future fused paths
        self.t += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::ensure_allclose;

    #[test]
    fn vanilla_step() {
        let mut opt = Sgd::vanilla(vec![1.0; 4]);
        let mut rng = Prng::new(0);
        opt.step(&[2.0, 2.0, 2.0, 2.0], 0.25, &mut rng);
        ensure_allclose(&opt.x, &[0.5; 4], 1e-6, 1e-7, "x").unwrap();
        assert_eq!(opt.bits_sent, 4 * 32);
    }

    #[test]
    fn unbiased_rand_k_is_unbiased_over_many_steps() {
        // With a constant gradient, E[update] = η·∇f per step. Average
        // displacement over many steps must approach the vanilla one.
        let d = 10;
        let steps = 20_000;
        let eta = 1e-3;
        let mut opt = Sgd::unbiased_rand_k(vec![0.0; d], 2);
        let mut rng = Prng::new(5);
        let g: Vec<f32> = (0..d).map(|i| (i as f32) - 4.5).collect();
        for _ in 0..steps {
            opt.step(&g, eta, &mut rng);
        }
        let expected: Vec<f32> = g.iter().map(|&gi| -gi * (eta as f32) * steps as f32).collect();
        for (xi, ei) in opt.x.iter().zip(&expected) {
            assert!(
                (xi - ei).abs() <= 0.05 * ei.abs().max(1.0),
                "{xi} vs {ei}"
            );
        }
    }

    #[test]
    fn qsgd_bits_use_appendix_b_formula() {
        let d = 2000;
        let mut opt = Sgd::qsgd(vec![0.0; d], 16, None);
        let mut rng = Prng::new(1);
        let g = vec![1.0f32; d];
        opt.step(&g, 0.1, &mut rng);
        let per_iter = crate::compress::Qsgd::new(16).bits_for_dim(d);
        assert_eq!(opt.bits_sent, per_iter);
        assert_eq!(opt.name(), "sgd_qsgd_4bit");
    }

    #[test]
    fn names() {
        assert_eq!(Sgd::vanilla(vec![0.0; 2]).name(), "sgd_identity");
        assert_eq!(Sgd::unbiased_rand_k(vec![0.0; 8], 2).name(), "sgd_unbiased_rand_2");
    }
}
