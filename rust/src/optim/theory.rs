//! The paper's convergence bound, as executable mathematics.
//!
//! Implements Theorem 2.4 / eq. (9) with the Remark 2.5 shift policy and
//! the Lemma 3.3 weight sums in closed form, so experiments can overlay
//! *predicted* suboptimality against *measured* (EXPERIMENTS.md does
//! exactly that) and tests can validate the recursions the proof rests
//! on (Lemma A.2, Lemma A.3) numerically.

/// Problem + algorithm constants of Theorem 2.4.
#[derive(Clone, Copy, Debug)]
pub struct TheoryParams {
    /// Dimension.
    pub d: usize,
    /// Contraction parameter of the compressor (`0 < k ≤ d`).
    pub k: f64,
    /// Second-moment bound `G² ≥ E‖∇f_i(x)‖²`.
    pub g_sq: f64,
    /// Strong convexity `μ`.
    pub mu: f64,
    /// Smoothness `L`.
    pub ell: f64,
    /// `‖x₀ − x*‖²`.
    pub x0_dist_sq: f64,
    /// Free parameter `α > 4` (Remark 2.6 uses 5).
    pub alpha: f64,
}

impl TheoryParams {
    /// `ρ = 4α / ((α−4)(α+1)²)` (Theorem 2.4).
    pub fn rho(&self) -> f64 {
        4.0 * self.alpha / ((self.alpha - 4.0) * (self.alpha + 1.0).powi(2))
    }

    /// The smallest admissible shift: `a ≥ ((α+1)·d/k + ρ) / (ρ + 1)`;
    /// Remark 2.5 notes `a = (α+2)·d/k` always suffices.
    pub fn min_shift(&self) -> f64 {
        let dk = self.d as f64 / self.k;
        ((self.alpha + 1.0) * dk + self.rho()) / (self.rho() + 1.0)
    }

    /// Remark 2.5's convenient shift `a = (α+2)·d/k`.
    pub fn remark_shift(&self) -> f64 {
        (self.alpha + 2.0) * self.d as f64 / self.k
    }

    /// `S_T = Σ_{t<T} (a+t)²` in the Lemma 3.3 closed form.
    pub fn weight_sum(a: f64, t: usize) -> f64 {
        let t = t as f64;
        t / 6.0 * (2.0 * t * t + 6.0 * a * t - 3.0 * t + 6.0 * a * a - 6.0 * a + 1.0)
    }

    /// The three terms of eq. (9) at horizon `T` with shift `a`:
    /// (variance term, initial-distance term, memory term), whose sum
    /// upper-bounds `E f(x̄_T) − f*`.
    pub fn bound_terms(&self, a: f64, t: usize) -> (f64, f64, f64) {
        assert!(self.alpha > 4.0, "alpha must exceed 4");
        assert!(a >= self.min_shift() - 1e-9, "shift {a} below admissible minimum");
        let s_t = Self::weight_sum(a, t);
        let tf = t as f64;
        let dk = self.d as f64 / self.k;
        let term1 = 4.0 * tf * (tf + 2.0 * a) / (self.mu * s_t) * self.g_sq;
        let term2 = self.mu * a.powi(3) / (8.0 * s_t) * self.x0_dist_sq;
        let term3 = 64.0 * tf * (1.0 + 2.0 * self.ell / self.mu) / (self.mu * s_t)
            * (4.0 * self.alpha / (self.alpha - 4.0))
            * dk
            * dk
            * self.g_sq;
        (term1, term2, term3)
    }

    /// Total bound of eq. (9).
    pub fn bound(&self, a: f64, t: usize) -> f64 {
        let (t1, t2, t3) = self.bound_terms(a, t);
        t1 + t2 + t3
    }

    /// Horizon after which the SGD-rate term dominates the bound,
    /// computed *numerically* as the first power-of-two `T` where
    /// `term1 > term2 + term3` at the Remark-2.5 shift. (Remark 2.6
    /// quotes `T = Ω((d/k)·√κ)` for the simplified eq.-(10) constants;
    /// the crossover of the full eq.-(9) expression also carries the
    /// `64·20·(1+2κ)` prefactor, so we solve it directly.)
    pub fn transient_horizon(&self) -> f64 {
        let a = self.remark_shift();
        let mut t = 8usize;
        while t < 1 << 60 {
            let (t1, t2, t3) = self.bound_terms(a, t);
            if t1 > t2 + t3 {
                return t as f64;
            }
            t *= 2;
        }
        t as f64
    }

    /// Lemma 3.2's memory bound at stepsize `η_t = 8/(μ(a+t))`:
    /// `E‖m_t‖² ≤ η_t²·(4α/(α−4))·(d/k)²·G²`.
    pub fn memory_bound(&self, a: f64, t: usize) -> f64 {
        let eta = 8.0 / (self.mu * (a + t as f64));
        let dk = self.d as f64 / self.k;
        eta * eta * 4.0 * self.alpha / (self.alpha - 4.0) * dk * dk * self.g_sq
    }
}

/// Numeric check of Lemma A.3: iterate the recursion
/// `h_{t+1} = min((1 − k/2d)h_t + (2d/k)η_t²A, (t+1)Σ_{i≤t}η_i²A)` and
/// confirm `h_t ≤ (4α/(α−4))·η_t²·(d/k)²·A` for all `t < horizon`.
/// Returns the maximum ratio `h_t / bound_t` observed (must be ≤ 1).
pub fn lemma_a3_max_ratio(d: usize, k: f64, alpha: f64, a: f64, horizon: usize) -> f64 {
    let a_const = 1.0f64; // A — scales out
    let dk = d as f64 / k;
    let mut h = 0.0f64;
    let mut eta_sq_sum = 0.0f64;
    let mut max_ratio: f64 = 0.0;
    for t in 0..horizon {
        let eta = 1.0 / (a + t as f64);
        let bound = 4.0 * alpha / (alpha - 4.0) * eta * eta * dk * dk * a_const;
        if t > 0 {
            max_ratio = max_ratio.max(h / bound);
        }
        // advance the recursion
        let opt1 = (1.0 - k / (2.0 * d as f64)) * h + 2.0 * dk * eta * eta * a_const;
        eta_sq_sum += eta * eta;
        let opt2 = (t as f64 + 1.0) * eta_sq_sum * a_const;
        h = opt1.min(opt2);
    }
    max_ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> TheoryParams {
        // Moderate conditioning so the transient horizon is testable
        // (with the paper's λ = 1/n at d/k = 2000 it is astronomically
        // large — which is itself why the experiments set a = d/k rather
        // than chasing the asymptotic regime).
        TheoryParams {
            d: 100,
            k: 10.0,
            g_sq: 1.0,
            mu: 1e-3,
            ell: 1e-2,
            x0_dist_sq: 10.0,
            alpha: 5.0,
        }
    }

    #[test]
    fn remark_shift_is_admissible() {
        let p = params();
        assert!(p.remark_shift() >= p.min_shift());
        // Remark 2.5: ((α+1)d/k + ρ)/(ρ+1) ≤ (α+2)d/k = 7·10.
        assert!((p.remark_shift() - 70.0).abs() < 1e-9);
    }

    #[test]
    fn weight_sum_matches_brute_force_and_cubic_lower_bound() {
        for &(a, t) in &[(1.0, 10usize), (50.0, 100), (2_000.0, 7)] {
            let brute: f64 = (0..t).map(|i| (a + i as f64).powi(2)).sum();
            let closed = TheoryParams::weight_sum(a, t);
            assert!((brute - closed).abs() / brute < 1e-12, "a={a} t={t}");
            assert!(closed >= (t as f64).powi(3) / 3.0);
        }
    }

    #[test]
    fn bound_decreases_in_t_and_sgd_term_dominates_late() {
        let p = params();
        let a = p.remark_shift();
        let horizon = p.transient_horizon() as usize;
        let b1 = p.bound(a, 4 * horizon);
        let b2 = p.bound(a, 16 * horizon);
        assert!(b2 < b1, "bound must shrink: {b1} vs {b2}");
        // Past the transient, term1 (the SGD-rate term) dominates.
        let (t1, t2, t3) = p.bound_terms(a, 16 * horizon);
        assert!(t1 > t2 + t3, "t1={t1} t2={t2} t3={t3}");
    }

    #[test]
    fn bound_scales_inversely_with_t_asymptotically() {
        let p = params();
        let a = p.remark_shift();
        let t0 = 64 * p.transient_horizon() as usize;
        let r = p.bound(a, t0) / p.bound(a, 2 * t0);
        assert!((r - 2.0).abs() < 0.3, "expected ~1/T scaling, ratio {r}");
    }

    #[test]
    fn larger_k_gives_smaller_memory_bound() {
        let mut p = params();
        p.k = 1.0;
        let m1 = p.memory_bound(p.remark_shift(), 100);
        p.k = 10.0;
        let m10 = p.memory_bound(p.remark_shift(), 100);
        assert!(m10 < m1, "m10={m10} m1={m1}");
    }

    #[test]
    fn lemma_a3_recursion_stays_under_bound() {
        for &(d, k, alpha) in &[(100usize, 1.0f64, 5.0f64), (2_000, 1.0, 5.0), (2_000, 10.0, 6.0), (64, 2.0, 4.5)] {
            let p = TheoryParams {
                d,
                k,
                alpha,
                ..params()
            };
            let a = p.remark_shift();
            let ratio = lemma_a3_max_ratio(d, k, alpha, a, 50_000);
            assert!(
                ratio <= 1.0 + 1e-9,
                "Lemma A.3 violated: d={d} k={k} alpha={alpha} ratio={ratio}"
            );
            assert!(ratio > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "below admissible minimum")]
    fn rejects_inadmissible_shift() {
        let p = params();
        p.bound(1.0, 100);
    }
}
