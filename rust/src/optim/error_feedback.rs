//! The error-feedback update — the one place in the crate that
//! implements the Mem-SGD recursion core (Algorithm 1 lines 4/6,
//! Algorithm 2 lines 5/7, and the per-node step of the parameter-server
//! drivers):
//!
//! ```text
//! v ← m + η·∇f        (the memory-augmented transmission candidate)
//! u ← comp(v)          (compressed update, what goes on the wire)
//! m ← v − u            (suppressed residual, carried to the next step)
//! ```
//!
//! Two entry points:
//!
//! * [`apply`] — the raw recursion over caller-owned buffers. Used by
//!   [`crate::optim::MemSgd`] (which owns `x`/`m` publicly for
//!   checkpointing) and by the per-worker [`ErrorFeedbackStep`].
//! * [`ErrorFeedbackStep`] — a self-contained per-worker state bundle
//!   (memory + scratch + compressor + reusable update + bit counter)
//!   that every topology engine instantiates once per worker. It also
//!   covers the **memory-free** baselines (vanilla SGD, QSGD, the §2.2
//!   unbiased rand-k) so the four training topologies can run *any*
//!   [`crate::coordinator::config::MethodSpec`] through one code path.
//!
//! The stepsize multiplies the gradient **when it enters the memory**,
//! not at retrieval — load-bearing for the Section 2.3 analysis and
//! asserted by the Mem-SGD unit tests.
//!
//! ## Local-update scheduling
//!
//! Under a `LocalUpdate { batch, sync_every }` schedule
//! ([`crate::coordinator::config::LocalUpdate`]) a worker takes `H`
//! raw minibatch steps on a local iterate, accumulating `Σ_h η_h·g_h`,
//! and only then calls [`ErrorFeedbackStep::sync`] — one compression
//! and one transmission per `H` local steps, with the error memory `m`
//! staying worker-local throughout. `sync(accum)` is `step(accum, 1.0)`;
//! since multiplying by 1.0 is exact, `H = 1` reproduces the per-sample
//! recursion bit for bit (pinned by `tests/local_update_equivalence.rs`).

use crate::compress::{Compressor, SparseVec, Update};
use crate::util::prng::Prng;

/// One error-feedback step over caller-owned buffers.
///
/// `v` is scratch (rebuilt from scratch here); on return `memory` holds
/// `v − u` and `out` holds the compressed update `u` the caller applies
/// to its iterate (`x ← x − u`). Returns the wire cost of `u` in bits.
///
/// Implementation note (kept from the Mem-SGD hot-path tuning): the
/// `v = m + η·g` pass is its own loop so it auto-vectorizes, and the
/// memory update swaps the `m`/`v` buffers instead of copying, then
/// subtracts the (usually sparse) update in `O(nnz)`.
#[inline]
pub fn apply(
    comp: &mut dyn Compressor,
    memory: &mut Vec<f32>,
    v: &mut Vec<f32>,
    grad: &[f32],
    eta: f32,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), grad.len());
    debug_assert_eq!(v.len(), grad.len());
    for ((vi, &mi), &gi) in v.iter_mut().zip(memory.iter()).zip(grad) {
        *vi = mi + eta * gi;
    }
    let bits = comp.compress(v, rng, out);
    std::mem::swap(memory, v);
    out.sub_from(memory);
    bits
}

/// [`apply`] for a **sparse** gradient: `v` starts as a copy of the
/// memory and only the gradient's stored coordinates are recombined as
/// `v[j] = m[j] + η·g[j]` — the same floating-point expression the dense
/// pass evaluates there, while untouched coordinates carry `m[j]`
/// verbatim (the dense pass computes `m[j] + η·0`, the same value). The
/// gradient's `O(d)` cost disappears; the memory copy and the compressor
/// scan remain `O(d)`, which is why the engines reserve this for the
/// sync step / `H = 1` and keep the intra-phase local steps fully
/// `O(nnz)` (`coordinator::experiment`).
#[inline]
pub fn apply_sparse(
    comp: &mut dyn Compressor,
    memory: &mut Vec<f32>,
    v: &mut Vec<f32>,
    grad: &SparseVec,
    eta: f32,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), grad.dim);
    debug_assert_eq!(v.len(), grad.dim);
    v.copy_from_slice(memory);
    for (&j, &g) in grad.idx.iter().zip(&grad.val) {
        let j = j as usize;
        v[j] = memory[j] + eta * g;
    }
    let bits = comp.compress(v, rng, out);
    std::mem::swap(memory, v);
    out.sub_from(memory);
    bits
}

/// Per-worker error-feedback state: everything one sequential stream,
/// shared-memory worker, or parameter-server node needs to turn a
/// stochastic gradient into a compressed update.
pub struct ErrorFeedbackStep {
    /// Error memory `m` (all zeros for memory-free methods).
    memory: Vec<f32>,
    /// Scratch `v = m + η·g`.
    v: Vec<f32>,
    comp: Box<dyn Compressor>,
    update: Update,
    /// Post-compression scaling of the transmitted values (`d/k` for the
    /// §2.2 unbiased rand-k baseline; 1 otherwise). Only valid without
    /// memory — scaling a remembered residual would double-count it.
    scale: f32,
    use_memory: bool,
    /// Cumulative wire cost of every update produced so far.
    pub bits_sent: u64,
}

impl ErrorFeedbackStep {
    /// Error feedback gated on the operator: contraction operators
    /// (top-k, rand-k, ...) keep a memory; non-contractions (QSGD) run
    /// memory-free exactly as in the paper's §4.3 baseline —
    /// accumulating unbiased quantization noise would amplify it
    /// instead of correcting it.
    pub fn new(d: usize, comp: Box<dyn Compressor>) -> Self {
        let use_memory = comp.contraction_k(d).is_some();
        Self::build(d, comp, 1.0, use_memory)
    }

    /// Memory-free step (vanilla/unbiased baselines): `u = scale·comp(η·g)`.
    pub fn memory_free(d: usize, comp: Box<dyn Compressor>, scale: f32) -> Self {
        Self::build(d, comp, scale, false)
    }

    fn build(d: usize, comp: Box<dyn Compressor>, scale: f32, use_memory: bool) -> Self {
        debug_assert!(scale == 1.0 || !use_memory, "scaling requires memory-free mode");
        ErrorFeedbackStep {
            memory: vec![0.0; d],
            v: vec![0.0; d],
            comp,
            update: Update::new_sparse(d),
            scale,
            use_memory,
            bits_sent: 0,
        }
    }

    /// Produce the next compressed update from `grad` at stepsize `eta`;
    /// afterwards [`ErrorFeedbackStep::update`] holds the update to apply
    /// to the iterate. Returns this step's wire cost in bits.
    pub fn step(&mut self, grad: &[f32], eta: f32, rng: &mut Prng) -> u64 {
        let bits = if self.use_memory {
            apply(
                self.comp.as_mut(),
                &mut self.memory,
                &mut self.v,
                grad,
                eta,
                rng,
                &mut self.update,
            )
        } else {
            debug_assert_eq!(self.v.len(), grad.len());
            for (vi, &gi) in self.v.iter_mut().zip(grad) {
                *vi = eta * gi;
            }
            let bits = self.comp.compress(&self.v, rng, &mut self.update);
            scale_update(&mut self.update, self.scale);
            bits
        };
        self.bits_sent += bits;
        bits
    }

    /// [`ErrorFeedbackStep::step`] for a sparse gradient — identical
    /// trajectory (same FP expression `m + η·g` on the gradient's stored
    /// coordinates, memory copied verbatim elsewhere), but the gradient
    /// never materializes densely. Used by the topology engines whenever
    /// the backend advertises [`crate::models::GradBackend::supports_sparse_grad`].
    pub fn step_sparse(&mut self, grad: &SparseVec, eta: f32, rng: &mut Prng) -> u64 {
        let bits = if self.use_memory {
            apply_sparse(
                self.comp.as_mut(),
                &mut self.memory,
                &mut self.v,
                grad,
                eta,
                rng,
                &mut self.update,
            )
        } else {
            debug_assert_eq!(self.v.len(), grad.dim);
            self.v.iter_mut().for_each(|vi| *vi = 0.0);
            for (&j, &g) in grad.idx.iter().zip(&grad.val) {
                self.v[j as usize] = eta * g;
            }
            let bits = self.comp.compress(&self.v, rng, &mut self.update);
            scale_update(&mut self.update, self.scale);
            bits
        };
        self.bits_sent += bits;
        bits
    }

    /// Local-update sync: compress an **already stepsize-scaled**
    /// accumulator `Σ_h η_h·g_h` of `H` local steps against the
    /// worker-local memory — the communication event of the
    /// `LocalUpdate { batch, sync_every }` schedule.
    ///
    /// The memory `m` never travels and is untouched between syncs; only
    /// this call's compressed aggregate goes on the wire, so a worker
    /// syncing every `H` steps sends `H`-fold fewer updates. Exactly
    /// `step(accum, 1.0, rng)`: multiplying by 1.0 is exact in IEEE-754,
    /// so with `H = 1` (accum = `η·g`) this reproduces `step(g, η, rng)`
    /// bit for bit — pinned by `tests/local_update_equivalence.rs`.
    pub fn sync(&mut self, accum: &[f32], rng: &mut Prng) -> u64 {
        self.step(accum, 1.0, rng)
    }

    /// The update produced by the last [`ErrorFeedbackStep::step`].
    pub fn update(&self) -> &Update {
        &self.update
    }

    /// Current error memory.
    pub fn memory(&self) -> &[f32] {
        &self.memory
    }

    /// Whether this method carries an error memory.
    pub fn uses_memory(&self) -> bool {
        self.use_memory
    }

    /// `‖m‖²` — the quantity Lemma 3.2 bounds.
    pub fn memory_norm_sq(&self) -> f64 {
        crate::util::stats::l2_norm_sq(&self.memory)
    }
}

/// Post-compression unbiasing scale of the memory-free baselines
/// (`d/k` for §2.2 rand-k; a no-op at 1.0).
fn scale_update(update: &mut Update, scale: f32) {
    if scale == 1.0 {
        return;
    }
    match update {
        Update::Sparse(s) => {
            for val in s.val.iter_mut() {
                *val *= scale;
            }
        }
        Update::Dense(g) => {
            for val in g.iter_mut() {
                *val *= scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{from_spec, TopK};

    #[test]
    fn step_matches_manual_recursion() {
        let d = 4;
        let mut ef = ErrorFeedbackStep::new(d, Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        // grad [10, 1, 0, 0] at eta 1: v = [10,1,0,0], u = [10,0,0,0],
        // m = [0,1,0,0].
        ef.step(&[10.0, 1.0, 0.0, 0.0], 1.0, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![10.0, 0.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0, 1.0, 0.0, 0.0]);
        assert!(ef.uses_memory());
        // Zero gradient: the memory flushes.
        ef.step(&[0.0; 4], 1.0, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0; 4]);
        assert!(ef.memory_norm_sq() < 1e-12);
    }

    #[test]
    fn memory_free_scales_the_update() {
        let d = 4;
        // Unbiased rand-k style: scale d/k = 4 applied post-compression.
        let mut ef =
            ErrorFeedbackStep::memory_free(d, Box::new(crate::compress::Identity), 4.0);
        let mut rng = Prng::new(1);
        ef.step(&[1.0, 2.0, 3.0, 4.0], 0.5, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![2.0, 4.0, 6.0, 8.0]);
        assert!(!ef.uses_memory());
        assert_eq!(ef.memory(), &[0.0; 4]);
    }

    #[test]
    fn qsgd_runs_memory_free_by_default() {
        let ef = ErrorFeedbackStep::new(8, from_spec("qsgd:16").unwrap());
        assert!(!ef.uses_memory());
        let ef = ErrorFeedbackStep::new(8, from_spec("top_k:2").unwrap());
        assert!(ef.uses_memory());
    }

    #[test]
    fn sync_of_scaled_accum_is_step_bit_for_bit() {
        // ef.sync(η·g) must equal ef.step(g, η) exactly — the H = 1
        // reduction of the local-update schedule.
        let d = 6;
        let grads = [
            [0.3f32, -2.0, 0.7, 0.0, 1.1, -0.4],
            [1.5f32, 0.2, -0.9, 3.0, -0.1, 0.6],
        ];
        let eta = 0.37f32;
        let mut a = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut b = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut rng_a = Prng::new(9);
        let mut rng_b = Prng::new(9);
        for g in &grads {
            let bits_a = a.step(g, eta, &mut rng_a);
            let accum: Vec<f32> = g.iter().map(|&gi| eta * gi).collect();
            let bits_b = b.sync(&accum, &mut rng_b);
            assert_eq!(bits_a, bits_b);
            assert_eq!(a.update().to_dense(d), b.update().to_dense(d));
            assert_eq!(a.memory(), b.memory());
        }
    }

    #[test]
    fn memory_stays_local_across_syncs() {
        // Two local phases worth of accumulation: the residual carried
        // between syncs is exactly what the compressor suppressed.
        let d = 4;
        let mut ef = ErrorFeedbackStep::new(d, Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        // Phase 1 aggregate [10, 1, 0, 0]: sends the 10, keeps the 1.
        ef.sync(&[10.0, 1.0, 0.0, 0.0], &mut rng);
        assert_eq!(ef.memory(), &[0.0, 1.0, 0.0, 0.0]);
        // Phase 2 aggregate flushes the suppressed coordinate.
        ef.sync(&[0.0; 4], &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0; 4]);
    }

    #[test]
    fn sparse_step_replays_dense_step_bit_for_bit() {
        // Every method kind (memory-carrying, memory-free, memory-free
        // scaled) must produce identical trajectories when the same
        // gradient arrives sparse instead of dense.
        let d = 8;
        let builders: Vec<(&str, fn() -> ErrorFeedbackStep)> = vec![
            ("mem top_k", || ErrorFeedbackStep::new(8, from_spec("top_k:2").unwrap())),
            ("mem rand_k", || ErrorFeedbackStep::new(8, from_spec("rand_k:2").unwrap())),
            ("free qsgd", || ErrorFeedbackStep::new(8, from_spec("qsgd:16").unwrap())),
            ("free scaled", || {
                ErrorFeedbackStep::memory_free(8, Box::new(crate::compress::RandK::new(2)), 4.0)
            }),
        ];
        for (name, build) in builders {
            let mut dense_ef = build();
            let mut sparse_ef = build();
            let mut rng_a = Prng::new(21);
            let mut rng_b = Prng::new(21);
            for t in 0..25usize {
                let mut g = vec![0.0f32; d];
                let mut sg = SparseVec::new(d);
                for j in [1usize, 4, 6] {
                    let val = ((t * 7 + j * 3) % 11) as f32 / 11.0 - 0.4;
                    g[j] = val;
                    sg.push(j as u32, val);
                }
                let bits_a = dense_ef.step(&g, 0.3, &mut rng_a);
                let bits_b = sparse_ef.step_sparse(&sg, 0.3, &mut rng_b);
                assert_eq!(bits_a, bits_b, "{name} t={t}");
                assert_eq!(
                    dense_ef.update().to_dense(d),
                    sparse_ef.update().to_dense(d),
                    "{name} t={t}"
                );
                assert_eq!(dense_ef.memory(), sparse_ef.memory(), "{name} t={t}");
            }
        }
    }

    #[test]
    fn raw_apply_sparse_matches_apply() {
        let d = 5;
        let mut comp_a = TopK::new(1);
        let mut comp_b = TopK::new(1);
        let (mut m_a, mut v_a) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut m_b, mut v_b) = (vec![0.0f32; d], vec![0.0f32; d]);
        let mut out_a = Update::new_sparse(d);
        let mut out_b = Update::new_sparse(d);
        let mut rng = Prng::new(0);
        for t in 0..10 {
            let g = vec![0.0, 1.0 + t as f32, 0.0, -0.5, 0.0];
            let sg = SparseVec::from_parts(d, vec![1, 3], vec![1.0 + t as f32, -0.5]);
            apply(&mut comp_a, &mut m_a, &mut v_a, &g, 0.7, &mut rng, &mut out_a);
            apply_sparse(&mut comp_b, &mut m_b, &mut v_b, &sg, 0.7, &mut rng, &mut out_b);
            assert_eq!(m_a, m_b, "t={t}");
            assert_eq!(out_a.to_dense(d), out_b.to_dense(d), "t={t}");
        }
    }

    #[test]
    fn bits_accumulate_across_steps() {
        let d = 100;
        let mut ef = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut rng = Prng::new(1);
        for _ in 0..10 {
            ef.step(&vec![1.0; d], 0.1, &mut rng);
        }
        // top-2 on d=100: 2·(32+7) = 78 bits per step.
        assert_eq!(ef.bits_sent, 10 * 78);
    }
}
