//! The error-feedback update — the one place in the crate that
//! implements the Mem-SGD recursion core (Algorithm 1 lines 4/6,
//! Algorithm 2 lines 5/7, and the per-node step of the parameter-server
//! drivers):
//!
//! ```text
//! v ← m + η·∇f        (the memory-augmented transmission candidate)
//! u ← comp(v)          (compressed update, what goes on the wire)
//! m ← v − u            (suppressed residual, carried to the next step)
//! ```
//!
//! Two entry points:
//!
//! * [`apply`] — the raw recursion over caller-owned buffers. Used by
//!   [`crate::optim::MemSgd`] (which owns `x` publicly for
//!   checkpointing) and by the per-worker [`ErrorFeedbackStep`].
//! * [`ErrorFeedbackStep`] — a self-contained per-worker state bundle
//!   (memory + scratch + compressor + reusable update + bit counter)
//!   that every topology engine instantiates once per worker. It also
//!   covers the **memory-free** baselines (vanilla SGD, QSGD, the §2.2
//!   unbiased rand-k) so the four training topologies can run *any*
//!   [`crate::coordinator::config::MethodSpec`] through one code path.
//!
//! The stepsize multiplies the gradient **when it enters the memory**,
//! not at retrieval — load-bearing for the Section 2.3 analysis and
//! asserted by the Mem-SGD unit tests.
//!
//! ## Local-update scheduling
//!
//! Under a `LocalUpdate { batch, sync_every }` schedule
//! ([`crate::coordinator::config::LocalUpdate`]) a worker takes `H`
//! raw minibatch steps on a local iterate, accumulating `Σ_h η_h·g_h`,
//! and only then calls [`ErrorFeedbackStep::sync`] — one compression
//! and one transmission per `H` local steps, with the error memory `m`
//! staying worker-local throughout. `sync(accum)` is `step(accum, 1.0)`;
//! since multiplying by 1.0 is exact, `H = 1` reproduces the per-sample
//! recursion bit for bit (pinned by `tests/local_update_equivalence.rs`).
//!
//! ## The active-set (dimension-free) path
//!
//! On sparse workloads the residual `m` stays concentrated on the
//! coordinates the gradients touch, so the whole recursion only ever
//! needs to visit `support(m) ∪ support(g)`. When the compressor
//! advertises [`crate::compress::Compressor::supports_active_scan`]
//! (top-k, threshold), the sparse entry points
//! ([`ErrorFeedbackStep::step_sparse`], [`ErrorFeedbackStep::sync_active`],
//! [`crate::optim::MemSgd::step_sparse`]) run over exactly that set:
//! the memory keeps **dense value storage** (zero outside its tracked
//! support) plus a generation-stamped [`ActiveIndex`], `v = m + η·g` is
//! built only at union coordinates with the dense path's literal FP
//! expressions, the compressor scans the union, and the support is
//! re-derived as the exact nonzero set of the new residual — `O(touched)`
//! per sync, **bit-identical** to the dense route
//! (`tests/sparse_pipeline.rs`). Non-active compressors and dense
//! gradients keep the historical dense route untouched.

use crate::compress::{ActiveIndex, ActiveView, Compressor, SparseVec, Update};
use crate::util::prng::Prng;

/// One error-feedback step over caller-owned buffers.
///
/// `v` is scratch (rebuilt from scratch here); on return `memory` holds
/// `v − u` and `out` holds the compressed update `u` the caller applies
/// to its iterate (`x ← x − u`). Returns the wire cost of `u` in bits.
///
/// Implementation note (kept from the Mem-SGD hot-path tuning): the
/// `v = m + η·g` pass is its own loop so it auto-vectorizes, and the
/// memory update swaps the `m`/`v` buffers instead of copying, then
/// subtracts the (usually sparse) update in `O(nnz)`.
#[inline]
pub fn apply(
    comp: &mut dyn Compressor,
    memory: &mut Vec<f32>,
    v: &mut Vec<f32>,
    grad: &[f32],
    eta: f32,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), grad.len());
    debug_assert_eq!(v.len(), grad.len());
    for ((vi, &mi), &gi) in v.iter_mut().zip(memory.iter()).zip(grad) {
        *vi = mi + eta * gi;
    }
    let bits = comp.compress(v, rng, out);
    std::mem::swap(memory, v);
    out.sub_from(memory);
    bits
}

/// [`apply`] for a **sparse** gradient: `v` starts as a copy of the
/// memory and only the gradient's stored coordinates are recombined as
/// `v[j] = m[j] + η·g[j]` — the same floating-point expression the dense
/// pass evaluates there, while untouched coordinates carry `m[j]`
/// verbatim (the dense pass computes `m[j] + η·0`, the same value). The
/// gradient's `O(d)` cost disappears; the memory copy and the compressor
/// scan remain `O(d)` — this is the fallback for compressors without an
/// active scan, while the crate-internal `active_apply_grad` is the
/// `O(touched)` route.
#[inline]
pub fn apply_sparse(
    comp: &mut dyn Compressor,
    memory: &mut Vec<f32>,
    v: &mut Vec<f32>,
    grad: &SparseVec,
    eta: f32,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), grad.dim);
    debug_assert_eq!(v.len(), grad.dim);
    v.copy_from_slice(memory);
    for (&j, &g) in grad.idx.iter().zip(&grad.val) {
        let j = j as usize;
        v[j] = memory[j] + eta * g;
    }
    let bits = comp.compress(v, rng, out);
    std::mem::swap(memory, v);
    out.sub_from(memory);
    bits
}

/// Rebuild `support` as the exact nonzero set of `memory` (`O(d)`; the
/// re-sync after a dense step invalidated the incremental tracking).
fn rebuild_support(memory: &[f32], support: &mut ActiveIndex) {
    support.grow(memory.len());
    support.clear();
    for (j, &mj) in memory.iter().enumerate() {
        if mj != 0.0 {
            support.insert(j as u32);
        }
    }
}

/// Bring the active-set bookkeeping up to date before an active step:
/// size both stamp tables and, when a dense-entry step (or an external
/// memory load) invalidated the incremental tracking, rebuild
/// `m_support` as `support(memory)` exactly. The one shared
/// implementation of this invariant — [`ErrorFeedbackStep`] and
/// [`crate::optim::MemSgd`] both route through it.
pub(crate) fn ensure_support_tracking(
    memory: &[f32],
    m_support: &mut ActiveIndex,
    v_support: &mut ActiveIndex,
    support_valid: &mut bool,
) {
    v_support.grow(memory.len());
    if *support_valid {
        m_support.grow(memory.len());
    } else {
        rebuild_support(memory, m_support);
        *support_valid = true;
    }
}

/// The `O(touched)` error-feedback step for a sparse gradient against an
/// actively-tracked memory.
///
/// Invariants required (and preserved): `memory` is exactly zero outside
/// `m_support`, and `m_support` holds exactly its nonzero coordinates.
/// `v` is dense scratch whose entries are only meaningful at the
/// coordinates built this call (`v_support`). Every touched coordinate
/// evaluates the dense path's literal FP expression (`m[j] + η·g[j]` at
/// gradient coordinates, `m[j]` verbatim elsewhere on the support), and
/// every *untouched* coordinate of the conceptual dense `v` is an exact
/// zero — which is why the compressor's active scan selects exactly what
/// its dense scan would (`Compressor::compress_active` contract).
#[allow(clippy::too_many_arguments)] // mirrors the recursion's full state bundle
pub(crate) fn active_apply_grad(
    comp: &mut dyn Compressor,
    memory: &mut [f32],
    v: &mut [f32],
    m_support: &mut ActiveIndex,
    v_support: &mut ActiveIndex,
    grad: &SparseVec,
    eta: f32,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), grad.dim);
    debug_assert_eq!(v.len(), grad.dim);
    v_support.clear();
    for (&j, &g) in grad.idx.iter().zip(&grad.val) {
        let jj = j as usize;
        v[jj] = memory[jj] + eta * g;
        v_support.insert(j);
    }
    for &j in m_support.touched() {
        if v_support.insert(j) {
            // Dense computes m[j] + η·0 here — the same value.
            v[j as usize] = memory[j as usize];
        }
    }
    finish_active(comp, memory, v, m_support, v_support, rng, out)
}

/// [`active_apply_grad`] for an **already stepsize-scaled** active-set
/// accumulator (the `sync` of the local-update schedule): `v = m + a`
/// over `support(m) ∪ touched(a)`. The dense sync computes
/// `m[j] + 1.0·a[j]`; `×1.0` is exact, and on support-only coordinates
/// `m[j] + 0.0 == m[j]` bitwise because the support holds only nonzero
/// entries — so this is the dense sync bit for bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn active_apply_accum(
    comp: &mut dyn Compressor,
    memory: &mut [f32],
    v: &mut [f32],
    m_support: &mut ActiveIndex,
    v_support: &mut ActiveIndex,
    acc: ActiveView<'_>,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    debug_assert_eq!(memory.len(), acc.vals.len());
    debug_assert_eq!(v.len(), acc.vals.len());
    v_support.clear();
    for &j in acc.touched {
        let jj = j as usize;
        v[jj] = memory[jj] + acc.vals[jj];
        v_support.insert(j);
    }
    for &j in m_support.touched() {
        if v_support.insert(j) {
            v[j as usize] = memory[j as usize];
        }
    }
    finish_active(comp, memory, v, m_support, v_support, rng, out)
}

/// Shared tail of the active recursion: compress the built `v`, write
/// the new residual `m = v − u` back over the built coordinates, and
/// re-derive the support as its exact nonzero set (this is what keeps
/// the active set tracking the *residual*, not the ever-growing union
/// of everything ever touched).
fn finish_active(
    comp: &mut dyn Compressor,
    memory: &mut [f32],
    v: &mut [f32],
    m_support: &mut ActiveIndex,
    v_support: &mut ActiveIndex,
    rng: &mut Prng,
    out: &mut Update,
) -> u64 {
    let view = ActiveView { vals: &*v, touched: v_support.touched() };
    let bits = comp
        .compress_active(view, rng, out)
        .expect("compressor advertised supports_active_scan");
    // m ← v − u. Outside the built set the dense recursion yields
    // v[j] − u[j] = 0 − 0 = 0, which is what the untouched dense memory
    // already holds (u may carry zero-valued padding coordinates there;
    // subtracting an exact zero from an exact zero is a no-op).
    for &j in v_support.touched() {
        memory[j as usize] = v[j as usize];
    }
    out.sub_from(memory);
    m_support.clear();
    for &j in v_support.touched() {
        if memory[j as usize] != 0.0 {
            m_support.insert(j);
        }
    }
    bits
}

/// Per-worker error-feedback state: everything one sequential stream,
/// shared-memory worker, or parameter-server node needs to turn a
/// stochastic gradient into a compressed update.
pub struct ErrorFeedbackStep {
    /// Error memory `m` (all zeros for memory-free methods). Dense
    /// storage always; on the active path it is additionally tracked by
    /// `m_support` (exactly its nonzero coordinates).
    memory: Vec<f32>,
    /// Scratch `v = m + η·g`. On the active path only the coordinates in
    /// `v_support` are meaningful after a step.
    v: Vec<f32>,
    comp: Box<dyn Compressor>,
    update: Update,
    /// Post-compression scaling of the transmitted values (`d/k` for the
    /// §2.2 unbiased rand-k baseline; 1 otherwise). Only valid without
    /// memory — scaling a remembered residual would double-count it.
    scale: f32,
    use_memory: bool,
    /// Active-set bookkeeping, engaged by the sparse entry points when
    /// the compressor supports `O(touched)` scans.
    m_support: ActiveIndex,
    v_support: ActiveIndex,
    /// Whether `m_support` currently equals `support(memory)` exactly
    /// (a dense step invalidates it; the next active step rebuilds).
    support_valid: bool,
    /// Cumulative wire cost of every update produced so far.
    pub bits_sent: u64,
}

impl ErrorFeedbackStep {
    /// Error feedback gated on the operator: contraction operators
    /// (top-k, rand-k, ...) keep a memory; non-contractions (QSGD) run
    /// memory-free exactly as in the paper's §4.3 baseline —
    /// accumulating unbiased quantization noise would amplify it
    /// instead of correcting it.
    pub fn new(d: usize, comp: Box<dyn Compressor>) -> Self {
        let use_memory = comp.contraction_k(d).is_some();
        Self::build(d, comp, 1.0, use_memory)
    }

    /// Memory-free step (vanilla/unbiased baselines): `u = scale·comp(η·g)`.
    pub fn memory_free(d: usize, comp: Box<dyn Compressor>, scale: f32) -> Self {
        Self::build(d, comp, scale, false)
    }

    fn build(d: usize, comp: Box<dyn Compressor>, scale: f32, use_memory: bool) -> Self {
        debug_assert!(scale == 1.0 || !use_memory, "scaling requires memory-free mode");
        ErrorFeedbackStep {
            memory: vec![0.0; d],
            v: vec![0.0; d],
            comp,
            update: Update::new_sparse(d),
            scale,
            use_memory,
            m_support: ActiveIndex::new(),
            v_support: ActiveIndex::new(),
            support_valid: true, // m = 0: the empty support is exact
            bits_sent: 0,
        }
    }

    /// Whether the sparse entry points of this state run the
    /// `O(touched)` active path (memory-carrying method × compressor
    /// with an active scan). The topology engines consult this to pick
    /// the dimension-free phase route.
    pub fn wants_active(&self) -> bool {
        self.use_memory && self.comp.supports_active_scan()
    }

    /// Make `m_support` exact (rebuilding after a dense step if needed)
    /// and size both stamp tables.
    fn ensure_support(&mut self) {
        ensure_support_tracking(
            &self.memory,
            &mut self.m_support,
            &mut self.v_support,
            &mut self.support_valid,
        );
    }

    /// Produce the next compressed update from `grad` at stepsize `eta`;
    /// afterwards [`ErrorFeedbackStep::update`] holds the update to apply
    /// to the iterate. Returns this step's wire cost in bits.
    pub fn step(&mut self, grad: &[f32], eta: f32, rng: &mut Prng) -> u64 {
        let bits = if self.use_memory {
            // The dense route mutates the memory without maintaining the
            // support; a later active step rebuilds it.
            self.support_valid = false;
            apply(
                self.comp.as_mut(),
                &mut self.memory,
                &mut self.v,
                grad,
                eta,
                rng,
                &mut self.update,
            )
        } else {
            debug_assert_eq!(self.v.len(), grad.len());
            for (vi, &gi) in self.v.iter_mut().zip(grad) {
                *vi = eta * gi;
            }
            let bits = self.comp.compress(&self.v, rng, &mut self.update);
            scale_update(&mut self.update, self.scale);
            bits
        };
        self.bits_sent += bits;
        bits
    }

    /// [`ErrorFeedbackStep::step`] for a sparse gradient — identical
    /// trajectory (same FP expression `m + η·g` on the gradient's stored
    /// coordinates, memory carried verbatim elsewhere), but the gradient
    /// never materializes densely. Used by the topology engines whenever
    /// the backend advertises [`crate::models::GradBackend::supports_sparse_grad`].
    /// With an active-scan compressor the whole step (v-build, scan,
    /// residual update) costs `O(touched)` instead of `O(d)`.
    pub fn step_sparse(&mut self, grad: &SparseVec, eta: f32, rng: &mut Prng) -> u64 {
        let bits = if self.use_memory {
            if self.comp.supports_active_scan() {
                self.ensure_support();
                active_apply_grad(
                    self.comp.as_mut(),
                    &mut self.memory,
                    &mut self.v,
                    &mut self.m_support,
                    &mut self.v_support,
                    grad,
                    eta,
                    rng,
                    &mut self.update,
                )
            } else {
                self.support_valid = false;
                apply_sparse(
                    self.comp.as_mut(),
                    &mut self.memory,
                    &mut self.v,
                    grad,
                    eta,
                    rng,
                    &mut self.update,
                )
            }
        } else {
            debug_assert_eq!(self.v.len(), grad.dim);
            self.v.iter_mut().for_each(|vi| *vi = 0.0);
            for (&j, &g) in grad.idx.iter().zip(&grad.val) {
                self.v[j as usize] = eta * g;
            }
            let bits = self.comp.compress(&self.v, rng, &mut self.update);
            scale_update(&mut self.update, self.scale);
            bits
        };
        self.bits_sent += bits;
        bits
    }

    /// Local-update sync: compress an **already stepsize-scaled**
    /// accumulator `Σ_h η_h·g_h` of `H` local steps against the
    /// worker-local memory — the communication event of the
    /// `LocalUpdate { batch, sync_every }` schedule.
    ///
    /// The memory `m` never travels and is untouched between syncs; only
    /// this call's compressed aggregate goes on the wire, so a worker
    /// syncing every `H` steps sends `H`-fold fewer updates. Exactly
    /// `step(accum, 1.0, rng)`: multiplying by 1.0 is exact in IEEE-754,
    /// so with `H = 1` (accum = `η·g`) this reproduces `step(g, η, rng)`
    /// bit for bit — pinned by `tests/local_update_equivalence.rs`.
    pub fn sync(&mut self, accum: &[f32], rng: &mut Prng) -> u64 {
        self.step(accum, 1.0, rng)
    }

    /// [`ErrorFeedbackStep::sync`] for an **active-set** accumulator —
    /// the `O(touched)` communication event of the dimension-free phase.
    /// Bit-identical to `sync(acc.to_dense())` (pinned by the unit tests
    /// below and `tests/sparse_pipeline.rs` end to end). Panics if this
    /// state is not on the active path ([`ErrorFeedbackStep::wants_active`]);
    /// the engines route accordingly.
    pub fn sync_active(&mut self, acc: ActiveView<'_>, rng: &mut Prng) -> u64 {
        assert!(
            self.wants_active(),
            "sync_active requires a memory-carrying method whose compressor supports active scans"
        );
        debug_assert_eq!(acc.vals.len(), self.memory.len());
        self.ensure_support();
        let bits = active_apply_accum(
            self.comp.as_mut(),
            &mut self.memory,
            &mut self.v,
            &mut self.m_support,
            &mut self.v_support,
            acc,
            rng,
            &mut self.update,
        );
        self.bits_sent += bits;
        bits
    }

    /// The update produced by the last [`ErrorFeedbackStep::step`].
    pub fn update(&self) -> &Update {
        &self.update
    }

    /// The method's compression operator — what the wire engines use to
    /// frame [`ErrorFeedbackStep::update`] into its typed payload
    /// ([`Compressor::encode_payload`]).
    pub fn compressor(&self) -> &dyn Compressor {
        self.comp.as_ref()
    }

    /// Current error memory (dense view; exact on every path).
    pub fn memory(&self) -> &[f32] {
        &self.memory
    }

    /// Whether this method carries an error memory.
    pub fn uses_memory(&self) -> bool {
        self.use_memory
    }

    /// `‖m‖²` — the quantity Lemma 3.2 bounds.
    pub fn memory_norm_sq(&self) -> f64 {
        crate::util::stats::l2_norm_sq(&self.memory)
    }
}

/// Post-compression unbiasing scale of the memory-free baselines
/// (`d/k` for §2.2 rand-k; a no-op at 1.0).
fn scale_update(update: &mut Update, scale: f32) {
    if scale == 1.0 {
        return;
    }
    match update {
        Update::Sparse(s) => {
            for val in s.val.iter_mut() {
                *val *= scale;
            }
        }
        Update::Dense(g) => {
            for val in g.iter_mut() {
                *val *= scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{from_spec, TopK};

    #[test]
    fn step_matches_manual_recursion() {
        let d = 4;
        let mut ef = ErrorFeedbackStep::new(d, Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        // grad [10, 1, 0, 0] at eta 1: v = [10,1,0,0], u = [10,0,0,0],
        // m = [0,1,0,0].
        ef.step(&[10.0, 1.0, 0.0, 0.0], 1.0, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![10.0, 0.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0, 1.0, 0.0, 0.0]);
        assert!(ef.uses_memory());
        // Zero gradient: the memory flushes.
        ef.step(&[0.0; 4], 1.0, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0; 4]);
        assert!(ef.memory_norm_sq() < 1e-12);
    }

    #[test]
    fn memory_free_scales_the_update() {
        let d = 4;
        // Unbiased rand-k style: scale d/k = 4 applied post-compression.
        let mut ef =
            ErrorFeedbackStep::memory_free(d, Box::new(crate::compress::Identity), 4.0);
        let mut rng = Prng::new(1);
        ef.step(&[1.0, 2.0, 3.0, 4.0], 0.5, &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![2.0, 4.0, 6.0, 8.0]);
        assert!(!ef.uses_memory());
        assert_eq!(ef.memory(), &[0.0; 4]);
    }

    #[test]
    fn qsgd_runs_memory_free_by_default() {
        let ef = ErrorFeedbackStep::new(8, from_spec("qsgd:16").unwrap());
        assert!(!ef.uses_memory());
        let ef = ErrorFeedbackStep::new(8, from_spec("top_k:2").unwrap());
        assert!(ef.uses_memory());
    }

    #[test]
    fn active_path_engages_exactly_for_active_scan_contractions() {
        assert!(ErrorFeedbackStep::new(8, from_spec("top_k:2").unwrap()).wants_active());
        assert!(ErrorFeedbackStep::new(8, from_spec("threshold:0.5").unwrap()).wants_active());
        assert!(!ErrorFeedbackStep::new(8, from_spec("rand_k:2").unwrap()).wants_active());
        assert!(!ErrorFeedbackStep::new(8, from_spec("qsgd:16").unwrap()).wants_active());
        // Memory-free states never run the active path, whatever the
        // operator could do.
        assert!(!ErrorFeedbackStep::memory_free(8, Box::new(TopK::new(2)), 1.0).wants_active());
    }

    #[test]
    fn sync_of_scaled_accum_is_step_bit_for_bit() {
        // ef.sync(η·g) must equal ef.step(g, η) exactly — the H = 1
        // reduction of the local-update schedule.
        let d = 6;
        let grads = [
            [0.3f32, -2.0, 0.7, 0.0, 1.1, -0.4],
            [1.5f32, 0.2, -0.9, 3.0, -0.1, 0.6],
        ];
        let eta = 0.37f32;
        let mut a = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut b = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut rng_a = Prng::new(9);
        let mut rng_b = Prng::new(9);
        for g in &grads {
            let bits_a = a.step(g, eta, &mut rng_a);
            let accum: Vec<f32> = g.iter().map(|&gi| eta * gi).collect();
            let bits_b = b.sync(&accum, &mut rng_b);
            assert_eq!(bits_a, bits_b);
            assert_eq!(a.update().to_dense(d), b.update().to_dense(d));
            assert_eq!(a.memory(), b.memory());
        }
    }

    #[test]
    fn memory_stays_local_across_syncs() {
        // Two local phases worth of accumulation: the residual carried
        // between syncs is exactly what the compressor suppressed.
        let d = 4;
        let mut ef = ErrorFeedbackStep::new(d, Box::new(TopK::new(1)));
        let mut rng = Prng::new(0);
        // Phase 1 aggregate [10, 1, 0, 0]: sends the 10, keeps the 1.
        ef.sync(&[10.0, 1.0, 0.0, 0.0], &mut rng);
        assert_eq!(ef.memory(), &[0.0, 1.0, 0.0, 0.0]);
        // Phase 2 aggregate flushes the suppressed coordinate.
        ef.sync(&[0.0; 4], &mut rng);
        assert_eq!(ef.update().to_dense(d), vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ef.memory(), &[0.0; 4]);
    }

    #[test]
    fn sparse_step_replays_dense_step_bit_for_bit() {
        // Every method kind (memory-carrying active, memory-carrying
        // dense-route, memory-free, memory-free scaled) must produce
        // identical trajectories when the same gradient arrives sparse
        // instead of dense.
        let d = 8;
        let builders: Vec<(&str, fn() -> ErrorFeedbackStep)> = vec![
            ("mem top_k", || ErrorFeedbackStep::new(8, from_spec("top_k:2").unwrap())),
            ("mem threshold", || ErrorFeedbackStep::new(8, from_spec("threshold:0.25").unwrap())),
            ("mem rand_k", || ErrorFeedbackStep::new(8, from_spec("rand_k:2").unwrap())),
            ("free qsgd", || ErrorFeedbackStep::new(8, from_spec("qsgd:16").unwrap())),
            ("free scaled", || {
                ErrorFeedbackStep::memory_free(8, Box::new(crate::compress::RandK::new(2)), 4.0)
            }),
        ];
        for (name, build) in builders {
            let mut dense_ef = build();
            let mut sparse_ef = build();
            let mut rng_a = Prng::new(21);
            let mut rng_b = Prng::new(21);
            for t in 0..25usize {
                let mut g = vec![0.0f32; d];
                let mut sg = SparseVec::new(d);
                for j in [1usize, 4, 6] {
                    let val = ((t * 7 + j * 3) % 11) as f32 / 11.0 - 0.4;
                    g[j] = val;
                    sg.push(j as u32, val);
                }
                let bits_a = dense_ef.step(&g, 0.3, &mut rng_a);
                let bits_b = sparse_ef.step_sparse(&sg, 0.3, &mut rng_b);
                assert_eq!(bits_a, bits_b, "{name} t={t}");
                assert_eq!(
                    dense_ef.update().to_dense(d),
                    sparse_ef.update().to_dense(d),
                    "{name} t={t}"
                );
                assert_eq!(dense_ef.memory(), sparse_ef.memory(), "{name} t={t}");
            }
        }
    }

    #[test]
    fn sync_active_replays_dense_sync_bit_for_bit() {
        // The dimension-free communication event against its dense
        // reference, over a trajectory long enough for the residual
        // support to grow, move, and flush.
        for spec in ["top_k:2", "threshold:0.3"] {
            let d = 10;
            let mut dense_ef = ErrorFeedbackStep::new(d, from_spec(spec).unwrap());
            let mut active_ef = ErrorFeedbackStep::new(d, from_spec(spec).unwrap());
            let mut rng_a = Prng::new(33);
            let mut rng_b = Prng::new(33);
            let mut vals = vec![0.0f32; d];
            for t in 0..40usize {
                let mut touched: Vec<u32> = Vec::new();
                for j in [(t * 3) % d, (t * 5 + 1) % d, (t * 7 + 4) % d] {
                    if !touched.contains(&(j as u32)) {
                        vals[j] = ((t * 11 + j * 3) % 13) as f32 / 13.0 - 0.4;
                        touched.push(j as u32);
                    }
                }
                let mut acc = vec![0.0f32; d];
                for &j in &touched {
                    acc[j as usize] = vals[j as usize];
                }
                let bits_a = dense_ef.sync(&acc, &mut rng_a);
                let view = ActiveView { vals: &vals, touched: &touched };
                let bits_b = active_ef.sync_active(view, &mut rng_b);
                assert_eq!(bits_a, bits_b, "{spec} t={t}");
                assert_eq!(
                    dense_ef.update().to_dense(d),
                    active_ef.update().to_dense(d),
                    "{spec} t={t}"
                );
                assert_eq!(dense_ef.memory(), active_ef.memory(), "{spec} t={t}");
            }
        }
    }

    #[test]
    fn mixed_dense_and_sparse_calls_stay_consistent() {
        // Interleaving dense steps (which invalidate the support) with
        // sparse steps (which rebuild it) must track an all-dense twin
        // exactly — the transition logic is the risky part.
        let d = 8;
        let mut mixed = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut dense = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut rng_a = Prng::new(5);
        let mut rng_b = Prng::new(5);
        for t in 0..30usize {
            let mut g = vec![0.0f32; d];
            let mut sg = SparseVec::new(d);
            for j in [0usize, 2, 5, 7] {
                let val = ((t * 5 + j * 9) % 17) as f32 / 17.0 - 0.45;
                g[j] = val;
                sg.push(j as u32, val);
            }
            dense.step(&g, 0.2, &mut rng_b);
            if t % 3 == 0 {
                mixed.step(&g, 0.2, &mut rng_a); // dense entry, invalidates
            } else {
                mixed.step_sparse(&sg, 0.2, &mut rng_a); // active entry, rebuilds
            }
            assert_eq!(mixed.memory(), dense.memory(), "t={t}");
            assert_eq!(mixed.update().to_dense(d), dense.update().to_dense(d), "t={t}");
        }
    }

    #[test]
    fn raw_apply_sparse_matches_apply() {
        let d = 5;
        let mut comp_a = TopK::new(1);
        let mut comp_b = TopK::new(1);
        let (mut m_a, mut v_a) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut m_b, mut v_b) = (vec![0.0f32; d], vec![0.0f32; d]);
        let mut out_a = Update::new_sparse(d);
        let mut out_b = Update::new_sparse(d);
        let mut rng = Prng::new(0);
        for t in 0..10 {
            let g = vec![0.0, 1.0 + t as f32, 0.0, -0.5, 0.0];
            let sg = SparseVec::from_parts(d, vec![1, 3], vec![1.0 + t as f32, -0.5]);
            apply(&mut comp_a, &mut m_a, &mut v_a, &g, 0.7, &mut rng, &mut out_a);
            apply_sparse(&mut comp_b, &mut m_b, &mut v_b, &sg, 0.7, &mut rng, &mut out_b);
            assert_eq!(m_a, m_b, "t={t}");
            assert_eq!(out_a.to_dense(d), out_b.to_dense(d), "t={t}");
        }
    }

    #[test]
    fn raw_active_apply_matches_apply() {
        let d = 6;
        let mut comp_a = TopK::new(2);
        let mut comp_b = TopK::new(2);
        let (mut m_a, mut v_a) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut m_b, mut v_b) = (vec![0.0f32; d], vec![0.0f32; d]);
        let mut m_sup = ActiveIndex::new();
        let mut v_sup = ActiveIndex::new();
        m_sup.grow(d);
        v_sup.grow(d);
        let mut out_a = Update::new_sparse(d);
        let mut out_b = Update::new_sparse(d);
        let mut rng = Prng::new(0);
        for t in 0..12 {
            let mut g = vec![0.0f32; d];
            let mut sg = SparseVec::new(d);
            for j in [1usize, 3, 4] {
                let val = ((t * 7 + j) % 9) as f32 - 4.0;
                g[j] = val;
                sg.push(j as u32, val);
            }
            apply(&mut comp_a, &mut m_a, &mut v_a, &g, 0.6, &mut rng, &mut out_a);
            active_apply_grad(
                &mut comp_b,
                &mut m_b,
                &mut v_b,
                &mut m_sup,
                &mut v_sup,
                &sg,
                0.6,
                &mut rng,
                &mut out_b,
            );
            assert_eq!(m_a, m_b, "t={t}");
            assert_eq!(out_a.to_dense(d), out_b.to_dense(d), "t={t}");
            // The tracked support is exactly the residual's nonzero set.
            let mut sup: Vec<u32> = m_sup.touched().to_vec();
            sup.sort_unstable();
            let want: Vec<u32> = (0..d as u32).filter(|&j| m_b[j as usize] != 0.0).collect();
            assert_eq!(sup, want, "t={t}");
        }
    }

    #[test]
    fn bits_accumulate_across_steps() {
        let d = 100;
        let mut ef = ErrorFeedbackStep::new(d, from_spec("top_k:2").unwrap());
        let mut rng = Prng::new(1);
        for _ in 0..10 {
            ef.step(&vec![1.0; d], 0.1, &mut rng);
        }
        // top-2 on d=100: 2·(32+7) = 78 bits per step.
        assert_eq!(ef.bits_sent, 10 * 78);
    }
}
