//! Stepsize schedules.
//!
//! * [`Schedule::InvT`] — the theoretical rate of Theorem 2.4 / Table 2:
//!   `η_t = γ / (λ·(t + a))` with shift `a`. The paper sets `γ = 2` and
//!   `a = d/k` (epsilon) or `a = 10·d/k` (RCV1); setting `a = 1` is the
//!   "without delay" ablation of Figure 2.
//! * [`Schedule::Bottou`] — `η_t = γ₀ / (1 + γ₀·λ·t)`, the practical rate
//!   used for the QSGD comparison (Section 4.3, tuned via Figure 5).
//! * [`Schedule::Const`] — constant rate, used by the multicore
//!   experiment on epsilon (Section 4.4, `η ≡ 0.05`).

/// A stepsize schedule `t ↦ η_t`.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// `η_t = gamma / (lambda * (t + shift))`.
    InvT { gamma: f64, lambda: f64, shift: f64 },
    /// `η_t = gamma0 / (1 + gamma0 * lambda * t)`.
    Bottou { gamma0: f64, lambda: f64 },
    /// `η_t = eta`.
    Const { eta: f64 },
}

impl Schedule {
    /// Theoretical schedule of Table 2.
    pub fn inv_t(gamma: f64, lambda: f64, shift: f64) -> Schedule {
        assert!(gamma > 0.0 && lambda > 0.0 && shift > 0.0);
        Schedule::InvT {
            gamma,
            lambda,
            shift,
        }
    }

    /// Bottou's practical schedule (Section 4.3).
    pub fn bottou(gamma0: f64, lambda: f64) -> Schedule {
        assert!(gamma0 > 0.0 && lambda > 0.0);
        Schedule::Bottou { gamma0, lambda }
    }

    /// Constant schedule (Section 4.4 multicore on epsilon).
    pub fn constant(eta: f64) -> Schedule {
        assert!(eta > 0.0);
        Schedule::Const { eta }
    }

    /// The paper's recommended shift for a k-contraction on a
    /// d-dimensional problem: `a = multiplier · d/k` (Remark 2.5 /
    /// Table 2: multiplier 1 for epsilon, 10 for RCV1).
    pub fn paper_shift(d: usize, k: f64, multiplier: f64) -> f64 {
        (multiplier * d as f64 / k).max(1.0)
    }

    /// Stepsize at iteration `t` (0-based).
    #[inline]
    pub fn eta(&self, t: usize) -> f64 {
        match *self {
            Schedule::InvT {
                gamma,
                lambda,
                shift,
            } => gamma / (lambda * (t as f64 + shift)),
            Schedule::Bottou { gamma0, lambda } => gamma0 / (1.0 + gamma0 * lambda * t as f64),
            Schedule::Const { eta } => eta,
        }
    }

    /// The averaging shift associated with this schedule (`a` for InvT,
    /// 1.0 otherwise) — the weights of Theorem 2.4 are `w_t = (a + t)²`.
    pub fn averaging_shift(&self) -> f64 {
        match *self {
            Schedule::InvT { shift, .. } => shift,
            _ => 1.0,
        }
    }

    /// Spec string for metric records.
    pub fn describe(&self) -> String {
        match *self {
            Schedule::InvT {
                gamma,
                lambda,
                shift,
            } => format!("inv_t(gamma={gamma},lambda={lambda},a={shift})"),
            Schedule::Bottou { gamma0, lambda } => {
                format!("bottou(gamma0={gamma0},lambda={lambda})")
            }
            Schedule::Const { eta } => format!("const(eta={eta})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_t_values() {
        // Table 2 for epsilon with k=1: gamma=2, lambda=1/n, a=d/k=2000.
        let s = Schedule::inv_t(2.0, 1.0 / 400_000.0, 2000.0);
        let eta0 = s.eta(0);
        assert!((eta0 - 2.0 * 400_000.0 / 2000.0).abs() < 1e-9);
        // decreasing
        assert!(s.eta(1) < eta0);
        assert!(s.eta(1000) < s.eta(100));
    }

    #[test]
    fn bottou_starts_at_gamma0() {
        let s = Schedule::bottou(0.1, 0.01);
        assert_eq!(s.eta(0), 0.1);
        assert!(s.eta(10) < 0.1);
        // η_t = γ0/(1+γ0 λ t): at t = 1/(γ0 λ) it's halved.
        let t_half = (1.0 / (0.1 * 0.01)) as usize;
        assert!((s.eta(t_half) - 0.05).abs() < 1e-6);
    }

    #[test]
    fn const_is_const() {
        let s = Schedule::constant(0.05);
        assert_eq!(s.eta(0), 0.05);
        assert_eq!(s.eta(1_000_000), 0.05);
        assert_eq!(s.averaging_shift(), 1.0);
    }

    #[test]
    fn paper_shift_formula() {
        assert_eq!(Schedule::paper_shift(2000, 1.0, 1.0), 2000.0);
        assert_eq!(Schedule::paper_shift(47236, 10.0, 10.0), 47236.0);
        // fractional k (ultra-sparsification) grows the shift:
        assert_eq!(Schedule::paper_shift(100, 0.5, 1.0), 200.0);
        // never below 1:
        assert_eq!(Schedule::paper_shift(1, 10.0, 1.0), 1.0);
    }

    #[test]
    fn averaging_shift_follows_inv_t() {
        let s = Schedule::inv_t(2.0, 0.1, 123.0);
        assert_eq!(s.averaging_shift(), 123.0);
    }

    #[test]
    fn describe_round_trips_params() {
        assert!(Schedule::inv_t(2.0, 0.5, 7.0).describe().contains("a=7"));
        assert!(Schedule::bottou(1.0, 0.5).describe().contains("gamma0=1"));
    }
}
