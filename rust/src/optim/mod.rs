//! Optimizers: Mem-SGD (Algorithm 1), vanilla/unbiased-sparsified SGD
//! (Section 2.2 baselines), the shared [`error_feedback`] step every
//! training topology runs, stepsize schedules (Table 2), and the
//! quadratically-weighted iterate averaging of Theorem 2.4.

pub mod averaging;
pub mod error_feedback;
pub mod memsgd;
pub mod schedule;
pub mod sgd;
pub mod theory;

pub use averaging::WeightedAverage;
pub use error_feedback::ErrorFeedbackStep;
pub use memsgd::MemSgd;
pub use schedule::Schedule;
pub use sgd::Sgd;
pub use theory::TheoryParams;
