//! Run records: what every experiment driver emits.
//!
//! A [`RunRecord`] carries the loss curve (indexed by iteration *and*
//! cumulative transmitted bits — the two x-axes of Figures 2 and 3),
//! configuration provenance, and wall-clock. Records serialize to JSON
//! (machine consumption / EXPERIMENTS.md tooling) and to aligned text
//! tables (human consumption in the CLI).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One point of a loss curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossPoint {
    /// Iteration index (stochastic-gradient count).
    pub t: usize,
    /// Cumulative transmitted bits up to this point.
    pub bits: u64,
    /// Full objective `f(x̄_t)` (or `f(x_t)` when averaging is off).
    pub loss: f64,
}

/// A complete experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    /// Method name, e.g. `memsgd(top_1)` or `sgd_qsgd_4bit`.
    pub method: String,
    /// Dataset provenance, e.g. `epsilon-like(n=20000,d=2000)`.
    pub dataset: String,
    /// Stepsize schedule description.
    pub schedule: String,
    /// Loss curve.
    pub curve: Vec<LossPoint>,
    /// Total iterations executed.
    pub steps: usize,
    /// Total transmitted bits.
    pub total_bits: u64,
    /// Wall-clock milliseconds.
    pub elapsed_ms: f64,
    /// Free-form scalar extras (e.g. `workers`, `collisions`).
    pub extra: BTreeMap<String, f64>,
}

impl RunRecord {
    /// Last recorded loss (`f64::NAN` if the curve is empty).
    pub fn final_loss(&self) -> f64 {
        self.curve.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// Smallest recorded loss.
    pub fn best_loss(&self) -> f64 {
        self.curve
            .iter()
            .map(|p| p.loss)
            .fold(f64::INFINITY, f64::min)
    }

    /// First iteration at which the loss reaches `target`, if any.
    pub fn iterations_to(&self, target: f64) -> Option<usize> {
        self.curve.iter().find(|p| p.loss <= target).map(|p| p.t)
    }

    /// Bits transmitted before the loss reaches `target`, if ever.
    pub fn bits_to(&self, target: f64) -> Option<u64> {
        self.curve.iter().find(|p| p.loss <= target).map(|p| p.bits)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("dataset", Json::str(&self.dataset)),
            ("schedule", Json::str(&self.schedule)),
            ("steps", Json::Num(self.steps as f64)),
            ("total_bits", Json::Num(self.total_bits as f64)),
            ("elapsed_ms", Json::Num(self.elapsed_ms)),
            (
                "extra",
                Json::Obj(
                    self.extra
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v)))
                        .collect(),
                ),
            ),
            (
                "curve",
                Json::arr(self.curve.iter().map(|p| {
                    Json::obj(vec![
                        ("t", Json::Num(p.t as f64)),
                        ("bits", Json::Num(p.bits as f64)),
                        ("loss", Json::Num(p.loss)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<RunRecord> {
        let mut rec = RunRecord {
            method: v.req("method")?.as_str()?.to_string(),
            dataset: v.req("dataset")?.as_str()?.to_string(),
            schedule: v.req("schedule")?.as_str()?.to_string(),
            steps: v.req("steps")?.as_usize()?,
            total_bits: v.req("total_bits")?.as_f64()? as u64,
            elapsed_ms: v.req("elapsed_ms")?.as_f64()?,
            ..Default::default()
        };
        if let Some(Json::Obj(extra)) = v.get("extra") {
            for (k, x) in extra {
                rec.extra.insert(k.clone(), x.as_f64()?);
            }
        }
        for p in v.req("curve")?.as_arr()? {
            rec.curve.push(LossPoint {
                t: p.req("t")?.as_usize()?,
                bits: p.req("bits")?.as_f64()? as u64,
                loss: p.req("loss")?.as_f64()?,
            });
        }
        Ok(rec)
    }
}

/// Write a set of records as a pretty JSON document.
pub fn write_records(path: impl AsRef<Path>, records: &[RunRecord]) -> Result<()> {
    let doc = Json::obj(vec![
        ("format", Json::Num(1.0)),
        ("records", Json::arr(records.iter().map(|r| r.to_json()))),
    ]);
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(path.as_ref(), doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Read records back (used by the report tooling and tests).
pub fn read_records(path: impl AsRef<Path>) -> Result<Vec<RunRecord>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let doc = Json::parse(&text)?;
    doc.req("records")?
        .as_arr()?
        .iter()
        .map(RunRecord::from_json)
        .collect()
}

/// Render records as an aligned comparison table (one row per record):
/// method, final loss, best loss, total MB transmitted.
pub fn summary_table(records: &[RunRecord]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<36} {:>12} {:>12} {:>14} {:>10}\n",
        "method", "final loss", "best loss", "bits sent", "steps"
    ));
    for r in records {
        out.push_str(&format!(
            "{:<36} {:>12.6} {:>12.6} {:>14} {:>10}\n",
            r.method,
            r.final_loss(),
            r.best_loss(),
            fmt_bits(r.total_bits),
            r.steps
        ));
    }
    out
}

/// Human-readable bit counts.
pub fn fmt_bits(bits: u64) -> String {
    let bytes = bits as f64 / 8.0;
    if bytes < 1e3 {
        format!("{bytes:.0}B")
    } else if bytes < 1e6 {
        format!("{:.1}KB", bytes / 1e3)
    } else if bytes < 1e9 {
        format!("{:.1}MB", bytes / 1e6)
    } else {
        format!("{:.2}GB", bytes / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunRecord {
        RunRecord {
            method: "memsgd(top_1)".into(),
            dataset: "epsilon-like".into(),
            schedule: "inv_t".into(),
            curve: vec![
                LossPoint { t: 0, bits: 0, loss: 0.693 },
                LossPoint { t: 100, bits: 4300, loss: 0.5 },
                LossPoint { t: 200, bits: 8600, loss: 0.42 },
            ],
            steps: 200,
            total_bits: 8600,
            elapsed_ms: 12.5,
            extra: [("workers".to_string(), 4.0)].into_iter().collect(),
        }
    }

    #[test]
    fn accessors() {
        let r = sample();
        assert_eq!(r.final_loss(), 0.42);
        assert_eq!(r.best_loss(), 0.42);
        assert_eq!(r.iterations_to(0.5), Some(100));
        assert_eq!(r.bits_to(0.5), Some(4300));
        assert_eq!(r.iterations_to(0.1), None);
        assert!(RunRecord::default().final_loss().is_nan());
    }

    #[test]
    fn json_round_trip() {
        let r = sample();
        let j = r.to_json();
        let r2 = RunRecord::from_json(&j).unwrap();
        assert_eq!(r.method, r2.method);
        assert_eq!(r.curve, r2.curve);
        assert_eq!(r.total_bits, r2.total_bits);
        assert_eq!(r.extra, r2.extra);
    }

    #[test]
    fn file_round_trip() {
        let path = std::env::temp_dir().join("memsgd_records_test.json");
        write_records(&path, &[sample(), sample()]).unwrap();
        let back = read_records(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].method, "memsgd(top_1)");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn table_contains_method_names() {
        let t = summary_table(&[sample()]);
        assert!(t.contains("memsgd(top_1)"));
        assert!(t.contains("final loss"));
    }

    #[test]
    fn fmt_bits_units() {
        assert_eq!(fmt_bits(80), "10B");
        assert_eq!(fmt_bits(8_000 * 10), "10.0KB");
        assert_eq!(fmt_bits(80_000_000), "10.0MB");
        assert_eq!(fmt_bits(80_000_000_000), "10.00GB");
    }
}
