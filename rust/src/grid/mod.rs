//! Learning-rate grid search (Figure 5 / Appendix B).
//!
//! For the QSGD comparison the paper fixes the practical schedule
//! `η_t = γ₀/(1 + γ₀λt)` and grid-searches `γ₀` per method × dataset on
//! a training subset. [`search`] reproduces that: run every candidate
//! for a short budget, score by final weighted-average loss, return the
//! per-method winner (which `memsgd figure3` then consumes).

use anyhow::Result;

use crate::coordinator::config::{LocalUpdate, MethodSpec};
use crate::coordinator::experiment::Experiment;
use crate::data::Dataset;
use crate::metrics::RunRecord;
use crate::models::LogisticModel;
use crate::optim::Schedule;

/// One grid-search cell.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub method: String,
    pub gamma0: f64,
    pub final_loss: f64,
    pub record: RunRecord,
}

/// Result of a per-method sweep.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub cells: Vec<GridCell>,
}

impl GridResult {
    /// The best γ₀ for `method` (lowest final loss).
    pub fn best(&self, method: &str) -> Option<&GridCell> {
        self.cells
            .iter()
            .filter(|c| c.method == method)
            .min_by(|a, b| a.final_loss.partial_cmp(&b.final_loss).unwrap())
    }

    /// All methods present.
    pub fn methods(&self) -> Vec<String> {
        let mut out: Vec<String> = self.cells.iter().map(|c| c.method.clone()).collect();
        out.dedup();
        out
    }

    /// Aligned table of every cell (γ₀ columns per method row).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>10} {:>12}   {}\n",
            "method", "gamma0", "final loss", "best?"
        ));
        for c in &self.cells {
            let best = self
                .best(&c.method)
                .map(|b| (b.gamma0 - c.gamma0).abs() < 1e-12)
                .unwrap_or(false);
            out.push_str(&format!(
                "{:<28} {:>10} {:>12.6}   {}\n",
                c.method,
                c.gamma0,
                c.final_loss,
                if best { "<-- best" } else { "" }
            ));
        }
        out
    }
}

/// Grid-search `gamma0` for each (typed) method with the Bottou
/// schedule. Cells are keyed by [`MethodSpec::name`].
///
/// `steps` is the per-candidate training budget (the paper tunes on a
/// subset; callers pass a fraction of the full run).
pub fn search(
    data: &Dataset,
    methods: &[MethodSpec],
    gamma0_grid: &[f64],
    steps: usize,
    seed: u64,
) -> Result<GridResult> {
    search_local(data, methods, gamma0_grid, steps, LocalUpdate::default(), seed)
}

/// [`search`] under a [`LocalUpdate`] schedule: every candidate run
/// takes `sync_every` local steps of `batch`-sample minibatches per
/// communication, so a γ₀ can be tuned for the exact schedule the full
/// run will use (the winning γ₀ genuinely depends on `B` and `H`).
pub fn search_local(
    data: &Dataset,
    methods: &[MethodSpec],
    gamma0_grid: &[f64],
    steps: usize,
    local: LocalUpdate,
    seed: u64,
) -> Result<GridResult> {
    local.validate()?;
    let lam = 1.0 / data.n() as f64;
    let mut cells = Vec::new();
    for method in methods {
        for &gamma0 in gamma0_grid {
            let record = Experiment::new(LogisticModel::new(data, lam))
                .dataset(&data.name)
                .method(method.clone())
                .schedule(Schedule::bottou(gamma0, lam))
                .steps(steps)
                .eval_points(4)
                .seed(seed)
                .local_update(local)
                .run()?;
            let final_loss = record.final_loss();
            cells.push(GridCell {
                method: method.name(),
                gamma0,
                final_loss,
                record,
            });
        }
    }
    Ok(GridResult { cells })
}

/// The paper's default γ₀ grid (log-spaced decades around 1).
pub fn default_gamma0_grid() -> Vec<f64> {
    vec![0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn finds_a_sane_gamma0() {
        let data = synthetic::epsilon_like(300, 16, 4);
        let methods = vec![MethodSpec::mem_top_k(1), MethodSpec::Sgd];
        let grid = vec![0.001, 1.0, 1000.0];
        let res = search(&data, &methods, &grid, 1_500, 3).unwrap();
        assert_eq!(res.cells.len(), 6);
        for m in &methods {
            let best = res.best(&m.name()).unwrap();
            // The absurd extremes must not win: 0.001 barely moves,
            // 1000 blows up.
            assert_eq!(best.gamma0, 1.0, "method {} picked {}", m.name(), best.gamma0);
        }
        let t = res.table();
        assert!(t.contains("<-- best"));
        assert!(t.contains("memsgd(top_1)"));
    }

    #[test]
    fn local_schedule_search_cuts_bits_and_validates() {
        let data = synthetic::epsilon_like(200, 16, 2);
        let methods = vec![MethodSpec::mem_top_k(1)];
        let grid = vec![1.0];
        let base = search(&data, &methods, &grid, 1_200, 5).unwrap();
        let h3 = search_local(
            &data,
            &methods,
            &grid,
            1_200,
            LocalUpdate::new(1, 3).unwrap(),
            5,
        )
        .unwrap();
        // Same budget, a third of the syncs: top-1 bits drop exactly 3x.
        assert_eq!(base.cells[0].record.total_bits, 3 * h3.cells[0].record.total_bits);
        assert!(h3.cells[0].final_loss.is_finite());
        // Zero schedules are rejected at the search edge too.
        assert!(search_local(
            &data,
            &methods,
            &grid,
            100,
            LocalUpdate { batch: 1, sync_every: 0 },
            5
        )
        .is_err());
    }

    #[test]
    fn methods_listing_dedups() {
        let data = synthetic::epsilon_like(100, 8, 5);
        let res = search(&data, &[MethodSpec::Sgd], &[0.1, 1.0], 200, 1).unwrap();
        assert_eq!(res.methods(), vec!["sgd".to_string()]);
        assert!(res.best("nonexistent").is_none());
    }

    #[test]
    fn default_grid_is_log_spaced() {
        let g = default_gamma0_grid();
        assert!(g.len() >= 6);
        assert!(g.windows(2).all(|w| w[1] > w[0]));
    }
}
