//! The discrete-event engine behind Figure 4 (see module docs in
//! [`crate::sim`] for the model).

use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::util::prng::Prng;

/// Which coordinates an update writes.
#[derive(Clone, Debug, PartialEq)]
pub enum WritePattern {
    /// All `d` coordinates (Hogwild-style dense SGD, `k = d`).
    Dense,
    /// `k` uniformly random coordinates (rand-k).
    Uniform { k: usize },
    /// `k` coordinates from a Zipf(1.0) distribution over a popular
    /// subset of the space — models top-k's deterministic preference for
    /// the informative coordinates (all workers chase the same ones,
    /// which is exactly why the paper observes more collisions for top-k
    /// in the parallel setting).
    Popular { k: usize, hot_fraction: f64 },
}

impl WritePattern {
    fn nnz(&self, d: usize) -> usize {
        match *self {
            WritePattern::Dense => d,
            WritePattern::Uniform { k } | WritePattern::Popular { k, .. } => k.min(d),
        }
    }
}

/// Machine + workload constants. Defaults are calibrated to a
/// Xeon-class part: ~1 f32 FMA per core-ns on the gradient, ~1 ns per
/// store-buffer slot, ~60 ns per coherence miss, 16 f32 per cache line.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Problem dimension.
    pub d: usize,
    /// Update shape per iteration.
    pub pattern: WritePattern,
    /// Gradient compute cost per coordinate (ns).
    pub compute_ns_per_coord: f64,
    /// Serialized write cost per written entry (ns) — the coherence
    /// fabric must take exclusive ownership of the line.
    pub write_ns: f64,
    /// Fixed serialized cost per iteration (ns): the unavoidable shared
    /// accesses every iteration performs regardless of update size
    /// (sampling counter, epoch bookkeeping, one owned-line handoff).
    /// This is what eventually bends even the k=1 curve (Figure 4's
    /// flattening past ~10 cores).
    pub bus_fixed_ns: f64,
    /// Coherence re-fetch penalty per stale cache line (ns), *effective*
    /// — i.e. after overlap with compute (hardware prefetch hides most
    /// of the nominal ~60 ns).
    pub miss_penalty_ns: f64,
    /// Extra slack added to the lost-update race window (ns). The window
    /// itself is the worker's whole read-to-write span: a collision is
    /// "someone else wrote coordinate c after I read it and before I
    /// wrote it", which is exactly the non-atomic `x[c] -= g` race of
    /// Algorithm 2.
    pub collision_window_ns: f64,
    /// Extra stall added to the later writer on a collision (ns). The
    /// baseline line-handoff cost is already part of `write_ns`, so the
    /// default is 0; raise it to model pathological ping-pong.
    pub stall_ns: f64,
    /// f32 coordinates per cache line.
    pub line_coords: usize,
    /// Total iteration budget, split across workers (the "same total
    /// work" protocol).
    pub total_updates: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            d: 2000,
            pattern: WritePattern::Uniform { k: 1 },
            compute_ns_per_coord: 1.0,
            write_ns: 5.0,
            bus_fixed_ns: 150.0,
            miss_penalty_ns: 3.0,
            collision_window_ns: 0.0,
            stall_ns: 0.0,
            line_coords: 16,
            total_updates: 20_000,
            seed: 1,
        }
    }
}

/// One point of the speedup curve.
#[derive(Clone, Copy, Debug)]
pub struct SpeedupPoint {
    pub workers: usize,
    /// Simulated wall time to finish the budget (ns).
    pub time_ns: f64,
    /// Lost (overwritten) updates.
    pub lost_updates: usize,
    /// time(1 worker) / time(W workers).
    pub speedup: f64,
}

/// Simulate one worker count; returns (time_ns, lost_updates).
fn simulate(cfg: &SimConfig, workers: usize) -> (f64, usize) {
    let d = cfg.d;
    let u = cfg.pattern.nnz(d);
    let lines_total = d.div_ceil(cfg.line_coords);
    let budget = cfg.total_updates;
    let mut rng = Prng::new(cfg.seed ^ (workers as u64) << 32);

    // Zipf CDF for the Popular pattern.
    let zipf_cdf: Option<Vec<f64>> = match cfg.pattern {
        WritePattern::Popular { hot_fraction, .. } => {
            let hot = ((d as f64 * hot_fraction) as usize).max(1);
            let mut cdf = Vec::with_capacity(hot);
            let mut acc = 0.0;
            for j in 0..hot {
                acc += 1.0 / (j + 1) as f64;
                cdf.push(acc);
            }
            Some(cdf)
        }
        _ => None,
    };

    // Event queue: workers keyed by the time they become ready.
    #[derive(PartialEq)]
    struct Ev(f64, usize);
    impl Eq for Ev {}
    impl Ord for Ev {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            other.0.partial_cmp(&self.0).unwrap() // min-heap on time
        }
    }
    impl PartialOrd for Ev {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let mut heap: BinaryHeap<Ev> = (0..workers).map(|w| Ev(0.0, w)).collect();
    let mut bus_free = 0.0f64;
    // coordinate → (last write time, last writer)
    let mut last_write: HashMap<u32, (f64, usize)> = HashMap::new();
    // per-worker: global write counter at its previous iteration (to
    // estimate stale lines cheaply), and scratch for written coords.
    let mut writes_seen = vec![0u64; workers];
    let mut total_writes = 0u64;
    let mut done = 0usize;
    let mut lost = 0usize;
    let mut coords: Vec<u32> = Vec::with_capacity(u);
    let mut finish_time = 0.0f64;

    // Fixed total-iteration budget (the paper's "same total work, more
    // cores" protocol); collisions are reported as a convergence-quality
    // statistic, not re-queued — Algorithm 2 never retries a lost write.
    while done < budget {
        let Ev(t, w) = heap.pop().expect("no workers");
        // --- compute phase -------------------------------------------------
        // Stale lines: writes by *other* workers since this worker's last
        // iteration, one line each (conservative: distinct), capped at the
        // whole vector.
        let others_writes = (total_writes - writes_seen[w]).saturating_sub(0);
        let stale_lines = (others_writes as usize).min(lines_total);
        let t_compute =
            cfg.compute_ns_per_coord * d as f64 + cfg.miss_penalty_ns * stale_lines as f64;
        let compute_done = t + t_compute;
        // --- write phase (serialized) --------------------------------------
        let bus_start = compute_done.max(bus_free);
        let mut t_cursor = bus_start + cfg.bus_fixed_ns;
        coords.clear();
        match &cfg.pattern {
            WritePattern::Dense => {
                // Dense writes: model per-line, not per-coordinate, writes
                // (hardware write-combines within a line).
                for l in 0..lines_total {
                    coords.push((l * cfg.line_coords) as u32);
                }
            }
            WritePattern::Uniform { k } => {
                for _ in 0..*k {
                    coords.push(rng.below(d) as u32);
                }
            }
            WritePattern::Popular { k, .. } => {
                let cdf = zipf_cdf.as_ref().unwrap();
                let total = *cdf.last().unwrap();
                for _ in 0..*k {
                    let x = rng.f64() * total;
                    let j = match cdf.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
                        Ok(j) | Err(j) => j.min(cdf.len() - 1),
                    };
                    coords.push(j as u32);
                }
            }
        }
        let mut iteration_lost = false;
        for &c in &coords {
            t_cursor += cfg.write_ns;
            match last_write.get(&c) {
                // Lost-update race: another worker wrote c after this
                // worker read the vector (iteration start at `t`), so the
                // plain load-then-store drops one of the two updates. The
                // time cost of the line handoff is already in `write_ns`;
                // `stall_ns` adds optional extra ping-pong latency.
                Some(&(tw, ww)) if ww != w && tw + cfg.collision_window_ns > t => {
                    t_cursor += cfg.stall_ns;
                    iteration_lost = true;
                }
                _ => {}
            }
            last_write.insert(c, (t_cursor, w));
        }
        bus_free = t_cursor;
        total_writes += coords.len() as u64;
        writes_seen[w] = total_writes;
        if iteration_lost {
            lost += 1;
        }
        done += 1;
        finish_time = finish_time.max(t_cursor);
        heap.push(Ev(t_cursor, w));
    }
    (finish_time, lost)
}

/// Sweep worker counts and return the normalized speedup series.
pub fn speedup_series(cfg: &SimConfig, worker_counts: &[usize]) -> Vec<SpeedupPoint> {
    let (t1, _) = simulate(cfg, 1);
    worker_counts
        .iter()
        .map(|&w| {
            let (t, lost) = simulate(cfg, w);
            SpeedupPoint {
                workers: w,
                time_ns: t,
                lost_updates: lost,
                speedup: t1 / t,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts() -> Vec<usize> {
        vec![1, 2, 4, 8, 12, 16, 20, 24]
    }

    #[test]
    fn single_worker_speedup_is_one() {
        let cfg = SimConfig::default();
        let pts = speedup_series(&cfg, &[1]);
        assert!((pts[0].speedup - 1.0).abs() < 1e-9);
        assert_eq!(pts[0].lost_updates, 0); // no other writers → no collisions
    }

    #[test]
    fn sparse_updates_scale_nearly_linearly() {
        let cfg = SimConfig {
            pattern: WritePattern::Uniform { k: 1 },
            total_updates: 8_000,
            ..Default::default()
        };
        let pts = speedup_series(&cfg, &counts());
        let at = |w: usize| pts.iter().find(|p| p.workers == w).unwrap().speedup;
        assert!(at(8) > 6.0, "k=1 speedup at 8 workers: {}", at(8));
        assert!(at(12) > 8.0, "k=1 speedup at 12 workers: {}", at(12));
        // monotone non-decreasing up to 8 (no pathological dips)
        assert!(at(2) > 1.5 && at(4) > 3.0);
    }

    #[test]
    fn dense_updates_saturate_early() {
        let cfg = SimConfig {
            pattern: WritePattern::Dense,
            total_updates: 2_000,
            ..Default::default()
        };
        let pts = speedup_series(&cfg, &counts());
        let at = |w: usize| pts.iter().find(|p| p.workers == w).unwrap().speedup;
        // The paper's Figure 4: dense lock-free SGD plateaus while
        // Mem-SGD keeps climbing.
        assert!(at(24) < 6.0, "dense speedup at 24 workers: {}", at(24));
        let sparse = SimConfig {
            pattern: WritePattern::Uniform { k: 1 },
            total_updates: 2_000,
            ..Default::default()
        };
        let sp = speedup_series(&sparse, &counts());
        let sat = |w: usize| sp.iter().find(|p| p.workers == w).unwrap().speedup;
        assert!(
            sat(16) > 1.8 * at(16),
            "sparse {} should dominate dense {} at 16 workers",
            sat(16),
            at(16)
        );
    }

    #[test]
    fn popular_pattern_collides_more_than_uniform() {
        // top-k's deterministic coordinate preference → more collisions
        // (the paper's explanation for top-k ≈ rand-k in parallel).
        let mk = |pattern| SimConfig {
            pattern,
            total_updates: 10_000,
            ..Default::default()
        };
        let uni = speedup_series(&mk(WritePattern::Uniform { k: 1 }), &[16]);
        let pop = speedup_series(
            &mk(WritePattern::Popular { k: 1, hot_fraction: 0.02 }),
            &[16],
        );
        assert!(
            pop[0].lost_updates > 2 * uni[0].lost_updates.max(1),
            "popular lost {} vs uniform lost {}",
            pop[0].lost_updates,
            uni[0].lost_updates
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = SimConfig::default();
        let a = speedup_series(&cfg, &[4]);
        let b = speedup_series(&cfg, &[4]);
        assert_eq!(a[0].time_ns, b[0].time_ns);
        assert_eq!(a[0].lost_updates, b[0].lost_updates);
    }

    #[test]
    fn more_workers_never_slow_wall_clock_catastrophically() {
        // Even dense mode must not be *slower* than 1 worker by more
        // than the stall overhead (sanity bound on the model).
        let cfg = SimConfig {
            pattern: WritePattern::Dense,
            total_updates: 1_000,
            ..Default::default()
        };
        let pts = speedup_series(&cfg, &[1, 24]);
        assert!(pts[1].speedup > 0.5, "W=24 speedup {}", pts[1].speedup);
    }
}
