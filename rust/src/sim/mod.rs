//! Discrete-event multicore model — the Figure 4 substrate.
//!
//! The paper measures wall-clock speedup of Algorithm 2 on a 24-core
//! Xeon. This machine exposes **one** physical core, so measured
//! speedups are physically impossible here; instead we simulate the
//! *mechanism* the paper identifies — sparse updates dirty few cache
//! lines, so lock-free workers rarely stall on each other, while dense
//! (Hogwild-style) writers thrash the coherence fabric (DESIGN.md §3).
//!
//! The model, per worker iteration:
//!
//! 1. **Compute phase** — gradient cost `compute_ns_per_coord · d` plus
//!    a *coherence read penalty*: every cache line another worker wrote
//!    since this worker's previous iteration is invalid here and must be
//!    re-fetched (`miss_penalty_ns` per stale line, capped at the whole
//!    vector's d/16 lines).
//! 2. **Write phase** — the update's `u` coordinates are stored through
//!    a serialized shared resource (store-buffer drain / bus): FIFO,
//!    `write_ns` per coordinate.
//! 3. **Collision** — when two workers write the same coordinate within
//!    `collision_window_ns`, the later write is counted *lost* (plain
//!    load-then-store semantics drop one update) and the writer stalls
//!    `stall_ns` (cache-line ping-pong).
//!
//! Speedup is time-to-complete a fixed total budget of *effective*
//! (non-lost) updates, normalized to one worker — the same protocol as
//! the paper's "same total work, more cores" runs.

pub mod multicore;
pub mod network;

pub use multicore::{speedup_series, SimConfig, SpeedupPoint, WritePattern};
pub use network::{ComputeModel, NetworkModel, PricedRun};
