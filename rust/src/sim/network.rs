//! Network cost model — prices a distributed run in simulated wall-clock.
//!
//! The paper's abstract claims "communication can be reduced by a factor
//! of the dimension of the problem … whilst still converging at the same
//! rate", and §5 argues the distributed setting is where sparsification
//! "might have the largest impact". This module turns the bit counts the
//! optimizers already report into *time*, so the `figure6` experiment can
//! answer the question the paper's Figures 2–3 imply but never plot:
//! time-to-accuracy of Mem-SGD vs dense SGD vs QSGD on links of different
//! speed.
//!
//! The model is a synchronous parameter-server round over `W` workers:
//!
//! ```text
//! round = compute  +  2·latency  +  Σ_w upload_bits / server_bw
//!                                +  broadcast_bits  / server_bw
//! ```
//!
//! * the server's ingress link is the shared bottleneck (uploads
//!   serialize into it; workers' own egress is assumed at least as fast),
//! * the broadcast goes out once on the egress link (switch multicast /
//!   tree broadcast; choosing `W·broadcast` instead only rescales the
//!   dense baseline *harder*, so this is the conservative choice),
//! * compute is `max_w` of the per-worker gradient time (stragglers via
//!   [`ComputeModel::straggler_factor`]).
//!
//! All quantities are f64 seconds; nothing here does real I/O.

/// A point-to-point link / NIC profile.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkModel {
    pub name: String,
    /// One-way message latency (seconds).
    pub latency_s: f64,
    /// Server NIC bandwidth (bits per second), shared by the uploads.
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    pub fn new(name: &str, latency_s: f64, bandwidth_bps: f64) -> Self {
        assert!(latency_s >= 0.0 && bandwidth_bps > 0.0);
        NetworkModel {
            name: name.to_string(),
            latency_s,
            bandwidth_bps,
        }
    }

    /// Commodity gigabit Ethernet: 50 µs, 1 Gb/s.
    pub fn eth_1g() -> Self {
        NetworkModel::new("1GbE", 50e-6, 1e9)
    }

    /// Datacenter 10 GbE: 20 µs, 10 Gb/s.
    pub fn eth_10g() -> Self {
        NetworkModel::new("10GbE", 20e-6, 10e9)
    }

    /// HPC interconnect (EDR InfiniBand class): 2 µs, 100 Gb/s.
    pub fn ib_100g() -> Self {
        NetworkModel::new("100Gb-IB", 2e-6, 100e9)
    }

    /// The three presets, slowest first.
    pub fn presets() -> Vec<NetworkModel> {
        vec![Self::eth_1g(), Self::eth_10g(), Self::ib_100g()]
    }

    /// Time to move `bits` through the (server) link.
    pub fn xfer_s(&self, bits: u64) -> f64 {
        bits as f64 / self.bandwidth_bps
    }

    /// Wall-clock of one synchronous round.
    ///
    /// `upload_bits` is the *sum* over workers; `broadcast_bits` the
    /// aggregated model delta sent back once.
    pub fn round_s(&self, upload_bits: u64, broadcast_bits: u64, compute_s: f64) -> f64 {
        compute_s + 2.0 * self.latency_s + self.xfer_s(upload_bits) + self.xfer_s(broadcast_bits)
    }
}

/// How long a worker takes to produce one stochastic gradient.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    /// Seconds per gradient coordinate touched (fused multiply + sigmoid
    /// amortized); ~1 ns/coord matches the measured native backend.
    pub s_per_coord: f64,
    /// Coordinates touched per gradient (d for dense data, row nnz for
    /// sparse).
    pub coords_per_grad: f64,
    /// Slowest-worker multiplier ≥ 1 applied to the round's compute
    /// phase (synchronous rounds wait for the straggler).
    pub straggler_factor: f64,
}

impl ComputeModel {
    pub fn new(s_per_coord: f64, coords_per_grad: f64) -> Self {
        ComputeModel {
            s_per_coord,
            coords_per_grad,
            straggler_factor: 1.0,
        }
    }

    /// Per-round compute wall-clock (`grads_per_worker` local steps).
    pub fn round_s(&self, grads_per_worker: usize) -> f64 {
        self.s_per_coord * self.coords_per_grad * grads_per_worker as f64 * self.straggler_factor
    }

    /// Compute wall-clock of one local-update phase: `sync_every` local
    /// steps of `batch`-sample minibatches (each sample touching
    /// `coords_per_grad` coordinates) — what a round costs under a
    /// `LocalUpdate { batch, sync_every }` schedule, where the same
    /// gradient work takes `sync_every`-fold fewer communication rounds.
    pub fn phase_s(&self, batch: usize, sync_every: usize) -> f64 {
        self.round_s(batch.max(1).saturating_mul(sync_every.max(1)))
    }
}

/// Summary of pricing one finished run on one network.
#[derive(Clone, Debug)]
pub struct PricedRun {
    pub network: String,
    pub method: String,
    /// Simulated seconds spent in compute across the run.
    pub compute_s: f64,
    /// Simulated seconds spent on the wire.
    pub comm_s: f64,
    /// compute + comm.
    pub total_s: f64,
    /// comm / total ∈ [0, 1].
    pub comm_fraction: f64,
}

/// Price a sequence of per-round `(upload_bits, broadcast_bits)` message
/// sizes on a network + compute model.
pub fn price_rounds(
    net: &NetworkModel,
    compute: &ComputeModel,
    method: &str,
    rounds: &[(u64, u64)],
    grads_per_round: usize,
) -> PricedRun {
    let mut compute_s = 0.0;
    let mut comm_s = 0.0;
    for &(up, down) in rounds {
        let c = compute.round_s(grads_per_round);
        compute_s += c;
        comm_s += net.round_s(up, down, 0.0);
    }
    let total_s = compute_s + comm_s;
    PricedRun {
        network: net.name.clone(),
        method: method.to_string(),
        compute_s,
        comm_s,
        total_s,
        comm_fraction: if total_s > 0.0 { comm_s / total_s } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_by_speed() {
        let p = NetworkModel::presets();
        assert_eq!(p.len(), 3);
        assert!(p[0].bandwidth_bps < p[1].bandwidth_bps);
        assert!(p[1].bandwidth_bps < p[2].bandwidth_bps);
        assert!(p[0].latency_s > p[2].latency_s);
    }

    #[test]
    fn xfer_time_scales_linearly() {
        let net = NetworkModel::eth_1g();
        assert!((net.xfer_s(1_000_000_000) - 1.0).abs() < 1e-12);
        assert!((net.xfer_s(500_000_000) - 0.5).abs() < 1e-12);
        assert_eq!(net.xfer_s(0), 0.0);
    }

    #[test]
    fn round_time_decomposition() {
        let net = NetworkModel::new("t", 1e-3, 1e6);
        // 1000 bits up + 1000 down at 1e6 bps = 2 ms; latency 2 ms; compute 5 ms.
        let r = net.round_s(1000, 1000, 5e-3);
        assert!((r - (5e-3 + 2e-3 + 2e-3)).abs() < 1e-12, "r={r}");
    }

    #[test]
    fn dense_gradient_dominates_slow_links() {
        // d=2000 dense f32 upload from 8 workers vs top-1 sparse upload:
        // on 1GbE the dense round must be >100× more expensive on the wire.
        let net = NetworkModel::eth_1g();
        let dense_up = 8 * 2000 * 32u64;
        let sparse_up = 8 * (32 + 11) as u64;
        let dense = net.round_s(dense_up, 2000 * 32, 0.0);
        let sparse = net.round_s(sparse_up, 8 * (32 + 11), 0.0);
        assert!(dense / sparse > 4.0, "dense={dense} sparse={sparse}");
        // And pure transfer (without latency floor) >100×:
        assert!(net.xfer_s(dense_up) / net.xfer_s(sparse_up) > 100.0);
    }

    #[test]
    fn priced_run_fraction_bounds() {
        let net = NetworkModel::eth_10g();
        let cm = ComputeModel::new(1e-9, 2000.0);
        let rounds: Vec<(u64, u64)> = (0..100).map(|_| (64_000, 64_000)).collect();
        let p = price_rounds(&net, &cm, "sgd", &rounds, 1);
        assert!(p.comm_fraction > 0.0 && p.comm_fraction < 1.0);
        assert!((p.total_s - (p.compute_s + p.comm_s)).abs() < 1e-12);
    }

    #[test]
    fn local_phase_compute_scales_with_batch_and_sync_interval() {
        let cm = ComputeModel::new(1e-9, 500.0);
        assert_eq!(cm.phase_s(1, 1), cm.round_s(1));
        assert_eq!(cm.phase_s(2, 3), cm.round_s(6));
        // Degenerate zeros are clamped, not propagated into a free round.
        assert_eq!(cm.phase_s(0, 4), cm.round_s(4));
        // A local-update round costs H·B gradients but is paid H-fold
        // less often: per-gradient compute is unchanged.
        let per_grad = cm.phase_s(4, 8) / 32.0;
        assert!((per_grad - cm.round_s(1)).abs() < 1e-18);
    }

    #[test]
    fn straggler_inflates_compute_only() {
        let mut cm = ComputeModel::new(1e-9, 1000.0);
        let base = cm.round_s(10);
        cm.straggler_factor = 3.0;
        assert!((cm.round_s(10) - 3.0 * base).abs() < 1e-15);
    }
}
