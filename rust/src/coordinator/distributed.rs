//! Distributed data-parallel Mem-SGD — the paper's motivating setting
//! ("communicating the stochastic gradients to the other workers is a
//! major limiting factor", §1; "those are the domains where sparsified
//! SGD might have the largest impact", §5).
//!
//! Synchronous parameter-server rounds over `W` workers, message-passing
//! semantics (no shared memory):
//!
//! ```text
//! round t:  worker w:  g_t^w ← comp(m_t^w + η_t ∇f_{i_w}(x_t))     (upload)
//!                      m_{t+1}^w ← m_t^w + η_t ∇f_{i_w}(x_t) − g_t^w
//!           server:    x_{t+1} ← x_t − (1/W) Σ_w g_t^w             (broadcast)
//! ```
//!
//! Each worker keeps its **own** error memory (exactly Algorithm 2's
//! per-worker `m^w`, but with consistent reads — the synchronous
//! analogue). Communication accounting covers both directions: `W`
//! compressed uploads plus one broadcast whose cost is the *union* of
//! the workers' supports (at most `W·k` coordinates; the server
//! aggregates before broadcasting).
//!
//! The simulation runs in-process but preserves the exact dataflow of a
//! real deployment: workers only ever observe `x_t` and their private
//! memory, and the server only ever observes the compressed uploads.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Compressor, Update};
use crate::data::Dataset;
use crate::metrics::{LossPoint, RunRecord};
use crate::models::{GradBackend, LogisticModel};
use crate::optim::Schedule;
use crate::util::prng::Prng;

/// Configuration of a synchronous distributed run.
#[derive(Clone, Debug)]
pub struct DistributedConfig {
    /// Worker (node) count.
    pub workers: usize,
    /// Synchronous rounds (each consumes `workers` stochastic gradients).
    pub rounds: usize,
    /// Per-worker compressor spec.
    pub compressor: String,
    /// Stepsize schedule over rounds.
    pub schedule: Schedule,
    /// Loss evaluations along the run.
    pub eval_points: usize,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 8,
            rounds: 5_000,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            eval_points: 10,
            lam: None,
            seed: 1,
        }
    }
}

/// One worker's state: private error memory + compressor + RNG stream.
struct Worker {
    memory: Vec<f32>,
    v: Vec<f32>,
    comp: Box<dyn Compressor>,
    update: Update,
    rng: Prng,
    bits_uploaded: u64,
}

/// Run synchronous distributed Mem-SGD; evaluates the final server
/// iterate plus a loss curve, and accounts upload + broadcast bits.
pub fn run(data: &Dataset, cfg: &DistributedConfig) -> Result<RunRecord> {
    let d = data.d();
    let n = data.n();
    let lam = cfg.lam.unwrap_or(1.0 / n as f64);
    let mut model = LogisticModel::new(data, lam);
    let mut root_rng = Prng::new(cfg.seed);

    let mut workers: Vec<Worker> = (0..cfg.workers)
        .map(|w| {
            Ok(Worker {
                memory: vec![0.0; d],
                v: vec![0.0; d],
                comp: compress::from_spec(&cfg.compressor)?,
                update: Update::new_sparse(d),
                rng: root_rng.split(w as u64 + 1),
                bits_uploaded: 0,
            })
        })
        .collect::<Result<_>>()?;

    let mut x = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];
    // Server-side aggregation buffer: coordinate → summed update.
    let mut agg: BTreeMap<u32, f32> = BTreeMap::new();
    let mut agg_dense = vec![0.0f32; d];
    let mut broadcast_bits = 0u64;
    let idx_bits = crate::compress::sparse::index_bits(d);

    let eval_every = (cfg.rounds / cfg.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: format!("dist_memsgd({},W={})", cfg.compressor, cfg.workers),
        dataset: data.name.clone(),
        schedule: cfg.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint {
        t: 0,
        bits: 0,
        loss: model.full_loss(&x),
    });

    for round in 0..cfg.rounds {
        let eta = cfg.schedule.eta(round);
        let etaf = eta as f32;
        agg.clear();
        let mut any_dense = false;
        for worker in workers.iter_mut() {
            // Local stochastic gradient at the *current broadcast* x.
            let i = worker.rng.below(n);
            model.sample_grad(&x, i, &mut grad);
            // Error feedback only for contraction operators; unbiased
            // quantizers (QSGD) run memory-free exactly as in the paper's
            // §4.3 baseline — accumulating their unbiased noise would
            // amplify it instead of correcting it.
            let use_memory = worker.comp.contraction_k(d).is_some();
            if use_memory {
                for ((vj, &mj), &gj) in worker.v.iter_mut().zip(&worker.memory).zip(&grad) {
                    *vj = mj + etaf * gj;
                }
            } else {
                for (vj, &gj) in worker.v.iter_mut().zip(&grad) {
                    *vj = etaf * gj;
                }
            }
            worker.bits_uploaded += worker.comp.compress(&worker.v, &mut worker.rng, &mut worker.update);
            // Server receives the upload and folds it into the aggregate.
            match &worker.update {
                Update::Sparse(s) => {
                    for (&j, &vj) in s.idx.iter().zip(&s.val) {
                        *agg.entry(j).or_insert(0.0) += vj;
                    }
                }
                Update::Dense(g) => {
                    any_dense = true;
                    for (a, &gj) in agg_dense.iter_mut().zip(g) {
                        *a += gj;
                    }
                }
            }
            // Local memory update m ← v − g (contraction operators only).
            if use_memory {
                std::mem::swap(&mut worker.memory, &mut worker.v);
                worker.update.sub_from(&mut worker.memory);
            }
        }
        // Server applies the mean update and broadcasts it.
        let scale = 1.0 / cfg.workers as f32;
        if any_dense {
            for (xj, a) in x.iter_mut().zip(agg_dense.iter_mut()) {
                *xj -= *a * scale;
                *a = 0.0;
            }
            broadcast_bits += 32 * d as u64;
        } else {
            for (&j, &vj) in agg.iter() {
                x[j as usize] -= vj * scale;
            }
            broadcast_bits += agg.len() as u64 * (32 + idx_bits);
        }

        if (round + 1) % eval_every == 0 || round + 1 == cfg.rounds {
            let uploads: u64 = workers.iter().map(|w| w.bits_uploaded).sum();
            record.curve.push(LossPoint {
                t: round + 1,
                bits: uploads + broadcast_bits,
                loss: model.full_loss(&x),
            });
        }
    }

    let uploads: u64 = workers.iter().map(|w| w.bits_uploaded).sum();
    record.steps = cfg.rounds * cfg.workers;
    record.total_bits = uploads + broadcast_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), cfg.workers as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record
        .extra
        .insert("broadcast_bits".into(), broadcast_bits as f64);
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(800, 32, 21)
    }

    fn cfg(workers: usize, comp: &str, rounds: usize) -> DistributedConfig {
        DistributedConfig {
            workers,
            rounds,
            compressor: comp.into(),
            schedule: Schedule::constant(0.5),
            eval_points: 4,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn converges_with_top1_uploads() {
        let data = data();
        let rec = run(&data, &cfg(8, "top_k:1", 3_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
        assert_eq!(rec.steps, 24_000);
    }

    #[test]
    fn one_worker_equals_sequential_memsgd_shape() {
        // W = 1 distributed is Algorithm 1 with the same stream: must
        // converge to the same ballpark as the sequential driver.
        let data = data();
        let rec = run(&data, &cfg(1, "top_k:2", 6_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
    }

    #[test]
    fn communication_accounting_both_directions() {
        let data = data();
        let w = 4;
        let rounds = 100;
        let rec = run(&data, &cfg(w, "top_k:1", rounds)).unwrap();
        // uploads: exactly W·rounds·(32+5) bits for d=32.
        assert_eq!(rec.extra["upload_bits"] as u64, (w * rounds) as u64 * 37);
        // broadcast: union support ≤ W coords per round.
        let bc = rec.extra["broadcast_bits"] as u64;
        assert!(bc > 0 && bc <= (w * rounds) as u64 * 37, "bc={bc}");
        assert_eq!(rec.total_bits, rec.extra["upload_bits"] as u64 + bc);
    }

    #[test]
    fn dense_uploads_cost_full_vectors() {
        let data = data();
        let rec = run(&data, &cfg(2, "identity", 50)).unwrap();
        // 2 workers × 50 rounds × 32·d upload + 50 × 32·d broadcast.
        assert_eq!(
            rec.total_bits,
            (2 * 50 + 50) as u64 * 32 * 32
        );
    }

    #[test]
    fn more_workers_reduce_rounds_to_target() {
        // Data-parallel variance reduction: with the same round budget,
        // W=8 (8 gradients/round) should do at least as well as W=1.
        let data = data();
        let w1 = run(&data, &cfg(1, "top_k:1", 2_000)).unwrap();
        let w8 = run(&data, &cfg(8, "top_k:1", 2_000)).unwrap();
        assert!(
            w8.final_loss() <= w1.final_loss() + 0.01,
            "W=8 {} vs W=1 {}",
            w8.final_loss(),
            w1.final_loss()
        );
    }

    #[test]
    fn sign_compressor_works_distributed() {
        let data = data();
        let rec = run(&data, &cfg(4, "sign", 1_500)).unwrap();
        assert!(rec.final_loss() < 0.67, "loss {}", rec.final_loss());
        // 1 bit per coord per upload: 4·1500·(32+32) upload bits.
        assert_eq!(rec.extra["upload_bits"] as u64, 4 * 1500 * (32 + 32));
    }

    #[test]
    fn deterministic_in_seed() {
        let data = data();
        let a = run(&data, &cfg(3, "rand_k:2", 300)).unwrap();
        let b = run(&data, &cfg(3, "rand_k:2", 300)).unwrap();
        assert_eq!(a.final_loss(), b.final_loss());
        assert_eq!(a.total_bits, b.total_bits);
    }
}
