//! Distributed data-parallel Mem-SGD — the paper's motivating setting
//! ("communicating the stochastic gradients to the other workers is a
//! major limiting factor", §1; "those are the domains where sparsified
//! SGD might have the largest impact", §5).
//!
//! Synchronous parameter-server rounds over `W` workers, message-passing
//! semantics (no shared memory):
//!
//! ```text
//! round t:  worker w:  g_t^w ← comp(m_t^w + η_t ∇f_{i_w}(x_t))     (upload)
//!                      m_{t+1}^w ← m_t^w + η_t ∇f_{i_w}(x_t) − g_t^w
//!           server:    x_{t+1} ← x_t − (1/W) Σ_w g_t^w             (broadcast)
//! ```
//!
//! Each worker keeps its **own** error memory (exactly Algorithm 2's
//! per-worker `m^w`, but with consistent reads — the synchronous
//! analogue). Communication accounting covers both directions: `W`
//! compressed uploads plus one broadcast whose cost is the *union* of
//! the workers' supports (at most `W·k` coordinates; the server
//! aggregates before broadcasting).
//!
//! The simulation runs in-process but preserves the exact dataflow of a
//! real deployment: workers only ever observe `x_t` and their private
//! memory, and the server only ever observes the compressed uploads.
//!
//! The round loop lives in the generic parameter-server engine of
//! [`super::experiment`] (topology `ParamServerSync { nodes }`), which
//! runs the crate-wide [`crate::optim::ErrorFeedbackStep`] against any
//! [`crate::models::GradBackend`]; this module keeps the deprecated
//! string-spec [`run`] shim.

use anyhow::Result;

use super::config::MethodSpec;
use super::experiment;
use crate::compress::CompressorSpec;
use crate::data::Dataset;
use crate::metrics::RunRecord;
use crate::models::LogisticModel;
use crate::optim::Schedule;

/// Configuration of a synchronous distributed run.
#[derive(Clone, Debug)]
pub struct DistributedConfig {
    /// Worker (node) count.
    pub workers: usize,
    /// Synchronous rounds (each consumes `workers` stochastic gradients).
    pub rounds: usize,
    /// Per-worker compressor spec.
    pub compressor: String,
    /// Stepsize schedule over rounds.
    pub schedule: Schedule,
    /// Loss evaluations along the run.
    pub eval_points: usize,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 8,
            rounds: 5_000,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            eval_points: 10,
            lam: None,
            seed: 1,
        }
    }
}

/// Run synchronous distributed Mem-SGD; evaluates the final server
/// iterate plus a loss curve, and accounts upload + broadcast bits.
///
/// Deprecated shim: parses the compressor spec once and delegates to the
/// generic parameter-server engine behind
/// [`super::experiment::Experiment`] (topology `ParamServerSync`).
pub fn run(data: &Dataset, cfg: &DistributedConfig) -> Result<RunRecord> {
    let comp = CompressorSpec::parse(&cfg.compressor)?;
    let lam = cfg.lam.unwrap_or(1.0 / data.n() as f64);
    let settings = experiment::Settings {
        method: MethodSpec::MemSgd { comp },
        schedule: cfg.schedule.clone(),
        steps: cfg.rounds * cfg.workers.max(1),
        eval_points: cfg.eval_points,
        average: false,
        seed: cfg.seed,
        dataset: data.name.clone(),
        local: super::config::LocalUpdate::default(),
    };
    let mut model = LogisticModel::new(data, lam);
    experiment::param_server_sync(&mut model, cfg.workers, &settings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(800, 32, 21)
    }

    fn cfg(workers: usize, comp: &str, rounds: usize) -> DistributedConfig {
        DistributedConfig {
            workers,
            rounds,
            compressor: comp.into(),
            schedule: Schedule::constant(0.5),
            eval_points: 4,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn converges_with_top1_uploads() {
        let data = data();
        let rec = run(&data, &cfg(8, "top_k:1", 3_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
        assert_eq!(rec.steps, 24_000);
    }

    #[test]
    fn one_worker_equals_sequential_memsgd_shape() {
        // W = 1 distributed is Algorithm 1 with the same stream: must
        // converge to the same ballpark as the sequential driver.
        let data = data();
        let rec = run(&data, &cfg(1, "top_k:2", 6_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
    }

    #[test]
    fn communication_accounting_both_directions() {
        let data = data();
        let w = 4;
        let rounds = 100;
        let rec = run(&data, &cfg(w, "top_k:1", rounds)).unwrap();
        // uploads: exactly W·rounds·(32+5) bits for d=32.
        assert_eq!(rec.extra["upload_bits"] as u64, (w * rounds) as u64 * 37);
        // broadcast: union support ≤ W coords per round.
        let bc = rec.extra["broadcast_bits"] as u64;
        assert!(bc > 0 && bc <= (w * rounds) as u64 * 37, "bc={bc}");
        assert_eq!(rec.total_bits, rec.extra["upload_bits"] as u64 + bc);
    }

    #[test]
    fn dense_uploads_cost_full_vectors() {
        let data = data();
        let rec = run(&data, &cfg(2, "identity", 50)).unwrap();
        // 2 workers × 50 rounds × 32·d upload + 50 × 32·d broadcast.
        assert_eq!(
            rec.total_bits,
            (2 * 50 + 50) as u64 * 32 * 32
        );
    }

    #[test]
    fn more_workers_reduce_rounds_to_target() {
        // Data-parallel variance reduction: with the same round budget,
        // W=8 (8 gradients/round) should do at least as well as W=1.
        let data = data();
        let w1 = run(&data, &cfg(1, "top_k:1", 2_000)).unwrap();
        let w8 = run(&data, &cfg(8, "top_k:1", 2_000)).unwrap();
        assert!(
            w8.final_loss() <= w1.final_loss() + 0.01,
            "W=8 {} vs W=1 {}",
            w8.final_loss(),
            w1.final_loss()
        );
    }

    #[test]
    fn sign_compressor_works_distributed() {
        let data = data();
        let rec = run(&data, &cfg(4, "sign", 1_500)).unwrap();
        assert!(rec.final_loss() < 0.67, "loss {}", rec.final_loss());
        // 1 bit per coord per upload: 4·1500·(32+32) upload bits.
        assert_eq!(rec.extra["upload_bits"] as u64, 4 * 1500 * (32 + 32));
    }

    #[test]
    fn deterministic_in_seed() {
        let data = data();
        let a = run(&data, &cfg(3, "rand_k:2", 300)).unwrap();
        let b = run(&data, &cfg(3, "rand_k:2", 300)).unwrap();
        assert_eq!(a.final_loss(), b.final_loss());
        assert_eq!(a.total_bits, b.total_bits);
    }
}
