//! Deterministic fault injection and the failure policies that absorb
//! the injected faults.
//!
//! The paper's own mechanism motivates this module: error feedback
//! accumulates whatever gradient mass did not ship, so a contribution
//! lost to a dead peer this round is not gone — it is carried in the
//! survivor's memory and shipped later. That makes graceful degradation
//! theory-backed rather than heuristic, and this module supplies both
//! halves of testing it:
//!
//! * **Fault plans** ([`FaultSpec`] → [`FaultPlan`]): a seeded,
//!   per-node, per-operation schedule of injected faults (cut the
//!   connection, drop a frame, corrupt a byte, delay an operation),
//!   drawn from the crate [`Prng`] so the same `spec:seed` string
//!   replays the exact same schedule bit for bit — in-process, across
//!   OS processes, and in CI.
//! * **Fault wrappers** ([`FaultyChannel`] / [`FaultyTransport`]):
//!   decorators over the existing [`Channel`] / [`Transport`] traits
//!   that count operations on the wrapped endpoint and fire the
//!   scheduled faults. The engines underneath are unmodified — they see
//!   a peer that genuinely misbehaves.
//! * **Failure policies** ([`FailurePolicy`]): what an engine does when
//!   a peer dies. `FailFast` is today's behavior (one dead peer fails
//!   the run, every thread still joined). `DropRound` aggregates the
//!   quorum that arrived, marks the dead node, and keeps going — the
//!   suppressed mass stays in the dead node's error memory, exactly the
//!   regime Alistarh et al. and Basu et al. analyze. `WaitRejoin`
//!   additionally lets a replacement worker handshake back in and
//!   resume from a model `SNAPSHOT` frame.
//!
//! ## Counting contract
//!
//! A fault is addressed `(op, at)`: it fires on the `at`-th (0-indexed)
//! `send` or `recv` **performed on the wrapped endpoint**. On the
//! parameter-server sync protocol the server performs exactly one
//! `recv` per node per round, so "cut node 3's channel at recv #5"
//! reads as "node 3 dies in round 5, having contributed rounds 0–4" —
//! which is also exactly what the simulated twin replays
//! ([`FaultPlan::sim_deaths`]). A plan wrapped on the *worker* side of
//! the same link uses the mirrored ops ([`FaultPlan::wrap_peer`]):
//! a server-side `recv` cut is a worker-side `send` cut. Wrap a plan on
//! **one** side of a link, never both — double-wrapping injects every
//! fault twice.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Channel, Transport};
use crate::util::prng::Prng;

/// The error text an injected connection cut surfaces as, on both the
/// cut operation itself and every operation after it. Tests match on
/// this substring to distinguish injected faults from real I/O errors.
pub const PEER_HUNG_UP: &str = "injected fault: peer hung up mid-round";

// ---------------------------------------------------------------------------
// Failure policies
// ---------------------------------------------------------------------------

/// What an engine does when a peer dies mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Today's behavior and the default: the first dead peer fails the
    /// whole run with a descriptive error naming the node. Every
    /// surviving thread is still joined.
    FailFast,
    /// Aggregate the quorum that arrived, mark the dead node, keep
    /// going. The dead node's unsent mass stays in its error memory
    /// (simulated) or is simply never folded (wire) — the surviving
    /// trajectory is still deterministic. The run fails only when live
    /// nodes drop below `min_quorum`.
    DropRound {
        /// Minimum live nodes required to continue (clamped to ≥ 1).
        min_quorum: usize,
    },
    /// Like `DropRound`, but after each degraded round the server waits
    /// up to `timeout` for a replacement worker to handshake back in
    /// with a `resume` Hello; the rejoiner is re-synced from a model
    /// `SNAPSHOT` frame. Only the multi-process cluster runtime can
    /// accept new connections mid-run, so `Experiment` rejects this
    /// policy outside `memsgd serve`.
    WaitRejoin {
        /// How long to wait for a rejoining worker each degraded round.
        timeout: Duration,
    },
}

impl Default for FailurePolicy {
    fn default() -> FailurePolicy {
        FailurePolicy::FailFast
    }
}

impl FailurePolicy {
    /// Parse a policy spec string: `fail-fast`, `drop-round` (quorum 1),
    /// `drop-round:<quorum>`, or `wait-rejoin:<secs>`.
    pub fn parse(spec: &str) -> Result<FailurePolicy> {
        if spec == "fail-fast" {
            return Ok(FailurePolicy::FailFast);
        }
        if spec == "drop-round" {
            return Ok(FailurePolicy::DropRound { min_quorum: 1 });
        }
        if let Some(q) = spec.strip_prefix("drop-round:") {
            let min_quorum = q
                .parse::<usize>()
                .with_context(|| format!("bad drop-round quorum '{q}'"))?;
            return Ok(FailurePolicy::DropRound { min_quorum });
        }
        if let Some(s) = spec.strip_prefix("wait-rejoin:") {
            let secs = s
                .parse::<u64>()
                .with_context(|| format!("bad wait-rejoin timeout '{s}'"))?;
            return Ok(FailurePolicy::WaitRejoin { timeout: Duration::from_secs(secs) });
        }
        bail!(
            "unknown failure policy '{spec}' \
             (expected fail-fast, drop-round[:<quorum>], or wait-rejoin:<secs>)"
        );
    }

    /// The canonical spec string [`FailurePolicy::parse`] accepts back.
    pub fn spec_string(&self) -> String {
        match self {
            FailurePolicy::FailFast => "fail-fast".to_string(),
            FailurePolicy::DropRound { min_quorum } => format!("drop-round:{min_quorum}"),
            FailurePolicy::WaitRejoin { timeout } => {
                format!("wait-rejoin:{}", timeout.as_secs())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fault plans
// ---------------------------------------------------------------------------

/// Which endpoint operation a fault fires on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    /// Fires on the `at`-th `send` performed on the wrapped endpoint.
    Send,
    /// Fires on the `at`-th `recv` performed on the wrapped endpoint.
    Recv,
}

impl FaultOp {
    fn mirrored(self) -> FaultOp {
        match self {
            FaultOp::Send => FaultOp::Recv,
            FaultOp::Recv => FaultOp::Send,
        }
    }
}

/// What an injected fault does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Swallow the frame: a faulted `send` reports success without
    /// transmitting; a faulted `recv` discards the arrived frame and
    /// keeps reading.
    DropFrame,
    /// Sleep `ms` milliseconds before performing the operation — the
    /// straggler / deadline-pressure fault.
    Delay {
        /// Injected latency in milliseconds.
        ms: u64,
    },
    /// XOR one byte of the frame (at `offset % len`) — the torn-wire
    /// fault the hardened decoders must survive.
    CorruptByte {
        /// Byte position, reduced modulo the frame length.
        offset: u64,
        /// Nonzero XOR mask applied to that byte.
        xor: u8,
    },
    /// Hang up the connection: the operation and every one after it
    /// fail with [`PEER_HUNG_UP`], and the wrapped endpoint is dropped
    /// so the real peer observes a genuine close.
    Cut,
}

impl FaultAction {
    fn describe(&self) -> String {
        match self {
            FaultAction::DropFrame => "drop-frame".to_string(),
            FaultAction::Delay { ms } => format!("delay:{ms}ms"),
            FaultAction::CorruptByte { offset, xor } => {
                format!("corrupt-byte:+{offset}^{xor:#04x}")
            }
            FaultAction::Cut => "cut".to_string(),
        }
    }
}

/// One scheduled fault on one endpoint: fire `action` on the `at`-th
/// (0-indexed) operation of kind `op`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    /// Which operation kind is counted.
    pub op: FaultOp,
    /// 0-indexed operation count at which the fault fires.
    pub at: u64,
    /// What happens when it fires.
    pub action: FaultAction,
}

impl Fault {
    fn describe(&self) -> String {
        let op = match self.op {
            FaultOp::Send => "send",
            FaultOp::Recv => "recv",
        };
        format!("{op} #{} {}", self.at, self.action.describe())
    }
}

/// A parsed `--fault-plan` spec: a fault class plus the seed that
/// materializes it into a concrete [`FaultPlan`] once the run's node
/// count and round count are known.
///
/// Spec grammar (`parse` rejects anything else):
///
/// ```text
/// none                    no faults (parses to Option::None)
/// kill:<k>:<seed>         k distinct victims, each cut at a seeded round
/// drop:<k>:<seed>         k victims, one dropped frame each
/// corrupt:<k>:<seed>      k victims, one corrupted byte each
/// delay:<k>:<ms>:<seed>   k victims, one <ms>-millisecond stall each
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    class: FaultClass,
    seed: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FaultClass {
    Kill { k: usize },
    Drop { k: usize },
    Corrupt { k: usize },
    Delay { k: usize, ms: u64 },
}

impl FaultSpec {
    /// Parse a `--fault-plan` spec string; `none` parses to `None`.
    pub fn parse(spec: &str) -> Result<Option<FaultSpec>> {
        if spec == "none" {
            return Ok(None);
        }
        let parts: Vec<&str> = spec.split(':').collect();
        let usize_at = |i: usize, what: &str| -> Result<usize> {
            parts[i]
                .parse::<usize>()
                .with_context(|| format!("bad {what} '{}' in fault plan '{spec}'", parts[i]))
        };
        let u64_at = |i: usize, what: &str| -> Result<u64> {
            parts[i]
                .parse::<u64>()
                .with_context(|| format!("bad {what} '{}' in fault plan '{spec}'", parts[i]))
        };
        let class = match (parts[0], parts.len()) {
            ("kill", 3) => FaultClass::Kill { k: usize_at(1, "victim count")? },
            ("drop", 3) => FaultClass::Drop { k: usize_at(1, "victim count")? },
            ("corrupt", 3) => FaultClass::Corrupt { k: usize_at(1, "victim count")? },
            ("delay", 4) => FaultClass::Delay {
                k: usize_at(1, "victim count")?,
                ms: u64_at(2, "delay milliseconds")?,
            },
            _ => bail!(
                "unknown fault plan '{spec}' (expected none, kill:<k>:<seed>, \
                 drop:<k>:<seed>, corrupt:<k>:<seed>, or delay:<k>:<ms>:<seed>)"
            ),
        };
        let seed = u64_at(parts.len() - 1, "seed")?;
        Ok(Some(FaultSpec { class, seed }))
    }

    /// The canonical spec string [`FaultSpec::parse`] accepts back.
    pub fn spec_string(&self) -> String {
        match self.class {
            FaultClass::Kill { k } => format!("kill:{k}:{}", self.seed),
            FaultClass::Drop { k } => format!("drop:{k}:{}", self.seed),
            FaultClass::Corrupt { k } => format!("corrupt:{k}:{}", self.seed),
            FaultClass::Delay { k, ms } => format!("delay:{k}:{ms}:{}", self.seed),
        }
    }

    /// Materialize the concrete per-node schedule for a run of `nodes`
    /// endpoints over `rounds` rounds. Deterministic in the spec alone:
    /// the same `(spec, nodes, rounds)` triple always yields the
    /// byte-identical plan (the replay contract the proptest pins).
    ///
    /// Victims are drawn distinct and scheduled in sorted node order;
    /// every fault round is drawn from `[1, rounds)` so round 0 always
    /// completes at full quorum (the engines need one full round to be
    /// comparable across policies). Requires `rounds ≥ 2` for that
    /// reason, and clamps the victim count to `nodes`.
    pub fn plan(&self, nodes: usize, rounds: usize) -> Result<FaultPlan> {
        if nodes == 0 {
            bail!("fault plan '{}' needs at least one node", self.spec_string());
        }
        if rounds < 2 {
            bail!(
                "fault plan '{}' needs at least 2 rounds (round 0 always \
                 completes at full quorum), run has {rounds}",
                self.spec_string()
            );
        }
        let (k, action_for): (usize, Box<dyn Fn(&mut Prng) -> FaultAction>) = match self.class {
            FaultClass::Kill { k } => (k, Box::new(|_| FaultAction::Cut)),
            FaultClass::Drop { k } => (k, Box::new(|_| FaultAction::DropFrame)),
            FaultClass::Corrupt { k } => (
                k,
                Box::new(|rng: &mut Prng| FaultAction::CorruptByte {
                    offset: rng.next_u64(),
                    xor: (rng.below(255) + 1) as u8,
                }),
            ),
            FaultClass::Delay { k, ms } => (k, Box::new(move |_| FaultAction::Delay { ms })),
        };
        let mut rng = Prng::new(self.seed);
        let mut victims = Vec::new();
        rng.sample_distinct(nodes, k.min(nodes), &mut victims);
        victims.sort_unstable();
        let mut faults: BTreeMap<usize, Vec<Fault>> = BTreeMap::new();
        for &v in &victims {
            let at = 1 + rng.below(rounds - 1) as u64;
            let action = action_for(&mut rng);
            faults
                .entry(v as usize)
                .or_default()
                .push(Fault { op: FaultOp::Recv, at, action });
        }
        Ok(FaultPlan { spec: self.spec_string(), faults })
    }
}

/// A concrete, materialized fault schedule: for each affected node, the
/// ordered faults on that node's channel. Plans are authored from the
/// viewpoint of the endpoint that will be wrapped (the server end of a
/// PS link, the node's own end of a ring link): `op` counts operations
/// **on the wrapped endpoint**.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    spec: String,
    faults: BTreeMap<usize, Vec<Fault>>,
}

impl FaultPlan {
    /// An empty plan (wraps nothing, injects nothing).
    pub fn none() -> FaultPlan {
        FaultPlan { spec: "none".to_string(), faults: BTreeMap::new() }
    }

    /// Manual plan: cut `node`'s channel on its `at`-th `send` — the
    /// shape the legacy `CutTransport` test fixture injected.
    pub fn cut_send(node: usize, at: u64) -> FaultPlan {
        FaultPlan {
            spec: format!("manual:cut-send:{node}:{at}"),
            faults: BTreeMap::from([(
                node,
                vec![Fault { op: FaultOp::Send, at, action: FaultAction::Cut }],
            )]),
        }
    }

    /// Manual plan: cut `node`'s channel on its `at`-th `recv`.
    pub fn cut_recv(node: usize, at: u64) -> FaultPlan {
        FaultPlan {
            spec: format!("manual:cut-recv:{node}:{at}"),
            faults: BTreeMap::from([(
                node,
                vec![Fault { op: FaultOp::Recv, at, action: FaultAction::Cut }],
            )]),
        }
    }

    /// The spec string this plan was materialized from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// True when the plan schedules no faults at all.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The scheduled faults for `node` (empty slice when unaffected).
    pub fn faults_for(&self, node: usize) -> &[Fault] {
        self.faults.get(&node).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// The round a node's channel is cut, if any: the earliest `at` of
    /// a `Cut` fault on it (one server `recv` per node per round on the
    /// sync protocol, so the recv count *is* the round count).
    pub fn death_round(&self, node: usize) -> Option<u64> {
        self.faults_for(node)
            .iter()
            .filter(|f| f.action == FaultAction::Cut)
            .map(|f| f.at)
            .min()
    }

    /// Mirror the plan into the simulated engines: per node, the round
    /// at which it dies (`None` = survives). Only pure kill plans have
    /// a simulated twin — frame drops, byte corruption, and delays are
    /// wire phenomena with no simulated counterpart — so any other
    /// fault kind is rejected loudly.
    pub fn sim_deaths(&self, nodes: usize) -> Result<Vec<Option<u64>>> {
        let mut deaths = vec![None; nodes];
        for (&node, faults) in &self.faults {
            if node >= nodes {
                bail!(
                    "fault plan '{}' targets node {node}, run has {nodes} nodes",
                    self.spec
                );
            }
            for f in faults {
                if f.action != FaultAction::Cut {
                    bail!(
                        "fault plan '{}' schedules a non-cut fault ({}) — only kill \
                         plans mirror into the simulated engines",
                        self.spec,
                        f.describe()
                    );
                }
            }
            deaths[node] = self.death_round(node);
        }
        Ok(deaths)
    }

    /// Wrap `node`'s channel with this plan's faults for it; channels
    /// of unaffected nodes pass through unwrapped (zero overhead).
    pub fn wrap(&self, node: usize, ch: Box<dyn Channel>) -> Box<dyn Channel> {
        let faults = self.faults_for(node);
        if faults.is_empty() {
            ch
        } else {
            Box::new(FaultyChannel::new(ch, faults.to_vec()))
        }
    }

    /// [`FaultPlan::wrap`] for the *opposite* endpoint of the link the
    /// plan was authored for: every `op` is mirrored (a server-side
    /// `recv` cut is a worker-side `send` cut), so a worker process can
    /// apply the same plan string the server-side twin replays.
    pub fn wrap_peer(&self, node: usize, ch: Box<dyn Channel>) -> Box<dyn Channel> {
        let faults = self.faults_for(node);
        if faults.is_empty() {
            ch
        } else {
            let mirrored = faults
                .iter()
                .map(|f| Fault { op: f.op.mirrored(), ..*f })
                .collect();
            Box::new(FaultyChannel::new(ch, mirrored))
        }
    }

    /// Deterministic, human-readable serialization of the full
    /// schedule — the byte-identity surface the replay proptest pins.
    pub fn describe(&self) -> String {
        let mut out = format!("fault-plan {}\n", self.spec);
        for (node, faults) in &self.faults {
            for f in faults {
                out.push_str(&format!("node {node}: {}\n", f.describe()));
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Fault wrappers
// ---------------------------------------------------------------------------

/// A [`Channel`] decorator that counts operations and fires scheduled
/// [`Fault`]s. After a `Cut` the wrapped endpoint is dropped (so the
/// real peer observes a genuine close) and every further operation
/// fails with [`PEER_HUNG_UP`].
pub struct FaultyChannel {
    inner: Option<Box<dyn Channel>>,
    faults: Vec<Fault>,
    sends: u64,
    recvs: u64,
}

impl FaultyChannel {
    /// Wrap `inner` with the given fault schedule.
    pub fn new(inner: Box<dyn Channel>, faults: Vec<Fault>) -> FaultyChannel {
        FaultyChannel { inner: Some(inner), faults, sends: 0, recvs: 0 }
    }

    fn cut(&mut self) -> anyhow::Error {
        if let Some(mut ch) = self.inner.take() {
            ch.hangup();
        }
        anyhow!(PEER_HUNG_UP)
    }
}

impl Channel for FaultyChannel {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let at = self.sends;
        self.sends += 1;
        let mut owned: Option<Vec<u8>> = None;
        for i in 0..self.faults.len() {
            let f = self.faults[i];
            if f.op != FaultOp::Send || f.at != at {
                continue;
            }
            match f.action {
                FaultAction::Cut => return Err(self.cut()),
                FaultAction::DropFrame => return Ok(()),
                FaultAction::Delay { ms } => std::thread::sleep(Duration::from_millis(ms)),
                FaultAction::CorruptByte { offset, xor } => {
                    let buf = owned.get_or_insert_with(|| frame.to_vec());
                    if !buf.is_empty() {
                        let i = (offset % buf.len() as u64) as usize;
                        buf[i] ^= xor;
                    }
                }
            }
        }
        let ch = self.inner.as_mut().ok_or_else(|| anyhow!(PEER_HUNG_UP))?;
        ch.send(owned.as_deref().unwrap_or(frame))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loop {
            let at = self.recvs;
            self.recvs += 1;
            let mut drop_frame = false;
            let mut corruptions: Vec<(u64, u8)> = Vec::new();
            for i in 0..self.faults.len() {
                let f = self.faults[i];
                if f.op != FaultOp::Recv || f.at != at {
                    continue;
                }
                match f.action {
                    FaultAction::Cut => return Err(self.cut()),
                    FaultAction::DropFrame => drop_frame = true,
                    FaultAction::Delay { ms } => {
                        std::thread::sleep(Duration::from_millis(ms))
                    }
                    FaultAction::CorruptByte { offset, xor } => {
                        corruptions.push((offset, xor))
                    }
                }
            }
            let ch = self.inner.as_mut().ok_or_else(|| anyhow!(PEER_HUNG_UP))?;
            let mut frame = ch.recv()?;
            if drop_frame {
                continue; // discard the arrived frame, keep reading
            }
            for (offset, xor) in corruptions {
                if !frame.is_empty() {
                    let i = (offset % frame.len() as u64) as usize;
                    frame[i] ^= xor;
                }
            }
            return Ok(frame);
        }
    }

    fn hangup(&mut self) {
        if let Some(ch) = self.inner.as_mut() {
            ch.hangup();
        }
    }
}

/// A [`Transport`] decorator: the `i`-th `duplex()`'s **first** end
/// (the server/observer end, by the engines' convention) is wrapped
/// with the plan's faults for node `i`. Unaffected duplexes pass
/// through untouched, so an empty plan is exactly the inner transport.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    next: usize,
}

impl FaultyTransport {
    /// Wrap `inner` so its future duplexes carry `plan`'s faults.
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> FaultyTransport {
        FaultyTransport { inner, plan, next: 0 }
    }
}

impl Transport for FaultyTransport {
    fn duplex(&mut self) -> (Box<dyn Channel>, Box<dyn Channel>) {
        let i = self.next;
        self.next += 1;
        let (observer, peer) = self.inner.duplex();
        (self.plan.wrap(i, observer), peer)
    }
}

/// The channel a failure policy swaps in for a node it has marked dead:
/// every operation fails descriptively, and — crucially — the node's
/// *original* channel end has been dropped, so an in-process loopback
/// peer blocked on `recv` unblocks with "channel closed" instead of
/// hanging until a deadline.
pub struct DeadChannel {
    node: usize,
}

impl DeadChannel {
    /// A dead-end channel for `node`.
    pub fn new(node: usize) -> DeadChannel {
        DeadChannel { node }
    }
}

impl Channel for DeadChannel {
    fn send(&mut self, _frame: &[u8]) -> Result<()> {
        bail!("node {} marked dead by the failure policy", self.node);
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        bail!("node {} marked dead by the failure policy", self.node);
    }
}

/// The RNG stream a rejoining worker resumes on. It must be (a)
/// deterministic from `(seed, node, next_round)` alone — both the
/// server's simulated twin and the rejoining process derive it
/// independently — and (b) disjoint from every stream the original
/// incarnation consumed, so a rejoin never replays gradients.
pub fn rejoin_rng(seed: u64, node: u32, next_round: u64) -> Prng {
    Prng::new(seed)
        .split((node as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15) ^ (next_round + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::Loopback;

    #[test]
    fn policy_specs_roundtrip() {
        for spec in ["fail-fast", "drop-round:3", "wait-rejoin:45"] {
            let p = FailurePolicy::parse(spec).unwrap();
            assert_eq!(p.spec_string(), spec);
        }
        assert_eq!(
            FailurePolicy::parse("drop-round").unwrap(),
            FailurePolicy::DropRound { min_quorum: 1 }
        );
        assert_eq!(FailurePolicy::default(), FailurePolicy::FailFast);
        for bad in ["", "failfast", "drop-round:x", "wait-rejoin", "wait-rejoin:-1"] {
            assert!(FailurePolicy::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fault_specs_roundtrip_and_reject_junk() {
        assert!(FaultSpec::parse("none").unwrap().is_none());
        for spec in ["kill:2:42", "drop:1:7", "corrupt:3:99", "delay:2:250:5"] {
            let s = FaultSpec::parse(spec).unwrap().unwrap();
            assert_eq!(s.spec_string(), spec);
        }
        for bad in ["", "kill", "kill:2", "kill:2:42:9", "delay:2:5", "explode:1:2", "kill:x:1"] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn plans_replay_bit_for_bit_and_differ_across_seeds() {
        let spec = FaultSpec::parse("kill:3:1234").unwrap().unwrap();
        let a = spec.plan(8, 30).unwrap();
        let b = spec.plan(8, 30).unwrap();
        assert_eq!(a.describe(), b.describe());
        assert_eq!(a, b);
        let other = FaultSpec::parse("kill:3:1235").unwrap().unwrap();
        assert_ne!(a.describe(), other.plan(8, 30).unwrap().describe());
    }

    #[test]
    fn kill_plans_never_kill_round_zero_and_stay_in_range() {
        for seed in 0..50u64 {
            let spec = FaultSpec::parse(&format!("kill:4:{seed}")).unwrap().unwrap();
            let plan = spec.plan(6, 11).unwrap();
            let deaths = plan.sim_deaths(6).unwrap();
            assert_eq!(deaths.iter().filter(|d| d.is_some()).count(), 4, "seed={seed}");
            for d in deaths.into_iter().flatten() {
                assert!((1..11).contains(&d), "seed={seed} round={d}");
            }
        }
    }

    #[test]
    fn plan_rejects_degenerate_runs_and_clamps_victims() {
        let spec = FaultSpec::parse("kill:9:1").unwrap().unwrap();
        assert!(spec.plan(0, 10).is_err());
        assert!(spec.plan(4, 1).is_err());
        // More victims than nodes: clamped, every node scheduled once.
        let plan = spec.plan(4, 10).unwrap();
        assert_eq!((0..4).filter(|&n| !plan.faults_for(n).is_empty()).count(), 4);
    }

    #[test]
    fn sim_deaths_reject_non_kill_plans() {
        let spec = FaultSpec::parse("corrupt:1:3").unwrap().unwrap();
        let err = spec.plan(4, 10).unwrap().sim_deaths(4).unwrap_err();
        assert!(format!("{err:#}").contains("only kill plans"), "{err:#}");
        let narrow = FaultPlan::cut_recv(7, 2).sim_deaths(4).unwrap_err();
        assert!(format!("{narrow:#}").contains("targets node 7"), "{narrow:#}");
    }

    #[test]
    fn cut_send_fires_on_the_scheduled_send() {
        let (server, worker) = Loopback.duplex();
        let mut faulty = FaultPlan::cut_send(0, 2).wrap(0, server);
        let mut worker = worker;
        faulty.send(b"a").unwrap();
        faulty.send(b"b").unwrap();
        let err = faulty.send(b"c").unwrap_err();
        assert!(format!("{err:#}").contains(PEER_HUNG_UP), "{err:#}");
        // Every later operation fails the same way; the peer sees a close.
        assert!(faulty.recv().is_err());
        assert_eq!(worker.recv().unwrap(), b"a");
        assert_eq!(worker.recv().unwrap(), b"b");
        assert!(worker.recv().is_err(), "peer must observe the hangup");
    }

    #[test]
    fn recv_faults_drop_corrupt_and_cut() {
        let (server, worker) = Loopback.duplex();
        let faults = vec![
            Fault { op: FaultOp::Recv, at: 0, action: FaultAction::DropFrame },
            Fault {
                op: FaultOp::Recv,
                at: 2,
                action: FaultAction::CorruptByte { offset: 5, xor: 0xFF },
            },
            Fault { op: FaultOp::Recv, at: 3, action: FaultAction::Cut },
        ];
        let mut faulty = FaultyChannel::new(server, faults);
        let mut worker = worker;
        for frame in [b"one", b"two", b"xyz"] {
            worker.send(frame).unwrap();
        }
        // recv #0 drops "one" and keeps reading, yielding "two".
        assert_eq!(faulty.recv().unwrap(), b"two");
        // recv #2 corrupts byte 5 % 3 = 2 of "xyz".
        assert_eq!(faulty.recv().unwrap(), [b'x', b'y', b'z' ^ 0xFF]);
        let err = faulty.recv().unwrap_err();
        assert!(format!("{err:#}").contains(PEER_HUNG_UP), "{err:#}");
    }

    #[test]
    fn transport_wraps_only_affected_duplexes() {
        let mut t = FaultyTransport::new(Box::new(Loopback), FaultPlan::cut_send(1, 0));
        let (mut s0, mut w0) = t.duplex();
        let (mut s1, _w1) = t.duplex();
        s0.send(b"fine").unwrap();
        assert_eq!(w0.recv().unwrap(), b"fine");
        let err = s1.send(b"doomed").unwrap_err();
        assert!(format!("{err:#}").contains(PEER_HUNG_UP), "{err:#}");
    }

    #[test]
    fn wrap_peer_mirrors_ops() {
        // A recv-cut plan wrapped on the peer side cuts on *send*.
        let plan = FaultPlan::cut_recv(0, 1);
        let (_server, worker) = Loopback.duplex();
        let mut peer = plan.wrap_peer(0, worker);
        peer.send(b"round 0").unwrap();
        let err = peer.send(b"round 1").unwrap_err();
        assert!(format!("{err:#}").contains(PEER_HUNG_UP), "{err:#}");
    }

    #[test]
    fn dead_channel_is_descriptive() {
        let mut ch = DeadChannel::new(3);
        let err = ch.send(b"x").unwrap_err();
        assert!(format!("{err:#}").contains("node 3 marked dead"), "{err:#}");
        assert!(ch.recv().is_err());
    }

    #[test]
    fn rejoin_rng_is_deterministic_and_disjoint() {
        let a: Vec<u64> = (0..8).map({
            let mut r = rejoin_rng(7, 2, 5);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = rejoin_rng(7, 2, 5);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let mut other_node = rejoin_rng(7, 3, 5);
        let mut other_round = rejoin_rng(7, 2, 6);
        assert_ne!(a[0], other_node.next_u64());
        assert_ne!(a[0], other_round.next_u64());
    }
}
