//! Training-state checkpoints: save/restore the *complete* Algorithm-1
//! state so a run can be split across process lifetimes and resume
//! **bit-identically** — iterate `x_t`, error memory `m_t` (losing it
//! would silently change the algorithm: the suppressed mass of every
//! previous step lives there), iteration/bit counters, the weighted
//! averaging accumulator, and the PRNG position (so the resumed sample /
//! rand-k stream continues exactly where it stopped).
//!
//! Format: a little-endian binary container —
//!
//! ```text
//! magic "MEMSGDCK" | version u32 | compressor-spec (len u32 + utf8)
//! | t u64 | bits_sent u64 | batch u64 (version >= 2) | d u64
//! | x  [f32; d] | m [f32; d]
//! | rng [u64; 4]
//! | has_avg u8 | (shift f64 | sum_w f64 | avg_t u64 | acc [f64; d])?
//! ```
//!
//! Version 2 added the minibatch size `batch`: the RNG stream draws
//! `batch` sample indices per step, so resuming under a different
//! `--batch` would silently diverge — the reader treats version-1
//! checkpoints as `batch = 1` and `run_resumable` refuses mismatches.
//!
//! No compression, no external deps; `d = 47'236` checkpoints are ~0.9 MB.
//!
//! [`ClusterCheckpoint`] is the *cluster-level* sibling (`memsgd serve
//! --checkpoint`): the server's model, round counter, and per-node
//! liveness mask in their own container (magic `MEMSGDCL`). It is
//! deliberately smaller than the sequential checkpoint — worker error
//! memories live in other processes and die with them, so a server
//! restart resumes the *model*, not the suppressed mass; restart runs
//! are tested for completion and finiteness, never golden-pinned.

use std::fs;
use std::io::{Cursor, Read as _, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compress;
use crate::optim::{MemSgd, WeightedAverage};
use crate::util::prng::Prng;

const MAGIC: &[u8; 8] = b"MEMSGDCK";
const VERSION: u32 = 2;

/// Everything needed to resume a sequential Mem-SGD run.
pub struct Checkpoint {
    pub compressor_spec: String,
    pub t: usize,
    pub bits_sent: u64,
    /// Minibatch size the run was drawing (`batch` indices per step —
    /// part of the RNG-stream contract). Version-1 files load as 1.
    pub batch: usize,
    pub x: Vec<f32>,
    pub m: Vec<f32>,
    pub rng_state: [u64; 4],
    /// `(shift, acc, sum_w, t)` of the weighted average, if one is kept.
    pub avg: Option<(f64, Vec<f64>, f64, usize)>,
}

impl Checkpoint {
    /// Capture the state of a live optimizer + RNG (+ averager) at the
    /// default per-sample schedule (`batch = 1`); minibatch runs chain
    /// [`Checkpoint::with_batch`].
    pub fn capture(
        opt: &MemSgd,
        spec: &str,
        rng: &Prng,
        avg: Option<&WeightedAverage>,
    ) -> Checkpoint {
        Checkpoint {
            compressor_spec: spec.to_string(),
            t: opt.t,
            bits_sent: opt.bits_sent,
            batch: 1,
            x: opt.x.clone(),
            m: opt.memory().to_vec(),
            rng_state: rng.state(),
            avg: avg.map(|a| {
                let (shift, acc, sum_w, t) = a.state();
                (shift, acc.to_vec(), sum_w, t)
            }),
        }
    }

    /// Record the minibatch size the run draws per step (resume refuses
    /// a mismatch — the sample-index stream depends on it).
    pub fn with_batch(mut self, batch: usize) -> Checkpoint {
        self.batch = batch.max(1);
        self
    }

    /// Rebuild the optimizer, RNG and averager. The compressor is
    /// re-created from the stored spec (compressors are stateless across
    /// iterations by design — scratch buffers only).
    pub fn restore(&self) -> Result<(MemSgd, Prng, Option<WeightedAverage>)> {
        let comp = compress::from_spec(&self.compressor_spec)?;
        let mut opt = MemSgd::new(self.x.clone(), comp);
        opt.set_memory(&self.m);
        opt.t = self.t;
        opt.bits_sent = self.bits_sent;
        let rng = Prng::from_state(self.rng_state);
        let avg = self
            .avg
            .as_ref()
            .map(|(shift, acc, sum_w, t)| WeightedAverage::from_state(*shift, acc.clone(), *sum_w, *t));
        Ok((opt, rng, avg))
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = self.x.len();
        let mut out = Vec::with_capacity(64 + self.compressor_spec.len() + d * 8 + d * 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let spec = self.compressor_spec.as_bytes();
        out.extend_from_slice(&(spec.len() as u32).to_le_bytes());
        out.extend_from_slice(spec);
        out.extend_from_slice(&(self.t as u64).to_le_bytes());
        out.extend_from_slice(&self.bits_sent.to_le_bytes());
        out.extend_from_slice(&(self.batch as u64).to_le_bytes());
        out.extend_from_slice(&(d as u64).to_le_bytes());
        for &v in &self.x {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.m {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &s in &self.rng_state {
            out.extend_from_slice(&s.to_le_bytes());
        }
        match &self.avg {
            None => out.push(0),
            Some((shift, acc, sum_w, t)) => {
                out.push(1);
                out.extend_from_slice(&shift.to_le_bytes());
                out.extend_from_slice(&sum_w.to_le_bytes());
                out.extend_from_slice(&(*t as u64).to_le_bytes());
                debug_assert_eq!(acc.len(), d);
                for &v in acc {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        out
    }

    /// Parse from bytes (validates magic, version, lengths).
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut cur = Cursor::new(bytes);
        let mut magic = [0u8; 8];
        cur.read_exact(&mut magic).context("truncated magic")?;
        if &magic != MAGIC {
            bail!("not a memsgd checkpoint (bad magic)");
        }
        let version = read_u32(&mut cur)?;
        if version == 0 || version > VERSION {
            bail!("unsupported checkpoint version {version} (expected <= {VERSION})");
        }
        let spec_len = read_u32(&mut cur)? as usize;
        if spec_len > 4096 {
            bail!("implausible compressor-spec length {spec_len}");
        }
        let mut spec = vec![0u8; spec_len];
        cur.read_exact(&mut spec).context("truncated spec")?;
        let compressor_spec = String::from_utf8(spec).context("spec is not utf-8")?;
        let t = read_u64(&mut cur)? as usize;
        let bits_sent = read_u64(&mut cur)?;
        // Version 1 predates minibatch schedules: those runs drew one
        // sample index per step.
        let batch = if version >= 2 { read_u64(&mut cur)? as usize } else { 1 };
        let d = read_u64(&mut cur)? as usize;
        let remaining = bytes.len() as u64 - cur.position();
        // Checked arithmetic: a corrupted d must not overflow the size
        // estimate (and then blow up the x/m allocations below).
        let need = (d as u64)
            .checked_mul(8)
            .and_then(|v| v.checked_add(33))
            .ok_or_else(|| anyhow::anyhow!("implausible checkpoint dimension {d}"))?;
        if remaining < need {
            bail!("checkpoint truncated: d={d} but only {remaining} bytes left");
        }
        let mut x = vec![0.0f32; d];
        for v in &mut x {
            *v = f32::from_le_bytes(read_arr(&mut cur)?);
        }
        let mut memory = vec![0.0f32; d];
        for v in &mut memory {
            *v = f32::from_le_bytes(read_arr(&mut cur)?);
        }
        let mut rng_state = [0u64; 4];
        for s in &mut rng_state {
            *s = read_u64(&mut cur)?;
        }
        let mut has_avg = [0u8; 1];
        cur.read_exact(&mut has_avg).context("truncated avg flag")?;
        let avg = match has_avg[0] {
            0 => None,
            1 => {
                let shift = f64::from_le_bytes(read_arr(&mut cur)?);
                let sum_w = f64::from_le_bytes(read_arr(&mut cur)?);
                let at = read_u64(&mut cur)? as usize;
                let mut acc = vec![0.0f64; d];
                for v in &mut acc {
                    *v = f64::from_le_bytes(read_arr(&mut cur)?);
                }
                Some((shift, acc, sum_w, at))
            }
            other => bail!("bad averager flag {other}"),
        };
        Ok(Checkpoint {
            compressor_spec,
            t,
            bits_sent,
            batch,
            x,
            m: memory,
            rng_state,
            avg,
        })
    }

    /// Write to a file (atomically: temp + rename).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path).with_context(|| format!("rename into {}", path.display()))?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let bytes = fs::read(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Checkpoint::from_bytes(&bytes)
    }
}

const CLUSTER_MAGIC: &[u8; 8] = b"MEMSGDCL";
const CLUSTER_VERSION: u32 = 1;

/// A cluster server's mid-run state (`memsgd serve --checkpoint`): the
/// model, the next round to serve, and which nodes the failure policy
/// has marked dead. Saved atomically every `--checkpoint-every` rounds
/// by `serve_sync_protocol`; loaded at bind time so a killed server
/// restarts where it left off.
///
/// Format (little-endian):
///
/// ```text
/// magic "MEMSGDCL" | version u32 | round u64 | d u64 | x [f32; d]
/// | nodes u64 | dead [u8; nodes]
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterCheckpoint {
    /// The next round the restarted serve starts at.
    pub round: u64,
    /// The server model at that round boundary.
    pub x: Vec<f32>,
    /// Per-node liveness mask (`true` = marked dead by the policy).
    pub dead: Vec<bool>,
}

impl ClusterCheckpoint {
    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = self.x.len();
        let mut out = Vec::with_capacity(32 + d * 4 + self.dead.len());
        out.extend_from_slice(CLUSTER_MAGIC);
        out.extend_from_slice(&CLUSTER_VERSION.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&(d as u64).to_le_bytes());
        for &v in &self.x {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.dead.len() as u64).to_le_bytes());
        out.extend(self.dead.iter().map(|&b| b as u8));
        out
    }

    /// Parse from bytes (validates magic, version, lengths — checked
    /// arithmetic, like the sequential container).
    pub fn from_bytes(bytes: &[u8]) -> Result<ClusterCheckpoint> {
        let mut cur = Cursor::new(bytes);
        let mut magic = [0u8; 8];
        cur.read_exact(&mut magic).context("truncated magic")?;
        if &magic != CLUSTER_MAGIC {
            bail!("not a memsgd cluster checkpoint (bad magic)");
        }
        let version = read_u32(&mut cur)?;
        if version == 0 || version > CLUSTER_VERSION {
            bail!(
                "unsupported cluster checkpoint version {version} \
                 (expected <= {CLUSTER_VERSION})"
            );
        }
        let round = read_u64(&mut cur)?;
        let d = read_u64(&mut cur)? as usize;
        let remaining = bytes.len() as u64 - cur.position();
        let need = (d as u64)
            .checked_mul(4)
            .and_then(|v| v.checked_add(8))
            .ok_or_else(|| anyhow::anyhow!("implausible cluster checkpoint dimension {d}"))?;
        if remaining < need {
            bail!("cluster checkpoint truncated: d={d} but only {remaining} bytes left");
        }
        let mut x = vec![0.0f32; d];
        for v in &mut x {
            *v = f32::from_le_bytes(read_arr(&mut cur)?);
        }
        let nodes = read_u64(&mut cur)? as usize;
        let left = bytes.len() as u64 - cur.position();
        if (nodes as u64) > left {
            bail!("cluster checkpoint truncated: {nodes} nodes but only {left} bytes left");
        }
        let mut dead = Vec::with_capacity(nodes);
        for _ in 0..nodes {
            let mut b = [0u8; 1];
            cur.read_exact(&mut b).context("truncated liveness mask")?;
            dead.push(match b[0] {
                0 => false,
                1 => true,
                other => bail!("bad liveness flag {other}"),
            });
        }
        Ok(ClusterCheckpoint { round, x, dead })
    }

    /// Write to a file (atomically: temp + rename, like [`Checkpoint`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path).with_context(|| format!("rename into {}", path.display()))?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<ClusterCheckpoint> {
        let bytes = fs::read(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        ClusterCheckpoint::from_bytes(&bytes)
    }
}

fn read_u32(cur: &mut Cursor<&[u8]>) -> Result<u32> {
    Ok(u32::from_le_bytes(read_arr(cur)?))
}

fn read_u64(cur: &mut Cursor<&[u8]>) -> Result<u64> {
    Ok(u64::from_le_bytes(read_arr(cur)?))
}

fn read_arr<const N: usize>(cur: &mut Cursor<&[u8]>) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    cur.read_exact(&mut buf).context("checkpoint truncated")?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Update;

    fn trained_state(steps: usize) -> (MemSgd, Prng) {
        let mut opt = MemSgd::new(vec![0.5f32; 40], compress::from_spec("top_k:2").unwrap());
        let mut rng = Prng::new(42);
        let grad: Vec<f32> = (0..40).map(|i| (i as f32 * 0.37).sin()).collect();
        for t in 0..steps {
            opt.step(&grad, 0.1 / (t + 1) as f64, &mut rng);
        }
        (opt, rng)
    }

    #[test]
    fn roundtrip_bytes_exact() {
        let (opt, rng) = trained_state(50);
        let mut avg = WeightedAverage::new(40, 10.0);
        avg.update(&opt.x);
        let ck = Checkpoint::capture(&opt, "top_k:2", &rng, Some(&avg));
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.x, ck.x);
        assert_eq!(back.m, ck.m);
        assert_eq!(back.t, 50);
        assert_eq!(back.rng_state, rng.state());
        assert_eq!(back.compressor_spec, "top_k:2");
        let (_, acc, _, _) = (
            back.avg.as_ref().unwrap().0,
            &back.avg.as_ref().unwrap().1,
            back.avg.as_ref().unwrap().2,
            back.avg.as_ref().unwrap().3,
        );
        assert_eq!(acc.len(), 40);
    }

    #[test]
    fn resume_is_bit_identical_to_uninterrupted_run() {
        // Run 200 steps straight vs 100 + checkpoint/restore + 100: the
        // iterate, memory and RNG stream must match bit-for-bit.
        let grad_at = |t: usize| -> Vec<f32> {
            (0..40).map(|i| ((i + t) as f32 * 0.11).cos()).collect()
        };
        let mut full = MemSgd::new(vec![0.0f32; 40], compress::from_spec("rand_k:3").unwrap());
        let mut full_rng = Prng::new(7);
        for t in 0..200 {
            full.step(&grad_at(t), 0.05, &mut full_rng);
        }

        let mut half = MemSgd::new(vec![0.0f32; 40], compress::from_spec("rand_k:3").unwrap());
        let mut half_rng = Prng::new(7);
        for t in 0..100 {
            half.step(&grad_at(t), 0.05, &mut half_rng);
        }
        let ck = Checkpoint::capture(&half, "rand_k:3", &half_rng, None);
        let (mut resumed, mut resumed_rng, _) = ck.restore().unwrap();
        for t in 100..200 {
            resumed.step(&grad_at(t), 0.05, &mut resumed_rng);
        }

        assert_eq!(resumed.x, full.x);
        assert_eq!(resumed.memory(), full.memory());
        assert_eq!(resumed.t, full.t);
        assert_eq!(resumed.bits_sent, full.bits_sent);
        assert_eq!(resumed_rng.state(), full_rng.state());
    }

    #[test]
    fn file_roundtrip() {
        let (opt, rng) = trained_state(10);
        let ck = Checkpoint::capture(&opt, "top_k:2", &rng, None);
        let dir = std::env::temp_dir().join("memsgd_ck_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ck");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.x, ck.x);
        assert_eq!(back.m, ck.m);
        fs::remove_file(&path).ok();
    }

    #[test]
    fn batch_round_trips_and_version1_loads_as_batch_one() {
        let (opt, rng) = trained_state(5);
        let ck = Checkpoint::capture(&opt, "top_k:2", &rng, None).with_batch(6);
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.batch, 6);
        assert_eq!(back.x, ck.x);

        // Splice a version-1 container out of the version-2 bytes: drop
        // the 8 batch bytes (after magic 8 + version 4 + spec-len 4 +
        // spec + t 8 + bits 8) and rewrite the version field.
        let batch_off = 8 + 4 + 4 + "top_k:2".len() + 8 + 8;
        let mut v1 = bytes.clone();
        v1.drain(batch_off..batch_off + 8);
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let old = Checkpoint::from_bytes(&v1).unwrap();
        assert_eq!(old.batch, 1, "version-1 checkpoints predate minibatches");
        assert_eq!(old.x, ck.x);
        assert_eq!(old.rng_state, ck.rng_state);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(Checkpoint::from_bytes(b"nonsense").is_err());
        let (opt, rng) = trained_state(5);
        let bytes = Checkpoint::capture(&opt, "top_k:2", &rng, None).to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bad_magic).is_err());
        let mut bad_version = bytes;
        bad_version[8] = 99;
        assert!(Checkpoint::from_bytes(&bad_version).is_err());
    }

    #[test]
    fn cluster_checkpoint_roundtrips_bytes_and_file() {
        let ck = ClusterCheckpoint {
            round: 17,
            x: (0..40).map(|i| (i as f32 * 0.43).sin()).collect(),
            dead: vec![false, true, false, false],
        };
        let back = ClusterCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back, ck);
        let dir = std::env::temp_dir().join("memsgd_cluster_ck_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.ck");
        ck.save(&path).unwrap();
        assert_eq!(ClusterCheckpoint::load(&path).unwrap(), ck);
        fs::remove_file(&path).ok();
    }

    #[test]
    fn cluster_checkpoint_rejects_garbage_and_truncation() {
        assert!(ClusterCheckpoint::from_bytes(b"junk").is_err());
        let ck = ClusterCheckpoint { round: 3, x: vec![1.0; 8], dead: vec![false; 2] };
        let bytes = ck.to_bytes();
        assert!(ClusterCheckpoint::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(ClusterCheckpoint::from_bytes(&bad_magic).is_err());
        // The two containers must not parse as each other.
        let (opt, rng) = trained_state(5);
        let seq = Checkpoint::capture(&opt, "top_k:2", &rng, None).to_bytes();
        assert!(ClusterCheckpoint::from_bytes(&seq).is_err());
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn restored_optimizer_steps_consistently() {
        let (opt, rng) = trained_state(30);
        let ck = Checkpoint::capture(&opt, "top_k:2", &rng, None);
        let (mut restored, mut r, _) = ck.restore().unwrap();
        // A step after restore behaves like a step on the original.
        let mut orig = MemSgd::new(ck.x.clone(), compress::from_spec("top_k:2").unwrap());
        orig.set_memory(&ck.m);
        orig.t = ck.t;
        orig.bits_sent = ck.bits_sent;
        let mut orig_rng = Prng::from_state(ck.rng_state);
        let grad = vec![0.3f32; 40];
        let u1 = restored.step(&grad, 0.01, &mut r).to_dense(40);
        let u2 = orig.step(&grad, 0.01, &mut orig_rng).to_dense(40);
        assert_eq!(u1, u2);
        let _ = Update::new_sparse(1); // silence unused import in some cfgs
    }
}
