//! TCP backend for the wire fabric: length-delimited frames on real
//! sockets, plus the connection handshake of the multi-process cluster
//! runtime ([`super::cluster`]).
//!
//! [`super::transport`] deliberately kept [`super::transport::Channel`]
//! socket-shaped — one end of a reliable, ordered, message-framed
//! duplex link. This module supplies the real thing:
//!
//! * [`FrameAssembler`] — the resumable core of the framing codec: a
//!   per-connection state machine that consumes bytes in whatever
//!   chunks they arrive (one `feed` per nonblocking read on the poll
//!   backend, exact-sized blocking reads on the thread backend) and
//!   emits completed frames. The `max_frame_bytes` cap is enforced
//!   **against the 4-byte big-endian length prefix, before
//!   allocating** — TCP bytes are untrusted in a way in-process
//!   loopback frames never were, and a hostile peer must not be able to
//!   make the server allocate gigabytes with five bytes of input.
//! * [`read_frame`] / [`read_frame_deadline`] / [`write_frame`] — the
//!   blocking entry points over any [`std::io::Read`] /
//!   [`std::io::Write`]. `read_frame_deadline` additionally enforces a
//!   **whole-frame deadline**: the socket read timeout resets on every
//!   byte, so without it a peer trickling one byte per 59 s could hold
//!   a connection forever. The deadline is checked between reads, so
//!   the worst-case hold time is `deadline` plus one socket read
//!   timeout — bounded either way.
//! * [`TcpChannel`] — a [`super::transport::Channel`] over one
//!   [`TcpStream`], with `TCP_NODELAY` and read/write timeouts so a
//!   silent peer turns into a descriptive error instead of a hung
//!   barrier.
//! * [`TcpTransport`] — a [`super::transport::Transport`] that backs
//!   every `duplex()` with a connected localhost socket pair, so the
//!   in-process wire engines (and the golden suite in
//!   `tests/wire_protocol.rs`) run their exact protocol across a kernel
//!   socket.
//! * [`connect_with_retry`] — bounded-exponential-backoff dialing for
//!   workers that start before their server.
//! * [`Hello`] / [`check_compat`] — the handshake fingerprint (protocol
//!   version, model dim, `MethodSpec` string, `LocalUpdate` fields) and
//!   the compatibility check that rejects mismatched peers with a
//!   descriptive error.
//!
//! ## Handshake
//!
//! A connecting worker sends one `HELLO` frame (a JSON object, framed
//! like any other frame): `{"proto": v, "dim": d, "method": m,
//! "batch": b, "sync_every": h}`, where `0` / `""` mean "no
//! expectation". The server checks it against the run it is about to
//! serve ([`check_compat`]) and answers either a `WELCOME` frame
//! carrying the node id (assigned in accept order) plus the full run
//! configuration ([`super::cluster::RunConfig`]), or a
//! `{"error": reason}` frame before closing the connection. Everything
//! after the handshake is the binary wire protocol of
//! [`super::transport`], one bitstream message per frame.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Channel, Transport, MAX_FRAME_BYTES};
use crate::util::json::Json;

/// Version of the cluster wire protocol; bumped on any frame-format or
/// handshake change. Checked exactly (no wildcard) on both sides.
///
/// v2: `proto` travels as a JSON **string** in `HELLO`/`WELCOME` — a
/// u64 does not fit an f64 JSON number losslessly above 2^53, the same
/// reason [`super::cluster::RunConfig`] already stringifies its seed.
///
/// v3: `HELLO` gains the `resume` flag (a rejoining worker announcing
/// it needs a model `SNAPSHOT`, not round-0 state), the data plane
/// gains the `SNAPSHOT` frame kind, and sync `BROADCAST` values are
/// **pre-scaled by the server** (workers apply them at scale 1.0, so
/// the server can divide by the live-node count on degraded rounds).
/// Each of the three silently corrupts a v2 pairing, hence the bump.
pub const PROTOCOL_VERSION: u64 = 3;

/// Data-plane read timeout: how long a blocked `recv` waits for the
/// peer before failing the run. Generous — a sync-round barrier
/// legitimately waits for the slowest worker's compute — but bounded,
/// so a hung peer cannot hang the barrier forever.
pub const READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Write timeout for one frame (localhost writes buffer instantly;
/// this only trips when the peer has stopped draining).
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(60);

/// Handshake read timeout: a freshly accepted connection must present
/// its `HELLO` promptly or the server gives up on it.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Whole-frame deadline on the data plane: once the first byte of a
/// frame has arrived, the rest must follow within this budget. The
/// per-`read` socket timeout ([`READ_TIMEOUT`]) resets on every byte,
/// so it alone cannot bound a trickling peer — this deadline can.
pub const FRAME_DEADLINE: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------------
// Length-delimited framing
// ---------------------------------------------------------------------------

/// Write one length-delimited frame: 4-byte big-endian length prefix,
/// then the payload. The prefix and payload go out as a single write so
/// a frame is one segment on an idle `TCP_NODELAY` socket.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    if frame.len() > u32::MAX as usize {
        bail!("frame of {} bytes exceeds the u32 length prefix", frame.len());
    }
    let mut buf = Vec::with_capacity(4 + frame.len());
    buf.extend_from_slice(&(frame.len() as u32).to_be_bytes());
    buf.extend_from_slice(frame);
    w.write_all(&buf).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Where an in-progress frame stands: collecting the 4-byte length
/// prefix, or filling the (cap-checked, pre-allocated) payload.
enum AsmState {
    Prefix { buf: [u8; 4], got: usize },
    Payload { buf: Vec<u8>, filled: usize },
}

/// Resumable frame reassembly: a per-connection state machine that
/// accepts bytes in arbitrary chunks and emits completed frames.
///
/// Both I/O backends share it, so the framing invariants hold once:
/// the `max_frame_bytes` cap is checked **against the length prefix
/// before the payload buffer is allocated**, partial frames report
/// their progress on EOF, and a chunk spanning several frames yields
/// them all in order. The poll backend feeds it whatever a nonblocking
/// `read` returned ([`FrameAssembler::feed`]); the blocking paths pull
/// exactly-sized reads through it ([`FrameAssembler::fill_from`], which
/// never reads past the current frame's end).
pub struct FrameAssembler {
    max_frame_bytes: usize,
    state: AsmState,
    ready: VecDeque<Vec<u8>>,
    frames_completed: u64,
}

impl FrameAssembler {
    /// A fresh assembler enforcing `max_frame_bytes` on every frame.
    pub fn new(max_frame_bytes: usize) -> FrameAssembler {
        FrameAssembler {
            max_frame_bytes,
            state: AsmState::Prefix { buf: [0; 4], got: 0 },
            ready: VecDeque::new(),
            frames_completed: 0,
        }
    }

    /// Consume one chunk of received bytes, buffering any completed
    /// frames (pop them with [`FrameAssembler::next_frame`]). Errors on
    /// an oversized length prefix — the connection is then poisoned and
    /// must be dropped (resynchronizing an untrusted byte stream after
    /// a framing violation is not meaningful).
    pub fn feed(&mut self, mut chunk: &[u8]) -> Result<()> {
        while !chunk.is_empty() {
            let mut completed_len = None;
            match &mut self.state {
                AsmState::Prefix { buf, got } => {
                    let take = chunk.len().min(4 - *got);
                    buf[*got..*got + take].copy_from_slice(&chunk[..take]);
                    *got += take;
                    chunk = &chunk[take..];
                    if *got == 4 {
                        completed_len = Some(u32::from_be_bytes(*buf) as usize);
                    }
                }
                AsmState::Payload { buf, filled } => {
                    let take = chunk.len().min(buf.len() - *filled);
                    buf[*filled..*filled + take].copy_from_slice(&chunk[..take]);
                    *filled += take;
                    chunk = &chunk[take..];
                    if *filled == buf.len() {
                        let frame = std::mem::take(buf);
                        self.complete(frame);
                    }
                }
            }
            if let Some(len) = completed_len {
                self.begin_payload(len)?;
            }
        }
        Ok(())
    }

    /// One blocking read, sized to exactly what the current frame still
    /// needs — never past its end, so interleaving with other readers
    /// of the same stream stays frame-aligned. Returns the byte count
    /// (0 = EOF). Call [`FrameAssembler::next_frame`] first; a call
    /// with a completed frame still buffered reads nothing.
    pub fn fill_from<R: Read>(&mut self, r: &mut R) -> Result<usize> {
        let (n, completed_len) = match &mut self.state {
            AsmState::Prefix { buf, got } => {
                if !self.ready.is_empty() {
                    return Ok(0);
                }
                let n = r.read(&mut buf[*got..]).context("reading frame length")?;
                *got += n;
                let len =
                    if *got == 4 { Some(u32::from_be_bytes(*buf) as usize) } else { None };
                (n, len)
            }
            AsmState::Payload { buf, filled } => {
                let n = r.read(&mut buf[*filled..]).context("reading frame payload")?;
                *filled += n;
                if *filled == buf.len() {
                    let frame = std::mem::take(buf);
                    self.complete(frame);
                }
                (n, None)
            }
        };
        if let Some(len) = completed_len {
            self.begin_payload(len)?;
        }
        Ok(n)
    }

    fn begin_payload(&mut self, len: usize) -> Result<()> {
        if len > self.max_frame_bytes {
            bail!(
                "incoming frame declares {len} bytes, over the max_frame_bytes \
                 cap of {} — refusing to allocate",
                self.max_frame_bytes
            );
        }
        if len == 0 {
            self.complete(Vec::new());
        } else {
            self.state = AsmState::Payload { buf: vec![0u8; len], filled: 0 };
        }
        Ok(())
    }

    fn complete(&mut self, frame: Vec<u8>) {
        self.state = AsmState::Prefix { buf: [0; 4], got: 0 };
        self.frames_completed += 1;
        self.ready.push_back(frame);
    }

    /// Pop the next completed frame, in arrival order.
    pub fn next_frame(&mut self) -> Option<Vec<u8>> {
        self.ready.pop_front()
    }

    /// True while a frame is partially assembled (some bytes consumed,
    /// frame not complete) — the state per-frame deadlines key on.
    pub fn mid_frame(&self) -> bool {
        match &self.state {
            AsmState::Prefix { got, .. } => *got > 0,
            AsmState::Payload { .. } => true,
        }
    }

    /// Total frames completed over the assembler's lifetime.
    pub fn frames_completed(&self) -> u64 {
        self.frames_completed
    }

    /// The descriptive error for an EOF in the current state.
    pub fn eof_error(&self) -> anyhow::Error {
        match &self.state {
            AsmState::Prefix { got: 0, .. } => anyhow!("connection closed by peer"),
            AsmState::Prefix { got, .. } => {
                anyhow!("connection closed mid-frame ({got} of 4 length-prefix bytes)")
            }
            AsmState::Payload { buf, filled } => anyhow!(
                "connection closed mid-frame ({filled} of {} payload bytes)",
                buf.len()
            ),
        }
    }

    /// Human-readable progress of the in-flight frame, for deadline
    /// errors.
    fn progress(&self) -> String {
        match &self.state {
            AsmState::Prefix { got, .. } => format!("{got} of 4 length-prefix bytes"),
            AsmState::Payload { buf, filled } => {
                format!("{filled} of {} payload bytes", buf.len())
            }
        }
    }
}

/// Read one length-delimited frame, enforcing `max_frame_bytes`
/// **against the length prefix before allocating** the payload buffer.
///
/// Errors are descriptive and total: a clean close at a frame boundary
/// reports "connection closed by peer", an EOF inside the prefix or
/// payload reports how far the frame got, an oversized prefix is
/// rejected without touching the allocator, and a slow peer trickling
/// one byte per read still assembles the frame (reads loop until the
/// declared length arrives or the socket's read timeout trips).
pub fn read_frame<R: Read>(r: &mut R, max_frame_bytes: usize) -> Result<Vec<u8>> {
    read_frame_deadline(r, max_frame_bytes, None)
}

/// [`read_frame`] with a **whole-frame deadline**: once the first byte
/// of the frame has been consumed, the rest must arrive within
/// `deadline` or the read fails descriptively. This closes the
/// slow-loris hole the per-`read` socket timeout leaves open (it
/// resets on every byte). The deadline is checked between reads, so a
/// blocking reader's worst-case hold time is `deadline` plus one
/// socket read timeout.
pub fn read_frame_deadline<R: Read>(
    r: &mut R,
    max_frame_bytes: usize,
    deadline: Option<Duration>,
) -> Result<Vec<u8>> {
    let mut asm = FrameAssembler::new(max_frame_bytes);
    let started = Instant::now();
    loop {
        if let Some(frame) = asm.next_frame() {
            return Ok(frame);
        }
        let n = asm.fill_from(r)?;
        if n == 0 {
            return Err(asm.eof_error());
        }
        if let Some(limit) = deadline {
            if asm.mid_frame() && started.elapsed() >= limit {
                bail!(
                    "frame incomplete ({}) after {:?} — whole-frame deadline exceeded",
                    asm.progress(),
                    limit
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TcpChannel / TcpTransport
// ---------------------------------------------------------------------------

/// A [`Channel`] over one connected [`TcpStream`]: every `send` is one
/// length-delimited frame, every `recv` blocks for the next one (up to
/// [`READ_TIMEOUT`]). Dropping the channel closes the socket, which
/// turns the peer's blocked `recv` into an error — the same shutdown
/// contract as the in-process loopback.
pub struct TcpChannel {
    stream: TcpStream,
    max_frame_bytes: usize,
}

impl TcpChannel {
    /// Wrap a connected stream: sets `TCP_NODELAY` (frames are
    /// latency-sensitive barrier traffic) and the read/write timeouts.
    pub fn new(stream: TcpStream) -> Result<TcpChannel> {
        configure_stream(&stream)?;
        Ok(TcpChannel { stream, max_frame_bytes: MAX_FRAME_BYTES })
    }

    /// [`TcpChannel::new`] with a custom incoming-frame cap (tests use
    /// tiny caps to exercise the hostile-peer rejection path).
    pub fn with_max_frame_bytes(stream: TcpStream, max_frame_bytes: usize) -> Result<TcpChannel> {
        configure_stream(&stream)?;
        Ok(TcpChannel { stream, max_frame_bytes })
    }
}

/// Socket options shared by every cluster connection.
pub(crate) fn configure_stream(stream: &TcpStream) -> Result<()> {
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    stream
        .set_read_timeout(Some(READ_TIMEOUT))
        .context("setting read timeout")?;
    stream
        .set_write_timeout(Some(WRITE_TIMEOUT))
        .context("setting write timeout")?;
    Ok(())
}

impl Channel for TcpChannel {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        read_frame_deadline(&mut self.stream, self.max_frame_bytes, Some(FRAME_DEADLINE))
    }

    fn hangup(&mut self) {
        // Best-effort: the peer's blocked reads fail promptly instead of
        // waiting out a deadline. A failed shutdown means the socket is
        // already gone, which is the goal state anyway.
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// A [`Transport`] whose every [`Transport::duplex`] is a freshly
/// connected localhost TCP socket pair — the wire engines run their
/// exact protocol, but every frame crosses a kernel socket instead of
/// an in-process queue. `tests/wire_protocol.rs` uses this to pin
/// TCP ≡ Loopback ≡ simulated on the full method matrix.
///
/// `duplex` panics if the loopback interface cannot hand out a socket
/// pair (bind/connect/accept on `127.0.0.1:0` failing is environmental,
/// not a protocol condition the engines could recover from).
#[derive(Clone, Copy, Debug, Default)]
pub struct TcpTransport;

/// Create a connected localhost socket pair `(accepted, connecting)`.
pub fn socket_pair() -> Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding localhost listener")?;
    let addr = listener.local_addr().context("resolving listener addr")?;
    let client = TcpStream::connect(addr).context("connecting socket pair")?;
    let (server, _) = listener.accept().context("accepting socket pair")?;
    Ok((server, client))
}

impl Transport for TcpTransport {
    fn duplex(&mut self) -> (Box<dyn Channel>, Box<dyn Channel>) {
        let (server, worker) = socket_pair().expect("localhost TCP socket pair");
        let server = TcpChannel::new(server).expect("configuring server socket");
        let worker = TcpChannel::new(worker).expect("configuring worker socket");
        (Box::new(server), Box::new(worker))
    }
}

// ---------------------------------------------------------------------------
// Connect retry
// ---------------------------------------------------------------------------

/// Bounded exponential backoff for [`connect_with_retry`]: at most
/// `attempts` dials, sleeping `base`, `2·base`, `4·base`, ... (capped
/// at `cap`) between consecutive tries.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    pub attempts: u32,
    pub base: Duration,
    pub cap: Duration,
}

impl Default for Backoff {
    /// 8 attempts over ~12 s — enough for a worker launched seconds
    /// before its server, but a missing server still fails promptly.
    fn default() -> Backoff {
        Backoff {
            attempts: 8,
            base: Duration::from_millis(100),
            cap: Duration::from_secs(3),
        }
    }
}

/// Dial `addr`, retrying with bounded exponential backoff; gives up
/// with a descriptive error (attempt count + last failure) after
/// `policy.attempts` tries.
pub fn connect_with_retry(addr: &str, policy: &Backoff) -> Result<TcpStream> {
    let mut delay = policy.base;
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..policy.attempts {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = delay.checked_mul(2).unwrap_or(policy.cap).min(policy.cap);
        }
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow!(
            "failed to connect to {addr} after {} attempts: {e}",
            policy.attempts
        )),
        None => bail!("failed to connect to {addr}: zero attempts configured"),
    }
}

/// How one handshake attempt failed: transiently (worth retrying — the
/// server may still be binding its protocol state) or permanently (a
/// well-formed `{"error": …}` rejection frame; the server saw the
/// `HELLO` and said no, so retrying the same `HELLO` cannot succeed).
enum HandshakeFailure {
    Transient(anyhow::Error),
    Rejected(anyhow::Error),
}

/// One complete connection attempt: dial, configure, send `hello`,
/// read the server's answer frame.
fn handshake_once(
    addr: &str,
    hello: &Hello,
) -> std::result::Result<(TcpStream, Vec<u8>), HandshakeFailure> {
    use HandshakeFailure::Transient;
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Transient(anyhow!("connecting to {addr}: {e}")))?;
    configure_stream(&stream).map_err(Transient)?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .map_err(|e| Transient(anyhow!("setting handshake timeout: {e}")))?;
    write_frame(&mut stream, &hello.encode())
        .map_err(|e| Transient(e.push_context("sending HELLO")))?;
    let reply = read_frame_deadline(&mut stream, MAX_FRAME_BYTES, Some(HANDSHAKE_TIMEOUT))
        .map_err(|e| Transient(e.push_context("awaiting WELCOME")))?;
    if let Ok(text) = std::str::from_utf8(&reply) {
        if let Ok(j) = Json::parse(text) {
            if let Some(Ok(msg)) = j.get("error").map(|v| v.as_str()) {
                return Err(HandshakeFailure::Rejected(anyhow!(
                    "server rejected handshake: {msg}"
                )));
            }
        }
    }
    stream
        .set_read_timeout(Some(READ_TIMEOUT))
        .map_err(|e| Transient(anyhow!("restoring data-plane read timeout: {e}")))?;
    Ok((stream, reply))
}

/// Dial `addr` and run the full handshake — `HELLO` out, answer frame
/// back — retrying the *whole* attempt (fresh connection included) with
/// bounded exponential backoff on any transient failure. This covers
/// the gap [`connect_with_retry`] leaves: a server that `accept`s while
/// still binding its protocol state fails the handshake, not the
/// connect, and a worker started before its server must survive both.
/// A well-formed `{"error": …}` rejection is permanent and surfaces
/// immediately without further attempts. Returns the connected stream
/// (data-plane timeouts restored) and the server's answer frame.
pub fn handshake_with_retry(
    addr: &str,
    hello: &Hello,
    policy: &Backoff,
) -> Result<(TcpStream, Vec<u8>)> {
    let mut delay = policy.base;
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..policy.attempts {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = delay.checked_mul(2).unwrap_or(policy.cap).min(policy.cap);
        }
        match handshake_once(addr, hello) {
            Ok(ok) => return Ok(ok),
            Err(HandshakeFailure::Rejected(e)) => return Err(e),
            Err(HandshakeFailure::Transient(e)) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow!(
            "handshake with {addr} failed after {} attempts: {e:#}",
            policy.attempts
        )),
        None => bail!("handshake with {addr}: zero attempts configured"),
    }
}

// ---------------------------------------------------------------------------
// Handshake
// ---------------------------------------------------------------------------

/// The handshake fingerprint: what a worker expects (`0` / `""` = no
/// expectation) or what a server is about to serve (every field
/// concrete). Serialized as one JSON `HELLO` frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    pub proto: u64,
    pub dim: usize,
    pub method: String,
    pub batch: usize,
    pub sync_every: usize,
    /// A rejoining worker: it missed rounds and needs the server to
    /// answer the `WELCOME` with a model `SNAPSHOT` frame before the
    /// data plane resumes. Servers not expecting a rejoin reject it.
    pub resume: bool,
}

impl Hello {
    /// A worker with no expectations: checks only the protocol version.
    pub fn any() -> Hello {
        Hello {
            proto: PROTOCOL_VERSION,
            dim: 0,
            method: String::new(),
            batch: 0,
            sync_every: 0,
            resume: false,
        }
    }

    /// Serialize to the `HELLO` frame payload. `proto` goes out as a
    /// string: a u64 above 2^53 would round through a JSON f64 number
    /// (the same reason `RunConfig` stringifies its seed).
    pub fn encode(&self) -> Vec<u8> {
        Json::obj(vec![
            ("proto", Json::str(self.proto.to_string())),
            ("dim", Json::Num(self.dim as f64)),
            ("method", Json::str(self.method.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("sync_every", Json::Num(self.sync_every as f64)),
            ("resume", Json::Bool(self.resume)),
        ])
        .to_string()
        .into_bytes()
    }

    /// Parse a `HELLO` frame payload. `resume` defaults to `false` when
    /// absent (the field is advisory; the version check is what rejects
    /// old peers).
    pub fn decode(frame: &[u8]) -> Result<Hello> {
        let text = std::str::from_utf8(frame).context("HELLO frame is not UTF-8")?;
        let j = Json::parse(text).context("HELLO frame is not JSON")?;
        let proto_str = j.req("proto")?.as_str().context("HELLO proto must be a string")?;
        Ok(Hello {
            proto: proto_str
                .parse::<u64>()
                .with_context(|| format!("HELLO proto '{proto_str}' is not a u64"))?,
            dim: j.req("dim")?.as_usize()?,
            method: j.req("method")?.as_str()?.to_string(),
            batch: j.req("batch")?.as_usize()?,
            sync_every: j.req("sync_every")?.as_usize()?,
            resume: match j.get("resume") {
                Some(v) => v.as_bool().context("HELLO resume must be a bool")?,
                None => false,
            },
        })
    }
}

/// Check a worker's `HELLO` against the run the server is serving.
/// Protocol versions must match exactly; the config fields are checked
/// only where the worker stated an expectation. Every rejection names
/// both sides.
pub fn check_compat(worker: &Hello, server: &Hello) -> Result<()> {
    if worker.proto != server.proto {
        bail!(
            "handshake rejected: protocol version mismatch \
             (worker speaks v{}, server speaks v{})",
            worker.proto,
            server.proto
        );
    }
    if worker.dim != 0 && worker.dim != server.dim {
        bail!(
            "handshake rejected: dim mismatch (worker expects d={}, server runs d={})",
            worker.dim,
            server.dim
        );
    }
    if !worker.method.is_empty() && worker.method != server.method {
        bail!(
            "handshake rejected: method mismatch (worker expects '{}', server runs '{}')",
            worker.method,
            server.method
        );
    }
    if worker.batch != 0 && worker.batch != server.batch {
        bail!(
            "handshake rejected: local-update batch mismatch \
             (worker expects B={}, server runs B={})",
            worker.batch,
            server.batch
        );
    }
    if worker.sync_every != 0 && worker.sync_every != server.sync_every {
        bail!(
            "handshake rejected: local-update sync-interval mismatch \
             (worker expects H={}, server runs H={})",
            worker.sync_every,
            server.sync_every
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_over_in_memory_buffers() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 300]).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(read_frame(&mut r, 1024).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r, 1024).unwrap(), Vec::<u8>::new());
        assert_eq!(read_frame(&mut r, 1024).unwrap(), vec![7u8; 300]);
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err:#}").contains("closed by peer"), "{err:#}");
    }

    #[test]
    fn oversized_prefix_is_rejected_before_allocation() {
        // A 5-byte hostile input claiming a 4 GiB frame: the cap check
        // runs on the prefix, so no payload buffer is ever allocated.
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes());
        buf.push(0);
        let mut r: &[u8] = &buf;
        let err = read_frame(&mut r, 64).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("max_frame_bytes"), "{msg}");
        assert!(msg.contains("refusing to allocate"), "{msg}");
    }

    #[test]
    fn mid_frame_eof_reports_progress() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[1u8; 100]).unwrap();
        let mut r: &[u8] = &buf[..40]; // prefix + 36 of 100 payload bytes
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err:#}").contains("mid-frame"), "{err:#}");
        let mut r: &[u8] = &buf[..2]; // EOF inside the prefix itself
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err:#}").contains("length-prefix"), "{err:#}");
    }

    #[test]
    fn tcp_channel_carries_frames_both_ways() {
        let (s, w) = socket_pair().unwrap();
        let mut server = TcpChannel::new(s).unwrap();
        let mut worker = TcpChannel::new(w).unwrap();
        server.send(&[1, 2, 3]).unwrap();
        server.send(&[4]).unwrap();
        assert_eq!(worker.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(worker.recv().unwrap(), vec![4]);
        worker.send(&[9; 2000]).unwrap();
        assert_eq!(server.recv().unwrap(), vec![9; 2000]);
        drop(server);
        assert!(worker.recv().is_err(), "closed peer must error recv");
    }

    #[test]
    fn tcp_channel_enforces_its_frame_cap() {
        let (s, w) = socket_pair().unwrap();
        let mut server = TcpChannel::with_max_frame_bytes(s, 16).unwrap();
        let mut worker = TcpChannel::new(w).unwrap();
        worker.send(&[0u8; 64]).unwrap();
        let err = server.recv().unwrap_err();
        assert!(format!("{err:#}").contains("max_frame_bytes"), "{err:#}");
    }

    #[test]
    fn connect_with_retry_gives_up_after_the_bound() {
        // Bind then drop a listener so the port exists but nothing
        // accepts: connecting must fail fast with ECONNREFUSED.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let policy = Backoff { attempts: 3, base: Duration::from_millis(1), cap: Duration::from_millis(4) };
        let err = connect_with_retry(&addr, &policy).unwrap_err();
        assert!(format!("{err:#}").contains("after 3 attempts"), "{err:#}");
    }

    #[test]
    fn hello_roundtrips_and_compat_checks_are_descriptive() {
        let server = Hello {
            proto: PROTOCOL_VERSION,
            dim: 128,
            method: "memsgd:top_k:1".into(),
            batch: 2,
            sync_every: 3,
            resume: false,
        };
        let decoded = Hello::decode(&server.encode()).unwrap();
        assert_eq!(decoded, server);
        check_compat(&Hello::any(), &server).unwrap();
        check_compat(&server.clone(), &server).unwrap();

        let reject = |mutate: &dyn Fn(&mut Hello), needle: &str| {
            let mut w = Hello::any();
            mutate(&mut w);
            let err = check_compat(&w, &server).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "expected '{needle}' in '{msg}'");
        };
        reject(&|w| w.proto = PROTOCOL_VERSION + 1, "protocol version mismatch");
        reject(&|w| w.dim = 64, "dim mismatch");
        reject(&|w| w.method = "sgd".into(), "method mismatch");
        reject(&|w| w.batch = 9, "batch mismatch");
        reject(&|w| w.sync_every = 9, "sync-interval mismatch");
    }

    #[test]
    fn hello_resume_roundtrips_and_defaults_false() {
        let mut h = Hello::any();
        h.resume = true;
        assert_eq!(Hello::decode(&h.encode()).unwrap(), h);
        // A frame without the field (the v2 shape) decodes as false.
        let legacy = br#"{"proto":"3","dim":0,"method":"","batch":0,"sync_every":0}"#;
        assert!(!Hello::decode(legacy).unwrap().resume);
    }

    #[test]
    fn handshake_retries_past_a_dropped_connection() {
        // The server accepts the first connection and drops it without a
        // WELCOME (the "still binding its protocol state" shape), then
        // serves the second attempt properly: the worker must retry the
        // whole handshake, not just the connect.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (first, _) = listener.accept().unwrap();
            drop(first);
            let (mut second, _) = listener.accept().unwrap();
            let hello = read_frame(&mut second, MAX_FRAME_BYTES).unwrap();
            assert!(Hello::decode(&hello).is_ok());
            write_frame(&mut second, br#"{"welcome":true}"#).unwrap();
        });
        let policy =
            Backoff { attempts: 4, base: Duration::from_millis(5), cap: Duration::from_millis(40) };
        let (_stream, reply) = handshake_with_retry(&addr, &Hello::any(), &policy).unwrap();
        assert_eq!(reply, br#"{"welcome":true}"#);
        server.join().unwrap();
    }

    #[test]
    fn handshake_rejection_is_permanent() {
        // A well-formed {"error": ...} frame must surface immediately —
        // exactly one accept happens, so a retry would hang, and the
        // short join proves none was attempted.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let _ = read_frame(&mut conn, MAX_FRAME_BYTES).unwrap();
            write_frame(&mut conn, br#"{"error":"dim mismatch, go away"}"#).unwrap();
        });
        let policy =
            Backoff { attempts: 5, base: Duration::from_secs(2), cap: Duration::from_secs(2) };
        let start = Instant::now();
        let err = handshake_with_retry(&addr, &Hello::any(), &policy).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rejected"), "{msg}");
        assert!(msg.contains("dim mismatch, go away"), "{msg}");
        assert!(start.elapsed() < Duration::from_secs(1), "must not have retried");
        server.join().unwrap();
    }

    #[test]
    fn hello_proto_survives_above_f64_mantissa_range() {
        // 2^60 + 3 is not representable as an f64: a numeric JSON
        // round-trip would silently land on a neighboring even value.
        let mut h = Hello::any();
        h.proto = (1u64 << 60) + 3;
        let decoded = Hello::decode(&h.encode()).unwrap();
        assert_eq!(decoded.proto, (1u64 << 60) + 3);
        assert_eq!(decoded, h);
    }

    #[test]
    fn assembler_reassembles_across_arbitrary_chunk_boundaries() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"alpha").unwrap();
        write_frame(&mut wire, b"").unwrap();
        write_frame(&mut wire, &[3u8; 257]).unwrap();
        // Every chunk size must yield the same three frames, including
        // sizes that split the length prefix and span frame boundaries.
        for chunk in [1usize, 2, 3, 4, 5, 7, 64, wire.len()] {
            let mut asm = FrameAssembler::new(1024);
            for piece in wire.chunks(chunk) {
                asm.feed(piece).unwrap();
            }
            assert_eq!(asm.next_frame().unwrap(), b"alpha", "chunk={chunk}");
            assert_eq!(asm.next_frame().unwrap(), Vec::<u8>::new(), "chunk={chunk}");
            assert_eq!(asm.next_frame().unwrap(), vec![3u8; 257], "chunk={chunk}");
            assert!(asm.next_frame().is_none());
            assert!(!asm.mid_frame(), "chunk={chunk}");
            assert_eq!(asm.frames_completed(), 3);
        }
    }

    #[test]
    fn assembler_yields_multiple_frames_from_one_chunk() {
        let mut wire = Vec::new();
        for i in 0..5u8 {
            write_frame(&mut wire, &[i; 9]).unwrap();
        }
        let mut asm = FrameAssembler::new(64);
        asm.feed(&wire).unwrap();
        for i in 0..5u8 {
            assert_eq!(asm.next_frame().unwrap(), vec![i; 9]);
        }
        assert!(asm.next_frame().is_none());
    }

    #[test]
    fn assembler_rejects_oversized_prefix_mid_stream() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"ok").unwrap();
        wire.extend_from_slice(&u32::MAX.to_be_bytes());
        let mut asm = FrameAssembler::new(64);
        let err = asm.feed(&wire).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("refusing to allocate"), "{msg}");
        // The frame completed before the violation is still delivered.
        assert_eq!(asm.next_frame().unwrap(), b"ok");
    }

    #[test]
    fn assembler_eof_errors_track_state() {
        let asm = FrameAssembler::new(64);
        assert!(format!("{:#}", asm.eof_error()).contains("closed by peer"));
        let mut asm = FrameAssembler::new(64);
        asm.feed(&[0, 0]).unwrap();
        assert!(asm.mid_frame());
        assert!(format!("{:#}", asm.eof_error()).contains("length-prefix"));
        let mut asm = FrameAssembler::new(64);
        asm.feed(&[0, 0, 0, 10, 1, 2, 3]).unwrap();
        let msg = format!("{:#}", asm.eof_error());
        assert!(msg.contains("3 of 10 payload bytes"), "{msg}");
    }

    /// A reader that trickles one payload byte per `read`, pausing
    /// between bytes — the slow-loris shape the per-read socket timeout
    /// cannot bound.
    struct TricklingReader {
        wire: Vec<u8>,
        pos: usize,
        pause: Duration,
    }

    impl Read for TricklingReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.wire.len() || out.is_empty() {
                return Ok(0);
            }
            if self.pos > 0 {
                std::thread::sleep(self.pause);
            }
            out[0] = self.wire[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn whole_frame_deadline_stops_a_trickling_writer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[8u8; 64]).unwrap();
        // One byte per 5 ms against a 25 ms whole-frame budget: the
        // per-read progress keeps every individual read "alive", but
        // the deadline trips mid-frame.
        let mut r = TricklingReader { wire: wire.clone(), pos: 0, pause: Duration::from_millis(5) };
        let err = read_frame_deadline(&mut r, 1024, Some(Duration::from_millis(25))).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("deadline"), "{msg}");
        assert!(msg.contains("mid-frame") || msg.contains("incomplete"), "{msg}");
        // The same trickle with no deadline assembles the frame fine.
        let mut r = TricklingReader { wire, pos: 0, pause: Duration::from_millis(1) };
        assert_eq!(read_frame(&mut r, 1024).unwrap(), vec![8u8; 64]);
    }

    #[test]
    fn deadline_does_not_fire_between_frames() {
        // A prompt frame passes under a deadline, and silence at the
        // frame *boundary* afterwards is an EOF ("closed by peer"),
        // never a deadline error — the deadline only arms mid-frame.
        let mut wire = Vec::new();
        write_frame(&mut wire, b"prompt").unwrap();
        let mut r: &[u8] = &wire;
        let got = read_frame_deadline(&mut r, 1024, Some(FRAME_DEADLINE)).unwrap();
        assert_eq!(got, b"prompt");
        let err = read_frame_deadline(&mut r, 1024, Some(FRAME_DEADLINE)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("closed by peer"), "{msg}");
        assert!(!msg.contains("deadline"), "{msg}");
    }
}
