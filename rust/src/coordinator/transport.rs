//! The message-passing fabric of the threaded parameter-server engines:
//! byte-frame channels, an in-process loopback implementation, and the
//! typed wire-message codec.
//!
//! The simulated engines in [`super::experiment`] hand raw `f32` slices
//! between "nodes" that live in one thread; this module is what turns
//! that simulation into a real protocol — server and worker threads
//! that exchange **actually serialized** compressed updates, encoded
//! through the Elias codec in [`crate::compress::elias`]. The
//! abstraction is deliberately socket-shaped: a [`Channel`] is one end
//! of a reliable, ordered, message-framed duplex link carrying opaque
//! byte frames, nothing more — a TCP backend (length-prefixed frames
//! over a stream socket) can implement [`Transport`] without touching
//! the engines. The in-process [`Loopback`] is the reference
//! implementation; [`CountingTransport`] wraps any fabric and counts
//! the raw bytes crossing it, which is how the wire-accounting
//! invariant test verifies that reported bits equal transmitted bytes
//! (`tests/wire_protocol.rs`).
//!
//! ## Frame format
//!
//! Every frame is a [`crate::compress::elias::BitWriter`] bitstream,
//! zero-padded to a byte boundary (MSB-first within each byte). A
//! γ-coded message kind leads, then kind-specific header fields (all
//! γ-coded with a `+1` shift so zero is representable), then — for the
//! data-plane messages — one framed update payload in the
//! [`crate::compress::elias::decode_payload`] format:
//!
//! ```text
//! UPLOAD    := γ(1) γ(round+1) γ(node+1) γ(accounted_bits+1) payload
//! BROADCAST := γ(2) γ(round+1) payload
//! GO        := γ(3) γ(version+1)
//! APPLY     := γ(4) γ(version+1) payload
//! SHUTDOWN  := γ(5)
//! REDUCE    := γ(6) γ(round+1) γ(node+1) γ(accounted_bits+1) γ(hop_bits+1) payload
//! GATHER    := γ(7) γ(round+1) γ(accounted_bits+1) γ(hop_bits+1) payload
//! EXCHANGE  := γ(8) γ(round+1) γ(node+1) γ(accounted_bits+1) payload
//! REPORT    := γ(9) γ(round+1) γ(node+1) γ(accounted_bits+1) payload
//! SNAPSHOT  := γ(10) γ(next_round+1) payload
//! ```
//!
//! * `UPLOAD` — worker → server: one node's compressed sync for a
//!   round (sync engine) or server version (async engine).
//!   `accounted_bits` carries the *paper-accounting* cost of the
//!   update ([`crate::optim::ErrorFeedbackStep`]'s per-sync bit
//!   count), which the server needs for the run record and — in the
//!   async engine — to charge the simulated network model, exactly as
//!   the simulated engine does.
//! * `BROADCAST` — server → workers (sync engine): the node-id-ordered
//!   aggregate of a round's uploads; every worker applies it with
//!   `x[j] -= v[j] / nodes` to keep its replica bit-identical to the
//!   server's iterate.
//! * `GO` — server → one worker (async engine): compute one local
//!   phase at stepsize `η(version)` and upload it. The server's
//!   seeded discrete-event heap decides whose turn it is, which is
//!   what preserves the simulated engine's delivery-order arbitration
//!   (and hence its exact trajectory) on real threads.
//! * `APPLY` — server → workers (async engine): one applied update;
//!   replicas subtract it verbatim. Per-channel FIFO ordering
//!   guarantees a worker has applied every update the server applied
//!   before its next `GO`.
//! * `SHUTDOWN` — server → workers: the run is over.
//! * `REDUCE` — ring node `i` → node `i+1` (all-reduce engine): the
//!   running partial aggregate of this round's updates, folded in node
//!   id order `0..=i`. `node` names the sender (receivers validate the
//!   ring discipline); `accounted_bits` carries the running sum of the
//!   senders' paper-accounted sync bits, and `hop_bits` the running sum
//!   of the closed-form per-hop transmission costs (this hop included),
//!   so the recording node can reconcile header tallies against what
//!   the nodes report at join — and reproduce the simulated engine's
//!   bit curve without seeing the intermediate partials.
//! * `GATHER` — ring node → its right neighbor (all-reduce engine): the
//!   completed round aggregate circulating back around the ring.
//!   `accounted_bits` is the full round's accounted-bit sum and
//!   `hop_bits` the round's total reduce-phase hop cost (both fixed as
//!   the frame is forwarded verbatim hop by hop).
//! * `EXCHANGE` — gossip node → its matched partner: the sender's own
//!   compressed sync for the round, payload framed by the producing
//!   compressor like `UPLOAD`. `node` names the sender;
//!   `accounted_bits` is that sync's paper-accounted cost.
//! * `REPORT` — gossip node → the recording driver at an eval round:
//!   the node's dense iterate, with `accounted_bits` carrying the
//!   node's *cumulative* transmitted accounting so the driver can
//!   cross-check the join-time tallies.
//! * `SNAPSHOT` — server → one worker: the full dense model iterate,
//!   sent to re-sync a worker that was not present for the preceding
//!   rounds — a rejoiner under [`super::faults::FailurePolicy`]'s
//!   `WaitRejoin`, or every worker of a run restarted from a cluster
//!   checkpoint. `next_round` is the first round the receiver will
//!   participate in; the receiver replaces its replica with the payload
//!   verbatim (no folding), zeroes its error memory, and reseeds its
//!   gradient stream from [`super::faults::rejoin_rng`].
//!
//! ## Accounted vs transmitted bits
//!
//! The run records keep the paper's closed-form accounting in
//! `total_bits`/the loss curve (so wire runs stay comparable — and
//! bit-identical — to simulated runs); the bytes that actually crossed
//! the channel are reported separately in the record extras
//! (`wire_upload_payload_bits`, `wire_broadcast_payload_bits`,
//! `wire_frame_bits`, split by direction into
//! `wire_upload_frame_bits` + `wire_broadcast_frame_bits`). See the
//! README's "Wire protocol" section for the reconciliation between the
//! two.
//!
//! ## TCP backend: length framing + handshake
//!
//! [`super::net`] carries these frames across real sockets. On the
//! wire every frame is length-delimited — a 4-byte big-endian length
//! prefix, then exactly that many payload bytes (the bitstream above).
//! The receiver checks the prefix against [`MAX_FRAME_BYTES`] *before*
//! allocating, and [`decode_msg`] enforces the same cap, so a hostile
//! peer cannot force a huge allocation with a few bytes of input. A
//! cluster connection opens with a JSON handshake — the worker's
//! `HELLO` (protocol version + optional dim / `MethodSpec` /
//! `LocalUpdate` expectations), answered by the server's `WELCOME`
//! (node id assigned in accept order + the full run config) or an
//! `{"error": …}` rejection — and speaks the binary protocol from then
//! on. See [`super::net`] and [`super::cluster`] for the details.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, Result};

use crate::compress::elias::{decode_payload, BitReader, BitWriter};
use crate::compress::{Compressor, Update};

/// Hard cap on a single wire frame (16 MiB — a dense-raw payload for a
/// ~4M-coordinate model; every frame this crate produces is orders of
/// magnitude smaller). [`decode_msg`] and the TCP length-framing reader
/// ([`super::net::read_frame`]) both refuse anything larger before
/// allocating or decoding, so untrusted bytes cannot turn a length
/// field into a giant allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 24;

/// One end of a reliable, ordered, message-framed duplex link.
///
/// Implementations must be [`Send`]: endpoints are created on the
/// engine thread and moved into worker threads. `send` must not block
/// indefinitely on a connected peer (the loopback is unbounded; a
/// socket backend would buffer); `recv` blocks until a frame arrives
/// and errors descriptively when the peer is gone — engine shutdown
/// relies on dropped endpoints turning blocked `recv`s into errors
/// instead of deadlocks.
pub trait Channel: Send {
    /// Transmit one frame (a length-delimited opaque byte string).
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    /// Block for the next frame.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Best-effort local close: after this, the *peer*'s blocked
    /// operations should fail promptly (socket backends shut the
    /// stream down). Failure policies call it when marking a node dead
    /// so a half-open connection cannot hold a deadline hostage.
    /// Default: no-op — in-process backends rely on drop for the same
    /// effect.
    fn hangup(&mut self) {}
}

/// A transport fabric: hands out duplex channel pairs. The engines call
/// [`Transport::duplex`] once per worker on the server thread and move
/// one end into the worker.
pub trait Transport {
    /// Create one duplex link; returns `(server_end, worker_end)`.
    fn duplex(&mut self) -> (Box<dyn Channel>, Box<dyn Channel>);
}

/// In-process loopback transport over unbounded [`mpsc`] channels — the
/// reference [`Transport`]: frames are moved, never shared, so the
/// endpoints behave exactly like a socket pair with serialization at
/// the boundary.
#[derive(Clone, Copy, Debug, Default)]
pub struct Loopback;

struct LoopbackEnd {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

impl Channel for LoopbackEnd {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| anyhow!("channel closed: peer endpoint dropped"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("channel closed: peer endpoint dropped"))
    }
}

impl Transport for Loopback {
    fn duplex(&mut self) -> (Box<dyn Channel>, Box<dyn Channel>) {
        let (tx_sw, rx_sw) = mpsc::channel(); // server -> worker
        let (tx_ws, rx_ws) = mpsc::channel(); // worker -> server
        (
            Box::new(LoopbackEnd { tx: tx_sw, rx: rx_ws }),
            Box::new(LoopbackEnd { tx: tx_ws, rx: rx_sw }),
        )
    }
}

/// Wraps any [`Transport`] and counts every byte crossing it (tallied
/// once, at the sending endpoint), both in total and split by
/// direction: bytes sent from the worker end travel the **upload**
/// direction (worker → server), bytes sent from the server end travel
/// the **broadcast** direction (server → workers — `BROADCAST`, `GO`,
/// `APPLY`, `SHUTDOWN`). The wire-accounting tests compare these
/// independent counts against the engine-reported `wire_frame_bits` /
/// `wire_upload_frame_bits` / `wire_broadcast_frame_bits`.
pub struct CountingTransport {
    inner: Box<dyn Transport>,
    bytes: Arc<AtomicU64>,
    upload: Arc<AtomicU64>,
    broadcast: Arc<AtomicU64>,
}

impl CountingTransport {
    pub fn new(inner: Box<dyn Transport>) -> CountingTransport {
        CountingTransport {
            inner,
            bytes: Arc::new(AtomicU64::new(0)),
            upload: Arc::new(AtomicU64::new(0)),
            broadcast: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Handle on the total byte counter, both directions (keep a clone
    /// before handing the transport to the engine).
    pub fn counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.bytes)
    }

    /// Bytes sent worker → server (`UPLOAD` frames).
    pub fn upload_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.upload)
    }

    /// Bytes sent server → workers (`BROADCAST`/`GO`/`APPLY`/`SHUTDOWN`).
    pub fn broadcast_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.broadcast)
    }
}

struct CountingChannel {
    inner: Box<dyn Channel>,
    bytes: Arc<AtomicU64>,
    direction: Arc<AtomicU64>,
}

impl Channel for CountingChannel {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.direction.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }
}

impl Transport for CountingTransport {
    fn duplex(&mut self) -> (Box<dyn Channel>, Box<dyn Channel>) {
        let (s, w) = self.inner.duplex();
        (
            Box::new(CountingChannel {
                inner: s,
                bytes: Arc::clone(&self.bytes),
                direction: Arc::clone(&self.broadcast),
            }),
            Box::new(CountingChannel {
                inner: w,
                bytes: Arc::clone(&self.bytes),
                direction: Arc::clone(&self.upload),
            }),
        )
    }
}

// ---------------------------------------------------------------------------
// Typed wire messages
// ---------------------------------------------------------------------------

const MSG_UPLOAD: u64 = 1;
const MSG_BROADCAST: u64 = 2;
const MSG_GO: u64 = 3;
const MSG_APPLY: u64 = 4;
const MSG_SHUTDOWN: u64 = 5;
const MSG_REDUCE: u64 = 6;
const MSG_GATHER: u64 = 7;
const MSG_EXCHANGE: u64 = 8;
const MSG_REPORT: u64 = 9;
const MSG_SNAPSHOT: u64 = 10;

/// A decoded wire message (see the module docs for the frame format).
#[derive(Debug)]
pub enum WireMsg {
    /// Worker → server: one node's compressed sync.
    Upload { round: u64, node: u32, accounted_bits: u64, update: Update },
    /// Server → workers (sync): the round's aggregated update.
    Broadcast { round: u64, update: Update },
    /// Server → one worker (async): compute a phase at `η(version)`.
    Go { version: u64 },
    /// Server → workers (async): one applied update for the replicas.
    Apply { version: u64, update: Update },
    /// Server → workers: the run is over.
    Shutdown,
    /// Ring node → right neighbor (all-reduce): running partial fold.
    Reduce { round: u64, node: u32, accounted_bits: u64, hop_bits: u64, update: Update },
    /// Ring node → right neighbor (all-reduce): completed aggregate.
    Gather { round: u64, accounted_bits: u64, hop_bits: u64, update: Update },
    /// Gossip node → matched partner: the sender's compressed sync.
    Exchange { round: u64, node: u32, accounted_bits: u64, update: Update },
    /// Gossip node → driver (eval rounds): the node's dense iterate.
    Report { round: u64, node: u32, accounted_bits: u64, update: Update },
    /// Server → one worker: full model re-sync for a rejoiner or a
    /// checkpoint restart; `next_round` is the first round the
    /// receiver participates in.
    Snapshot { next_round: u64, update: Update },
}

/// [`decode_msg`]'s result: the message plus the measured bit length of
/// its update payload (0 for control messages) — what the engines
/// aggregate into the `wire_*_payload_bits` record extras.
#[derive(Debug)]
pub struct DecodedMsg {
    pub msg: WireMsg,
    pub payload_bits: u64,
}

/// Encode an `UPLOAD` into `w` (cleared first); the update payload is
/// framed by the producing compressor's typed codec
/// ([`Compressor::encode_payload`]). Returns the payload bit count;
/// the frame to transmit is `w.as_bytes()`.
pub fn encode_upload(
    w: &mut BitWriter,
    round: u64,
    node: u32,
    accounted_bits: u64,
    comp: &dyn Compressor,
    update: &Update,
) -> u64 {
    w.clear();
    w.put_gamma(MSG_UPLOAD);
    w.put_gamma(round + 1);
    w.put_gamma(node as u64 + 1);
    w.put_gamma(accounted_bits + 1);
    comp.encode_payload(update, w)
}

/// Encode a `BROADCAST` into `w` (cleared first) with the generic
/// update codec. Returns the payload bit count.
pub fn encode_broadcast(w: &mut BitWriter, round: u64, update: &Update) -> u64 {
    w.clear();
    w.put_gamma(MSG_BROADCAST);
    w.put_gamma(round + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Encode a `GO` into `w` (cleared first).
pub fn encode_go(w: &mut BitWriter, version: u64) {
    w.clear();
    w.put_gamma(MSG_GO);
    w.put_gamma(version + 1);
}

/// Encode an `APPLY` into `w` (cleared first) with the generic update
/// codec. Returns the payload bit count.
pub fn encode_apply(w: &mut BitWriter, version: u64, update: &Update) -> u64 {
    w.clear();
    w.put_gamma(MSG_APPLY);
    w.put_gamma(version + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Encode a `SHUTDOWN` into `w` (cleared first).
pub fn encode_shutdown(w: &mut BitWriter) {
    w.clear();
    w.put_gamma(MSG_SHUTDOWN);
}

/// Encode a `REDUCE` into `w` (cleared first) with the generic update
/// codec — the partial aggregate is a merged update, not one
/// compressor's output, so it goes through the self-describing
/// [`crate::compress::elias::encode_payload_update`] framing. Returns
/// the payload bit count.
pub fn encode_reduce(
    w: &mut BitWriter,
    round: u64,
    node: u32,
    accounted_bits: u64,
    hop_bits: u64,
    update: &Update,
) -> u64 {
    w.clear();
    w.put_gamma(MSG_REDUCE);
    w.put_gamma(round + 1);
    w.put_gamma(node as u64 + 1);
    w.put_gamma(accounted_bits + 1);
    w.put_gamma(hop_bits + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Encode a `GATHER` into `w` (cleared first) with the generic update
/// codec. Returns the payload bit count.
pub fn encode_gather(
    w: &mut BitWriter,
    round: u64,
    accounted_bits: u64,
    hop_bits: u64,
    update: &Update,
) -> u64 {
    w.clear();
    w.put_gamma(MSG_GATHER);
    w.put_gamma(round + 1);
    w.put_gamma(accounted_bits + 1);
    w.put_gamma(hop_bits + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Encode an `EXCHANGE` into `w` (cleared first); like `UPLOAD`, the
/// payload is the sender's own compressed sync, so it is framed by the
/// producing compressor's typed codec ([`Compressor::encode_payload`]).
/// Returns the payload bit count.
pub fn encode_exchange(
    w: &mut BitWriter,
    round: u64,
    node: u32,
    accounted_bits: u64,
    comp: &dyn Compressor,
    update: &Update,
) -> u64 {
    w.clear();
    w.put_gamma(MSG_EXCHANGE);
    w.put_gamma(round + 1);
    w.put_gamma(node as u64 + 1);
    w.put_gamma(accounted_bits + 1);
    comp.encode_payload(update, w)
}

/// Encode a `REPORT` into `w` (cleared first) with the generic update
/// codec. Returns the payload bit count.
pub fn encode_report(
    w: &mut BitWriter,
    round: u64,
    node: u32,
    accounted_bits: u64,
    update: &Update,
) -> u64 {
    w.clear();
    w.put_gamma(MSG_REPORT);
    w.put_gamma(round + 1);
    w.put_gamma(node as u64 + 1);
    w.put_gamma(accounted_bits + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Encode a `SNAPSHOT` into `w` (cleared first) with the generic
/// update codec — the model iterate is a dense vector, not one
/// compressor's output. Returns the payload bit count.
pub fn encode_snapshot(w: &mut BitWriter, next_round: u64, update: &Update) -> u64 {
    w.clear();
    w.put_gamma(MSG_SNAPSHOT);
    w.put_gamma(next_round + 1);
    crate::compress::elias::encode_payload_update(update, w)
}

/// Decode one frame. Total on arbitrary input (truncation, corruption,
/// unknown kinds, hostile counts — all descriptive errors, never
/// panics); update payloads are validated against `dim`.
pub fn decode_msg(frame: &[u8], dim: usize) -> Result<DecodedMsg> {
    if frame.len() > MAX_FRAME_BYTES {
        bail!(
            "frame of {} bytes exceeds the max_frame_bytes cap of {MAX_FRAME_BYTES}",
            frame.len()
        );
    }
    let mut r = BitReader::new(frame);
    let kind = r.get_gamma()?;
    let (msg, payload_bits) = match kind {
        MSG_UPLOAD => {
            let round = r.get_gamma()? - 1;
            let node = r.get_gamma()? - 1;
            if node > u32::MAX as u64 {
                bail!("decoded node id {node} out of range");
            }
            let accounted_bits = r.get_gamma()? - 1;
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            (
                WireMsg::Upload { round, node: node as u32, accounted_bits, update },
                payload,
            )
        }
        MSG_BROADCAST => {
            let round = r.get_gamma()? - 1;
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            (WireMsg::Broadcast { round, update }, payload)
        }
        MSG_GO => (WireMsg::Go { version: r.get_gamma()? - 1 }, 0),
        MSG_APPLY => {
            let version = r.get_gamma()? - 1;
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            (WireMsg::Apply { version, update }, payload)
        }
        MSG_SHUTDOWN => (WireMsg::Shutdown, 0),
        MSG_REDUCE | MSG_EXCHANGE | MSG_REPORT => {
            let round = r.get_gamma()? - 1;
            let node = r.get_gamma()? - 1;
            if node > u32::MAX as u64 {
                bail!("decoded node id {node} out of range");
            }
            let node = node as u32;
            let accounted_bits = r.get_gamma()? - 1;
            let hop_bits = if kind == MSG_REDUCE { r.get_gamma()? - 1 } else { 0 };
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            let msg = match kind {
                MSG_REDUCE => WireMsg::Reduce { round, node, accounted_bits, hop_bits, update },
                MSG_EXCHANGE => WireMsg::Exchange { round, node, accounted_bits, update },
                _ => WireMsg::Report { round, node, accounted_bits, update },
            };
            (msg, payload)
        }
        MSG_GATHER => {
            let round = r.get_gamma()? - 1;
            let accounted_bits = r.get_gamma()? - 1;
            let hop_bits = r.get_gamma()? - 1;
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            (WireMsg::Gather { round, accounted_bits, hop_bits, update }, payload)
        }
        MSG_SNAPSHOT => {
            let next_round = r.get_gamma()? - 1;
            let before = r.consumed();
            let update = decode_payload(&mut r, dim)?;
            let payload = r.consumed() - before;
            (WireMsg::Snapshot { next_round, update }, payload)
        }
        other => bail!("unknown wire message kind {other}"),
    };
    Ok(DecodedMsg { msg, payload_bits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{from_spec, SparseVec};
    use crate::util::prng::Prng;

    #[test]
    fn loopback_delivers_frames_in_order() {
        let mut t = Loopback;
        let (mut server, mut worker) = t.duplex();
        server.send(&[1, 2, 3]).unwrap();
        server.send(&[4]).unwrap();
        assert_eq!(worker.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(worker.recv().unwrap(), vec![4]);
        worker.send(&[9, 9]).unwrap();
        assert_eq!(server.recv().unwrap(), vec![9, 9]);
    }

    #[test]
    fn dropped_peer_turns_recv_and_send_into_errors() {
        let mut t = Loopback;
        let (server, mut worker) = t.duplex();
        drop(server);
        assert!(worker.recv().is_err());
        assert!(worker.send(&[1]).is_err());
    }

    #[test]
    fn counting_transport_counts_bytes_once_at_send() {
        let mut t = CountingTransport::new(Box::new(Loopback));
        let counter = t.counter();
        let upload = t.upload_counter();
        let broadcast = t.broadcast_counter();
        let (mut server, mut worker) = t.duplex();
        server.send(&[0; 10]).unwrap();
        worker.send(&[0; 3]).unwrap();
        worker.recv().unwrap();
        server.recv().unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 13);
        // Per-direction split: the server end sends the broadcast
        // direction, the worker end sends the upload direction.
        assert_eq!(broadcast.load(Ordering::Relaxed), 10);
        assert_eq!(upload.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn decode_msg_rejects_frames_over_the_cap() {
        let junk = vec![0u8; MAX_FRAME_BYTES + 1];
        let err = decode_msg(&junk, 10).unwrap_err();
        assert!(format!("{err:#}").contains("max_frame_bytes"), "{err:#}");
    }

    #[test]
    fn upload_roundtrips_through_the_frame_codec() {
        let comp = from_spec("top_k:2").unwrap();
        let mut sv = SparseVec::new(100);
        sv.push(42, -1.5);
        sv.push(7, 0.25);
        let update = Update::Sparse(sv);
        let mut w = BitWriter::new();
        let payload = encode_upload(&mut w, 12, 3, 4567, comp.as_ref(), &update);
        let dec = decode_msg(w.as_bytes(), 100).unwrap();
        assert_eq!(dec.payload_bits, payload);
        match dec.msg {
            WireMsg::Upload { round, node, accounted_bits, update: u } => {
                assert_eq!((round, node, accounted_bits), (12, 3, 4567));
                assert_eq!(u.to_dense(100), update.to_dense(100));
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn control_messages_roundtrip() {
        let mut w = BitWriter::new();
        encode_go(&mut w, 7);
        match decode_msg(w.as_bytes(), 10).unwrap().msg {
            WireMsg::Go { version } => assert_eq!(version, 7),
            other => panic!("wrong kind: {other:?}"),
        }
        encode_shutdown(&mut w);
        assert!(matches!(decode_msg(w.as_bytes(), 10).unwrap().msg, WireMsg::Shutdown));
        let bits = encode_apply(&mut w, 3, &Update::Dense(vec![1.0, -2.0]));
        let dec = decode_msg(w.as_bytes(), 2).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Apply { version, update } => {
                assert_eq!(version, 3);
                assert_eq!(update.to_dense(2), vec![1.0, -2.0]);
            }
            other => panic!("wrong kind: {other:?}"),
        }
        let bits = encode_broadcast(&mut w, 9, &Update::Sparse(SparseVec::new(4)));
        let dec = decode_msg(w.as_bytes(), 4).unwrap();
        assert_eq!(dec.payload_bits, bits);
        assert!(matches!(dec.msg, WireMsg::Broadcast { round: 9, .. }));
    }

    #[test]
    fn ring_and_gossip_messages_roundtrip() {
        let mut w = BitWriter::new();
        let mut sv = SparseVec::new(64);
        sv.push(3, 0.5);
        sv.push(60, -2.0);
        let partial = Update::Sparse(sv);
        let bits = encode_reduce(&mut w, 4, 2, 900, 128, &partial);
        let dec = decode_msg(w.as_bytes(), 64).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Reduce { round, node, accounted_bits, hop_bits, update } => {
                assert_eq!((round, node, accounted_bits, hop_bits), (4, 2, 900, 128));
                assert_eq!(update.to_dense(64), partial.to_dense(64));
            }
            other => panic!("wrong kind: {other:?}"),
        }

        let bits = encode_gather(&mut w, 4, 3600, 384, &partial);
        let dec = decode_msg(w.as_bytes(), 64).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Gather { round, accounted_bits, hop_bits, update } => {
                assert_eq!((round, accounted_bits, hop_bits), (4, 3600, 384));
                assert_eq!(update.to_dense(64), partial.to_dense(64));
            }
            other => panic!("wrong kind: {other:?}"),
        }

        let comp = from_spec("top_k:2").unwrap();
        let bits = encode_exchange(&mut w, 7, 1, 450, comp.as_ref(), &partial);
        let dec = decode_msg(w.as_bytes(), 64).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Exchange { round, node, accounted_bits, update } => {
                assert_eq!((round, node, accounted_bits), (7, 1, 450));
                assert_eq!(update.to_dense(64), partial.to_dense(64));
            }
            other => panic!("wrong kind: {other:?}"),
        }

        let iterate = Update::Dense(vec![1.0, -0.5, 0.25]);
        let bits = encode_report(&mut w, 9, 5, 12345, &iterate);
        let dec = decode_msg(w.as_bytes(), 3).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Report { round, node, accounted_bits, update } => {
                assert_eq!((round, node, accounted_bits), (9, 5, 12345));
                assert_eq!(update.to_dense(3), vec![1.0, -0.5, 0.25]);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn snapshot_roundtrips_a_dense_model() {
        let mut w = BitWriter::new();
        let model = Update::Dense(vec![0.5, -1.25, 0.0, 3.0]);
        let bits = encode_snapshot(&mut w, 17, &model);
        let dec = decode_msg(w.as_bytes(), 4).unwrap();
        assert_eq!(dec.payload_bits, bits);
        match dec.msg {
            WireMsg::Snapshot { next_round, update } => {
                assert_eq!(next_round, 17);
                assert_eq!(update.to_dense(4), vec![0.5, -1.25, 0.0, 3.0]);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn decode_msg_is_total_on_garbage() {
        // Empty, truncated, and random frames: errors, never panics.
        assert!(decode_msg(&[], 10).is_err());
        let mut w = BitWriter::new();
        let comp = from_spec("top_k:1").unwrap();
        let mut sv = SparseVec::new(50);
        sv.push(10, 1.0);
        encode_upload(&mut w, 1, 0, 50, comp.as_ref(), &Update::Sparse(sv));
        let bytes = w.as_bytes();
        for cut in 0..bytes.len() {
            // Every strict prefix must fail cleanly (the full frame
            // decodes, so any prefix is genuinely truncated).
            let _ = decode_msg(&bytes[..cut], 50);
        }
        let mut rng = Prng::new(77);
        for _ in 0..500 {
            let len = rng.below(40);
            let junk: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_msg(&junk, 64); // must not panic
        }
    }
}
