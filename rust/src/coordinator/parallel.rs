//! PARALLEL-MEM-SGD (Algorithm 2): lock-free shared-memory workers.
//!
//! Each of `W` workers keeps a **private** error memory `m^w` and runs
//! the Mem-SGD recursion against one **shared** parameter vector `x`
//! with no locks, no CAS loops, and non-atomic read-modify-write
//! semantics — a worker's `x[i] -= g` is a plain load followed by a
//! plain store, so concurrent writers can overwrite each other exactly
//! as in the paper ("We did not use atomic updates of the parameter in
//! the shared memory, allowing some workers to overwrite the progress of
//! others"). Rust's memory model forbids genuine data races, so each
//! cell is an `AtomicU32` accessed with `Relaxed` loads/stores: this
//! compiles to the same unsynchronized MOVs while keeping behavior
//! defined; lost updates remain possible because the read-modify-write
//! is *not* fused.
//!
//! The enforced sparsity of the updates is what makes this scheme scale
//! (Figure 4): a top-k worker dirties k cache lines per iteration where
//! Hogwild-style dense SGD dirties d/16 of them.
//!
//! The worker loop itself lives in the generic shared-memory engine of
//! [`super::experiment`] (topology `SharedMemory { workers }`), which
//! runs the crate-wide [`crate::optim::ErrorFeedbackStep`] against any
//! [`crate::models::GradBackend`]; this module keeps the lock-free
//! [`SharedParams`] vector and the deprecated [`run`] shim.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::config::MethodSpec;
use super::experiment;
use crate::compress::CompressorSpec;
use crate::data::Dataset;
use crate::metrics::RunRecord;
use crate::models::LogisticModel;
use crate::optim::Schedule;

/// Shared parameter vector: relaxed atomic f32 cells.
pub struct SharedParams {
    cells: Vec<AtomicU32>,
}

impl SharedParams {
    pub fn zeros(d: usize) -> Arc<SharedParams> {
        Arc::new(SharedParams {
            cells: (0..d).map(|_| AtomicU32::new(0f32.to_bits())).collect(),
        })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Unsynchronized read of one coordinate.
    #[inline]
    pub fn load(&self, i: usize) -> f32 {
        f32::from_bits(self.cells[i].load(Ordering::Relaxed))
    }

    /// Unsynchronized (lossy under contention) `x[i] -= v`.
    #[inline]
    pub fn sub(&self, i: usize, v: f32) {
        let old = f32::from_bits(self.cells[i].load(Ordering::Relaxed));
        self.cells[i].store((old - v).to_bits(), Ordering::Relaxed);
    }

    /// Snapshot into a local buffer (a stale, possibly inconsistent view
    /// — exactly what Algorithm 2's workers compute gradients on).
    pub fn snapshot_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cells.len());
        for (o, c) in out.iter_mut().zip(&self.cells) {
            *o = f32::from_bits(c.load(Ordering::Relaxed));
        }
    }

    pub fn snapshot(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.snapshot_into(&mut out);
        out
    }
}

/// Configuration of a parallel run.
#[derive(Clone, Debug)]
pub struct ParallelConfig {
    /// Worker count `W`.
    pub workers: usize,
    /// Iterations per worker (total work = `workers · steps_per_worker`
    /// unless `fixed_total_steps` redistributes it).
    pub steps_per_worker: usize,
    /// If true, `steps_per_worker` is interpreted as the *total* budget
    /// divided evenly across workers (the speedup-experiment convention:
    /// same total work, more workers).
    pub fixed_total_steps: bool,
    /// Compressor spec applied by every worker (`top_k:1`, `identity` for
    /// the Hogwild-style dense baseline, ...).
    pub compressor: String,
    /// Stepsize schedule (constant 0.05 in the paper's epsilon run).
    pub schedule: Schedule,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            workers: 2,
            steps_per_worker: 10_000,
            fixed_total_steps: true,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.05),
            lam: None,
            seed: 1,
        }
    }
}

/// Run Algorithm 2 and evaluate the **final iterate** (the paper's
/// Section 4.4 protocol). The record's `extra` carries `workers` and
/// `steps_per_worker`.
///
/// Deprecated shim: parses the compressor spec once and delegates to the
/// generic shared-memory engine behind [`super::experiment::Experiment`]
/// (topology `SharedMemory { workers }`).
pub fn run(data: &Dataset, cfg: &ParallelConfig) -> Result<RunRecord> {
    // Validate the spec before spawning anything.
    let comp = CompressorSpec::parse(&cfg.compressor)?;
    let n = data.n();
    let lam = cfg.lam.unwrap_or(1.0 / n as f64);
    let total_steps = if cfg.fixed_total_steps {
        cfg.steps_per_worker
    } else {
        cfg.steps_per_worker * cfg.workers.max(1)
    };
    let settings = experiment::Settings {
        method: MethodSpec::MemSgd { comp },
        schedule: cfg.schedule.clone(),
        steps: total_steps,
        eval_points: 1,
        average: false,
        seed: cfg.seed,
        dataset: data.name.clone(),
        local: super::config::LocalUpdate::default(),
    };
    let mut model = LogisticModel::new(data, lam);
    experiment::shared_memory(&mut model, cfg.workers, &settings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(600, 24, 5)
    }

    #[test]
    fn single_worker_converges() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 1,
            steps_per_worker: 6_000,
            compressor: "top_k:2".into(),
            schedule: Schedule::constant(0.5),
            seed: 3,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        assert!(rec.final_loss() < 0.62, "loss {}", rec.final_loss());
        assert_eq!(rec.extra["workers"], 1.0);
    }

    #[test]
    fn multiple_workers_reach_similar_loss_on_fixed_budget() {
        // Same total work split across 1 vs 4 workers: the final losses
        // must be in the same ballpark (Algorithm 2's claim that sparse
        // updates tolerate lock-free concurrency).
        let data = data();
        let mk = |workers| {
            run(
                &data,
                &ParallelConfig {
                    workers,
                    steps_per_worker: 8_000, // total budget
                    fixed_total_steps: true,
                    compressor: "top_k:2".into(),
                    schedule: Schedule::constant(0.5),
                    seed: 11,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let one = mk(1);
        let four = mk(4);
        assert!(
            (one.final_loss() - four.final_loss()).abs() < 0.08,
            "W=1 {} vs W=4 {}",
            one.final_loss(),
            four.final_loss()
        );
        assert_eq!(four.extra["steps_per_worker"], 2_000.0);
    }

    #[test]
    fn dense_lockfree_baseline_also_runs() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 2,
            steps_per_worker: 2_000,
            compressor: "identity".into(),
            schedule: Schedule::constant(0.2),
            seed: 7,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        assert!(rec.final_loss() < 0.69);
        assert!(rec.method.contains("identity"));
    }

    #[test]
    fn shared_params_lossy_sub_semantics() {
        let p = SharedParams::zeros(3);
        p.sub(1, 2.5);
        assert_eq!(p.load(1), -2.5);
        assert_eq!(p.load(0), 0.0);
        let snap = p.snapshot();
        assert_eq!(snap, vec![0.0, -2.5, 0.0]);
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn bits_are_accounted_across_workers() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 3,
            steps_per_worker: 300,
            fixed_total_steps: false,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            seed: 1,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        // 3 workers × 300 steps × (32 + ceil(log2 24)=5) bits
        assert_eq!(rec.total_bits, 3 * 300 * 37);
        assert_eq!(rec.steps, 900);
    }

    #[test]
    fn rejects_bad_compressor_before_spawning() {
        let data = data();
        let cfg = ParallelConfig {
            compressor: "bogus:1".into(),
            ..Default::default()
        };
        assert!(run(&data, &cfg).is_err());
    }
}
