//! PARALLEL-MEM-SGD (Algorithm 2): lock-free shared-memory workers.
//!
//! Each of `W` workers keeps a **private** error memory `m^w` and runs
//! the Mem-SGD recursion against one **shared** parameter vector `x`
//! with no locks, no CAS loops, and non-atomic read-modify-write
//! semantics — a worker's `x[i] -= g` is a plain load followed by a
//! plain store, so concurrent writers can overwrite each other exactly
//! as in the paper ("We did not use atomic updates of the parameter in
//! the shared memory, allowing some workers to overwrite the progress of
//! others"). Rust's memory model forbids genuine data races, so each
//! cell is an `AtomicU32` accessed with `Relaxed` loads/stores: this
//! compiles to the same unsynchronized MOVs while keeping behavior
//! defined; lost updates remain possible because the read-modify-write
//! is *not* fused.
//!
//! The enforced sparsity of the updates is what makes this scheme scale
//! (Figure 4): a top-k worker dirties k cache lines per iteration where
//! Hogwild-style dense SGD dirties d/16 of them.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Update};
use crate::data::Dataset;
use crate::metrics::{LossPoint, RunRecord};
use crate::models::{sigmoid, GradBackend, LogisticModel};
use crate::optim::Schedule;
use crate::util::prng::Prng;

/// Shared parameter vector: relaxed atomic f32 cells.
pub struct SharedParams {
    cells: Vec<AtomicU32>,
}

impl SharedParams {
    pub fn zeros(d: usize) -> Arc<SharedParams> {
        Arc::new(SharedParams {
            cells: (0..d).map(|_| AtomicU32::new(0f32.to_bits())).collect(),
        })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Unsynchronized read of one coordinate.
    #[inline]
    pub fn load(&self, i: usize) -> f32 {
        f32::from_bits(self.cells[i].load(Ordering::Relaxed))
    }

    /// Unsynchronized (lossy under contention) `x[i] -= v`.
    #[inline]
    pub fn sub(&self, i: usize, v: f32) {
        let old = f32::from_bits(self.cells[i].load(Ordering::Relaxed));
        self.cells[i].store((old - v).to_bits(), Ordering::Relaxed);
    }

    /// Snapshot into a local buffer (a stale, possibly inconsistent view
    /// — exactly what Algorithm 2's workers compute gradients on).
    pub fn snapshot_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cells.len());
        for (o, c) in out.iter_mut().zip(&self.cells) {
            *o = f32::from_bits(c.load(Ordering::Relaxed));
        }
    }

    pub fn snapshot(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.snapshot_into(&mut out);
        out
    }
}

/// Configuration of a parallel run.
#[derive(Clone, Debug)]
pub struct ParallelConfig {
    /// Worker count `W`.
    pub workers: usize,
    /// Iterations per worker (total work = `workers · steps_per_worker`
    /// unless `fixed_total_steps` redistributes it).
    pub steps_per_worker: usize,
    /// If true, `steps_per_worker` is interpreted as the *total* budget
    /// divided evenly across workers (the speedup-experiment convention:
    /// same total work, more workers).
    pub fixed_total_steps: bool,
    /// Compressor spec applied by every worker (`top_k:1`, `identity` for
    /// the Hogwild-style dense baseline, ...).
    pub compressor: String,
    /// Stepsize schedule (constant 0.05 in the paper's epsilon run).
    pub schedule: Schedule,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            workers: 2,
            steps_per_worker: 10_000,
            fixed_total_steps: true,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.05),
            lam: None,
            seed: 1,
        }
    }
}

/// Run Algorithm 2 and evaluate the **final iterate** (the paper's
/// Section 4.4 protocol). The record's `extra` carries `workers` and
/// `total_steps`.
pub fn run(data: &Dataset, cfg: &ParallelConfig) -> Result<RunRecord> {
    compress::from_spec(&cfg.compressor)?; // validate before spawning
    let d = data.d();
    let n = data.n();
    let lam = cfg.lam.unwrap_or(1.0 / n as f64);
    let steps_per_worker = if cfg.fixed_total_steps {
        (cfg.steps_per_worker / cfg.workers.max(1)).max(1)
    } else {
        cfg.steps_per_worker
    };

    let shared = SharedParams::zeros(d);
    let total_bits = Arc::new(AtomicU64::new(0));
    let started = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let total_bits = Arc::clone(&total_bits);
            let comp_spec = cfg.compressor.clone();
            let schedule = cfg.schedule.clone();
            let seed = cfg.seed;
            handles.push(scope.spawn(move || {
                worker_loop(
                    data,
                    &shared,
                    &total_bits,
                    &comp_spec,
                    &schedule,
                    lam,
                    steps_per_worker,
                    seed ^ (w as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
                )
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    let x = shared.snapshot();
    let mut model = LogisticModel::new(data, lam);
    let loss = model.full_loss(&x);
    let total_steps = steps_per_worker * cfg.workers;
    let bits = total_bits.load(Ordering::Relaxed);

    let mut record = RunRecord {
        method: format!("parallel_memsgd({},W={})", cfg.compressor, cfg.workers),
        dataset: data.name.clone(),
        schedule: cfg.schedule.describe(),
        curve: vec![LossPoint {
            t: total_steps,
            bits,
            loss,
        }],
        steps: total_steps,
        total_bits: bits,
        elapsed_ms,
        ..Default::default()
    };
    record.extra.insert("workers".into(), cfg.workers as f64);
    record
        .extra
        .insert("steps_per_worker".into(), steps_per_worker as f64);
    Ok(record)
}

/// One worker's Algorithm-2 loop (lines 3–8).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    data: &Dataset,
    shared: &SharedParams,
    total_bits: &AtomicU64,
    comp_spec: &str,
    schedule: &Schedule,
    lam: f64,
    steps: usize,
    seed: u64,
) -> Result<()> {
    let d = data.d();
    let n = data.n();
    let mut rng = Prng::new(seed);
    let mut comp = compress::from_spec(comp_spec)?;
    let mut m = vec![0.0f32; d]; // private memory m^w
    let mut v = vec![0.0f32; d];
    let mut xbuf = vec![0.0f32; d];
    let mut update = Update::new_sparse(d);
    let lamf = lam as f32;
    let mut bits = 0u64;

    for t in 0..steps {
        let i = rng.below(n);
        // Inconsistent read of the shared iterate (line 5's ∇f(x)).
        shared.snapshot_into(&mut xbuf);
        // coef = −y σ(−y ⟨a_i, x⟩); ∇f_i = coef·a_i + λx.
        let y = data.label(i);
        let z = data.dot_row(i, &xbuf);
        let coef = -y * sigmoid(-y * z);
        let eta = schedule.eta(t) as f32;
        // v = m + η ∇f_i(x), built without materializing the gradient.
        for ((vj, &mj), &xj) in v.iter_mut().zip(&*m).zip(&*xbuf) {
            *vj = mj + eta * lamf * xj;
        }
        match data.row(i) {
            crate::data::RowView::Dense(row) => {
                for (vj, &aj) in v.iter_mut().zip(row) {
                    *vj += eta * coef * aj;
                }
            }
            crate::data::RowView::Sparse { idx, val } => {
                for (&j, &aj) in idx.iter().zip(val) {
                    v[j as usize] += eta * coef * aj;
                }
            }
        }
        // g = comp(v); shared x ← x − g (lossy, lock-free); m ← v − g.
        bits += comp.compress(&v, &mut rng, &mut update);
        match &update {
            Update::Sparse(s) => {
                for (&j, &gj) in s.idx.iter().zip(&s.val) {
                    shared.sub(j as usize, gj);
                }
            }
            Update::Dense(g) => {
                for (j, &gj) in g.iter().enumerate() {
                    if gj != 0.0 {
                        shared.sub(j, gj);
                    }
                }
            }
        }
        m.copy_from_slice(&v);
        update.sub_from(&mut m);
    }
    total_bits.fetch_add(bits, Ordering::Relaxed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(600, 24, 5)
    }

    #[test]
    fn single_worker_converges() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 1,
            steps_per_worker: 6_000,
            compressor: "top_k:2".into(),
            schedule: Schedule::constant(0.5),
            seed: 3,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        assert!(rec.final_loss() < 0.62, "loss {}", rec.final_loss());
        assert_eq!(rec.extra["workers"], 1.0);
    }

    #[test]
    fn multiple_workers_reach_similar_loss_on_fixed_budget() {
        // Same total work split across 1 vs 4 workers: the final losses
        // must be in the same ballpark (Algorithm 2's claim that sparse
        // updates tolerate lock-free concurrency).
        let data = data();
        let mk = |workers| {
            run(
                &data,
                &ParallelConfig {
                    workers,
                    steps_per_worker: 8_000, // total budget
                    fixed_total_steps: true,
                    compressor: "top_k:2".into(),
                    schedule: Schedule::constant(0.5),
                    seed: 11,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let one = mk(1);
        let four = mk(4);
        assert!(
            (one.final_loss() - four.final_loss()).abs() < 0.08,
            "W=1 {} vs W=4 {}",
            one.final_loss(),
            four.final_loss()
        );
        assert_eq!(four.extra["steps_per_worker"], 2_000.0);
    }

    #[test]
    fn dense_lockfree_baseline_also_runs() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 2,
            steps_per_worker: 2_000,
            compressor: "identity".into(),
            schedule: Schedule::constant(0.2),
            seed: 7,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        assert!(rec.final_loss() < 0.69);
        assert!(rec.method.contains("identity"));
    }

    #[test]
    fn shared_params_lossy_sub_semantics() {
        let p = SharedParams::zeros(3);
        p.sub(1, 2.5);
        assert_eq!(p.load(1), -2.5);
        assert_eq!(p.load(0), 0.0);
        let snap = p.snapshot();
        assert_eq!(snap, vec![0.0, -2.5, 0.0]);
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn bits_are_accounted_across_workers() {
        let data = data();
        let cfg = ParallelConfig {
            workers: 3,
            steps_per_worker: 300,
            fixed_total_steps: false,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            seed: 1,
            ..Default::default()
        };
        let rec = run(&data, &cfg).unwrap();
        // 3 workers × 300 steps × (32 + ceil(log2 24)=5) bits
        assert_eq!(rec.total_bits, 3 * 300 * 37);
        assert_eq!(rec.steps, 900);
    }

    #[test]
    fn rejects_bad_compressor_before_spawning() {
        let data = data();
        let cfg = ParallelConfig {
            compressor: "bogus:1".into(),
            ..Default::default()
        };
        assert!(run(&data, &cfg).is_err());
    }
}
