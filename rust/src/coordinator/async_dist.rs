//! Asynchronous distributed Mem-SGD — the combination the paper singles
//! out as "a promising approach, as it combines the best of both worlds"
//! (§1.1) and "the domains where sparsified SGD might have the largest
//! impact" (§5).
//!
//! Event-driven simulation of an asynchronous parameter server:
//!
//! * `W` workers with heterogeneous speeds loop independently:
//!   fetch `x` → compute a stochastic gradient (simulated compute time)
//!   → compress with their **private** error memory → upload.
//! * The server's ingress link is a serialized resource (uploads queue
//!   behind each other, priced by a [`NetworkModel`]); the server applies
//!   each update the instant it is received — no barrier, no locking.
//! * Gradients are therefore computed on *stale* iterates; the staleness
//!   of an update is the number of server applications between its fetch
//!   and its arrival, and is reported in the run record.
//!
//! All time is simulated (integer nanoseconds — deterministic in the
//! seed); convergence is real: the actual logistic objective on the
//! actual dataset, so the run shows both the systems effect (sparse
//! uploads don't queue) and the optimization effect (staleness +
//! error-feedback still converge).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Compressor, Update};
use crate::data::Dataset;
use crate::metrics::{LossPoint, RunRecord};
use crate::models::{GradBackend, LogisticModel};
use crate::optim::Schedule;
use crate::sim::network::{ComputeModel, NetworkModel};
use crate::util::prng::Prng;

/// Configuration of an asynchronous distributed run.
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    /// Worker count.
    pub workers: usize,
    /// Total updates the server will apply before stopping.
    pub total_updates: usize,
    /// Per-worker compressor spec.
    pub compressor: String,
    /// Stepsize schedule indexed by the server's update counter.
    pub schedule: Schedule,
    /// Network pricing of uploads (server ingress is the shared queue).
    pub network: NetworkModel,
    /// Per-gradient compute cost.
    pub compute: ComputeModel,
    /// Speed spread: worker `w` computes at `1 + hetero·w/(W−1)` × the
    /// base time (0 = homogeneous fleet).
    pub hetero: f64,
    /// Loss evaluations along the run.
    pub eval_points: usize,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            workers: 8,
            total_updates: 20_000,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            network: NetworkModel::eth_1g(),
            compute: ComputeModel::new(1e-9, 2000.0),
            hetero: 0.5,
            eval_points: 10,
            lam: None,
            seed: 1,
        }
    }
}

/// Per-worker async state.
struct AsyncWorker {
    memory: Vec<f32>,
    v: Vec<f32>,
    comp: Box<dyn Compressor>,
    update: Update,
    rng: Prng,
    /// Server update-counter value at this worker's last fetch.
    fetch_version: u64,
    /// Compute-time multiplier ≥ 1.
    slow: f64,
    bits_uploaded: u64,
}

/// Pending event: a worker finishing its gradient at `t_ns`.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Finish {
    t_ns: u64,
    worker: usize,
}

/// Outcome extras beyond the shared [`RunRecord`].
#[derive(Clone, Debug)]
pub struct AsyncStats {
    /// Mean staleness (server updates between fetch and apply).
    pub mean_staleness: f64,
    /// Maximum observed staleness.
    pub max_staleness: u64,
    /// Simulated wall-clock of the whole run (seconds).
    pub sim_seconds: f64,
    /// Fraction of simulated time the server link was busy.
    pub link_utilization: f64,
}

/// Run asynchronous distributed Mem-SGD; returns the loss record (curve
/// is indexed by server updates, `extra` carries the async stats).
pub fn run(data: &Dataset, cfg: &AsyncConfig) -> Result<(RunRecord, AsyncStats)> {
    let d = data.d();
    let n = data.n();
    let lam = cfg.lam.unwrap_or(1.0 / n as f64);
    let mut model = LogisticModel::new(data, lam);
    let mut root_rng = Prng::new(cfg.seed);

    let mut workers: Vec<AsyncWorker> = (0..cfg.workers)
        .map(|w| {
            Ok(AsyncWorker {
                memory: vec![0.0; d],
                v: vec![0.0; d],
                comp: compress::from_spec(&cfg.compressor)?,
                update: Update::new_sparse(d),
                rng: root_rng.split(w as u64 + 1),
                fetch_version: 0,
                slow: 1.0
                    + if cfg.workers > 1 {
                        cfg.hetero * w as f64 / (cfg.workers - 1) as f64
                    } else {
                        0.0
                    },
                bits_uploaded: 0,
            })
        })
        .collect::<Result<_>>()?;

    let mut x = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];

    // Event queue: min-heap over finish time.
    let mut queue: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    let compute_ns = |w: &AsyncWorker, cm: &ComputeModel| -> u64 {
        (cm.s_per_coord * cm.coords_per_grad * w.slow * 1e9).max(1.0) as u64
    };
    for (i, w) in workers.iter().enumerate() {
        queue.push(Reverse(Finish {
            t_ns: compute_ns(w, &cfg.compute),
            worker: i,
        }));
    }

    let mut version = 0u64; // server update counter
    let mut link_free_ns = 0u64; // server ingress link busy-until
    let mut link_busy_total = 0u64;
    let mut staleness_sum = 0u64;
    let mut staleness_max = 0u64;
    let mut now_ns = 0u64;

    let eval_every = (cfg.total_updates / cfg.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: format!(
            "async_memsgd({},W={},{})",
            cfg.compressor, cfg.workers, cfg.network.name
        ),
        dataset: data.name.clone(),
        schedule: cfg.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint {
        t: 0,
        bits: 0,
        loss: model.full_loss(&x),
    });

    while version < cfg.total_updates as u64 {
        let Reverse(ev) = queue.pop().expect("queue never empties");
        now_ns = now_ns.max(ev.t_ns);
        let w = &mut workers[ev.worker];

        // The worker finished its gradient (computed on the x it fetched;
        // staleness-wise the fetch snapshot is what matters — we apply
        // against the *current* x exactly like a real lock-free PS).
        let i = w.rng.below(n);
        model.sample_grad(&x, i, &mut grad);
        let eta = cfg.schedule.eta(version as usize) as f32;
        // Error feedback only for contraction operators (unbiased
        // quantizers run memory-free, as in the paper's §4.3 baseline).
        let use_memory = w.comp.contraction_k(d).is_some();
        if use_memory {
            for ((vj, &mj), &gj) in w.v.iter_mut().zip(&w.memory).zip(&grad) {
                *vj = mj + eta * gj;
            }
        } else {
            for (vj, &gj) in w.v.iter_mut().zip(&grad) {
                *vj = eta * gj;
            }
        }
        let bits = w.comp.compress(&w.v, &mut w.rng, &mut w.update);
        w.bits_uploaded += bits;
        if use_memory {
            std::mem::swap(&mut w.memory, &mut w.v);
            w.update.sub_from(&mut w.memory);
        }

        // Upload queues behind the shared server link. The link is busy
        // for the serialization time only; propagation latency delays the
        // arrival but does not occupy the link.
        let xfer_ns = (cfg.network.xfer_s(bits) * 1e9).max(1.0) as u64;
        let latency_ns = (cfg.network.latency_s * 1e9) as u64;
        let start_ns = ev.t_ns.max(link_free_ns);
        link_free_ns = start_ns + xfer_ns;
        link_busy_total += xfer_ns;
        let arrive_ns = link_free_ns + latency_ns;
        now_ns = now_ns.max(arrive_ns);

        // Server applies instantly on receipt.
        w.update.sub_from(&mut x);
        version += 1;
        let stale = version - 1 - w.fetch_version;
        staleness_sum += stale;
        staleness_max = staleness_max.max(stale);

        // Worker refetches and starts the next gradient.
        w.fetch_version = version;
        queue.push(Reverse(Finish {
            t_ns: arrive_ns + compute_ns(w, &cfg.compute),
            worker: ev.worker,
        }));

        if version % eval_every as u64 == 0 || version == cfg.total_updates as u64 {
            let bits: u64 = workers.iter().map(|w| w.bits_uploaded).sum();
            record.curve.push(LossPoint {
                t: version as usize,
                bits,
                loss: model.full_loss(&x),
            });
        }
    }

    let total_bits: u64 = workers.iter().map(|w| w.bits_uploaded).sum();
    record.steps = version as usize;
    record.total_bits = total_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    let stats = AsyncStats {
        mean_staleness: staleness_sum as f64 / version.max(1) as f64,
        max_staleness: staleness_max,
        sim_seconds: now_ns as f64 / 1e9,
        link_utilization: if now_ns > 0 {
            (link_busy_total as f64 / now_ns as f64).min(1.0)
        } else {
            0.0
        },
    };
    record
        .extra
        .insert("mean_staleness".into(), stats.mean_staleness);
    record
        .extra
        .insert("max_staleness".into(), stats.max_staleness as f64);
    record.extra.insert("sim_seconds".into(), stats.sim_seconds);
    record
        .extra
        .insert("link_utilization".into(), stats.link_utilization);
    record.extra.insert("workers".into(), cfg.workers as f64);
    Ok((record, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(600, 32, 33)
    }

    fn cfg(workers: usize, comp: &str, updates: usize) -> AsyncConfig {
        AsyncConfig {
            workers,
            total_updates: updates,
            compressor: comp.into(),
            schedule: Schedule::constant(0.4),
            compute: ComputeModel::new(1e-9, 32.0),
            eval_points: 4,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn converges_despite_staleness() {
        let data = data();
        let (rec, stats) = run(&data, &cfg(8, "top_k:1", 12_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
        assert!(stats.mean_staleness > 0.0, "8 workers must be stale");
    }

    #[test]
    fn single_worker_has_zero_staleness() {
        let data = data();
        let (_, stats) = run(&data, &cfg(1, "top_k:2", 2_000)).unwrap();
        assert_eq!(stats.max_staleness, 0);
        assert_eq!(stats.mean_staleness, 0.0);
    }

    #[test]
    fn staleness_grows_with_workers() {
        let data = data();
        let (_, s2) = run(&data, &cfg(2, "top_k:1", 4_000)).unwrap();
        let (_, s16) = run(&data, &cfg(16, "top_k:1", 4_000)).unwrap();
        assert!(
            s16.mean_staleness > s2.mean_staleness,
            "W=16 {} vs W=2 {}",
            s16.mean_staleness,
            s2.mean_staleness
        );
    }

    #[test]
    fn sparse_uploads_saturate_link_less_than_dense() {
        let data = data();
        let mut c_sparse = cfg(8, "top_k:1", 3_000);
        let mut c_dense = cfg(8, "identity", 3_000);
        // Slow link so the wire matters.
        c_sparse.network = NetworkModel::new("slow", 10e-6, 1e7);
        c_dense.network = c_sparse.network.clone();
        let (_, ss) = run(&data, &c_sparse).unwrap();
        let (_, sd) = run(&data, &c_dense).unwrap();
        assert!(
            ss.sim_seconds < sd.sim_seconds / 3.0,
            "sparse {}s vs dense {}s",
            ss.sim_seconds,
            sd.sim_seconds
        );
        assert!(ss.link_utilization < sd.link_utilization);
    }

    #[test]
    fn heterogeneous_fleet_still_converges() {
        let data = data();
        let mut c = cfg(8, "top_k:1", 10_000);
        c.hetero = 3.0; // slowest worker 4× the fastest
        let (rec, _) = run(&data, &c).unwrap();
        assert!(rec.final_loss() < 0.65, "loss {}", rec.final_loss());
    }

    #[test]
    fn deterministic_in_seed() {
        let data = data();
        let (a, sa) = run(&data, &cfg(4, "rand_k:2", 1_000)).unwrap();
        let (b, sb) = run(&data, &cfg(4, "rand_k:2", 1_000)).unwrap();
        assert_eq!(a.final_loss(), b.final_loss());
        assert_eq!(sa.sim_seconds, sb.sim_seconds);
    }

    #[test]
    fn bit_accounting_matches_steps() {
        let data = data();
        let (rec, _) = run(&data, &cfg(4, "top_k:1", 500)).unwrap();
        // d=32: every upload is exactly 32+5 bits.
        assert_eq!(rec.total_bits, 500 * 37);
        assert_eq!(rec.steps, 500);
    }
}
