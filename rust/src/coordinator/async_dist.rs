//! Asynchronous distributed Mem-SGD — the combination the paper singles
//! out as "a promising approach, as it combines the best of both worlds"
//! (§1.1) and "the domains where sparsified SGD might have the largest
//! impact" (§5).
//!
//! Event-driven simulation of an asynchronous parameter server:
//!
//! * `W` workers with heterogeneous speeds loop independently:
//!   fetch `x` → compute a stochastic gradient (simulated compute time)
//!   → compress with their **private** error memory → upload.
//! * The server's ingress link is a serialized resource (uploads queue
//!   behind each other, priced by a [`NetworkModel`]); the server applies
//!   each update the instant it is received — no barrier, no locking.
//! * Gradients are therefore computed on *stale* iterates; the staleness
//!   of an update is the number of server applications between its fetch
//!   and its arrival, and is reported in the run record.
//!
//! All time is simulated (integer nanoseconds — deterministic in the
//! seed); convergence is real: the actual logistic objective on the
//! actual dataset, so the run shows both the systems effect (sparse
//! uploads don't queue) and the optimization effect (staleness +
//! error-feedback still converge).

use anyhow::Result;

use super::config::MethodSpec;
use super::experiment;
use crate::compress::CompressorSpec;
use crate::data::Dataset;
use crate::metrics::RunRecord;
use crate::models::LogisticModel;
use crate::optim::Schedule;
use crate::sim::network::{ComputeModel, NetworkModel};

/// Configuration of an asynchronous distributed run.
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    /// Worker count.
    pub workers: usize,
    /// Total updates the server will apply before stopping.
    pub total_updates: usize,
    /// Per-worker compressor spec.
    pub compressor: String,
    /// Stepsize schedule indexed by the server's update counter.
    pub schedule: Schedule,
    /// Network pricing of uploads (server ingress is the shared queue).
    pub network: NetworkModel,
    /// Per-gradient compute cost.
    pub compute: ComputeModel,
    /// Speed spread: worker `w` computes at `1 + hetero·w/(W−1)` × the
    /// base time (0 = homogeneous fleet).
    pub hetero: f64,
    /// Loss evaluations along the run.
    pub eval_points: usize,
    /// L2 strength; `None` = `1/n`.
    pub lam: Option<f64>,
    pub seed: u64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            workers: 8,
            total_updates: 20_000,
            compressor: "top_k:1".into(),
            schedule: Schedule::constant(0.1),
            network: NetworkModel::eth_1g(),
            compute: ComputeModel::new(1e-9, 2000.0),
            hetero: 0.5,
            eval_points: 10,
            lam: None,
            seed: 1,
        }
    }
}

/// Outcome extras beyond the shared [`RunRecord`].
#[derive(Clone, Debug)]
pub struct AsyncStats {
    /// Mean staleness (server updates between fetch and apply).
    pub mean_staleness: f64,
    /// Maximum observed staleness.
    pub max_staleness: u64,
    /// Simulated wall-clock of the whole run (seconds).
    pub sim_seconds: f64,
    /// Fraction of simulated time the server link was busy.
    pub link_utilization: f64,
}

/// Run asynchronous distributed Mem-SGD; returns the loss record (curve
/// is indexed by server updates, `extra` carries the async stats).
///
/// Deprecated shim: parses the compressor spec once and delegates to the
/// generic asynchronous parameter-server engine behind
/// [`super::experiment::Experiment`] (topology `ParamServerAsync`); the
/// event loop, staleness accounting, and link model live there.
pub fn run(data: &Dataset, cfg: &AsyncConfig) -> Result<(RunRecord, AsyncStats)> {
    let comp = CompressorSpec::parse(&cfg.compressor)?;
    let lam = cfg.lam.unwrap_or(1.0 / data.n() as f64);
    let settings = experiment::Settings {
        method: MethodSpec::MemSgd { comp },
        schedule: cfg.schedule.clone(),
        steps: cfg.total_updates,
        eval_points: cfg.eval_points,
        average: false,
        seed: cfg.seed,
        dataset: data.name.clone(),
        local: super::config::LocalUpdate::default(),
    };
    let mut model = LogisticModel::new(data, lam);
    let record = experiment::param_server_async(
        &mut model,
        cfg.workers,
        &cfg.network,
        &cfg.compute,
        cfg.hetero,
        &settings,
    )?;
    let stats = AsyncStats {
        mean_staleness: record.extra.get("mean_staleness").copied().unwrap_or(0.0),
        max_staleness: record.extra.get("max_staleness").copied().unwrap_or(0.0) as u64,
        sim_seconds: record.extra.get("sim_seconds").copied().unwrap_or(0.0),
        link_utilization: record.extra.get("link_utilization").copied().unwrap_or(0.0),
    };
    Ok((record, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn data() -> Dataset {
        synthetic::epsilon_like(600, 32, 33)
    }

    fn cfg(workers: usize, comp: &str, updates: usize) -> AsyncConfig {
        AsyncConfig {
            workers,
            total_updates: updates,
            compressor: comp.into(),
            schedule: Schedule::constant(0.4),
            compute: ComputeModel::new(1e-9, 32.0),
            eval_points: 4,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn converges_despite_staleness() {
        let data = data();
        let (rec, stats) = run(&data, &cfg(8, "top_k:1", 12_000)).unwrap();
        assert!(rec.final_loss() < 0.64, "loss {}", rec.final_loss());
        assert!(stats.mean_staleness > 0.0, "8 workers must be stale");
    }

    #[test]
    fn single_worker_has_zero_staleness() {
        let data = data();
        let (_, stats) = run(&data, &cfg(1, "top_k:2", 2_000)).unwrap();
        assert_eq!(stats.max_staleness, 0);
        assert_eq!(stats.mean_staleness, 0.0);
    }

    #[test]
    fn staleness_grows_with_workers() {
        let data = data();
        let (_, s2) = run(&data, &cfg(2, "top_k:1", 4_000)).unwrap();
        let (_, s16) = run(&data, &cfg(16, "top_k:1", 4_000)).unwrap();
        assert!(
            s16.mean_staleness > s2.mean_staleness,
            "W=16 {} vs W=2 {}",
            s16.mean_staleness,
            s2.mean_staleness
        );
    }

    #[test]
    fn sparse_uploads_saturate_link_less_than_dense() {
        let data = data();
        let mut c_sparse = cfg(8, "top_k:1", 3_000);
        let mut c_dense = cfg(8, "identity", 3_000);
        // Slow link so the wire matters.
        c_sparse.network = NetworkModel::new("slow", 10e-6, 1e7);
        c_dense.network = c_sparse.network.clone();
        let (_, ss) = run(&data, &c_sparse).unwrap();
        let (_, sd) = run(&data, &c_dense).unwrap();
        assert!(
            ss.sim_seconds < sd.sim_seconds / 3.0,
            "sparse {}s vs dense {}s",
            ss.sim_seconds,
            sd.sim_seconds
        );
        assert!(ss.link_utilization < sd.link_utilization);
    }

    #[test]
    fn heterogeneous_fleet_still_converges() {
        let data = data();
        let mut c = cfg(8, "top_k:1", 10_000);
        c.hetero = 3.0; // slowest worker 4× the fastest
        let (rec, _) = run(&data, &c).unwrap();
        assert!(rec.final_loss() < 0.65, "loss {}", rec.final_loss());
    }

    #[test]
    fn deterministic_in_seed() {
        let data = data();
        let (a, sa) = run(&data, &cfg(4, "rand_k:2", 1_000)).unwrap();
        let (b, sb) = run(&data, &cfg(4, "rand_k:2", 1_000)).unwrap();
        assert_eq!(a.final_loss(), b.final_loss());
        assert_eq!(sa.sim_seconds, sb.sim_seconds);
    }

    #[test]
    fn bit_accounting_matches_steps() {
        let data = data();
        let (rec, _) = run(&data, &cfg(4, "top_k:1", 500)).unwrap();
        // d=32: every upload is exactly 32+5 bits.
        assert_eq!(rec.total_bits, 500 * 37);
        assert_eq!(rec.steps, 500);
    }
}
