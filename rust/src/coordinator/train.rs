//! Sequential training (Algorithm 1 and the Section 4 baselines) —
//! **deprecated string-spec shim** over the unified experiment API.
//!
//! [`run`] / [`run_with_backend`] are kept so existing `TrainConfig`
//! call sites and `"memsgd:top_k:1"`-style spec strings continue to
//! work; they parse the spec once and delegate to the same sequential
//! engine the [`super::experiment::Experiment`] builder uses. New code
//! should prefer the builder:
//!
//! ```text
//! Experiment::new(backend).method(MethodSpec::mem_top_k(1))
//!     .schedule(s).steps(n).run()?
//! ```
//!
//! [`run_resumable`] (checkpointed Mem-SGD with bit-identical resume)
//! still lives here: checkpointing is specific to the sequential
//! Mem-SGD state (iterate + error memory + RNG + averager).

use std::time::Instant;

use anyhow::Result;

use super::config::{LocalUpdate, MethodSpec};
use super::experiment;
use crate::compress;
use crate::data::Dataset;
use crate::metrics::{LossPoint, RunRecord};
use crate::models::{GradBackend, LogisticModel};
use crate::optim::{Schedule, WeightedAverage};
use crate::util::prng::Prng;

/// Configuration of one sequential run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Method spec (see [`MethodSpec::parse`]), e.g. `memsgd:top_k:1`.
    pub method: String,
    /// Stepsize schedule.
    pub schedule: Schedule,
    /// Total stochastic-gradient steps.
    pub steps: usize,
    /// Number of loss evaluations along the run (plus the final point).
    pub eval_points: usize,
    /// Evaluate the Theorem-2.4 weighted average `x̄` (true, Section 4.2)
    /// or the last iterate `x_t` (false, Section 4.4).
    pub average: bool,
    /// Base PRNG seed (sampling, compression randomness).
    pub seed: u64,
    /// L2 strength; `None` = the paper's `λ = 1/n`.
    pub lam: Option<f64>,
    /// Local-update schedule (minibatch size `B`, sync interval `H`).
    /// Validated strictly at run time via [`LocalUpdate::validate`];
    /// [`run_resumable`] additionally requires `sync_every == 1` (the
    /// checkpoint format captures `(x, m, rng, averager)` but not a
    /// mid-phase local accumulator, so resuming inside a phase could not
    /// be bit-identical).
    pub local: LocalUpdate,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: "memsgd:top_k:1".into(),
            schedule: Schedule::constant(0.05),
            steps: 10_000,
            eval_points: 20,
            average: true,
            seed: 1,
            lam: None,
            local: LocalUpdate::default(),
        }
    }
}

impl TrainConfig {
    /// Convenience: `steps = epochs · n`.
    pub fn epochs(mut self, epochs: usize, n: usize) -> Self {
        self.steps = epochs * n;
        self
    }

    /// The paper's theoretical schedule for this dataset/method
    /// (Table 2): `η_t = γ/(λ(t+a))`, `a = multiplier·d/k`.
    pub fn with_paper_schedule(
        mut self,
        d: usize,
        n: usize,
        gamma: f64,
        shift_multiplier: f64,
    ) -> Result<Self> {
        let method = MethodSpec::parse(&self.method)?;
        self.schedule = method.paper_schedule(d, n, gamma, shift_multiplier, self.lam);
        Ok(self)
    }
}

/// Train logistic regression on `data` (λ = 1/n unless overridden).
///
/// Deprecated shim: parses `cfg.method` once and delegates to the
/// unified sequential engine behind [`super::experiment::Experiment`].
pub fn run(data: &Dataset, cfg: &TrainConfig) -> Result<RunRecord> {
    let lam = cfg.lam.unwrap_or(1.0 / data.n() as f64);
    let mut model = LogisticModel::new(data, lam);
    run_with_backend(&mut model, &data.name.clone(), cfg)
}

/// Train against any gradient backend (the PJRT transformer path uses
/// this directly). Deprecated shim over the unified sequential engine.
pub fn run_with_backend<B: GradBackend>(
    backend: &mut B,
    dataset_name: &str,
    cfg: &TrainConfig,
) -> Result<RunRecord> {
    cfg.local.validate()?;
    let settings = experiment::Settings {
        method: MethodSpec::parse(&cfg.method)?,
        schedule: cfg.schedule.clone(),
        steps: cfg.steps,
        eval_points: cfg.eval_points,
        average: cfg.average,
        seed: cfg.seed,
        dataset: dataset_name.to_string(),
        local: cfg.local,
    };
    experiment::sequential(backend, &settings)
}

// ---------------------------------------------------------------------------
// Resumable training (checkpointed Mem-SGD)
// ---------------------------------------------------------------------------

/// When and where [`run_resumable`] persists its state.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Checkpoint file (written atomically: temp + rename).
    pub path: std::path::PathBuf,
    /// Save every this many steps (and always at the end).
    pub every: usize,
    /// Load `path` and continue from its iteration if it exists.
    pub resume: bool,
}

/// [`run`] with periodic checkpointing and optional resume — the
/// preempted-worker story: a run killed at any point and restarted with
/// `resume: true` produces the **bit-identical** final iterate, memory
/// and RNG stream (see `resume_matches_uninterrupted_run` below and the
/// property suite). Mem-SGD methods only: the error memory is the state
/// that must not be lost (dropping it silently changes the algorithm —
/// every suppressed coordinate since step 0 lives there).
pub fn run_resumable(
    data: &Dataset,
    cfg: &TrainConfig,
    policy: &CheckpointPolicy,
) -> Result<RunRecord> {
    use crate::coordinator::checkpoint::Checkpoint;
    use crate::optim::MemSgd;

    let comp_spec = cfg
        .method
        .strip_prefix("memsgd:")
        .ok_or_else(|| anyhow::anyhow!("run_resumable requires a memsgd:* method"))?;
    // Strict local-schedule validation — no panic on user input. Any
    // minibatch size works (the per-step checkpoint state is unchanged);
    // sync_every > 1 is refused because the checkpoint cannot capture a
    // mid-phase accumulator, which would break bit-identical resume.
    cfg.local.validate()?;
    anyhow::ensure!(
        cfg.local.sync_every == 1,
        "run_resumable supports --local-steps 1 only: the checkpoint captures \
         (x, m, rng, averager) but not a mid-phase local accumulator, so resuming \
         inside a local phase could not be bit-identical (got --local-steps {})",
        cfg.local.sync_every
    );
    let batch = cfg.local.batch;
    let lam = cfg.lam.unwrap_or(1.0 / data.n() as f64);
    let mut model = LogisticModel::new(data, lam);
    let d = data.d();
    let n = data.n();
    // Non-contractions (QSGD) run memory-free everywhere else
    // (MethodSpec::error_feedback / build); there is no error memory to
    // checkpoint, so refuse here instead of silently running a
    // different algorithm than the other entry points.
    anyhow::ensure!(
        crate::compress::CompressorSpec::parse(comp_spec)?.contraction_k(d).is_some(),
        "run_resumable requires a contraction operator (memsgd with error memory), got '{comp_spec}'"
    );

    let (mut opt, mut rng, mut avg) = if policy.resume && policy.path.exists() {
        let ck = Checkpoint::load(&policy.path)?;
        anyhow::ensure!(
            ck.compressor_spec == comp_spec,
            "checkpoint was written by '{}', config asks for '{}'",
            ck.compressor_spec,
            comp_spec
        );
        anyhow::ensure!(
            ck.x.len() == d,
            "checkpoint dimension {} != dataset dimension {d}",
            ck.x.len()
        );
        // The RNG stream draws `batch` indices per step, so a mismatch
        // would resume a silently different trajectory.
        anyhow::ensure!(
            ck.batch == batch,
            "checkpoint was written with --batch {}, config asks for --batch {batch}",
            ck.batch
        );
        ck.restore()?
    } else {
        let opt = MemSgd::new(vec![0.0f32; d], compress::from_spec(comp_spec)?);
        let avg = cfg
            .average
            .then(|| WeightedAverage::new(d, cfg.schedule.averaging_shift().max(1.0)));
        (opt, Prng::new(cfg.seed), avg)
    };
    let start_t = opt.t;
    anyhow::ensure!(
        start_t <= cfg.steps,
        "checkpoint is at step {start_t}, past the configured budget {}",
        cfg.steps
    );

    let eval_every = (cfg.steps / cfg.eval_points.max(1)).max(1);
    let mut grad = vec![0.0f32; d];
    let mut idx: Vec<usize> = Vec::with_capacity(batch);
    let mut eval_x = vec![0.0f32; d];
    let mut record = RunRecord {
        method: format!("memsgd({comp_spec}) resumable"),
        dataset: data.name.clone(),
        schedule: cfg.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let mut eval = |t: usize, opt: &MemSgd, avg: &Option<WeightedAverage>,
                    model: &mut LogisticModel, record: &mut RunRecord| {
        match avg {
            Some(a) if a.count() > 0 => a.write_average(&mut eval_x),
            _ => eval_x.copy_from_slice(&opt.x),
        }
        let loss = model.full_loss(&eval_x);
        record.curve.push(LossPoint { t, bits: opt.bits_sent, loss });
    };

    eval(start_t, &opt, &avg, &mut model, &mut record);
    for t in start_t..cfg.steps {
        idx.clear();
        for _ in 0..batch {
            idx.push(rng.below(n));
        }
        model.sample_grad_batch(&opt.x, &idx, &mut grad);
        opt.step(&grad, cfg.schedule.eta(t), &mut rng);
        if let Some(a) = avg.as_mut() {
            a.update(&opt.x);
        }
        if (t + 1) % eval_every == 0 || t + 1 == cfg.steps {
            eval(t + 1, &opt, &avg, &mut model, &mut record);
        }
        if (t + 1) % policy.every.max(1) == 0 || t + 1 == cfg.steps {
            Checkpoint::capture(&opt, comp_spec, &rng, avg.as_ref())
                .with_batch(batch)
                .save(&policy.path)?;
        }
    }
    record.steps = cfg.steps - start_t;
    record.total_bits = opt.bits_sent;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("resumed_from".into(), start_t as f64);
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_data() -> Dataset {
        synthetic::epsilon_like(400, 32, 3)
    }

    fn base_cfg(method: &str, steps: usize) -> TrainConfig {
        TrainConfig {
            method: method.into(),
            steps,
            eval_points: 5,
            seed: 7,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn memsgd_converges_on_small_problem() {
        let data = small_data();
        let cfg = base_cfg("memsgd:top_k:2", 4_000)
            .with_paper_schedule(32, 400, 2.0, 1.0)
            .unwrap();
        let rec = run(&data, &cfg).unwrap();
        let first = rec.curve.first().unwrap().loss;
        let last = rec.final_loss();
        assert!(last < first * 0.9, "no progress: {first} → {last}");
        assert!(last < 0.66, "final loss {last}");
        assert_eq!(rec.steps, 4_000);
        assert!(rec.total_bits > 0);
    }

    #[test]
    fn memsgd_top1_approaches_vanilla_sgd() {
        // The paper's headline: Mem-SGD reaches the same loss as SGD.
        let data = small_data();
        let steps = 12_000;
        let mk = |method: &str| {
            run(
                &data,
                &base_cfg(method, steps)
                    .with_paper_schedule(32, 400, 2.0, 1.0)
                    .unwrap(),
            )
            .unwrap()
        };
        let mem = mk("memsgd:top_k:1");
        let sgd = mk("sgd");
        assert!(
            mem.final_loss() < sgd.final_loss() + 0.03,
            "memsgd {} vs sgd {}",
            mem.final_loss(),
            sgd.final_loss()
        );
        // ...while transmitting far fewer bits (d=32 → ≥ 10× here).
        assert!(mem.total_bits * 10 < sgd.total_bits);
    }

    #[test]
    fn unbiased_rand_k_is_worse_than_memsgd_at_equal_k() {
        // Section 2.2's variance blow-up: the unbiased d/k-scaled variant
        // with k=1 must trail Mem-SGD top-1 at equal iteration count.
        let data = small_data();
        let steps = 6_000;
        let mem = run(
            &data,
            &base_cfg("memsgd:top_k:1", steps)
                .with_paper_schedule(32, 400, 2.0, 1.0)
                .unwrap(),
        )
        .unwrap();
        let unb = run(
            &data,
            &base_cfg("sgd:unbiased_rand_k:1", steps)
                .with_paper_schedule(32, 400, 2.0, 1.0)
                .unwrap(),
        )
        .unwrap();
        assert!(
            mem.final_loss() < unb.final_loss(),
            "memsgd {} vs unbiased {}",
            mem.final_loss(),
            unb.final_loss()
        );
    }

    #[test]
    fn curve_is_recorded_on_schedule() {
        let data = small_data();
        let cfg = base_cfg("sgd", 1_000);
        let rec = run(&data, &cfg).unwrap();
        // initial point + 5 evals
        assert_eq!(rec.curve.len(), 6);
        assert_eq!(rec.curve[0].t, 0);
        assert_eq!(rec.curve.last().unwrap().t, 1_000);
        // bits monotone non-decreasing along the curve
        assert!(rec.curve.windows(2).all(|w| w[0].bits <= w[1].bits));
    }

    #[test]
    fn deterministic_given_seed() {
        let data = small_data();
        let cfg = base_cfg("memsgd:rand_k:2", 500);
        let a = run(&data, &cfg).unwrap();
        let b = run(&data, &cfg).unwrap();
        assert_eq!(a.final_loss(), b.final_loss());
        let mut c = cfg.clone();
        c.seed = 8;
        let cr = run(&data, &c).unwrap();
        assert_ne!(a.final_loss(), cr.final_loss());
    }

    #[test]
    fn averaging_off_uses_last_iterate() {
        let data = small_data();
        let mut cfg = base_cfg("sgd", 800);
        cfg.average = false;
        let rec = run(&data, &cfg).unwrap();
        assert!(rec.final_loss().is_finite());
    }

    #[test]
    fn paper_schedule_sets_shift_from_contraction() {
        let cfg = base_cfg("memsgd:top_k:2", 100)
            .with_paper_schedule(64, 1000, 2.0, 1.0)
            .unwrap();
        match cfg.schedule {
            Schedule::InvT { shift, .. } => assert_eq!(shift, 32.0), // d/k = 64/2
            _ => panic!("expected InvT"),
        }
    }

    #[test]
    fn resume_matches_uninterrupted_run() {
        // Straight 2000-step run vs 900 steps + kill + resume for the
        // rest: bit-identical final loss, bits, and (via the averager)
        // evaluation point.
        let data = small_data();
        let dir = std::env::temp_dir().join("memsgd_resumable_test");
        std::fs::create_dir_all(&dir).unwrap();
        let straight_path = dir.join("straight.ck");
        let split_path = dir.join("split.ck");
        std::fs::remove_file(&straight_path).ok();
        std::fs::remove_file(&split_path).ok();

        let cfg = |steps: usize| base_cfg("memsgd:top_k:2", steps);
        let straight = run_resumable(
            &data,
            &cfg(2_000),
            &CheckpointPolicy { path: straight_path.clone(), every: 10_000, resume: false },
        )
        .unwrap();

        // Phase 1: budget 900, checkpoint at the end.
        run_resumable(
            &data,
            &cfg(900),
            &CheckpointPolicy { path: split_path.clone(), every: 300, resume: false },
        )
        .unwrap();
        // Phase 2: resume to the full 2000-step budget.
        let resumed = run_resumable(
            &data,
            &cfg(2_000),
            &CheckpointPolicy { path: split_path.clone(), every: 10_000, resume: true },
        )
        .unwrap();

        assert_eq!(resumed.extra["resumed_from"], 900.0);
        assert_eq!(resumed.steps, 1_100);
        assert_eq!(resumed.final_loss(), straight.final_loss());
        assert_eq!(resumed.total_bits, straight.total_bits);
        std::fs::remove_file(&straight_path).ok();
        std::fs::remove_file(&split_path).ok();
    }

    #[test]
    fn resume_rejects_mismatched_spec_and_dimension() {
        let data = small_data();
        let dir = std::env::temp_dir().join("memsgd_resumable_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ck");
        std::fs::remove_file(&path).ok();
        run_resumable(
            &data,
            &base_cfg("memsgd:top_k:2", 200),
            &CheckpointPolicy { path: path.clone(), every: 100, resume: false },
        )
        .unwrap();
        // Different compressor: must refuse.
        let err = run_resumable(
            &data,
            &base_cfg("memsgd:rand_k:2", 400),
            &CheckpointPolicy { path: path.clone(), every: 100, resume: true },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("top_k:2"), "{err:#}");
        // Different dimension: must refuse.
        let other = synthetic::epsilon_like(100, 16, 4);
        assert!(run_resumable(
            &other,
            &base_cfg("memsgd:top_k:2", 400),
            &CheckpointPolicy { path: path.clone(), every: 100, resume: true },
        )
        .is_err());
        // Budget already consumed: must refuse.
        assert!(run_resumable(
            &data,
            &base_cfg("memsgd:top_k:2", 100),
            &CheckpointPolicy { path: path.clone(), every: 100, resume: true },
        )
        .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resumable_rejects_invalid_local_schedules_without_panicking() {
        let data = small_data();
        let policy = CheckpointPolicy {
            path: std::env::temp_dir().join("never_written_local.ck"),
            every: 100,
            resume: false,
        };
        // Zero batch / zero sync interval: strict error, not a panic.
        let mut cfg = base_cfg("memsgd:top_k:2", 100);
        cfg.local = LocalUpdate { batch: 0, sync_every: 1 };
        assert!(run_resumable(&data, &cfg, &policy).is_err());
        cfg.local = LocalUpdate { batch: 1, sync_every: 0 };
        assert!(run_resumable(&data, &cfg, &policy).is_err());
        // H > 1 cannot be checkpointed mid-phase: descriptive refusal.
        cfg.local = LocalUpdate::new(1, 2).unwrap();
        let err = run_resumable(&data, &cfg, &policy).unwrap_err();
        assert!(format!("{err:#}").contains("local-steps"), "{err:#}");
        // The string-spec sequential shim also validates strictly.
        let mut cfg = base_cfg("memsgd:top_k:2", 100);
        cfg.local = LocalUpdate { batch: 0, sync_every: 1 };
        assert!(run(&data, &cfg).is_err());
    }

    #[test]
    fn resumable_minibatch_resume_is_bit_identical() {
        let data = small_data();
        let dir = std::env::temp_dir().join("memsgd_resumable_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let straight_path = dir.join("straight.ck");
        let split_path = dir.join("split.ck");
        std::fs::remove_file(&straight_path).ok();
        std::fs::remove_file(&split_path).ok();

        let cfg = |steps: usize| {
            let mut c = base_cfg("memsgd:top_k:2", steps);
            c.local = LocalUpdate::new(3, 1).unwrap();
            c
        };
        let straight = run_resumable(
            &data,
            &cfg(1_000),
            &CheckpointPolicy { path: straight_path.clone(), every: 10_000, resume: false },
        )
        .unwrap();
        run_resumable(
            &data,
            &cfg(400),
            &CheckpointPolicy { path: split_path.clone(), every: 200, resume: false },
        )
        .unwrap();
        let resumed = run_resumable(
            &data,
            &cfg(1_000),
            &CheckpointPolicy { path: split_path.clone(), every: 10_000, resume: true },
        )
        .unwrap();
        assert_eq!(resumed.extra["resumed_from"], 400.0);
        assert_eq!(resumed.final_loss(), straight.final_loss());
        assert_eq!(resumed.total_bits, straight.total_bits);

        // Resuming a B=3 checkpoint with a different --batch must refuse
        // (the sample-index stream depends on it) instead of silently
        // continuing a different trajectory.
        let mut other = cfg(1_000);
        other.local = LocalUpdate::new(2, 1).unwrap();
        let err = run_resumable(
            &data,
            &other,
            &CheckpointPolicy { path: split_path.clone(), every: 10_000, resume: true },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("--batch"), "{err:#}");
        std::fs::remove_file(&straight_path).ok();
        std::fs::remove_file(&split_path).ok();
    }

    #[test]
    fn non_memsgd_method_is_rejected() {
        let data = small_data();
        let policy = CheckpointPolicy {
            path: std::env::temp_dir().join("never_written.ck"),
            every: 100,
            resume: false,
        };
        assert!(run_resumable(&data, &base_cfg("sgd", 100), &policy).is_err());
    }
}
