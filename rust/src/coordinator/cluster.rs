//! Multi-process cluster runtime: `memsgd serve` / `memsgd worker` /
//! `memsgd ring`.
//!
//! PR 5 put the parameter server on a real message-passing wire; this
//! module takes the wire **off-box**. The server
//! ([`ClusterServer`]) and each worker ([`run_worker`]) are separate
//! OS processes exchanging the exact wire protocol of
//! [`super::transport`] over TCP ([`super::net`]) — same frames, same
//! node-id-ordered aggregation, same seeded discrete-event arbiter, so
//! a localhost 3-process run reproduces the simulated engines' loss
//! curves and bit totals exactly (`tests/cluster_lifecycle.rs` pins
//! this; the CI `cluster-smoke` job diffs the `final:` lines).
//! [`RingNodeProcess`] is the **server-free** member of the family: one
//! process per all-reduce ring node, no server process at all — the
//! same `REDUCE`/`GATHER` frames the threaded engine passes between
//! threads flow between processes instead (the CI smoke job's
//! all-reduce case diffs node 0's `final:` line against the simulated
//! twin).
//!
//! ## Protocol
//!
//! 1. **Accept**: the server listens on `--listen`, accepting exactly
//!    `nodes` connections (bounded by [`ACCEPT_TIMEOUT`]). Node ids are
//!    assigned **in accept order** — worker randomness derives from the
//!    node id, not the process, so the trajectory is independent of
//!    which process lands which id.
//! 2. **Handshake**: the worker sends a `HELLO`
//!    ([`super::net::Hello`]); the server checks it against the run
//!    ([`super::net::check_compat`]) and answers either a `WELCOME`
//!    (`{"proto", "node", "config"}` with the full [`RunConfig`]) or an
//!    `{"error": reason}` frame. A mismatch fails the whole run
//!    descriptively — half-compatible clusters silently diverge, so
//!    they are refused up front.
//! 3. **Run**: the worker rebuilds the dataset from the config
//!    (`dataset`/`scale`/`seed` — both sides run the same deterministic
//!    generator), re-derives its RNG stream by replaying the root
//!    generator's splits in node-id order, and enters the same
//!    [`super::experiment::WireWorker`] loops the threaded engine uses.
//!    The server runs the shared protocol halves
//!    (`serve_sync_protocol` / `serve_async_protocol`) against
//!    multiplexed sockets.
//! 4. **Shutdown**: the server drains `SHUTDOWN` to every worker, the
//!    workers consume it and close, and the server flushes and shuts
//!    every socket down (joining its reader threads on the threads
//!    backend) — on error paths too, so a dropped worker fails the run
//!    cleanly instead of hanging the barrier.
//!
//! ## Multiplexing: two I/O backends
//!
//! The server multiplexes its accepted sockets behind per-node
//! [`Channel`] facades consumed by the single-threaded protocol loop;
//! *how* it multiplexes is the [`IoBackend`] chosen at bind time
//! (`memsgd serve --io poll|threads`):
//!
//! * **`poll`** (default on unix) — a `poll(2)`-backed event loop over
//!   nonblocking sockets (`super::mux`): zero reader threads, the
//!   accept loop and handshakes folded into the poller, per-frame
//!   deadlines, and write backpressure. See the `mux` module docs.
//! * **`threads`** (portable fallback, and the only backend off-unix)
//!   — one reader thread per accepted socket, each assembling frames
//!   with the same [`super::net::FrameAssembler`] codec and feeding
//!   the shared [`Channel`] buffers under a mutex + condvar (the
//!   condvar wait releases the lock, so no mutex is ever held across
//!   a blocking receive).
//!
//! Both backends run the identical protocol halves, so the golden
//! suites pin them to the same bit-for-bit trajectories.
//!
//! ## Determinism caveats
//!
//! The trajectory is bit-identical to the simulated and threaded
//! engines because every float fold happens on the server in node-id
//! order and workers replay the exact per-node RNG streams. This
//! requires both sides to build the **same dataset** — same
//! `dataset`/`scale`/`seed`, same build of the deterministic synthetic
//! generator. The handshake pins the dimension; it cannot detect two
//! builds whose generators disagree at equal `d`, so run matching
//! binaries.

use std::collections::VecDeque;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::checkpoint::ClusterCheckpoint;
use super::config::{LocalUpdate, MethodSpec};
use super::experiment::{
    annotate_local, finish_async_wire_record, finish_sync_wire_record, record_method_name,
    run_ring_driver, serve_async_protocol, serve_sync_protocol, AsyncServerTally,
    RingDriverTally, RingNode, Settings, SyncServe, SyncServerTally, Topology, WireWorker,
};
use super::faults::{rejoin_rng, DeadChannel, FailurePolicy, FaultSpec};
use super::net::{
    check_compat, configure_stream, connect_with_retry, handshake_with_retry,
    read_frame_deadline, write_frame, Backoff, FrameAssembler, Hello, TcpChannel,
    FRAME_DEADLINE, HANDSHAKE_TIMEOUT, PROTOCOL_VERSION, READ_TIMEOUT,
};
use super::transport::{decode_msg, Channel, WireMsg, MAX_FRAME_BYTES};
use crate::experiments::{self, Which};
use crate::metrics::{LossPoint, RunRecord};
use crate::models::{GradBackend, LogisticModel};
use crate::optim::Schedule;
use crate::sim::network::{ComputeModel, NetworkModel};
use crate::util::json::Json;
use crate::util::prng::Prng;

/// How long the server waits for all `nodes` workers to connect.
pub const ACCEPT_TIMEOUT: Duration = Duration::from_secs(120);

/// Poll interval of the non-blocking accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Worker compute-speed spread for the async topology — matches the
/// `Experiment` builder's default so `memsgd serve --topology ps-async`
/// reproduces `memsgd train --wire --topology ps-async` exactly.
const HETERO: f64 = 0.5;

/// How the server multiplexes its accepted sockets (`serve --io ...`).
/// Selected at bind time; both backends run the identical protocol and
/// produce bit-identical trajectories.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoBackend {
    /// `poll(2)` event loop over nonblocking sockets (`super::mux`):
    /// no per-connection reader threads, concurrent handshakes,
    /// per-frame deadlines, write backpressure. Unix only.
    Poll,
    /// One blocking reader thread per accepted socket — the portable
    /// fallback, and the only backend on non-unix platforms.
    Threads,
}

impl IoBackend {
    /// Parse a `--io` flag value (`poll` | `threads`).
    pub fn parse(s: &str) -> Result<IoBackend> {
        match s {
            "poll" => {
                if cfg!(unix) {
                    Ok(IoBackend::Poll)
                } else {
                    bail!("--io poll requires a unix platform (poll(2)); use --io threads")
                }
            }
            "threads" => Ok(IoBackend::Threads),
            other => bail!("unknown I/O backend '{other}' (poll | threads)"),
        }
    }

    /// The default backend: `poll` where the syscall exists, `threads`
    /// elsewhere.
    pub fn platform_default() -> IoBackend {
        if cfg!(unix) {
            IoBackend::Poll
        } else {
            IoBackend::Threads
        }
    }

    /// The `--io` flag spelling of this backend.
    pub fn name(&self) -> &'static str {
        match self {
            IoBackend::Poll => "poll",
            IoBackend::Threads => "threads",
        }
    }
}

/// The `WELCOME` frame payload for an accepted node — shared by both
/// I/O backends so the handshake is byte-identical under either.
/// `proto` travels as a string, like [`Hello`] and the config seed.
pub(crate) fn welcome_json(cfg: &RunConfig, node: usize) -> String {
    Json::obj(vec![
        ("proto", Json::str(PROTOCOL_VERSION.to_string())),
        ("node", Json::Num(node as f64)),
        ("config", cfg.to_json()),
    ])
    .to_string()
}

/// The full run description a server carries and ships to every worker
/// in the `WELCOME` frame. Both sides rebuild the dataset and schedule
/// from these fields, so the only state that crosses the wire at
/// run time is the protocol itself.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Dataset name (`epsilon` | `rcv1`).
    pub dataset: String,
    /// Dataset scale divisor (see [`experiments::dataset`]).
    pub scale: usize,
    /// Root PRNG seed — dataset generation and worker streams.
    pub seed: u64,
    /// Canonical method spec string ([`MethodSpec::spec_string`]).
    pub method: String,
    /// Stepsize schedule (f64 params round-trip exactly through JSON).
    pub schedule: Schedule,
    /// Total local-step budget across all workers.
    pub steps: usize,
    /// Loss evaluations along the run.
    pub eval_points: usize,
    /// Worker count — the server accepts exactly this many.
    pub nodes: usize,
    /// Local-update schedule (`B`, `H`).
    pub local: LocalUpdate,
    /// `ps-sync` | `ps-async`.
    pub topology: String,
    /// Network model name for `ps-async` (`1g` | `10g` | `100g`).
    pub network: String,
    /// Model dimension — pinned in the handshake.
    pub dim: usize,
    /// What the server does when a worker dies mid-run
    /// (`--failure-policy`; defaults to fail-fast, today's behavior).
    pub failure_policy: FailurePolicy,
    /// Server-side fault plan (`--fault-plan` on `serve`/`ring`;
    /// `None` = no injected faults). Workers injecting their own faults
    /// use the `memsgd worker --fault-plan` flag instead — a plan must
    /// wrap exactly one side of each link.
    pub fault_plan: Option<FaultSpec>,
    /// First round to serve — nonzero only when the server restarted
    /// from a cluster checkpoint; workers then consume an opening
    /// `SNAPSHOT` frame before the data plane starts.
    pub start_round: usize,
}

impl RunConfig {
    /// Reject configs that could not serve: unknown method/dataset/
    /// topology/network strings, zero nodes/steps/dim, invalid
    /// local-update schedule.
    pub fn validate(&self) -> Result<()> {
        MethodSpec::parse(&self.method).context("cluster config method")?;
        Which::parse(&self.dataset).context("cluster config dataset")?;
        self.local.validate()?;
        match self.topology.as_str() {
            "ps-sync" | "ps-async" | "all-reduce" => {}
            other => bail!(
                "unknown topology '{other}' in cluster config (ps-sync|ps-async|all-reduce)"
            ),
        }
        if self.topology == "ps-async" {
            self.network_model()?;
        }
        if self.nodes == 0 {
            bail!("cluster config: nodes must be >= 1");
        }
        if self.steps == 0 {
            bail!("cluster config: steps must be >= 1");
        }
        if self.dim == 0 {
            bail!("cluster config: dim must be set");
        }
        match self.failure_policy {
            FailurePolicy::FailFast => {}
            FailurePolicy::DropRound { .. } => {
                if self.topology == "all-reduce" {
                    bail!(
                        "cluster config: drop-round applies to the parameter-server \
                         topologies; every all-reduce ring hop is load-bearing"
                    );
                }
            }
            FailurePolicy::WaitRejoin { .. } => {
                if self.topology != "ps-sync" {
                    bail!(
                        "cluster config: wait-rejoin requires the ps-sync topology \
                         (only the sync server re-syncs a rejoiner from a SNAPSHOT)"
                    );
                }
            }
        }
        Ok(())
    }

    /// The network cost model behind the async topology's simulated
    /// clock.
    pub fn network_model(&self) -> Result<NetworkModel> {
        Ok(match self.network.as_str() {
            "1g" => NetworkModel::eth_1g(),
            "10g" => NetworkModel::eth_10g(),
            "100g" => NetworkModel::ib_100g(),
            other => bail!("unknown network '{other}' in cluster config (1g|10g|100g)"),
        })
    }

    /// The server's handshake fingerprint — every field concrete.
    pub fn hello(&self) -> Hello {
        Hello {
            proto: PROTOCOL_VERSION,
            dim: self.dim,
            method: self.method.clone(),
            batch: self.local.batch,
            sync_every: self.local.sync_every,
        }
    }

    /// Serialize for the `WELCOME` frame. The seed travels as a string
    /// (u64 does not fit an f64 JSON number losslessly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("scale", Json::Num(self.scale as f64)),
            ("seed", Json::str(self.seed.to_string())),
            ("method", Json::str(self.method.clone())),
            ("schedule", schedule_to_json(&self.schedule)),
            ("steps", Json::Num(self.steps as f64)),
            ("eval_points", Json::Num(self.eval_points as f64)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("batch", Json::Num(self.local.batch as f64)),
            ("sync_every", Json::Num(self.local.sync_every as f64)),
            ("topology", Json::str(self.topology.clone())),
            ("network", Json::str(self.network.clone())),
            ("dim", Json::Num(self.dim as f64)),
            ("failure_policy", Json::str(self.failure_policy.spec_string())),
            (
                "fault_plan",
                Json::str(
                    self.fault_plan
                        .as_ref()
                        .map(|s| s.spec_string())
                        .unwrap_or_else(|| "none".to_string()),
                ),
            ),
            ("start_round", Json::Num(self.start_round as f64)),
        ])
    }

    /// Parse and re-validate a config received from a peer.
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let cfg = RunConfig {
            dataset: j.req("dataset")?.as_str()?.to_string(),
            scale: j.req("scale")?.as_usize()?,
            seed: j
                .req("seed")?
                .as_str()?
                .parse::<u64>()
                .map_err(|e| anyhow!("cluster config seed: {e}"))?,
            method: j.req("method")?.as_str()?.to_string(),
            schedule: schedule_from_json(j.req("schedule")?)?,
            steps: j.req("steps")?.as_usize()?,
            eval_points: j.req("eval_points")?.as_usize()?,
            nodes: j.req("nodes")?.as_usize()?,
            local: LocalUpdate::new(
                j.req("batch")?.as_usize()?,
                j.req("sync_every")?.as_usize()?,
            )?,
            topology: j.req("topology")?.as_str()?.to_string(),
            network: j.req("network")?.as_str()?.to_string(),
            dim: j.req("dim")?.as_usize()?,
            // The failure keys are optional with pre-fault defaults, so
            // frames from older peers still parse (and mean fail-fast).
            failure_policy: match j.get("failure_policy") {
                Some(v) => FailurePolicy::parse(v.as_str()?)?,
                None => FailurePolicy::FailFast,
            },
            fault_plan: match j.get("fault_plan") {
                Some(v) => FaultSpec::parse(v.as_str()?)?,
                None => None,
            },
            start_round: match j.get("start_round") {
                Some(v) => v.as_usize()?,
                None => 0,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

fn schedule_to_json(s: &Schedule) -> Json {
    match *s {
        Schedule::InvT { gamma, lambda, shift } => Json::obj(vec![
            ("kind", Json::str("inv_t")),
            ("gamma", Json::Num(gamma)),
            ("lambda", Json::Num(lambda)),
            ("shift", Json::Num(shift)),
        ]),
        Schedule::Bottou { gamma0, lambda } => Json::obj(vec![
            ("kind", Json::str("bottou")),
            ("gamma0", Json::Num(gamma0)),
            ("lambda", Json::Num(lambda)),
        ]),
        Schedule::Const { eta } => {
            Json::obj(vec![("kind", Json::str("const")), ("eta", Json::Num(eta))])
        }
    }
}

/// Inverse of [`schedule_to_json`]. Constructs the enum literally after
/// checking positivity — a malformed peer frame must bail, not trip the
/// constructors' asserts.
fn schedule_from_json(j: &Json) -> Result<Schedule> {
    match j.req("kind")?.as_str()? {
        "inv_t" => {
            let gamma = j.req("gamma")?.as_f64()?;
            let lambda = j.req("lambda")?.as_f64()?;
            let shift = j.req("shift")?.as_f64()?;
            if !(gamma > 0.0 && lambda > 0.0 && shift > 0.0) {
                bail!("invalid inv_t schedule in cluster config (all params must be > 0)");
            }
            Ok(Schedule::InvT { gamma, lambda, shift })
        }
        "bottou" => {
            let gamma0 = j.req("gamma0")?.as_f64()?;
            let lambda = j.req("lambda")?.as_f64()?;
            if !(gamma0 > 0.0 && lambda > 0.0) {
                bail!("invalid bottou schedule in cluster config (all params must be > 0)");
            }
            Ok(Schedule::Bottou { gamma0, lambda })
        }
        "const" => {
            let eta = j.req("eta")?.as_f64()?;
            if !(eta > 0.0) {
                bail!("invalid const schedule in cluster config (eta must be > 0)");
            }
            Ok(Schedule::Const { eta })
        }
        other => bail!("unknown schedule kind '{other}' in cluster config"),
    }
}

// ---------------------------------------------------------------------------
// Server-side socket multiplexing
// ---------------------------------------------------------------------------

/// Lifetime count of per-connection reader threads this process has
/// spawned (threads backend only). The 32-worker stress test asserts
/// the poll backend leaves this untouched.
static READER_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Total reader threads spawned by this process so far — a test probe
/// for the no-reader-threads property of the poll backend.
#[doc(hidden)]
pub fn reader_threads_spawned() -> usize {
    READER_THREADS.load(Ordering::SeqCst)
}

/// State shared by every per-node [`MuxChannel`] on the threads
/// backend: per-node buffers for frames that arrived before the
/// protocol asked for them, the first terminal error per node, and a
/// condvar the reader threads signal. The protocol loop waits on the
/// condvar — the wait *releases* the mutex, so no lock is ever held
/// across a blocking receive and readers never contend with a parked
/// consumer.
struct MuxShared {
    inner: Mutex<MuxInner>,
    cv: Condvar,
}

struct MuxInner {
    pending: Vec<VecDeque<Vec<u8>>>,
    dead: Vec<Option<String>>,
    readers_alive: usize,
    /// Per-node reader generation: a rejoin bumps it, and pushes from a
    /// stale reader (the old socket's thread racing its own teardown)
    /// are discarded instead of re-killing the revived node.
    gen: Vec<u64>,
}

impl MuxShared {
    fn new(nodes: usize) -> MuxShared {
        MuxShared {
            inner: Mutex::new(MuxInner {
                pending: (0..nodes).map(|_| VecDeque::new()).collect(),
                dead: vec![None; nodes],
                readers_alive: nodes,
                gen: vec![0; nodes],
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> Result<MutexGuard<'_, MuxInner>> {
        self.inner.lock().map_err(|_| anyhow!("cluster mux poisoned"))
    }

    fn recv_for(&self, node: usize) -> Result<Vec<u8>> {
        let mut inner = self.lock()?;
        loop {
            if let Some(frame) = inner.pending[node].pop_front() {
                return Ok(frame);
            }
            if let Some(e) = &inner.dead[node] {
                bail!("node {node}: connection lost: {e}");
            }
            if inner.readers_alive == 0 {
                bail!("node {node}: every reader thread has exited");
            }
            // Bounded wait is belt-and-braces only: a silent peer trips
            // the reader's socket timeout within READ_TIMEOUT, which
            // marks the node dead and signals this condvar.
            let (guard, _) = self
                .cv
                .wait_timeout(inner, READ_TIMEOUT)
                .map_err(|_| anyhow!("cluster mux poisoned"))?;
            inner = guard;
        }
    }

    fn push_frame(&self, node: usize, gen: u64, frame: Vec<u8>) {
        if let Ok(mut inner) = self.inner.lock() {
            if inner.gen[node] == gen {
                inner.pending[node].push_back(frame);
            }
        }
        self.cv.notify_all();
    }

    fn push_dead(&self, node: usize, gen: u64, err: String) {
        if let Ok(mut inner) = self.inner.lock() {
            if inner.gen[node] == gen && inner.dead[node].is_none() {
                inner.dead[node] = Some(err);
            }
        }
        self.cv.notify_all();
    }

    fn reader_exited(&self) {
        if let Ok(mut inner) = self.inner.lock() {
            inner.readers_alive = inner.readers_alive.saturating_sub(1);
        }
        self.cv.notify_all();
    }

    /// Re-arm a node slot for a rejoined connection: clear buffered
    /// frames and the death marker, count the fresh reader, and bump
    /// the generation so the old reader's dying gasps are ignored.
    /// Returns the new generation to hand to [`spawn_reader`].
    fn revive(&self, node: usize) -> Result<u64> {
        let mut inner = self.lock()?;
        inner.pending[node].clear();
        inner.dead[node] = None;
        inner.readers_alive += 1;
        inner.gen[node] += 1;
        Ok(inner.gen[node])
    }
}

/// The threads backend's per-node [`Channel`] facade: `send` writes
/// straight to the node's socket; `recv` pulls that node's next frame
/// out of the shared mux (reader threads buffer every node's frames in
/// arrival order).
struct MuxChannel {
    node: usize,
    writer: TcpStream,
    shared: Arc<MuxShared>,
}

impl Channel for MuxChannel {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        write_frame(&mut self.writer, frame)
            .with_context(|| format!("sending to node {}", self.node))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.shared.recv_for(self.node)
    }

    fn hangup(&mut self) {
        // Both directions: the reader thread holds a clone of this
        // socket, and shutting it down turns its blocked read into an
        // immediate error instead of a deadline wait.
        let _ = self.writer.shutdown(Shutdown::Both);
    }
}

fn spawn_reader(
    node: usize,
    gen: u64,
    mut stream: TcpStream,
    shared: Arc<MuxShared>,
) -> std::thread::JoinHandle<()> {
    READER_THREADS.fetch_add(1, Ordering::SeqCst);
    std::thread::spawn(move || {
        loop {
            // The whole-frame deadline applies on the threads data
            // plane too: a trickling peer is cut off, not tolerated.
            match read_frame_deadline(&mut stream, MAX_FRAME_BYTES, Some(FRAME_DEADLINE)) {
                Ok(frame) => shared.push_frame(node, gen, frame),
                Err(e) => {
                    shared.push_dead(node, gen, format!("{e:#}"));
                    break;
                }
            }
        }
        shared.reader_exited();
    })
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// The cluster parameter server: binds, accepts exactly `cfg.nodes`
/// workers, runs the shared server-protocol half against their sockets,
/// and returns the same [`RunRecord`] the in-process engines produce
/// (plus a `cluster = 1` extra).
pub struct ClusterServer {
    listener: TcpListener,
    cfg: RunConfig,
    data: crate::data::Dataset,
    io: IoBackend,
    /// Cluster checkpoint sink: `(path, every-N-rounds)`.
    checkpoint: Option<(std::path::PathBuf, usize)>,
    /// The checkpoint this serve resumes from (loaded at
    /// [`ClusterServer::with_checkpoint`] time when the file exists).
    resume: Option<ClusterCheckpoint>,
}

impl ClusterServer {
    /// [`ClusterServer::bind_with_io`] with the platform-default I/O
    /// backend (`poll` on unix, `threads` elsewhere).
    pub fn bind(addr: &str, cfg: RunConfig) -> Result<ClusterServer> {
        ClusterServer::bind_with_io(addr, cfg, IoBackend::platform_default())
    }

    /// Validate the config, build the dataset, and bind `addr`
    /// (`"127.0.0.1:0"` picks a free port — [`ClusterServer::local_addr`]
    /// reports it; the lifecycle tests rely on this). The chosen
    /// [`IoBackend`] drives every accepted socket for the whole run.
    pub fn bind_with_io(addr: &str, cfg: RunConfig, io: IoBackend) -> Result<ClusterServer> {
        cfg.validate()?;
        if io == IoBackend::Poll && !cfg!(unix) {
            bail!("the poll I/O backend requires a unix platform; use IoBackend::Threads");
        }
        let which = Which::parse(&cfg.dataset)?;
        let data = experiments::dataset(which, cfg.scale, cfg.seed);
        if data.d() != cfg.dim {
            bail!(
                "cluster config declares dim {} but the {} dataset generator produced d={}",
                cfg.dim,
                cfg.dataset,
                data.d()
            );
        }
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        Ok(ClusterServer { listener, cfg, data, io, checkpoint: None, resume: None })
    }

    /// Arm cluster checkpointing (`serve --checkpoint path
    /// --checkpoint-every N`): the sync serve saves a
    /// [`ClusterCheckpoint`] every `every` rounds (and at the end), and
    /// if `path` already holds one, this serve *resumes* from it — the
    /// model and round counter restored, the `WELCOME` config carrying
    /// the nonzero `start_round` so every worker seeds its replica from
    /// the opening `SNAPSHOT`. Restart runs resume the model, not the
    /// workers' error memories (those died with their processes), so
    /// they are tested for completion and finiteness, never
    /// golden-pinned.
    pub fn with_checkpoint(
        mut self,
        path: std::path::PathBuf,
        every: usize,
    ) -> Result<ClusterServer> {
        if self.cfg.topology != "ps-sync" {
            bail!(
                "--checkpoint applies to the ps-sync topology; '{}' has no \
                 round boundary to checkpoint at",
                self.cfg.topology
            );
        }
        if path.exists() {
            let ck = ClusterCheckpoint::load(&path)?;
            if ck.x.len() != self.cfg.dim {
                bail!(
                    "cluster checkpoint {} holds d={}, run has d={}",
                    path.display(),
                    ck.x.len(),
                    self.cfg.dim
                );
            }
            if ck.dead.len() != self.cfg.nodes {
                bail!(
                    "cluster checkpoint {} holds {} nodes, run has {}",
                    path.display(),
                    ck.dead.len(),
                    self.cfg.nodes
                );
            }
            self.cfg.start_round = ck.round as usize;
            self.resume = Some(ck);
        }
        self.checkpoint = Some((path, every.max(1)));
        Ok(self)
    }

    /// The round the run will open at — nonzero only when
    /// [`ClusterServer::with_checkpoint`] found an existing checkpoint.
    pub fn start_round(&self) -> usize {
        self.cfg.start_round
    }

    /// The bound address (resolves a `:0` bind to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("resolving listen addr")
    }

    /// Accept, handshake, serve, shut down — on the I/O backend chosen
    /// at bind time. Teardown runs on success and failure alike:
    /// every socket is flushed and shut down (turning blocked peer
    /// reads into errors), and reader threads — if the backend spawned
    /// any — are joined, so no run leaks threads or sockets.
    pub fn run(self) -> Result<RunRecord> {
        match self.io {
            #[cfg(unix)]
            IoBackend::Poll => self.run_poll(),
            #[cfg(not(unix))]
            IoBackend::Poll => bail!("the poll I/O backend requires a unix platform"),
            IoBackend::Threads => self.run_threads(),
        }
    }

    /// The event-driven backend: `super::mux` accepts and handshakes
    /// all workers inside one `poll(2)` set, then the protocol loop
    /// pumps the same poller through its per-node channels. No
    /// per-connection threads anywhere.
    #[cfg(unix)]
    fn run_poll(self) -> Result<RunRecord> {
        let hello = self.cfg.hello();
        let streams = super::mux::accept_and_handshake(
            &self.listener,
            &hello,
            &|node| welcome_json(&self.cfg, node),
            self.cfg.nodes,
        )?;
        let (mut channels, mux) = super::mux::data_plane(streams);
        let served = if let FailurePolicy::WaitRejoin { timeout } = self.cfg.failure_policy {
            let mut rejoin = |node: usize,
                              _next_round: u64,
                              _x: &[f32]|
             -> Result<Option<Box<dyn Channel>>> {
                match accept_rejoin(&self.listener, &hello, &self.cfg, node, timeout)? {
                    None => Ok(None),
                    Some(stream) => {
                        stream
                            .set_nonblocking(true)
                            .context("setting rejoined socket non-blocking")?;
                        let asm = FrameAssembler::new(MAX_FRAME_BYTES);
                        Ok(Some(super::mux::adopt(&mux, node, stream, asm)?))
                    }
                }
            };
            self.serve(&mut channels, Some(&mut rejoin))
        } else {
            self.serve(&mut channels, None)
        };
        drop(channels);
        super::mux::drain_and_shutdown(&mux);
        served
    }

    /// The portable backend: serial blocking handshakes, then one
    /// reader thread per accepted socket feeding the condvar-signalled
    /// [`MuxShared`].
    fn run_threads(self) -> Result<RunRecord> {
        let nodes = self.cfg.nodes;
        let shared = Arc::new(MuxShared::new(nodes));
        let mut channels: Vec<Box<dyn Channel>> = Vec::with_capacity(nodes);
        let mut shutdowners: Vec<TcpStream> = Vec::with_capacity(nodes);
        let mut readers: Vec<std::thread::JoinHandle<()>> = Vec::with_capacity(nodes);
        let served = match self.accept_workers(
            &shared,
            &mut channels,
            &mut shutdowners,
            &mut readers,
        ) {
            Ok(()) => {
                if let FailurePolicy::WaitRejoin { timeout } = self.cfg.failure_policy {
                    let hello = self.cfg.hello();
                    let mut rejoin = |node: usize,
                                      _next_round: u64,
                                      _x: &[f32]|
                     -> Result<Option<Box<dyn Channel>>> {
                        match accept_rejoin(&self.listener, &hello, &self.cfg, node, timeout)? {
                            None => Ok(None),
                            Some(stream) => {
                                stream
                                    .set_read_timeout(Some(READ_TIMEOUT))
                                    .context("restoring data-plane read timeout")?;
                                let gen = shared.revive(node)?;
                                let reader = stream
                                    .try_clone()
                                    .context("cloning socket for reader thread")?;
                                let shutdowner = stream
                                    .try_clone()
                                    .context("cloning socket for shutdown")?;
                                readers.push(spawn_reader(node, gen, reader, Arc::clone(&shared)));
                                shutdowners.push(shutdowner);
                                Ok(Some(Box::new(MuxChannel {
                                    node,
                                    writer: stream,
                                    shared: Arc::clone(&shared),
                                })))
                            }
                        }
                    };
                    self.serve(&mut channels, Some(&mut rejoin))
                } else {
                    self.serve(&mut channels, None)
                }
            }
            Err(e) => Err(e),
        };
        drop(channels);
        for stream in &shutdowners {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for handle in readers {
            let _ = handle.join();
        }
        served
    }

    /// Accept exactly `nodes` connections, handshaking each in accept
    /// order (node id = accept index). A handshake mismatch sends the
    /// worker an `{"error": ...}` frame and fails the run — the caller's
    /// teardown closes every already-accepted socket.
    fn accept_workers(
        &self,
        shared: &Arc<MuxShared>,
        channels: &mut Vec<Box<dyn Channel>>,
        shutdowners: &mut Vec<TcpStream>,
        readers: &mut Vec<std::thread::JoinHandle<()>>,
    ) -> Result<()> {
        let nodes = self.cfg.nodes;
        let server_hello = self.cfg.hello();
        self.listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let deadline = Instant::now() + ACCEPT_TIMEOUT;
        let mut node = 0usize;
        while node < nodes {
            let mut stream = match self.listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "only {node} of {nodes} workers connected within {}s",
                            ACCEPT_TIMEOUT.as_secs()
                        );
                    }
                    std::thread::sleep(ACCEPT_POLL);
                    continue;
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            };
            stream
                .set_nonblocking(false)
                .context("setting accepted socket blocking")?;
            configure_stream(&stream)?;
            stream
                .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                .context("setting handshake timeout")?;
            // Socket timeout bounds each read; the whole-frame deadline
            // bounds a trickling HELLO as a whole.
            let frame =
                read_frame_deadline(&mut stream, MAX_FRAME_BYTES, Some(HANDSHAKE_TIMEOUT))
                    .with_context(|| format!("reading HELLO from connection {node}"))?;
            let worker_hello = Hello::decode(&frame)?;
            if let Err(e) = check_compat(&worker_hello, &server_hello) {
                let reject =
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                let _ = write_frame(&mut stream, reject.as_bytes());
                let _ = stream.shutdown(Shutdown::Both);
                return Err(e.push_context(format!("connection {node} failed the handshake")));
            }
            let welcome = welcome_json(&self.cfg, node);
            write_frame(&mut stream, welcome.as_bytes())
                .with_context(|| format!("sending WELCOME to node {node}"))?;
            stream
                .set_read_timeout(Some(READ_TIMEOUT))
                .context("restoring data-plane read timeout")?;
            let reader = stream.try_clone().context("cloning socket for reader thread")?;
            let shutdowner = stream.try_clone().context("cloning socket for shutdown")?;
            readers.push(spawn_reader(node, 0, reader, Arc::clone(shared)));
            shutdowners.push(shutdowner);
            channels.push(Box::new(MuxChannel {
                node,
                writer: stream,
                shared: Arc::clone(shared),
            }));
            node += 1;
        }
        Ok(())
    }

    /// The server-protocol half against the accepted sockets — the
    /// exact loops the threaded engines run, minus the in-process
    /// worker threads (those live in other processes now). The
    /// accounted upload bits come from the `UPLOAD` headers; the
    /// threaded engines' second bookkeeping source (worker `ef`
    /// counters) is out of reach across process boundaries, so the
    /// cross-check lives in the golden tests instead.
    ///
    /// `rejoin` is the backend-specific `WaitRejoin` hook (re-accept on
    /// the listener, swap the fresh socket into the data plane) — `None`
    /// under the other policies.
    #[allow(clippy::type_complexity)]
    fn serve(
        &self,
        ends: &mut [Box<dyn Channel>],
        rejoin: Option<&mut dyn FnMut(usize, u64, &[f32]) -> Result<Option<Box<dyn Channel>>>>,
    ) -> Result<RunRecord> {
        let cfg = &self.cfg;
        let method = MethodSpec::parse(&cfg.method)?;
        let n = self.data.n();
        let d = self.data.d();
        let mut backend = LogisticModel::new(&self.data, 1.0 / n as f64);
        let nodes = cfg.nodes.max(1);
        let h = cfg.local.sync_every.max(1);
        let s = Settings {
            method: method.clone(),
            schedule: cfg.schedule.clone(),
            steps: cfg.steps,
            eval_points: cfg.eval_points,
            average: false,
            seed: cfg.seed,
            dataset: self.data.name.clone(),
            local: cfg.local,
            policy: cfg.failure_policy,
            faults: cfg.fault_plan.clone(),
        };
        let started = Instant::now();
        let mut x = vec![0.0f32; d];
        match cfg.topology.as_str() {
            "ps-sync" => {
                let rounds = (cfg.steps / (nodes * h)).max(1);
                let eval_every = (rounds / cfg.eval_points.max(1)).max(1);
                // The server-side half of a `--fault-plan`: wrap the
                // accepted channels in place (workers injecting their
                // own faults leave this unset — one side per link).
                if let Some(spec) = &cfg.fault_plan {
                    let plan = spec.plan(nodes, rounds)?;
                    for (node, ch) in ends.iter_mut().enumerate() {
                        let inner =
                            std::mem::replace(ch, Box::new(DeadChannel::new(node)) as Box<_>);
                        *ch = plan.wrap(node, inner);
                    }
                }
                let mut ctl = SyncServe::with_policy(nodes, cfg.failure_policy);
                ctl.start_round = cfg.start_round.min(rounds);
                ctl.checkpoint = self.checkpoint.clone();
                ctl.rejoin = rejoin;
                if let Some(ck) = &self.resume {
                    x.copy_from_slice(&ck.x);
                    ctl.dead = ck.dead.clone();
                }
                let mut record = RunRecord {
                    method: record_method_name(&method, &Topology::ParamServerSync { nodes }),
                    dataset: s.dataset.clone(),
                    schedule: s.schedule.describe(),
                    ..Default::default()
                };
                record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });
                let mut tally = SyncServerTally::new(nodes);
                serve_sync_protocol(
                    &mut backend,
                    ends,
                    &mut x,
                    rounds,
                    eval_every,
                    &mut record,
                    &mut ctl,
                    &mut tally,
                )?;
                let uploads: u64 = tally.upload_acc.iter().sum();
                finish_sync_wire_record(&mut record, &s, nodes, rounds, uploads, &tally, started);
                record.extra.insert("cluster".into(), 1.0);
                Ok(record)
            }
            "ps-async" => {
                let net = cfg.network_model()?;
                let compute = ComputeModel::new(1e-9, 2000.0);
                let total_syncs = cfg.steps / h;
                let eval_every = (total_syncs / cfg.eval_points.max(1)).max(1);
                let grads_per_sync = (cfg.local.batch.max(1) * h) as f64;
                let slow: Vec<f64> = (0..nodes)
                    .map(|w| {
                        1.0 + if nodes > 1 {
                            HETERO * w as f64 / (nodes - 1) as f64
                        } else {
                            0.0
                        }
                    })
                    .collect();
                let mut record = RunRecord {
                    method: record_method_name(
                        &method,
                        &Topology::ParamServerAsync { nodes, net: net.clone() },
                    ),
                    dataset: s.dataset.clone(),
                    schedule: s.schedule.describe(),
                    ..Default::default()
                };
                // The async fault plan expands against the per-worker
                // turn budget — the identical expression the simulated
                // twin uses, so the schedules line up bit for bit.
                if let Some(spec) = &cfg.fault_plan {
                    let plan = spec.plan(nodes, (total_syncs / nodes).max(2))?;
                    for (node, ch) in ends.iter_mut().enumerate() {
                        let inner =
                            std::mem::replace(ch, Box::new(DeadChannel::new(node)) as Box<_>);
                        *ch = plan.wrap(node, inner);
                    }
                }
                let mut dead = vec![false; nodes];
                record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });
                let mut tally = AsyncServerTally::new(nodes);
                serve_async_protocol(
                    &mut backend,
                    ends,
                    &mut x,
                    &net,
                    &compute,
                    &slow,
                    grads_per_sync,
                    total_syncs,
                    eval_every,
                    &mut record,
                    cfg.failure_policy,
                    &mut dead,
                    &mut tally,
                )?;
                let total_bits: u64 = tally.upload_acc.iter().sum();
                finish_async_wire_record(&mut record, &s, nodes, total_bits, &tally, started);
                record.extra.insert("cluster".into(), 1.0);
                Ok(record)
            }
            "all-reduce" => bail!(
                "topology 'all-reduce' is server-free: there is no server process to run — \
                 launch one `memsgd ring --node I --nodes N` process per node instead"
            ),
            other => bail!("unknown topology '{other}' (validated config cannot reach this)"),
        }
    }
}

/// Wait up to `timeout` on the (already nonblocking) listener for a
/// replacement worker rejoining as `node` — the `WaitRejoin` accept
/// path, shared by both I/O backends. Only a `HELLO` carrying
/// `resume: true` and passing [`check_compat`] is welcomed; everything
/// else gets a descriptive `{"error": …}` frame and the wait continues.
/// Returns the handshaken blocking stream, or `None` on timeout (the
/// node then stays dead and the run continues degraded).
fn accept_rejoin(
    listener: &TcpListener,
    server_hello: &Hello,
    cfg: &RunConfig,
    node: usize,
    timeout: Duration,
) -> Result<Option<TcpStream>> {
    let deadline = Instant::now() + timeout;
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                // One rejoiner at a time is the contract (the serve is
                // parked between rounds), so a blocking handshake with
                // socket timeouts is enough here. A dud connection is
                // dropped and the wait continues — only the deadline
                // ends it.
                let handshaken = (|| -> Result<()> {
                    stream
                        .set_nonblocking(false)
                        .context("setting rejoining socket blocking")?;
                    configure_stream(&stream)?;
                    stream
                        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                        .context("setting handshake timeout")?;
                    let frame = read_frame_deadline(
                        &mut stream,
                        MAX_FRAME_BYTES,
                        Some(HANDSHAKE_TIMEOUT),
                    )
                    .context("reading rejoin HELLO")?;
                    let worker_hello = Hello::decode(&frame)?;
                    if !worker_hello.resume {
                        let reject = Json::obj(vec![(
                            "error",
                            Json::str("run in progress; reconnect with --resume"),
                        )])
                        .to_string();
                        let _ = write_frame(&mut stream, reject.as_bytes());
                        bail!("rejoining connection did not set the resume flag");
                    }
                    if let Err(e) = check_compat(&worker_hello, server_hello) {
                        let reject =
                            Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                        let _ = write_frame(&mut stream, reject.as_bytes());
                        return Err(e.push_context("rejoining connection is incompatible"));
                    }
                    write_frame(&mut stream, welcome_json(cfg, node).as_bytes())
                        .context("sending rejoin WELCOME")?;
                    Ok(())
                })();
                match handshaken {
                    Ok(()) => return Ok(Some(stream)),
                    Err(_) => {
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Ok(None);
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e).context("accepting rejoining worker"),
        }
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// A worker process: dial the server and run the **whole handshake**
/// with bounded-backoff retries ([`handshake_with_retry`] — a worker
/// started before its server survives both the refused connect and the
/// accepted-but-not-yet-serving window), rebuild the dataset and RNG
/// stream the config names, and run the wire-worker protocol to
/// completion. Returns the assigned node id and the accounted upload
/// bits.
///
/// `resume = true` sends a rejoin `HELLO`: the server answers the
/// `WELCOME` with a model `SNAPSHOT` frame, and the worker starts at
/// the carried round on a fresh error memory and the disjoint
/// [`rejoin_rng`] stream. `fault_plan` wraps this worker's own channel
/// with the plan's faults for its node, ops mirrored
/// ([`super::faults::FaultPlan::wrap_peer`]) — the worker-side way to
/// script a chaos run whose server replays the same plan string in its
/// simulated twin.
pub fn run_worker(
    addr: &str,
    expect: &Hello,
    backoff: &Backoff,
    resume: bool,
    fault_plan: Option<&FaultSpec>,
) -> Result<(usize, u64)> {
    let mut hello = expect.clone();
    hello.resume = resume;
    let (stream, frame) = handshake_with_retry(addr, &hello, backoff)?;
    let text = std::str::from_utf8(&frame).context("WELCOME frame is not UTF-8")?;
    let j = Json::parse(text).context("WELCOME frame is not JSON")?;
    let proto_str = j.req("proto")?.as_str().context("WELCOME proto must be a string")?;
    let proto = proto_str
        .parse::<u64>()
        .with_context(|| format!("WELCOME proto '{proto_str}' is not a u64"))?;
    if proto != PROTOCOL_VERSION {
        bail!(
            "protocol version mismatch (server speaks v{proto}, \
             worker speaks v{PROTOCOL_VERSION})"
        );
    }
    let node = j.req("node")?.as_usize()?;
    let cfg = RunConfig::from_json(j.req("config")?)?;
    // Belt and braces: the server already checked, but a worker must
    // never run a config it would not have accepted.
    check_compat(expect, &cfg.hello())?;
    if node >= cfg.nodes {
        bail!("server assigned node id {node}, out of range for {} nodes", cfg.nodes);
    }

    let which = Which::parse(&cfg.dataset)?;
    let data = experiments::dataset(which, cfg.scale, cfg.seed);
    if data.d() != cfg.dim {
        bail!(
            "dataset generators disagree: server declares d={}, local build produced d={}",
            cfg.dim,
            data.d()
        );
    }
    let method = MethodSpec::parse(&cfg.method)?;
    let d = data.d();
    let n = data.n();
    let nodes = cfg.nodes.max(1);
    let h = cfg.local.sync_every.max(1);

    // Re-derive this node's RNG stream: `split` advances the root, so
    // replay the splits in node-id order exactly as the single-process
    // engines perform them (worker w gets the root's (w+1)-th split).
    // A snapshot-resumed worker overrides this with the disjoint
    // `rejoin_rng` stream below.
    let mut root = Prng::new(cfg.seed);
    let mut rng = root.split(1);
    for w in 1..=node {
        rng = root.split(w as u64 + 1);
    }

    let bits = match cfg.topology.as_str() {
        "ps-sync" => {
            let rounds = (cfg.steps / (nodes * h)).max(1);
            let mut ch: Box<dyn Channel> = Box::new(TcpChannel::new(stream)?);
            if let Some(spec) = fault_plan {
                ch = spec.plan(nodes, rounds)?.wrap_peer(node, ch);
            }
            // A rejoiner — and every worker of a checkpoint-restarted
            // server — opens on a model SNAPSHOT: seed the replica from
            // it, start at the carried round, and switch to the
            // disjoint rejoin RNG stream (fresh error memory; the old
            // incarnation's suppressed mass died with it).
            let (start_round, x0) = if resume || cfg.start_round > 0 {
                let frame = ch.recv().context("reading SNAPSHOT")?;
                match decode_msg(&frame, d)?.msg {
                    WireMsg::Snapshot { next_round, update } => {
                        rng = rejoin_rng(cfg.seed, node as u32, next_round);
                        (next_round as usize, update.to_dense(d))
                    }
                    other => bail!("expected a SNAPSHOT frame, got {other:?}"),
                }
            } else {
                (0, vec![0.0f32; d])
            };
            let worker = WireWorker {
                ch,
                backend: LogisticModel::new(&data, 1.0 / n as f64),
                ef: method.error_feedback(d),
                rng,
                schedule: cfg.schedule.clone(),
                local: cfg.local,
                node: node as u32,
                d,
                n,
            };
            // Protocol v3 broadcasts arrive pre-scaled by the server's
            // 1/live quorum factor; replicas apply scale 1.0.
            worker.run_sync_from(start_round, rounds, 1.0, x0)?
        }
        "ps-async" => {
            if resume {
                bail!("--resume applies to the ps-sync topology (async workers have no round boundary to rejoin at)");
            }
            let mut ch: Box<dyn Channel> = Box::new(TcpChannel::new(stream)?);
            if let Some(spec) = fault_plan {
                let total_syncs = cfg.steps / h;
                ch = spec.plan(nodes, (total_syncs / nodes).max(2))?.wrap_peer(node, ch);
            }
            let worker = WireWorker {
                ch,
                backend: LogisticModel::new(&data, 1.0 / n as f64),
                ef: method.error_feedback(d),
                rng,
                schedule: cfg.schedule.clone(),
                local: cfg.local,
                node: node as u32,
                d,
                n,
            };
            worker.run_async()?
        }
        "all-reduce" => bail!(
            "topology 'all-reduce' is server-free: nodes join as ring peers — \
             use `memsgd ring`, not `memsgd worker`"
        ),
        other => bail!("unknown topology '{other}' in server config"),
    };
    Ok((node, bits))
}

// ---------------------------------------------------------------------------
// Server-free ring runtime (`memsgd ring`)
// ---------------------------------------------------------------------------

/// One process of a server-free multi-process all-reduce ring
/// (`memsgd ring --node I --nodes N`). There is **no server**: every
/// node is launched with the identical [`RunConfig`]
/// (`topology = "all-reduce"`), binds a listener for its previous ring
/// neighbor, dials its next neighbor, and the `REDUCE`/`GATHER` frames
/// of [`super::transport`] flow one direction around the ring — exactly
/// the threaded engine's protocol, one process per node. Node 0 drives
/// the recording (the engine's `run_ring_driver` loop) and returns the
/// [`RunRecord`]; the other nodes run the same per-round loop (the
/// engine's `RingNode`) and return `None`.
///
/// ## Handshake
///
/// Unlike the PS cluster, no side owns the config — every launch
/// carries it — so the handshake only has to prove the ring is
/// *compatible*, not distribute state: each node sends its
/// [`Hello`] fingerprint down its outgoing edge and answers the
/// fingerprint arriving on its incoming edge with an `{"ok": 1}` frame
/// (or an `{"error": reason}` rejection that fails the whole ring
/// descriptively — the ACK travels the reverse direction of the same
/// socket, which TCP's full duplex permits even though run-time frames
/// flow one way only). Node ids come from `--node`, not accept order,
/// so the operator controls the fold order explicitly.
pub struct RingNodeProcess {
    listener: TcpListener,
    cfg: RunConfig,
    data: crate::data::Dataset,
    node: usize,
}

impl RingNodeProcess {
    /// Validate the config (must be the `all-reduce` topology, `node`
    /// in range), build the dataset, and bind the listener for the
    /// previous ring neighbor (`"127.0.0.1:0"` picks a free port —
    /// [`RingNodeProcess::local_addr`] reports it).
    pub fn bind(addr: &str, cfg: RunConfig, node: usize) -> Result<RingNodeProcess> {
        cfg.validate()?;
        if cfg.topology != "all-reduce" {
            bail!(
                "`memsgd ring` runs the all-reduce topology; config says '{}' \
                 (use `memsgd serve` / `memsgd worker` for the parameter-server topologies)",
                cfg.topology
            );
        }
        if node >= cfg.nodes {
            bail!("ring node id {node} out of range for {} nodes", cfg.nodes);
        }
        let which = Which::parse(&cfg.dataset)?;
        let data = experiments::dataset(which, cfg.scale, cfg.seed);
        if data.d() != cfg.dim {
            bail!(
                "cluster config declares dim {} but the {} dataset generator produced d={}",
                cfg.dim,
                cfg.dataset,
                data.d()
            );
        }
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        Ok(RingNodeProcess { listener, cfg, data, node })
    }

    /// The bound address (resolves a `:0` bind to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("resolving listen addr")
    }

    /// Accept exactly one inbound connection — the previous ring
    /// neighbor — within [`ACCEPT_TIMEOUT`].
    fn accept_prev(&self) -> Result<TcpStream> {
        self.listener
            .set_nonblocking(true)
            .context("setting the ring listener non-blocking")?;
        let deadline = Instant::now() + ACCEPT_TIMEOUT;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .context("setting accepted ring socket blocking")?;
                    return Ok(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "node {}: previous ring node did not connect within {}s",
                            self.node,
                            ACCEPT_TIMEOUT.as_secs()
                        );
                    }
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => return Err(e).context("accepting ring connection"),
            }
        }
    }

    /// Dial `next`, handshake both ring edges, and run the node's half
    /// of the protocol to completion. Returns node 0's [`RunRecord`]
    /// (with `wire = 1` and `cluster = 1` extras), `None` elsewhere.
    /// With `nodes = 1` the ring is degenerate — no sockets, no
    /// transmitted bits, `next` never dialed.
    ///
    /// `fault_plan` (from this node's own `--fault-plan` flag) wraps
    /// the **inbound** ring edge, mirroring the simulated engine's
    /// `plan.wrap(me, left)` — every hop is load-bearing in a ring, so
    /// only fail-fast semantics apply (an injected cut takes the whole
    /// ring down by design).
    pub fn run(
        self,
        next: &str,
        backoff: &Backoff,
        fault_plan: Option<&FaultSpec>,
    ) -> Result<Option<RunRecord>> {
        let cfg = &self.cfg;
        let me = self.node;
        let nodes = cfg.nodes.max(1);
        let method = MethodSpec::parse(&cfg.method)?;
        let d = self.data.d();
        let n = self.data.n();
        let h = cfg.local.sync_every.max(1);
        let rounds = (cfg.steps / (nodes * h)).max(1);
        let scale = 1.0 / nodes as f32;

        // Re-derive this node's RNG stream by replaying the root
        // generator's splits in node-id order (see `run_worker`).
        let mut root = Prng::new(cfg.seed);
        let mut rng = root.split(1);
        for w in 1..=me {
            rng = root.split(w as u64 + 1);
        }
        let mut backend = LogisticModel::new(&self.data, 1.0 / n as f64);
        let mut ef = method.error_feedback(d);

        let ring = if nodes > 1 {
            let hello = cfg.hello();
            // Dial first and push our fingerprint into the buffer, then
            // take the inbound edge — every node does the same, so no
            // accept ever waits on a peer that is itself blocked
            // accepting.
            let mut send_stream = connect_with_retry(next, backoff)
                .with_context(|| format!("node {me}: dialing next ring node at {next}"))?;
            configure_stream(&send_stream)?;
            write_frame(&mut send_stream, &hello.encode())
                .with_context(|| format!("node {me}: sending ring HELLO"))?;
            let mut recv_stream = self.accept_prev()?;
            configure_stream(&recv_stream)?;
            let frame =
                read_frame_deadline(&mut recv_stream, MAX_FRAME_BYTES, Some(HANDSHAKE_TIMEOUT))
                    .with_context(|| format!("node {me}: reading ring HELLO from prev node"))?;
            let peer = Hello::decode(&frame)?;
            if let Err(e) = check_compat(&peer, &hello) {
                let reject =
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                let _ = write_frame(&mut recv_stream, reject.as_bytes());
                let _ = recv_stream.shutdown(Shutdown::Both);
                return Err(
                    e.push_context(format!("node {me}: previous ring node is incompatible"))
                );
            }
            let ack = Json::obj(vec![("ok", Json::Num(1.0))]).to_string();
            write_frame(&mut recv_stream, ack.as_bytes())
                .with_context(|| format!("node {me}: acking ring HELLO"))?;
            // Our own fingerprint's verdict arrives on the outgoing
            // edge (the next node wrote it against the run direction).
            let verdict =
                read_frame_deadline(&mut send_stream, MAX_FRAME_BYTES, Some(HANDSHAKE_TIMEOUT))
                    .with_context(|| format!("node {me}: reading ring ACK from next node"))?;
            let text = std::str::from_utf8(&verdict).context("ring ACK is not UTF-8")?;
            let j = Json::parse(text).context("ring ACK is not JSON")?;
            if let Some(err) = j.get("error") {
                bail!(
                    "node {me}: next ring node rejected the handshake: {}",
                    err.as_str().unwrap_or("unknown reason")
                );
            }
            j.req("ok").with_context(|| format!("node {me}: malformed ring ACK"))?;
            let mut left: Box<dyn Channel> = Box::new(TcpChannel::new(recv_stream)?);
            if let Some(spec) = fault_plan {
                left = spec.plan(nodes, rounds)?.wrap(me, left);
            }
            Some((left, Box::new(TcpChannel::new(send_stream)?) as Box<dyn Channel>))
        } else {
            None
        };

        if me != 0 {
            let (left, right) = ring.expect("a multi-node ring peer has both edges");
            let nd = RingNode {
                left,
                right,
                backend,
                ef,
                rng,
                schedule: cfg.schedule.clone(),
                local: cfg.local,
                node: me as u32,
                nodes,
                d,
                n,
            };
            nd.run(rounds, scale)?;
            return Ok(None);
        }

        // Node 0: drive and record. The header-carried tallies
        // reconstruct the simulated engine's exact accounting; the
        // cross-node reconciliation against every peer's own counters
        // lives in the golden tests (the peers' `ef` state is in other
        // processes).
        let started = Instant::now();
        let eval_every = (rounds / cfg.eval_points.max(1)).max(1);
        let mut record = RunRecord {
            method: record_method_name(&method, &Topology::AllReduce { nodes }),
            dataset: self.data.name.clone(),
            schedule: cfg.schedule.describe(),
            ..Default::default()
        };
        let mut x = vec![0.0f32; d];
        record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });
        let mut tally = RingDriverTally::new();
        let mut ring = ring;
        run_ring_driver(
            &mut backend,
            ring.as_mut().map(|(l, r)| (&mut **l as &mut dyn Channel, &mut **r as &mut dyn Channel)),
            &mut ef,
            &mut rng,
            &cfg.schedule,
            cfg.local,
            nodes,
            rounds,
            eval_every,
            &mut x,
            &mut record,
            &mut tally,
        )?;
        record.steps = rounds * nodes * h;
        record.total_bits = tally.reduce_bits + tally.gather_bits;
        record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
        record.extra.insert("workers".into(), nodes as f64);
        record.extra.insert("upload_bits".into(), tally.gather_acc as f64);
        record.extra.insert("reduce_bits".into(), tally.reduce_bits as f64);
        record.extra.insert("gather_bits".into(), tally.gather_bits as f64);
        record.extra.insert("wire".into(), 1.0);
        record.extra.insert("cluster".into(), 1.0);
        annotate_local(&mut record, cfg.local, rounds * nodes * h);
        Ok(Some(record))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunConfig {
        RunConfig {
            dataset: "epsilon".into(),
            scale: 2000,
            seed: u64::MAX - 7, // exercises the string-seed path
            method: "memsgd:top_k:1".into(),
            schedule: Schedule::InvT { gamma: 2.0, lambda: 1.0 / 200.0, shift: 2000.0 },
            steps: 200,
            eval_points: 4,
            nodes: 2,
            local: LocalUpdate { batch: 2, sync_every: 3 },
            topology: "ps-sync".into(),
            network: "1g".into(),
            dim: 2000,
            failure_policy: FailurePolicy::FailFast,
            fault_plan: None,
            start_round: 0,
        }
    }

    #[test]
    fn run_config_json_round_trips_every_schedule_kind() {
        for schedule in [
            Schedule::InvT { gamma: 2.0, lambda: 0.001, shift: 47.0 },
            Schedule::Bottou { gamma0: 0.25, lambda: 1.0 / 677.0 },
            Schedule::Const { eta: 0.05 },
        ] {
            let c = RunConfig { schedule, ..cfg() };
            let json = c.to_json().to_string();
            let back = RunConfig::from_json(&Json::parse(&json).unwrap()).unwrap();
            assert_eq!(back, c, "{json}");
        }
    }

    #[test]
    fn run_config_json_round_trips_failure_fields() {
        let c = RunConfig {
            failure_policy: FailurePolicy::DropRound { min_quorum: 2 },
            fault_plan: FaultSpec::parse("kill:1:42").unwrap(),
            start_round: 17,
            ..cfg()
        };
        let json = c.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back, c, "{json}");
    }

    #[test]
    fn run_config_json_defaults_failure_fields_for_old_peers() {
        // A WELCOME frame from a pre-v3 server carries none of the
        // failure keys; it must parse and mean fail-fast, no plan,
        // round zero.
        let json = cfg().to_json().to_string();
        let j = Json::parse(&json).unwrap();
        let stripped = Json::obj(
            ["dataset", "scale", "seed", "method", "schedule", "steps", "eval_points",
             "nodes", "batch", "sync_every", "topology", "network", "dim"]
                .iter()
                .map(|k| (*k, j.req(k).unwrap().clone()))
                .collect(),
        );
        let back = RunConfig::from_json(&stripped).unwrap();
        assert_eq!(back.failure_policy, FailurePolicy::FailFast);
        assert!(back.fault_plan.is_none());
        assert_eq!(back.start_round, 0);
    }

    #[test]
    fn run_config_validation_enforces_the_policy_matrix() {
        // drop-round needs a server to form a quorum; every all-reduce
        // ring hop is load-bearing.
        let mut c = cfg();
        c.topology = "all-reduce".into();
        c.failure_policy = FailurePolicy::DropRound { min_quorum: 1 };
        let msg = format!("{:#}", c.validate().unwrap_err());
        assert!(msg.contains("all-reduce"), "{msg}");
        // wait-rejoin needs the sync server's SNAPSHOT re-sync.
        let mut c = cfg();
        c.topology = "ps-async".into();
        c.failure_policy = FailurePolicy::WaitRejoin { timeout: Duration::from_secs(5) };
        let msg = format!("{:#}", c.validate().unwrap_err());
        assert!(msg.contains("ps-sync") || msg.contains("sync server"), "{msg}");
        // ps-sync accepts all three policies.
        for policy in [
            FailurePolicy::FailFast,
            FailurePolicy::DropRound { min_quorum: 1 },
            FailurePolicy::WaitRejoin { timeout: Duration::from_secs(5) },
        ] {
            let mut c = cfg();
            c.failure_policy = policy;
            assert!(c.validate().is_ok());
        }
    }

    #[test]
    fn run_config_validation_is_strict() {
        assert!(cfg().validate().is_ok());
        // The server-free ring topology is a valid *config*; only the
        // server refuses to serve it (there is no server to run).
        let mut ring_cfg = cfg();
        ring_cfg.topology = "all-reduce".into();
        assert!(ring_cfg.validate().is_ok());
        let reject = |mutate: &dyn Fn(&mut RunConfig), needle: &str| {
            let mut c = cfg();
            mutate(&mut c);
            let err = c.validate().unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "expected '{needle}' in '{msg}'");
        };
        reject(&|c| c.topology = "ring".into(), "unknown topology");
        reject(&|c| c.topology = "gossip".into(), "unknown topology");
        reject(&|c| c.method = "adam".into(), "method");
        reject(&|c| c.dataset = "mnist".into(), "dataset");
        reject(&|c| c.nodes = 0, "nodes");
        reject(&|c| c.steps = 0, "steps");
        reject(&|c| c.dim = 0, "dim");
        reject(
            &|c| {
                c.topology = "ps-async".into();
                c.network = "56k".into();
            },
            "unknown network",
        );
        reject(&|c| c.local = LocalUpdate { batch: 0, sync_every: 1 }, "batch");
    }

    #[test]
    fn schedule_from_json_bails_on_nonpositive_params() {
        // The Schedule constructors assert; a hostile frame must error
        // descriptively instead of panicking the process.
        for bad in [
            r#"{"kind":"const","eta":0}"#,
            r#"{"kind":"inv_t","gamma":-1,"lambda":0.1,"shift":10}"#,
            r#"{"kind":"bottou","gamma0":1,"lambda":0}"#,
            r#"{"kind":"warp","eta":1}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(schedule_from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn io_backend_parses_and_defaults() {
        assert_eq!(IoBackend::parse("threads").unwrap(), IoBackend::Threads);
        if cfg!(unix) {
            assert_eq!(IoBackend::parse("poll").unwrap(), IoBackend::Poll);
            assert_eq!(IoBackend::platform_default(), IoBackend::Poll);
        } else {
            assert!(IoBackend::parse("poll").is_err());
            assert_eq!(IoBackend::platform_default(), IoBackend::Threads);
        }
        let err = IoBackend::parse("epoll").unwrap_err();
        assert!(format!("{err:#}").contains("poll | threads"), "{err:#}");
        assert_eq!(IoBackend::Poll.name(), "poll");
        assert_eq!(IoBackend::Threads.name(), "threads");
    }

    #[test]
    fn welcome_frame_stringifies_proto() {
        let text = welcome_json(&cfg(), 1);
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.req("proto").unwrap().as_str().unwrap(), PROTOCOL_VERSION.to_string());
        assert_eq!(j.req("node").unwrap().as_usize().unwrap(), 1);
        let back = RunConfig::from_json(j.req("config").unwrap()).unwrap();
        assert_eq!(back, cfg());
    }

    #[test]
    fn ring_bind_validates_topology_and_node_id() {
        let err = RingNodeProcess::bind("127.0.0.1:0", cfg(), 0).unwrap_err();
        assert!(format!("{err:#}").contains("all-reduce"), "{err:#}");
        let mut c = cfg();
        c.topology = "all-reduce".into();
        let err = RingNodeProcess::bind("127.0.0.1:0", c.clone(), 2).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        let p = RingNodeProcess::bind("127.0.0.1:0", c, 1).unwrap();
        assert_ne!(p.local_addr().unwrap().port(), 0);
    }

    #[test]
    fn hello_mirrors_the_config() {
        let c = cfg();
        let h = c.hello();
        assert_eq!(h.proto, PROTOCOL_VERSION);
        assert_eq!(h.dim, 2000);
        assert_eq!(h.method, "memsgd:top_k:1");
        assert_eq!(h.batch, 2);
        assert_eq!(h.sync_every, 3);
    }
}
