//! `poll(2)`-backed event-driven I/O for the cluster server — the
//! default server backend on unix (`memsgd serve --io poll`).
//!
//! PR 6's server spent one OS thread per accepted socket, parked in a
//! blocking `read`. That scales the *protocol* but not the process: at
//! N workers the server carries N sleeping threads, the accept loop
//! wakes every 25 ms to poll a nonblocking listener, and the serial
//! handshake lets one connected-but-silent client head-of-line-block
//! every worker behind it. This module replaces all of that with a
//! single-threaded event loop over nonblocking sockets:
//!
//! * **FFI shim, no new crates** — the loop sits on `poll(2)` through a
//!   three-line `extern "C"` declaration and a `#[repr(C)]` pollfd
//!   mirror (the vendored-dependency style of this repo: the libc
//!   surface we need is one syscall, so we bind it directly).
//!   `nfds_t` is `c_ulong` on Linux and `c_uint` elsewhere — the one
//!   platform wrinkle, handled by a cfg-gated alias.
//! * **Event-driven accept + handshake** ([`accept_and_handshake`]) —
//!   the listener and every in-flight handshake live in one poll set.
//!   Node ids are still assigned in accept order (the determinism
//!   contract), but a client that connects and then stalls only burns
//!   its own [`super::net::HANDSHAKE_TIMEOUT`]; workers behind it
//!   handshake concurrently.
//! * **Multiplexed data plane** ([`data_plane`] / [`PollChannel`]) —
//!   one [`super::net::FrameAssembler`] per connection turns whatever
//!   bytes `poll` reports into completed frames. There is **no
//!   event-loop thread**: the single protocol thread pumps the poller
//!   from inside [`Channel::recv`] / [`Channel::send`], so the mutex
//!   around [`Mux`] is uncontended and never held against another
//!   blocked thread (the thread-backend hazard this PR removes).
//! * **Per-frame deadlines** — each connection tracks when its
//!   in-flight frame started; a peer trickling bytes slower than
//!   [`super::net::FRAME_DEADLINE`] is declared dead even while the
//!   protocol loop is blocked on a *different* node. `recv` itself is
//!   bounded by [`super::net::READ_TIMEOUT`].
//! * **Write backpressure** — `send` enqueues the frame in the
//!   connection's outbox and pumps the loop until that outbox drains,
//!   failing after [`super::net::WRITE_TIMEOUT`] without progress. The
//!   outbox therefore never holds more than one frame: bounded memory,
//!   blocking-send semantics, and reads from every other node keep
//!   flowing while a slow peer drains.
//!
//! ## Fallback selection
//!
//! The portable reader-thread path from PR 6 remains available as
//! `--io threads` ([`super::cluster::IoBackend`]), and is the only
//! backend on non-unix targets (this module is compiled on unix only).
//! Both backends run the identical protocol halves against the same
//! framing codec, so the golden suites pin them to the same
//! bit-for-bit trajectories.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::raw::{c_int, c_short};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::cluster::ACCEPT_TIMEOUT;
use super::net::{
    check_compat, write_frame, FrameAssembler, Hello, FRAME_DEADLINE, HANDSHAKE_TIMEOUT,
    READ_TIMEOUT, WRITE_TIMEOUT,
};
use super::transport::{Channel, MAX_FRAME_BYTES};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// poll(2) FFI shim
// ---------------------------------------------------------------------------

/// `struct pollfd` (POSIX): identical layout on every unix libc.
#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: c_short,
    revents: c_short,
}

const POLLIN: c_short = 0x001;
const POLLOUT: c_short = 0x004;

/// `nfds_t`: `unsigned long` on Linux/glibc/musl, `unsigned int` on the
/// BSD family (including macOS).
#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
}

/// One `poll(2)` call with EINTR retry. Returns the number of fds with
/// nonzero `revents` (0 = timed out).
fn poll_once(fds: &mut [PollFd], timeout: Duration) -> Result<usize> {
    let ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
    loop {
        // SAFETY: `fds` is a live, exclusively borrowed slice of
        // `#[repr(C)]` pollfd-layout structs; the kernel writes only
        // `revents` within the `fds.len()` entries passed.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() == ErrorKind::Interrupted {
            continue;
        }
        return Err(err).context("poll(2)");
    }
}

/// Poll granularity while a channel operation waits on the loop: events
/// wake the poller immediately, so this bounds only how often deadline
/// sweeps run.
const POLL_TICK: Duration = Duration::from_millis(100);

// ---------------------------------------------------------------------------
// Accept + handshake
// ---------------------------------------------------------------------------

/// One accepted connection mid-handshake.
struct Pending {
    node: usize,
    stream: TcpStream,
    asm: FrameAssembler,
    /// The framed `WELCOME` bytes still to flush (empty while the
    /// `HELLO` is being read).
    outbox: VecDeque<u8>,
    deadline: Instant,
    /// The `HELLO` passed compatibility and the `WELCOME` was queued.
    welcomed: bool,
    done: bool,
}

/// Accept exactly `nodes` connections and handshake them concurrently:
/// listener and every in-flight handshake share one poll set, node ids
/// are assigned in accept order, and each connection gets
/// [`HANDSHAKE_TIMEOUT`] from its accept to a fully flushed `WELCOME`.
/// A compatibility rejection sends the worker an `{"error": reason}`
/// frame (best-effort, blocking with a timeout — the run is failing
/// anyway) and fails the run, exactly like the threads backend.
///
/// Returns the streams in node-id order, still nonblocking, each paired
/// with its [`FrameAssembler`] so bytes a worker pipelined behind its
/// `HELLO` are carried into the data plane instead of dropped.
pub(crate) fn accept_and_handshake(
    listener: &TcpListener,
    server_hello: &Hello,
    welcome_for: &dyn Fn(usize) -> String,
    nodes: usize,
) -> Result<Vec<(TcpStream, FrameAssembler)>> {
    listener
        .set_nonblocking(true)
        .context("setting the listener non-blocking")?;
    let overall = Instant::now() + ACCEPT_TIMEOUT;
    let mut pending: Vec<Pending> = Vec::with_capacity(nodes);
    let mut completed = 0usize;
    while completed < nodes {
        let now = Instant::now();
        if now >= overall {
            bail!(
                "only {} of {nodes} workers connected within {}s",
                pending.len(),
                ACCEPT_TIMEOUT.as_secs()
            );
        }
        for p in &pending {
            if !p.done && now >= p.deadline {
                bail!(
                    "connection {} did not complete its handshake within {}s",
                    p.node,
                    HANDSHAKE_TIMEOUT.as_secs()
                );
            }
        }

        let mut fds: Vec<PollFd> = Vec::with_capacity(pending.len() + 1);
        let mut which: Vec<usize> = Vec::with_capacity(pending.len() + 1);
        if pending.len() < nodes {
            fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
            which.push(usize::MAX);
        }
        for (i, p) in pending.iter().enumerate() {
            if p.done {
                continue;
            }
            let events = if p.welcomed { POLLOUT } else { POLLIN };
            fds.push(PollFd { fd: p.stream.as_raw_fd(), events, revents: 0 });
            which.push(i);
        }
        // Short timeout: events interrupt it; it only paces the
        // deadline checks above.
        if poll_once(&mut fds, Duration::from_millis(25))? == 0 {
            continue;
        }

        for (k, fd) in fds.iter().enumerate() {
            if fd.revents == 0 {
                continue;
            }
            if which[k] == usize::MAX {
                accept_ready(listener, &mut pending, nodes)?;
            } else {
                let p = &mut pending[which[k]];
                if !p.welcomed {
                    handshake_read(p, server_hello, welcome_for)?;
                }
                // Flush whatever the read just queued (the common case:
                // the whole WELCOME fits the send buffer immediately).
                if p.welcomed && !p.done {
                    handshake_flush(p)?;
                    if p.done {
                        completed += 1;
                    }
                }
            }
        }
    }
    pending.sort_by_key(|p| p.node);
    Ok(pending.into_iter().map(|p| (p.stream, p.asm)).collect())
}

/// Drain the listener's ready connections (up to `nodes` total).
fn accept_ready(listener: &TcpListener, pending: &mut Vec<Pending>, nodes: usize) -> Result<()> {
    while pending.len() < nodes {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(true).context("setting accepted socket non-blocking")?;
                stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                let node = pending.len();
                pending.push(Pending {
                    node,
                    stream,
                    asm: FrameAssembler::new(MAX_FRAME_BYTES),
                    outbox: VecDeque::new(),
                    deadline: Instant::now() + HANDSHAKE_TIMEOUT,
                    welcomed: false,
                    done: false,
                });
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) => return Err(e).context("accepting worker connection"),
        }
    }
    Ok(())
}

/// Pull readable bytes into the pending connection's assembler; when
/// the `HELLO` completes, check compatibility and queue the `WELCOME`
/// (or send the rejection and fail the run).
fn handshake_read(
    p: &mut Pending,
    server_hello: &Hello,
    welcome_for: &dyn Fn(usize) -> String,
) -> Result<()> {
    let mut buf = [0u8; 4096];
    loop {
        match p.stream.read(&mut buf) {
            Ok(0) => {
                bail!("reading HELLO from connection {}: {:#}", p.node, p.asm.eof_error())
            }
            Ok(n) => {
                p.asm
                    .feed(&buf[..n])
                    .with_context(|| format!("reading HELLO from connection {}", p.node))?;
                if let Some(frame) = p.asm.next_frame() {
                    let worker_hello = Hello::decode(&frame)?;
                    if let Err(e) = check_compat(&worker_hello, server_hello) {
                        // Failure path: a short blocking write is fine,
                        // the run is over either way.
                        let reject =
                            Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                        let _ = p.stream.set_nonblocking(false);
                        let _ = p.stream.set_write_timeout(Some(HANDSHAKE_TIMEOUT));
                        let _ = write_frame(&mut p.stream, reject.as_bytes());
                        let _ = p.stream.shutdown(Shutdown::Both);
                        return Err(
                            e.push_context(format!("connection {} failed the handshake", p.node))
                        );
                    }
                    let welcome = welcome_for(p.node).into_bytes();
                    p.outbox.extend(&(welcome.len() as u32).to_be_bytes());
                    p.outbox.extend(welcome.iter());
                    p.welcomed = true;
                    return Ok(());
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                return Err(e).context(format!("reading HELLO from connection {}", p.node))
            }
        }
    }
}

/// Flush as much of the queued `WELCOME` as the socket accepts; marks
/// the handshake done once the outbox drains.
fn handshake_flush(p: &mut Pending) -> Result<()> {
    while !p.outbox.is_empty() {
        let (head, _) = p.outbox.as_slices();
        match p.stream.write(head) {
            Ok(0) => bail!("connection {} closed while flushing WELCOME", p.node),
            Ok(n) => {
                p.outbox.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                return Err(e).context(format!("sending WELCOME to node {}", p.node))
            }
        }
    }
    p.done = true;
    Ok(())
}

// ---------------------------------------------------------------------------
// Data plane
// ---------------------------------------------------------------------------

/// One post-handshake connection in the event loop.
struct Conn {
    stream: TcpStream,
    asm: FrameAssembler,
    /// Framed bytes queued for this peer (at most one frame — `send`
    /// drains it before returning).
    outbox: VecDeque<u8>,
    /// When the in-flight inbound frame started, for [`FRAME_DEADLINE`].
    frame_started: Option<Instant>,
    /// First terminal error; the connection is out of the poll set.
    dead: Option<String>,
}

/// The poll backend's shared state: every accepted connection, pumped
/// by whichever [`PollChannel`] operation is currently blocked. Only
/// the single protocol thread ever locks it.
pub(crate) struct Mux {
    conns: Vec<Conn>,
}

impl Mux {
    fn new(streams: Vec<(TcpStream, FrameAssembler)>) -> Mux {
        let conns = streams
            .into_iter()
            .map(|(stream, asm)| {
                let frame_started = if asm.mid_frame() { Some(Instant::now()) } else { None };
                Conn { stream, asm, outbox: VecDeque::new(), frame_started, dead: None }
            })
            .collect();
        Mux { conns }
    }

    /// One event-loop cycle: poll every live connection (write interest
    /// only where an outbox is queued), service the ready ones, then
    /// sweep the per-frame deadlines. Returns whether any byte moved.
    fn pump(&mut self, wait: Duration) -> Result<bool> {
        let mut fds: Vec<PollFd> = Vec::with_capacity(self.conns.len());
        let mut which: Vec<usize> = Vec::with_capacity(self.conns.len());
        for (i, c) in self.conns.iter().enumerate() {
            if c.dead.is_some() {
                continue;
            }
            let mut events = POLLIN;
            if !c.outbox.is_empty() {
                events |= POLLOUT;
            }
            fds.push(PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
            which.push(i);
        }
        if fds.is_empty() {
            return Ok(false); // every connection dead; callers report it
        }
        let ready = poll_once(&mut fds, wait)?;
        let mut progressed = false;
        if ready > 0 {
            for (k, fd) in fds.iter().enumerate() {
                if fd.revents != 0 {
                    progressed |= self.service(which[k]);
                }
            }
        }
        let now = Instant::now();
        for c in &mut self.conns {
            let trickling = c.dead.is_none()
                && c.asm.mid_frame()
                && c.frame_started.is_some_and(|t0| now.duration_since(t0) >= FRAME_DEADLINE);
            if trickling {
                c.dead = Some(format!(
                    "frame incomplete after {FRAME_DEADLINE:?} — \
                     whole-frame deadline exceeded"
                ));
            }
        }
        Ok(progressed)
    }

    /// Service one ready connection: flush its outbox, then drain its
    /// readable bytes into the assembler. Errors land in `dead` — the
    /// protocol loop reports them on the next operation against the
    /// node, like the reader-thread backend.
    fn service(&mut self, i: usize) -> bool {
        let c = &mut self.conns[i];
        let mut progressed = false;
        while !c.outbox.is_empty() {
            let (head, _) = c.outbox.as_slices();
            match c.stream.write(head) {
                Ok(0) => {
                    c.dead = Some("connection closed while writing".into());
                    return progressed;
                }
                Ok(n) => {
                    c.outbox.drain(..n);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    c.dead = Some(e.to_string());
                    return progressed;
                }
            }
        }
        let mut buf = [0u8; 64 * 1024];
        loop {
            match c.stream.read(&mut buf) {
                Ok(0) => {
                    c.dead = Some(format!("{:#}", c.asm.eof_error()));
                    break;
                }
                Ok(n) => {
                    progressed = true;
                    let before = c.asm.frames_completed();
                    if let Err(e) = c.asm.feed(&buf[..n]) {
                        c.dead = Some(format!("{e:#}"));
                        break;
                    }
                    if c.asm.mid_frame() {
                        // A fresh partial frame (or continued one):
                        // restart the clock only at a frame boundary.
                        if c.asm.frames_completed() > before || c.frame_started.is_none() {
                            c.frame_started = Some(Instant::now());
                        }
                    } else {
                        c.frame_started = None;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    c.dead = Some(e.to_string());
                    break;
                }
            }
        }
        progressed
    }
}

/// Replace a dead connection with a freshly handshaken one (the
/// `WaitRejoin` path): the new nonblocking stream takes the node's slot
/// in the poll set — outbox cleared, assembler carried over from the
/// handshake — and a fresh [`PollChannel`] facade is returned. The old
/// socket (if any) is shut down first so its peer unblocks.
pub(crate) fn adopt(
    mux: &Arc<Mutex<Mux>>,
    node: usize,
    stream: TcpStream,
    asm: FrameAssembler,
) -> Result<Box<dyn Channel>> {
    let mut m = mux.lock().map_err(|_| anyhow!("cluster mux poisoned"))?;
    if node >= m.conns.len() {
        bail!("adopting node {node}, mux has {} slots", m.conns.len());
    }
    let _ = m.conns[node].stream.shutdown(Shutdown::Both);
    let frame_started = if asm.mid_frame() { Some(Instant::now()) } else { None };
    m.conns[node] = Conn { stream, asm, outbox: VecDeque::new(), frame_started, dead: None };
    Ok(Box::new(PollChannel { node, mux: Arc::clone(mux) }))
}

/// Wrap handshaken streams into per-node [`Channel`]s over one shared
/// [`Mux`]; the second return is the teardown handle for
/// [`drain_and_shutdown`].
pub(crate) fn data_plane(
    streams: Vec<(TcpStream, FrameAssembler)>,
) -> (Vec<Box<dyn Channel>>, Arc<Mutex<Mux>>) {
    let nodes = streams.len();
    let mux = Arc::new(Mutex::new(Mux::new(streams)));
    let channels = (0..nodes)
        .map(|node| Box::new(PollChannel { node, mux: Arc::clone(&mux) }) as Box<dyn Channel>)
        .collect();
    (channels, mux)
}

/// Flush every remaining outbox (bounded — error paths may leave the
/// final frames queued), then shut every socket down so blocked peers
/// error out instead of hanging.
pub(crate) fn drain_and_shutdown(mux: &Arc<Mutex<Mux>>) {
    if let Ok(mut m) = mux.lock() {
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            let queued = m.conns.iter().any(|c| c.dead.is_none() && !c.outbox.is_empty());
            if !queued || m.pump(POLL_TICK).is_err() {
                break;
            }
        }
        for c in &m.conns {
            let _ = c.stream.shutdown(Shutdown::Both);
        }
    }
}

/// The poll backend's per-node [`Channel`] facade. `recv` and `send`
/// pump the shared event loop while they wait, so *every* node's
/// traffic progresses regardless of which node the protocol is blocked
/// on — the property the reader threads provided, without the threads.
pub(crate) struct PollChannel {
    node: usize,
    mux: Arc<Mutex<Mux>>,
}

impl Channel for PollChannel {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let mut mux = self.mux.lock().map_err(|_| anyhow!("cluster mux poisoned"))?;
        {
            let c = &mut mux.conns[self.node];
            if let Some(e) = &c.dead {
                bail!("sending to node {}: connection lost: {e}", self.node);
            }
            if frame.len() > u32::MAX as usize {
                bail!("frame of {} bytes exceeds the u32 length prefix", frame.len());
            }
            c.outbox.extend(&(frame.len() as u32).to_be_bytes());
            c.outbox.extend(frame.iter());
        }
        // Blocking-send semantics with backpressure: pump until this
        // node's outbox drains, failing after WRITE_TIMEOUT without a
        // byte of progress toward this peer.
        let mut last_progress = Instant::now();
        loop {
            let queued = mux.conns[self.node].outbox.len();
            if queued == 0 {
                return Ok(());
            }
            if let Some(e) = &mux.conns[self.node].dead {
                bail!("sending to node {}: connection lost: {e}", self.node);
            }
            if last_progress.elapsed() >= WRITE_TIMEOUT {
                bail!(
                    "sending to node {}: write stalled for {WRITE_TIMEOUT:?} — \
                     peer not draining",
                    self.node
                );
            }
            mux.pump(POLL_TICK)?;
            if mux.conns[self.node].outbox.len() < queued {
                last_progress = Instant::now();
            }
        }
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut mux = self.mux.lock().map_err(|_| anyhow!("cluster mux poisoned"))?;
        let deadline = Instant::now() + READ_TIMEOUT;
        loop {
            if let Some(frame) = mux.conns[self.node].asm.next_frame() {
                return Ok(frame);
            }
            if let Some(e) = &mux.conns[self.node].dead {
                bail!("node {}: connection lost: {e}", self.node);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("node {}: no frame within {READ_TIMEOUT:?}", self.node);
            }
            let wait = deadline.duration_since(now).min(POLL_TICK);
            mux.pump(wait)?;
        }
    }

    /// Shut the node's socket down and take it out of the poll set —
    /// the failure-policy hangup. The peer's blocked read errors out
    /// immediately instead of waiting for a deadline.
    fn hangup(&mut self) {
        if let Ok(mut m) = self.mux.lock() {
            let c = &mut m.conns[self.node];
            let _ = c.stream.shutdown(Shutdown::Both);
            if c.dead.is_none() {
                c.dead = Some("hung up by the failure policy".into());
            }
        }
    }
}
