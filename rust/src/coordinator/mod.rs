//! Layer-3 coordinator: the paper's system contribution, behind **one
//! unified experiment API**.
//!
//! ## The builder (start here)
//!
//! [`experiment::Experiment`] is the single entry point for training:
//! pick a gradient backend, a typed [`config::MethodSpec`], a stepsize
//! [`crate::optim::Schedule`], and an [`experiment::Topology`] — the
//! same per-worker error-feedback step
//! ([`crate::optim::ErrorFeedbackStep`]) then runs on whichever
//! coordination fabric was chosen, returning one unified
//! [`crate::metrics::RunRecord`]:
//!
//! ```text
//! Experiment::new(LogisticModel::new(&data, lam))
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.05))
//!     .topology(Topology::SharedMemory { workers: 8 })
//!     .steps(100_000)
//!     .seed(1)
//!     .run()?
//! ```
//!
//! | topology | paper setting |
//! |---|---|
//! | `Sequential` | Algorithm 1 + §4.2/4.3 baselines, loss curve + Theorem-2.4 averaging |
//! | `SharedMemory { workers }` | Algorithm 2: lock-free threads, unsynchronized reads/writes (§4.4) |
//! | `ParamServerSync { nodes }` | synchronous data-parallel rounds, per-node memories, both directions accounted (§1/§5) |
//! | `ParamServerAsync { nodes, net }` | stale gradients + serialized server ingress under a network cost model (§1.1) |
//! | `AllReduce { nodes }` | server-free ring reduce+gather of the same compressed syncs — the error-feedback analysis never names a server |
//! | `Gossip { nodes, graph }` | decentralized pairwise averaging on a seeded random matching (complete or ring neighbor graph) |
//!
//! ## Invariants (what the golden suites pin)
//!
//! * **Floating-point fold order is fixed and explicit.** Every
//!   aggregation folds contributions in node-id order: the PS server
//!   folds uploads `0, 1, …, W−1` regardless of arrival order, the
//!   ring folds around the ring starting at the driver, and a gossip
//!   pair folds lower-id-first. Simulated and threaded/multi-process
//!   engines share the single fold implementation
//!   ([`experiment::RingPartial`] for the server-free engines), so
//!   trajectories agree **bit for bit** — `tests/wire_protocol.rs`,
//!   `tests/cluster_lifecycle.rs`, and `tests/allreduce_gossip.rs`
//!   diff them float-for-float.
//! * **Deadline semantics.** Blocking reads on the wire carry absolute
//!   deadlines ([`net::read_frame_deadline`]): a peer death surfaces as
//!   a descriptive error naming the node, never a hang
//!   (`tests/failure_injection.rs`), and every engine thread is joined
//!   on both the success and the error path.
//! * **Failure semantics are a policy, and degraded runs replay.**
//!   What happens *after* the deadline trips is chosen by
//!   [`faults::FailurePolicy`]: fail fast (default), drop the dead
//!   node and aggregate the surviving quorum, or wait for a rejoin.
//!   Fault schedules are seeded ([`faults::FaultPlan`]), so a degraded
//!   run is as replayable as a healthy one — under a fixed plan the
//!   simulated and wire engines still agree bit for bit
//!   (`tests/chaos.rs`).
//! * **Tie-breaking is deterministic.** Compressor selection ties break
//!   toward the lowest coordinate index (the `util::select` contract),
//!   which is what lets the dense and active-set scans — and therefore
//!   every topology — pick identical support sets.
//! * **Accounted bits reconcile with transmitted bits.** Each wire
//!   engine records both the paper-accounted cost and the measured
//!   frame bytes on the channel; the suites assert the two reconcile
//!   exactly per direction (uploads/broadcasts, reduce/gather hops,
//!   gossip exchanges).
//!
//! ## Migration from the deprecated per-driver entry points
//!
//! The pre-builder drivers each re-implemented the error-feedback step
//! and took incompatible stringly configs. They remain as thin shims —
//! every existing spec string still works — but new code should use the
//! builder:
//!
//! | old call | new builder chain |
//! |---|---|
//! | `train::run(&data, &TrainConfig { method: "memsgd:top_k:1".into(), .. })` | `Experiment::new(LogisticModel::new(&data, lam)).method(MethodSpec::mem_top_k(1)).topology(Topology::Sequential).run()?` |
//! | `train::run_with_backend(&mut b, name, &cfg)` | `Experiment::new(b).dataset(name).parse_method(&cfg.method)?.run_sequential()?` |
//! | `parallel::run(&data, &ParallelConfig { workers: 8, compressor: "top_k:1".into(), .. })` | `.method(MethodSpec::mem_top_k(1)).topology(Topology::SharedMemory { workers: 8 }).run()?` |
//! | `distributed::run(&data, &DistributedConfig { workers: 8, .. })` | `.topology(Topology::ParamServerSync { nodes: 8 }).run()?` |
//! | `async_dist::run(&data, &AsyncConfig { workers: 8, network, .. })` | `.topology(Topology::ParamServerAsync { nodes: 8, net: network }).compute(cm).hetero(0.5).run()?` |
//!
//! `steps` on the builder is always the **total** stochastic-gradient
//! budget (the engines derive per-worker steps / server rounds from it);
//! spec strings are parsed exactly once, at the CLI/JSON edge
//! ([`config::MethodSpec::parse`]), and rejected loudly on trailing
//! junk. The orthogonal [`config::LocalUpdate`] schedule (minibatch
//! size `B`, sync interval `H`) applies to every topology through
//! `Experiment::local_update`: `H` error-compensated local steps
//! between communications cut the transmitted bits by another factor
//! of `H`, and `B = 1, H = 1` reproduces the classic per-sample
//! engines bit for bit.
//!
//! ## Modules
//!
//! * [`experiment`] — the typed builder, the [`experiment::Topology`]
//!   enum, and the six generic engines (all `GradBackend`-generic; no
//!   engine names a concrete model) — plus the threaded **wire**
//!   engines behind `Experiment::wire`, which run the two
//!   parameter-server topologies and the two server-free topologies
//!   (ring all-reduce, gossip) as real threads exchanging Elias-coded
//!   updates, bit-identical to the simulation
//!   (`tests/wire_protocol.rs`, `tests/allreduce_gossip.rs`).
//! * [`transport`] — the message-passing fabric of the wire engines:
//!   the socket-shaped [`transport::Transport`]/[`transport::Channel`]
//!   abstraction, the in-process loopback, the byte-counting wrapper,
//!   and the typed wire-message codec (frame format documented there).
//! * [`faults`] — deterministic fault injection and failure policies:
//!   seeded per-node fault schedules ([`faults::FaultPlan`]) behind
//!   `--fault-plan`, [`faults::FaultyChannel`] /
//!   [`faults::FaultyTransport`] decorators over the transport traits,
//!   and the [`faults::FailurePolicy`] knob
//!   (fail-fast / drop-round / wait-rejoin) every engine honors.
//! * [`net`] — the TCP backend of the same abstraction:
//!   length-delimited frames on real sockets ([`net::TcpChannel`] /
//!   [`net::TcpTransport`]), the version/config handshake
//!   ([`net::Hello`]), and bounded-backoff connect
//!   ([`net::connect_with_retry`]).
//! * [`cluster`] — the multi-process runtime behind `memsgd serve` /
//!   `memsgd worker` / `memsgd ring`: a JSON-carried
//!   [`cluster::RunConfig`], the accept/handshake loop with
//!   deterministic node-id assignment, two server I/O backends
//!   ([`cluster::IoBackend`]: a `poll(2)` event loop in `mux`, or
//!   portable reader threads), and the server-free
//!   [`cluster::RingNodeProcess`] (one OS process per ring node, no
//!   server at all), reproducing the simulated engines bit for bit
//!   across OS processes.
//! * [`config`] — typed [`config::MethodSpec`] (`memsgd:<comp>`, `sgd`,
//!   `sgd:qsgd:<levels>`, `sgd:unbiased_rand_k:<k>`) and the legacy
//!   [`config::Optimizer`] stepping interface.
//! * [`train`] — deprecated sequential shim + checkpointed
//!   [`train::run_resumable`] (bit-identical resume).
//! * [`parallel`] — lock-free [`parallel::SharedParams`] + deprecated
//!   shim for Algorithm 2.
//! * [`distributed`] / [`async_dist`] — deprecated parameter-server
//!   shims (sync / async).
//! * [`checkpoint`] — binary save/restore of full sequential training
//!   state (iterate, error memory, averaging, RNG position).

pub mod async_dist;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod distributed;
pub mod experiment;
pub mod faults;
#[cfg(unix)]
pub(crate) mod mux;
pub mod net;
pub mod parallel;
pub mod train;
pub mod transport;

pub use config::{LocalUpdate, MethodSpec};
pub use experiment::{Experiment, GossipGraph, Topology};
pub use faults::{FailurePolicy, FaultPlan, FaultSpec};
