//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`config`] — method specs (`memsgd:top_k:1`, `sgd:qsgd:16`, ...) and
//!   experiment configuration.
//! * [`train`] — the sequential Mem-SGD / SGD driver (Algorithm 1 plus
//!   all Section 4.2–4.3 baselines): loss-evaluation schedule,
//!   communication accounting, weighted-average evaluation.
//! * [`parallel`] — PARALLEL-MEM-SGD (Algorithm 2): lock-free
//!   shared-memory workers over `std::thread`, unsynchronized reads and
//!   non-read-modify-write stores exactly as in the paper's Section 4.4
//!   implementation.

//! * [`distributed`] — synchronous data-parallel Mem-SGD over a
//!   parameter-server topology (the paper's §1/§5 motivating setting):
//!   per-node error memories, compressed uploads, aggregated sparse
//!   broadcast, both directions accounted.

//! * [`async_dist`] — asynchronous parameter-server Mem-SGD under a
//!   network cost model: stale gradients, heterogeneous workers,
//!   serialized server ingress (the §1.1 "sparsification + asynchrony"
//!   combination, simulated in deterministic event time).
//! * [`checkpoint`] — binary save/restore of full training state
//!   (iterate, error memory, averaging, RNG position).

pub mod async_dist;
pub mod checkpoint;
pub mod config;
pub mod distributed;
pub mod parallel;
pub mod train;
