//! The unified experiment API: **one typed builder + one generic engine
//! behind all six training topologies**.
//!
//! The paper's claim is that Mem-SGD keeps vanilla-SGD rates whether it
//! runs sequentially (Algorithm 1), over lock-free shared memory
//! (Algorithm 2), or against a parameter server (§1/§5) — and the
//! error-feedback analysis never mentions a server, so the server-free
//! fabrics (ring all-reduce, gossip) are covered by the same theory.
//! This module makes that claim an API fact: every topology executes
//! the *same* per-worker [`ErrorFeedbackStep`] against the *same*
//! [`GradBackend`] abstraction — only the coordination fabric differs.
//!
//! ```no_run
//! use memsgd::coordinator::experiment::{Experiment, Topology};
//! use memsgd::coordinator::config::{LocalUpdate, MethodSpec};
//! use memsgd::models::LogisticModel;
//! use memsgd::optim::Schedule;
//! # fn main() -> anyhow::Result<()> {
//! # let data = memsgd::data::synthetic::epsilon_like(1000, 64, 1);
//! let record = Experiment::new(LogisticModel::new(&data, 1e-3))
//!     .dataset(&data.name)
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.1))
//!     .topology(Topology::ParamServerSync { nodes: 8 })
//!     .local_update(LocalUpdate::new(8, 4)?) // B = 8 samples, sync every H = 4
//!     .steps(10_000)
//!     .eval_points(20)
//!     .seed(1)
//!     .run()?;
//! println!("{} -> {}", record.method, record.final_loss());
//! # Ok(())
//! # }
//! ```
//!
//! ## Local-update scheduling (`B`, `H`)
//!
//! Every engine runs the same generalized **local-update schedule**
//! [`LocalUpdate`]: a worker draws `B`-sample minibatch gradients
//! ([`GradBackend::sample_grad_batch`]) and takes `H` raw local steps on
//! a worker-local iterate before compressing the accumulated update
//! (against its worker-local error memory) and communicating — the
//! Qsparse-local-SGD axis on top of the paper's sparsification. `steps`
//! stays the total **local-step** budget, and each engine divides it
//! exactly as it always divided gradients: `Sequential` and
//! `ParamServerAsync` take `steps / H` syncs / server updates,
//! `SharedMemory` takes `(steps / workers) / H` syncs per worker, and
//! `ParamServerSync` takes `steps / (nodes·H)` rounds (remainders
//! dropped; the multi-worker engines keep their historical floor of
//! one sync per worker) — so communicated bits drop by ≈`H` at a
//! fixed budget. Stepsize indexing:
//! the sequential and shared-memory engines index `η` by the worker's
//! local step count, the parameter-server engines hold `η` constant
//! within a sync (indexed by round / server update) — each matches its
//! pre-local-update behavior exactly at `H = 1`. With the default
//! `B = 1, H = 1` the four original engines reproduce the classic
//! per-sample trajectories **bit for bit**
//! (`tests/local_update_equivalence.rs`); the server-free engines below
//! follow the `ParamServerSync` division (`steps / (nodes·H)` rounds,
//! η constant within a round).
//!
//! ## Sparse gradient pipeline
//!
//! All engines share one worker phase (`WorkerScratch::phase`),
//! which runs sparsity-aware whenever the backend advertises
//! [`GradBackend::supports_sparse_grad`] (CSR models without L2 — the
//! RCV1 regime where each gradient is a scaled sparse row): local steps
//! cost `O(nnz)` instead of `O(d)`, with the dense error-feedback pass
//! and compressor scan paid only at the per-`H`-steps sync, and the
//! resulting trajectories are **bit-identical** to the dense path
//! (`tests/sparse_pipeline.rs`).
//!
//! Worker randomness is derived uniformly across topologies: one root
//! generator `Prng::new(seed)` hands out child streams in worker order
//! (`root.split(1)` for worker 0, then `root.split(2)` for worker 1,
//! ... — the root's state advances with each split, so the sequence of
//! split calls is part of the contract), and the sequential engine is
//! "worker 0 of 1". Consequently a 1-worker `SharedMemory` or
//! `ParamServerSync` run reproduces the `Sequential` trajectory **bit
//! for bit** for deterministic compressors — the cross-topology
//! consistency test in `tests/experiment_api.rs` pins this down.
//!
//! ## Wire mode (real threads, real bytes)
//!
//! [`Experiment::wire`] moves the four message-passing topologies
//! (parameter-server sync/async, all-reduce, gossip) from
//! the single-threaded simulation onto a real message-passing runtime
//! ([`super::transport`]): one server thread plus `nodes` worker
//! threads, every update **serialized through the Elias payload codec**
//! ([`crate::compress::elias::decode_payload`]) and shipped over a
//! [`super::transport::Transport`] channel. `ParamServerSync` runs
//! barriered rounds with node-id-ordered aggregation; the server
//! receives each node's upload in id order, so the floating-point fold
//! — and with it the whole trajectory — is **bit-identical** to the
//! simulated engine. `ParamServerAsync` keeps the simulated engine's
//! seeded discrete-event heap on the server as the delivery-order
//! arbiter: workers compute on live threads, but the heap decides whose
//! upload the server takes next, so simulated-time results stay
//! reproducible (and, again, bit-identical — `tests/wire_protocol.rs`
//! pins both engines on every MethodSpec × LocalUpdate combination).
//! The run record keeps the paper's closed-form bit accounting (so
//! curves stay comparable across modes) and reports the measured bytes
//! that actually crossed the channel in the `wire_*` extras.
//!
//! ## Server-free topologies (ring all-reduce, gossip)
//!
//! [`Topology::AllReduce`] replaces the parameter server with a ring
//! fold: each round every node's compressed sync folds into a
//! circulating partial in node-id order (`REDUCE`, `n − 1` hops — the
//! fixed floating-point fold order is the **invariant** that keeps
//! simulated and threaded trajectories bit-identical), the completed
//! aggregate circulates back (`GATHER`, `n − 1` hops), and every node
//! applies the mean. Losses equal `ParamServerSync`'s exactly; only the
//! bit accounting differs (closed-form per-hop ring costs instead of
//! upload + broadcast):
//!
//! ```
//! use memsgd::coordinator::experiment::{Experiment, Topology};
//! use memsgd::coordinator::config::MethodSpec;
//! use memsgd::models::LogisticModel;
//! use memsgd::optim::Schedule;
//! # fn main() -> anyhow::Result<()> {
//! let data = memsgd::data::synthetic::epsilon_like(240, 12, 5);
//! let record = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
//!     .dataset(&data.name)
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.4))
//!     .topology(Topology::AllReduce { nodes: 3 })
//!     .steps(120)
//!     .seed(7)
//!     .run()?;
//! assert!(record.method.starts_with("allreduce_"));
//! # Ok(())
//! # }
//! ```
//!
//! [`Topology::Gossip`] drops global synchronization entirely: nodes
//! keep private iterates, and each round a matching drawn on a
//! configurable neighbor graph ([`GossipGraph`]) from the topology's
//! own PRNG stream pairs nodes; matched pairs exchange compressed syncs
//! and apply the pair mean. The matching stream is
//! `root.split(nodes + 1)`, drawn **after** the worker streams, and
//! every graph consumes a fixed number of draws per round — so runs
//! replay bit-for-bit and wire nodes derive the schedule with zero
//! coordination traffic:
//!
//! ```
//! use memsgd::coordinator::experiment::{Experiment, GossipGraph, Topology};
//! use memsgd::coordinator::config::MethodSpec;
//! use memsgd::models::LogisticModel;
//! use memsgd::optim::Schedule;
//! # fn main() -> anyhow::Result<()> {
//! let data = memsgd::data::synthetic::epsilon_like(240, 12, 5);
//! let record = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
//!     .dataset(&data.name)
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.4))
//!     .topology(Topology::Gossip { nodes: 4, graph: GossipGraph::Ring })
//!     .steps(160)
//!     .seed(7)
//!     .run()?;
//! assert!(record.method.contains("ring"));
//! # Ok(())
//! # }
//! ```
//!
//! Both engines accept [`Experiment::wire`] / the builder's transport
//! hooks exactly like the parameter-server topologies — real threads,
//! every hop serialized through the payload codec, trajectories still
//! bit-identical to the simulation (`tests/allreduce_gossip.rs`):
//!
//! ```
//! use memsgd::coordinator::experiment::{Experiment, Topology};
//! use memsgd::coordinator::config::MethodSpec;
//! use memsgd::models::LogisticModel;
//! use memsgd::optim::Schedule;
//! # fn main() -> anyhow::Result<()> {
//! let data = memsgd::data::synthetic::epsilon_like(240, 12, 5);
//! let wired = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
//!     .dataset(&data.name)
//!     .method(MethodSpec::mem_top_k(1))
//!     .schedule(Schedule::constant(0.4))
//!     .topology(Topology::AllReduce { nodes: 3 })
//!     .steps(120)
//!     .seed(7)
//!     .wire(true) // threaded ring over the loopback transport
//!     .run()?;
//! assert_eq!(wired.extra.get("wire"), Some(&1.0));
//! # Ok(())
//! # }
//! ```
//!
//! The deprecated per-driver entry points
//! ([`super::train::run`], [`super::parallel::run`],
//! [`super::distributed::run`], [`super::async_dist::run`]) are thin
//! shims over this module; new code should use the builder.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::config::{LocalUpdate, MethodSpec};
use super::faults::{DeadChannel, FailurePolicy, FaultSpec, PEER_HUNG_UP};
use super::parallel::SharedParams;
use super::transport::{
    decode_msg, encode_apply, encode_broadcast, encode_exchange, encode_gather, encode_go,
    encode_reduce, encode_report, encode_shutdown, encode_snapshot, encode_upload, Channel,
    Loopback, Transport, WireMsg,
};
use crate::compress::elias::BitWriter;
use crate::compress::{ActiveIndex, ActiveView, SparseMerge, SparseVec, Update};
use crate::metrics::{LossPoint, RunRecord};
use crate::models::GradBackend;
use crate::optim::{ErrorFeedbackStep, Schedule, WeightedAverage};
use crate::sim::network::{ComputeModel, NetworkModel};
use crate::util::prng::Prng;

/// How workers coordinate: the four training fabrics of the paper plus
/// the two server-free extensions.
#[derive(Clone, Debug)]
pub enum Topology {
    /// Algorithm 1: one worker, exact reads, loss curve + optional
    /// Theorem-2.4 weighted averaging.
    Sequential,
    /// Algorithm 2: `workers` lock-free threads over one shared
    /// parameter vector (final-iterate evaluation, §4.4 protocol).
    SharedMemory { workers: usize },
    /// Synchronous parameter-server rounds over `nodes` workers with
    /// per-node error memories and aggregated sparse broadcast.
    ParamServerSync { nodes: usize },
    /// Asynchronous parameter server under a network cost model:
    /// stale gradients, serialized server ingress, simulated time.
    ParamServerAsync { nodes: usize, net: NetworkModel },
    /// Server-free synchronous ring all-reduce over `nodes` workers:
    /// each round the compressed syncs fold around the ring in node-id
    /// order (`REDUCE`), the completed aggregate circulates back
    /// (`GATHER`), and every node applies the mean — the
    /// `ParamServerSync` trajectory without a server.
    AllReduce { nodes: usize },
    /// Server-free gossip over `nodes` workers with private iterates:
    /// each round a matching drawn on `graph` from the topology's own
    /// seeded PRNG stream pairs nodes, matched pairs exchange their
    /// compressed syncs and apply the pair mean, and the loss curve
    /// evaluates the node-mean iterate.
    Gossip { nodes: usize, graph: GossipGraph },
}

/// The neighbor graph a [`Topology::Gossip`] round matching is drawn
/// on. Every graph consumes a fixed number of PRNG draws per round, so
/// wire nodes replay the schedule independently from a clone of the
/// topology stream (see `gossip_matching`'s invariants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GossipGraph {
    /// Any pair may be matched: a uniform random matching from a
    /// Fisher–Yates permutation paired off consecutively (odd node
    /// counts leave one node unmatched per round).
    Complete,
    /// Only ring-adjacent pairs: one parity draw per round selects the
    /// even or odd edge set of the ring.
    Ring,
}

impl GossipGraph {
    /// Stable name used in record method strings and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            GossipGraph::Complete => "complete",
            GossipGraph::Ring => "ring",
        }
    }
}

impl Topology {
    /// Number of concurrent workers this topology runs.
    pub fn workers(&self) -> usize {
        match self {
            Topology::Sequential => 1,
            Topology::SharedMemory { workers } => (*workers).max(1),
            Topology::ParamServerSync { nodes } => (*nodes).max(1),
            Topology::ParamServerAsync { nodes, .. } => (*nodes).max(1),
            Topology::AllReduce { nodes } => (*nodes).max(1),
            Topology::Gossip { nodes, .. } => (*nodes).max(1),
        }
    }
}

/// Resolved run settings shared by every engine.
pub(crate) struct Settings {
    pub method: MethodSpec,
    pub schedule: Schedule,
    pub steps: usize,
    pub eval_points: usize,
    pub average: bool,
    pub seed: u64,
    pub dataset: String,
    pub local: LocalUpdate,
    pub policy: FailurePolicy,
    pub faults: Option<FaultSpec>,
}

/// Builder for one training run: backend × method × schedule × topology.
///
/// `steps` is always the **total stochastic-gradient budget**. The
/// multi-worker engines split it evenly by integer division —
/// `SharedMemory` runs `max(1, steps / workers)` steps per worker,
/// `ParamServerSync` runs `max(1, steps / nodes)` rounds of `nodes`
/// gradients — so when `steps` is not a multiple of the worker count
/// the *executed* total differs from the request (remainder dropped,
/// or rounded up to one step/round per worker). The executed count is
/// what [`RunRecord::steps`] reports; pass a multiple of the worker
/// count for exact budgets.
///
/// Under a non-default [`LocalUpdate`] schedule, `steps` counts **local
/// steps** (each a `B`-sample minibatch gradient): every engine takes
/// one communication per `H` local steps on top of its usual split —
/// `steps / H` syncs for `Sequential`/`ParamServerAsync`,
/// `(steps / workers) / H` per `SharedMemory` worker,
/// `steps / (nodes·H)` rounds for `ParamServerSync`. Pass a multiple of
/// `workers·H` for exact budgets; the consumed sample count `steps·B`
/// is reported in the record's `grad_samples` extra.
pub struct Experiment<B: GradBackend> {
    backend: B,
    method: MethodSpec,
    schedule: Schedule,
    topology: Topology,
    steps: usize,
    eval_points: usize,
    average: bool,
    seed: u64,
    dataset: String,
    compute: ComputeModel,
    hetero: f64,
    local: LocalUpdate,
    wire: bool,
    transport: Option<Box<dyn Transport>>,
    policy: FailurePolicy,
    faults: Option<FaultSpec>,
}

impl<B: GradBackend> Experiment<B> {
    /// Start from a gradient backend with the defaults of the sequential
    /// figure drivers: Mem-SGD top-1, constant η = 0.05, 10 000 steps.
    pub fn new(backend: B) -> Self {
        Experiment {
            backend,
            method: MethodSpec::mem_top_k(1),
            schedule: Schedule::constant(0.05),
            topology: Topology::Sequential,
            steps: 10_000,
            eval_points: 20,
            average: true,
            seed: 1,
            dataset: "unnamed".into(),
            compute: ComputeModel::new(1e-9, 2000.0),
            hetero: 0.5,
            local: LocalUpdate::default(),
            wire: false,
            transport: None,
            policy: FailurePolicy::FailFast,
            faults: None,
        }
    }

    /// The (typed) optimizer + compressor combination to run.
    pub fn method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    /// Parse a `memsgd:top_k:1`-style spec — the CLI/JSON edge.
    pub fn parse_method(mut self, spec: &str) -> Result<Self> {
        self.method = MethodSpec::parse(spec)?;
        Ok(self)
    }

    /// Stepsize schedule (indexed by worker-local step / server round).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Coordination fabric.
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Total stochastic-gradient budget across all workers (split by
    /// integer division for multi-worker topologies — see the type-level
    /// docs; `RunRecord::steps` reports the executed count).
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Number of loss evaluations along the run (plus the start point).
    pub fn eval_points(mut self, eval_points: usize) -> Self {
        self.eval_points = eval_points;
        self
    }

    /// Evaluate the Theorem-2.4 weighted average instead of the last
    /// iterate (`Sequential` only; the multi-worker topologies follow
    /// the paper's final-iterate protocol).
    pub fn average(mut self, average: bool) -> Self {
        self.average = average;
        self
    }

    /// Base PRNG seed; one root `Prng::new(seed)` hands each worker an
    /// independent child stream in worker order (see the module docs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Dataset provenance recorded in the run record.
    pub fn dataset(mut self, name: &str) -> Self {
        self.dataset = name.to_string();
        self
    }

    /// Local-update schedule: minibatch size `B` and sync interval `H`
    /// (default `B = 1, H = 1`, the paper's per-sample schedule).
    /// Construct through [`LocalUpdate::new`], the strict parse edge
    /// that rejects zero/overflowing values.
    pub fn local_update(mut self, local: LocalUpdate) -> Self {
        self.local = local;
        self
    }

    /// Per-gradient compute cost (`ParamServerAsync` only).
    pub fn compute(mut self, compute: ComputeModel) -> Self {
        self.compute = compute;
        self
    }

    /// Worker speed spread (`ParamServerAsync` only): worker `w` computes
    /// at `1 + hetero·w/(W−1)` × the base time.
    pub fn hetero(mut self, hetero: f64) -> Self {
        self.hetero = hetero;
        self
    }

    /// Run the parameter-server topologies on the threaded
    /// message-passing runtime instead of the single-threaded
    /// simulation: one server thread, `nodes` worker threads, every
    /// update serialized through the Elias payload codec and carried by
    /// an in-process loopback [`super::transport::Transport`].
    /// Trajectories are bit-identical to the simulated engines (see the
    /// module docs); requires [`Experiment::run`] (the backend is
    /// replicated across worker threads) and a `ParamServerSync` /
    /// `ParamServerAsync` topology.
    pub fn wire(mut self, wire: bool) -> Self {
        self.wire = wire;
        self
    }

    /// [`Experiment::wire`] over a custom transport fabric (e.g. a
    /// byte-counting wrapper — [`super::transport::CountingTransport`]).
    pub fn wire_transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.wire = true;
        self.transport = Some(transport);
        self
    }

    /// What happens when a node dies mid-run (default: fail fast, the
    /// historical behavior). `DropRound` applies to the parameter-server
    /// topologies — the server aggregates the surviving quorum and the
    /// survivors' error memories carry the suppressed mass; `WaitRejoin`
    /// needs a listener to re-accept on and is therefore only honored by
    /// the multi-process cluster runtime (`memsgd serve`).
    pub fn failure_policy(mut self, policy: FailurePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Inject a seeded [`FaultSpec`] into the run: the spec expands into
    /// a per-node [`super::faults::FaultPlan`] once the engine knows the
    /// round count, and the same spec + seed replays the same deaths in
    /// the simulated and wire engines alike.
    pub fn fault_plan(mut self, spec: FaultSpec) -> Self {
        self.faults = Some(spec);
        self
    }

    /// Parse a `kill:1:42`-style `--fault-plan` spec (the CLI edge);
    /// `"none"` clears any previously set plan.
    pub fn parse_fault_plan(mut self, spec: &str) -> Result<Self> {
        self.faults = FaultSpec::parse(spec)?;
        Ok(self)
    }

    fn settings(&self) -> Settings {
        Settings {
            method: self.method.clone(),
            schedule: self.schedule.clone(),
            steps: self.steps,
            eval_points: self.eval_points,
            average: self.average,
            seed: self.seed,
            dataset: self.dataset.clone(),
            local: self.local,
            policy: self.policy,
            faults: self.faults.clone(),
        }
    }

    /// The failure-policy × topology support matrix (the same matrix
    /// `docs/ARCHITECTURE.md` documents): reject combinations loudly at
    /// the builder edge instead of silently ignoring the knob.
    fn validate_failure_config(&self) -> Result<()> {
        let ps = matches!(
            self.topology,
            Topology::ParamServerSync { .. } | Topology::ParamServerAsync { .. }
        );
        match self.policy {
            FailurePolicy::FailFast => {}
            FailurePolicy::WaitRejoin { .. } => bail!(
                "wait-rejoin requires the multi-process cluster runtime \
                 (memsgd serve) — in-process runs have no listener for the \
                 dead node to reconnect to"
            ),
            FailurePolicy::DropRound { .. } if ps => {}
            FailurePolicy::DropRound { .. } => bail!(
                "drop-round applies to the parameter-server topologies; \
                 {:?} has no server to drop a node from (every ring hop \
                 and gossip exchange is load-bearing)",
                self.topology
            ),
        }
        if self.faults.is_some() && !ps {
            bail!(
                "--fault-plan expands against the parameter-server round \
                 structure; got {:?} — inject ring/gossip faults by wrapping \
                 a transport in FaultyTransport (or memsgd ring --fault-plan)",
                self.topology
            );
        }
        Ok(())
    }

    /// Run on the calling thread without requiring `B: Clone + Send` —
    /// for backends that cannot be replicated across threads (e.g. a
    /// PJRT runtime). Available for every topology except
    /// [`Topology::SharedMemory`], whose engine clones one backend per
    /// worker thread; the parameter-server engines simulate their nodes
    /// in-process against the single backend.
    pub fn run_single_threaded(mut self) -> Result<RunRecord> {
        // Same strict edge as every other schedule-accepting API: a
        // literally constructed zero/overflowing LocalUpdate is refused,
        // not silently clamped.
        self.local.validate()?;
        self.validate_failure_config()?;
        if self.wire {
            bail!(
                "the wire engines spawn worker threads and replicate the backend; \
                 use run() (backend must be Clone + Send)"
            );
        }
        let s = self.settings();
        match self.topology.clone() {
            Topology::Sequential => sequential(&mut self.backend, &s),
            Topology::ParamServerSync { nodes } => param_server_sync(&mut self.backend, nodes, &s),
            Topology::ParamServerAsync { nodes, net } => {
                let compute = self.compute.clone();
                let hetero = self.hetero;
                param_server_async(&mut self.backend, nodes, &net, &compute, hetero, &s)
            }
            Topology::AllReduce { nodes } => all_reduce(&mut self.backend, nodes, &s),
            Topology::Gossip { nodes, graph } => gossip(&mut self.backend, nodes, graph, &s),
            Topology::SharedMemory { .. } => bail!(
                "SharedMemory replicates the backend across threads; \
                 use run() (backend must be Clone + Send)"
            ),
        }
    }

    /// [`Experiment::run_single_threaded`] restricted to
    /// [`Topology::Sequential`] (errors on anything else) — the
    /// strictest entry point for backends where even the simulated
    /// multi-node schedules make no sense.
    pub fn run_sequential(self) -> Result<RunRecord> {
        match self.topology {
            Topology::Sequential => self.run_single_threaded(),
            _ => bail!(
                "run_sequential requires Topology::Sequential; \
                 use run_single_threaded() (parameter-server topologies) \
                 or run() (backend must be Clone + Send)"
            ),
        }
    }
}

impl<B: GradBackend + Clone + Send> Experiment<B> {
    /// Execute the run and return the unified [`RunRecord`].
    pub fn run(mut self) -> Result<RunRecord> {
        self.local.validate()?;
        self.validate_failure_config()?;
        if self.wire {
            let s = self.settings();
            let mut transport = self.transport.take().unwrap_or_else(|| Box::new(Loopback));
            return match self.topology.clone() {
                Topology::ParamServerSync { nodes } => {
                    param_server_sync_wire(&mut self.backend, nodes, &mut *transport, &s)
                }
                Topology::ParamServerAsync { nodes, net } => {
                    let compute = self.compute.clone();
                    let hetero = self.hetero;
                    param_server_async_wire(
                        &mut self.backend,
                        nodes,
                        &net,
                        &compute,
                        hetero,
                        &mut *transport,
                        &s,
                    )
                }
                Topology::AllReduce { nodes } => {
                    all_reduce_wire(&mut self.backend, nodes, &mut *transport, &s)
                }
                Topology::Gossip { nodes, graph } => {
                    gossip_wire(&mut self.backend, nodes, graph, &mut *transport, &s)
                }
                other => bail!(
                    "wire transport applies to the message-passing topologies \
                     (ParamServerSync / ParamServerAsync / AllReduce / Gossip); \
                     got {other:?} — drop .wire(true) or change the topology"
                ),
            };
        }
        match self.topology.clone() {
            Topology::SharedMemory { workers } => {
                let s = self.settings();
                shared_memory(&mut self.backend, workers, &s)
            }
            _ => self.run_single_threaded(),
        }
    }
}

/// Legacy-compatible record naming per topology.
pub(crate) fn record_method_name(method: &MethodSpec, topology: &Topology) -> String {
    let w = topology.workers();
    match topology {
        Topology::Sequential => method.name(),
        Topology::SharedMemory { .. } => match method {
            MethodSpec::MemSgd { comp } => {
                format!("parallel_memsgd({},W={w})", comp.spec_string())
            }
            other => format!("parallel_{}(W={w})", other.name()),
        },
        Topology::ParamServerSync { .. } => match method {
            MethodSpec::MemSgd { comp } => format!("dist_memsgd({},W={w})", comp.spec_string()),
            other => format!("dist_{}(W={w})", other.name()),
        },
        Topology::ParamServerAsync { net, .. } => match method {
            MethodSpec::MemSgd { comp } => {
                format!("async_memsgd({},W={w},{})", comp.spec_string(), net.name)
            }
            other => format!("async_{}(W={w},{})", other.name(), net.name),
        },
        Topology::AllReduce { .. } => match method {
            MethodSpec::MemSgd { comp } => {
                format!("allreduce_memsgd({},W={w})", comp.spec_string())
            }
            other => format!("allreduce_{}(W={w})", other.name()),
        },
        Topology::Gossip { graph, .. } => match method {
            MethodSpec::MemSgd { comp } => {
                format!("gossip_memsgd({},W={w},{})", comp.spec_string(), graph.name())
            }
            other => format!("gossip_{}(W={w},{})", other.name(), graph.name()),
        },
    }
}

/// Record one loss evaluation (weighted average when enabled, last
/// iterate otherwise).
fn push_eval<B: GradBackend>(
    record: &mut RunRecord,
    backend: &mut B,
    x: &[f32],
    avg: &Option<WeightedAverage>,
    eval_x: &mut [f32],
    t: usize,
    bits: u64,
) {
    match avg {
        Some(a) if a.count() > 0 => a.write_average(eval_x),
        _ => eval_x.copy_from_slice(x),
    }
    let loss = backend.full_loss(eval_x);
    record.curve.push(LossPoint { t, bits, loss });
}

// ---------------------------------------------------------------------------
// Local-update phase (shared by all four engines)
// ---------------------------------------------------------------------------

/// Reusable per-worker scratch for the local-update phases: the local
/// iterate, the minibatch gradient (dense buffer and sparse emission),
/// the stepsize-scaled accumulator the sync compresses, and the
/// minibatch index buffer.
/// [`WorkerScratch::phase`] re-initializes it on entry, so one instance
/// serves every phase (and, on the single-threaded engines, every
/// worker) allocation-free.
struct WorkerScratch {
    local: LocalUpdate,
    n: usize,
    x_loc: Vec<f32>,
    grad: Vec<f32>,
    /// Sparse-pipeline emission buffer (stays empty on dense backends).
    sgrad: SparseVec,
    acc: Vec<f32>,
    idx: Vec<usize>,
    /// Active-route scratch (allocated on the first active phase): the
    /// stepsize-scaled accumulator's dense value backing, the saved
    /// pre-phase values of the in-place-modified iterate coordinates,
    /// and the generation-stamped membership set shared by both.
    acc_vals: Vec<f32>,
    x_orig: Vec<f32>,
    phase_idx: ActiveIndex,
}

impl WorkerScratch {
    fn new(d: usize, n: usize, local: LocalUpdate) -> WorkerScratch {
        // Per-route buffers are sized lazily on each route's first phase
        // (`ensure_dense_phase` / `ensure_active`): the H = 1 fast path
        // touches neither, and an active-route run never pays for the
        // dense-sync route's local iterate and accumulator (2×O(d)).
        WorkerScratch {
            local,
            n,
            x_loc: Vec::new(),
            grad: vec![0.0; d],
            sgrad: SparseVec::new(d),
            acc: Vec::new(),
            idx: Vec::with_capacity(local.batch.max(1)),
            acc_vals: Vec::new(),
            x_orig: Vec::new(),
            phase_idx: ActiveIndex::new(),
        }
    }

    /// One-time sizing of the dense-sync-route buffers (no-op afterwards).
    fn ensure_dense_phase(&mut self, d: usize) {
        if self.x_loc.len() < d {
            self.x_loc.resize(d, 0.0);
            self.acc.resize(d, 0.0);
        }
    }

    /// One-time sizing of the active-route buffers (no-op afterwards).
    fn ensure_active(&mut self, d: usize) {
        if self.acc_vals.len() < d {
            self.acc_vals.resize(d, 0.0);
            self.x_orig.resize(d, 0.0);
        }
        self.phase_idx.grow(d);
    }

    /// One worker's local phase: `H = local.sync_every` error-compensated
    /// minibatch steps starting from the global iterate `x`, then one
    /// compressed sync through `ef`.
    ///
    /// `x` is borrowed mutably but is **bit-for-bit unchanged on
    /// return**: the dense routes work on an internal copy, and the
    /// active route applies its local steps to `x` in place and restores
    /// every touched coordinate before syncing back — the caller then
    /// applies `ef.update()` exactly as before.
    ///
    /// Each local step applies the *raw* update `η·g` to the worker-local
    /// iterate and adds it to the accumulator; only the sync's compressed
    /// aggregate ever travels, and the error memory inside `ef` stays
    /// worker-local between syncs. `eta(h)` maps the local step index to
    /// its stepsize. With `B = H = 1` this is bit-for-bit the classic
    /// per-sample `ef.step(g, η)` (golden-trajectory suite). Returns the
    /// sync's wire bits.
    ///
    /// ## Sparse pipeline
    ///
    /// When the backend advertises
    /// [`GradBackend::supports_sparse_grad`] (CSR models without L2, the
    /// RCV1 regime), the phase runs sparsity-aware — O(nnz) local steps —
    /// in one of two flavors:
    ///
    /// * **Active route** (`ef.wants_active()`: memory-carrying method ×
    ///   active-scan compressor, i.e. top-k / threshold): the entire
    ///   phase is `O(touched)`. Local steps mutate `x` in place at
    ///   gradient coordinates only (first touches save the original
    ///   value), the stepsize-scaled accumulator lives in a
    ///   generation-stamped active set (`O(1)` reset), and the sync runs
    ///   [`ErrorFeedbackStep::sync_active`] — the `v = m + accum` build,
    ///   the compressor scan, and the residual update all visit
    ///   `support(m) ∪ touched` instead of `d` coordinates. No per-phase
    ///   `O(d)` pass remains.
    /// * **Dense-sync route** (other compressors): each local step emits
    ///   the minibatch gradient as a [`SparseVec`] and coordinate-merges
    ///   `η·g` via the fused [`SparseVec::local_step`] kernel, with the
    ///   dense `v = m + accum` pass and compressor scan paid once per
    ///   sync — unchanged from before the active path existed.
    ///
    /// All routes evaluate the same floating-point expressions in the
    /// same order on every touched coordinate, so trajectories are
    /// **bit-identical** on every topology (`tests/sparse_pipeline.rs`
    /// pins all combinations).
    fn phase<B: GradBackend>(
        &mut self,
        backend: &mut B,
        ef: &mut ErrorFeedbackStep,
        rng: &mut Prng,
        x: &mut [f32],
        eta: impl Fn(usize) -> f32,
    ) -> u64 {
        let h_steps = self.local.sync_every.max(1);
        let batch = self.local.batch.max(1);
        let sparse = backend.supports_sparse_grad();
        // Fast path — H = 1 is the classic (minibatch) step: gradient at
        // the fetched iterate, one error-feedback step. No local iterate,
        // no accumulator, none of the extra O(d) passes; `v = m + η·g`
        // and `v = m + 1.0·(η·g)` round identically, so this is the
        // general path bit for bit (and literally the pre-local-update
        // engine loop, which the golden suite pins).
        if h_steps == 1 {
            self.idx.clear();
            for _ in 0..batch {
                self.idx.push(rng.below(self.n));
            }
            if sparse {
                backend.sample_grad_batch_sparse(x, &self.idx, &mut self.sgrad);
                return ef.step_sparse(&self.sgrad, eta(0), rng);
            }
            backend.sample_grad_batch(x, &self.idx, &mut self.grad);
            return ef.step(&self.grad, eta(0), rng);
        }
        if sparse && ef.wants_active() {
            // Active route: H local steps in place on `x`, O(touched)
            // total. Per touched coordinate the FP op sequence is the
            // dense loop's (`step = η·g; acc += step; x -= step`, with
            // the first accumulation evaluating `0.0 + step` exactly as
            // the zero-initialized dense accumulator does), and the
            // restore puts back the saved original bits.
            let d = x.len();
            self.ensure_active(d);
            self.phase_idx.clear();
            for h in 0..h_steps {
                self.idx.clear();
                for _ in 0..batch {
                    self.idx.push(rng.below(self.n));
                }
                let e = eta(h);
                backend.sample_grad_batch_sparse(x, &self.idx, &mut self.sgrad);
                for (&j, &g) in self.sgrad.idx.iter().zip(&self.sgrad.val) {
                    let jj = j as usize;
                    let step = e * g;
                    if self.phase_idx.insert(j) {
                        self.x_orig[jj] = x[jj];
                        self.acc_vals[jj] = 0.0 + step;
                    } else {
                        self.acc_vals[jj] += step;
                    }
                    x[jj] -= step;
                }
            }
            let bits = ef.sync_active(
                ActiveView { vals: &self.acc_vals, touched: self.phase_idx.touched() },
                rng,
            );
            for &j in self.phase_idx.touched() {
                let jj = j as usize;
                x[jj] = self.x_orig[jj];
            }
            return bits;
        }
        self.ensure_dense_phase(x.len());
        self.x_loc.copy_from_slice(x);
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        for h in 0..h_steps {
            self.idx.clear();
            for _ in 0..batch {
                self.idx.push(rng.below(self.n));
            }
            let e = eta(h);
            if sparse {
                backend.sample_grad_batch_sparse(&self.x_loc, &self.idx, &mut self.sgrad);
                self.sgrad.local_step(e, &mut self.acc, &mut self.x_loc);
                continue;
            }
            backend.sample_grad_batch(&self.x_loc, &self.idx, &mut self.grad);
            for ((a, xl), &g) in self.acc.iter_mut().zip(self.x_loc.iter_mut()).zip(&self.grad) {
                let step = e * g;
                *a += step;
                *xl -= step;
            }
        }
        ef.sync(&self.acc, rng)
    }
}

/// Stamp a non-default local-update schedule into the record's `extra`
/// map (`batch`, `sync_every`, and the total samples consumed). Default
/// schedules leave the record untouched so legacy records stay
/// byte-identical.
pub(crate) fn annotate_local(record: &mut RunRecord, local: LocalUpdate, local_steps: usize) {
    if !local.is_default() {
        let batch = local.batch.max(1);
        record.extra.insert("batch".into(), batch as f64);
        record.extra.insert("sync_every".into(), local.sync_every.max(1) as f64);
        record
            .extra
            .insert("grad_samples".into(), local_steps as f64 * batch as f64);
    }
}

// ---------------------------------------------------------------------------
// Sequential engine (Algorithm 1 + the Section 4 baselines)
// ---------------------------------------------------------------------------

pub(crate) fn sequential<B: GradBackend>(backend: &mut B, s: &Settings) -> Result<RunRecord> {
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let mut root = Prng::new(s.seed);
    let mut rng = root.split(1); // "worker 0 of 1" — see module docs
    let mut ef = s.method.error_feedback(d);
    let mut x = vec![0.0f32; d];
    let mut avg = s
        .average
        .then(|| WeightedAverage::new(d, s.schedule.averaging_shift().max(1.0)));

    // One sync per H local steps (remainder dropped; steps = 0 keeps
    // running nothing, as before); the averager and the loss curve
    // track the global iterate, which only moves at syncs.
    let syncs = s.steps / h;
    let eval_every = (syncs / s.eval_points.max(1)).max(1);
    let mut ws = WorkerScratch::new(d, n, local);
    let mut eval_x = vec![0.0f32; d];
    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::Sequential),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };

    let started = Instant::now();
    push_eval(&mut record, backend, &x, &avg, &mut eval_x, 0, 0);
    for si in 0..syncs {
        ws.phase(backend, &mut ef, &mut rng, &mut x, |hh| s.schedule.eta(si * h + hh) as f32);
        ef.update().sub_from(&mut x);
        if let Some(a) = avg.as_mut() {
            a.update(&x);
        }
        if (si + 1) % eval_every == 0 || si + 1 == syncs {
            push_eval(&mut record, backend, &x, &avg, &mut eval_x, (si + 1) * h, ef.bits_sent);
        }
    }
    record.steps = syncs * h;
    record.total_bits = ef.bits_sent;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    annotate_local(&mut record, local, syncs * h);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Shared-memory engine (Algorithm 2: lock-free threads)
// ---------------------------------------------------------------------------

pub(crate) fn shared_memory<B: GradBackend + Clone + Send>(
    backend: &mut B,
    workers: usize,
    s: &Settings,
) -> Result<RunRecord> {
    let workers = workers.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h_int = local.sync_every.max(1);
    let per_worker = (s.steps / workers).max(1);
    let syncs = (per_worker / h_int).max(1);
    let shared = SharedParams::zeros(d);
    let total_bits = Arc::new(AtomicU64::new(0));
    let mut root = Prng::new(s.seed);
    let started = Instant::now();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let mut rng = root.split(w as u64 + 1);
            let mut ef = s.method.error_feedback(d);
            let mut wb = (*backend).clone();
            let shared = Arc::clone(&shared);
            let total_bits = Arc::clone(&total_bits);
            let schedule = s.schedule.clone();
            handles.push(scope.spawn(move || {
                let mut xbuf = vec![0.0f32; d];
                let mut ws = WorkerScratch::new(d, n, local);
                for si in 0..syncs {
                    // Inconsistent read of the shared iterate (line 5's
                    // ∇f(x)), then H local error-compensated steps on it.
                    shared.snapshot_into(&mut xbuf);
                    ws.phase(&mut wb, &mut ef, &mut rng, &mut xbuf, |hh| {
                        schedule.eta(si * h_int + hh) as f32
                    });
                    // shared x ← x − u (lossy, lock-free).
                    match ef.update() {
                        Update::Sparse(sv) => {
                            for (&j, &gj) in sv.idx.iter().zip(&sv.val) {
                                shared.sub(j as usize, gj);
                            }
                        }
                        Update::Dense(g) => {
                            for (j, &gj) in g.iter().enumerate() {
                                if gj != 0.0 {
                                    shared.sub(j, gj);
                                }
                            }
                        }
                    }
                }
                total_bits.fetch_add(ef.bits_sent, Ordering::Relaxed);
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });

    let elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    let x = shared.snapshot();
    let loss = backend.full_loss(&x);
    let total_steps = syncs * h_int * workers;
    let bits = total_bits.load(Ordering::Relaxed);

    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::SharedMemory { workers }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        curve: vec![LossPoint { t: total_steps, bits, loss }],
        steps: total_steps,
        total_bits: bits,
        elapsed_ms,
        ..Default::default()
    };
    record.extra.insert("workers".into(), workers as f64);
    record.extra.insert("steps_per_worker".into(), (syncs * h_int) as f64);
    annotate_local(&mut record, local, total_steps);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Synchronous parameter-server engine (the §1/§5 motivating setting)
// ---------------------------------------------------------------------------

pub(crate) fn param_server_sync<B: GradBackend>(
    backend: &mut B,
    nodes: usize,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    let mut root_rng = Prng::new(s.seed);

    struct Node {
        ef: ErrorFeedbackStep,
        rng: Prng,
    }
    let mut workers: Vec<Node> = (0..nodes)
        .map(|w| Node {
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
        })
        .collect();

    // The simulated twin of the wire fault machinery: expand the plan
    // against the same (nodes, rounds) shape the wire server uses, so a
    // fixed spec kills the same node in the same round on both paths.
    let deaths: Vec<Option<u64>> = match &s.faults {
        Some(spec) => spec.plan(nodes, rounds)?.sim_deaths(nodes)?,
        None => vec![None; nodes],
    };
    let mut dead = vec![false; nodes];

    let mut x = vec![0.0f32; d];
    let mut ws = WorkerScratch::new(d, n, local);
    // Server-side aggregation buffer: coordinate → summed update.
    let mut agg: BTreeMap<u32, f32> = BTreeMap::new();
    let mut agg_dense = vec![0.0f32; d];
    let mut broadcast_bits = 0u64;
    let idx_bits = crate::compress::sparse::index_bits(d);

    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::ParamServerSync { nodes }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    for round in 0..rounds {
        // η is held constant within a round (its H local steps included),
        // matching the pre-local-update round indexing at H = 1.
        let etaf = s.schedule.eta(round) as f32;
        agg.clear();
        let mut any_dense = false;
        for (widx, worker) in workers.iter_mut().enumerate() {
            if dead[widx] {
                continue;
            }
            if deaths[widx].is_some_and(|at| round as u64 >= at) {
                // Mirror of the wire cut: the server-side recv for this
                // node fails in round `at`, so rounds 0..at contributed
                // and nothing after. The node's error memory keeps the
                // suppressed mass it never got to ship.
                match s.policy {
                    FailurePolicy::FailFast => bail!("node {widx}: {PEER_HUNG_UP}"),
                    _ => {
                        dead[widx] = true;
                        continue;
                    }
                }
            }
            // H local error-compensated steps from the *current
            // broadcast* x, then one compressed upload per node.
            ws.phase(backend, &mut worker.ef, &mut worker.rng, &mut x, |_| etaf);
            // Server receives the upload and folds it into the aggregate.
            match worker.ef.update() {
                // Once any node has gone dense the round aggregates in
                // `agg_dense`; sparse contributions fold straight into
                // it so nothing is dropped. Spilling `agg` at the
                // moment the first dense upload arrives (before folding
                // it) keeps the per-coordinate addition order identical
                // to the node-id fold contract.
                Update::Sparse(sv) => {
                    if any_dense {
                        for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                            agg_dense[j as usize] += vj;
                        }
                    } else {
                        for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                            *agg.entry(j).or_insert(0.0) += vj;
                        }
                    }
                }
                Update::Dense(g) => {
                    if !any_dense {
                        any_dense = true;
                        for (&j, &vj) in agg.iter() {
                            agg_dense[j as usize] += vj;
                        }
                        agg.clear();
                    }
                    for (a, &gj) in agg_dense.iter_mut().zip(g) {
                        *a += gj;
                    }
                }
            }
        }
        // Server applies the mean update and broadcasts it. The mean is
        // over the *live* quorum — with every node alive `live == nodes`
        // and `1.0 / live as f32` is bit-identical to the historical
        // expression, so fault-free trajectories are unchanged.
        let live = dead.iter().filter(|&&dd| !dd).count();
        if live == 0 {
            bail!("round {round}: every node is dead");
        }
        if let FailurePolicy::DropRound { min_quorum } = s.policy {
            let quorum = min_quorum.max(1);
            if live < quorum {
                bail!("round {round}: {live} live nodes below the drop-round quorum of {quorum}");
            }
        }
        let scale = 1.0 / live as f32;
        if any_dense {
            for (xj, a) in x.iter_mut().zip(agg_dense.iter_mut()) {
                *xj -= *a * scale;
                *a = 0.0;
            }
            broadcast_bits += 32 * d as u64;
        } else {
            for (&j, &vj) in agg.iter() {
                x[j as usize] -= vj * scale;
            }
            broadcast_bits += agg.len() as u64 * (32 + idx_bits);
        }

        if (round + 1) % eval_every == 0 || round + 1 == rounds {
            let uploads: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
            record.curve.push(LossPoint {
                t: round + 1,
                bits: uploads + broadcast_bits,
                loss: backend.full_loss(&x),
            });
        }
    }

    let uploads: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
    record.steps = rounds * nodes * h;
    record.total_bits = uploads + broadcast_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record.extra.insert("broadcast_bits".into(), broadcast_bits as f64);
    annotate_local(&mut record, local, rounds * nodes * h);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Server-free topologies: ring all-reduce and gossip
// ---------------------------------------------------------------------------

/// The running aggregate of a server-free fold (a ring-reduce round or
/// a gossip pair): a sparse accumulator with an O(1)-membership merge
/// table ([`SparseMerge`]), spilling to a dense buffer the moment any
/// folded update is dense. The spill happens *before* the dense update
/// folds — so the per-coordinate addition order is exactly the caller's
/// fold order no matter which contribution went dense (the mixed
/// sparse/dense aggregation drop of PR 7, designed out structurally).
///
/// The simulated engines and every wire node fold through this one
/// type, so simulated and threaded trajectories agree **by
/// construction** — there is no second fold implementation to drift.
pub struct RingPartial {
    d: usize,
    sv: SparseVec,
    merge: SparseMerge,
    dense: Vec<f32>,
    any_dense: bool,
    /// Scratch [`Update`] for the payload codec (refilled per frame).
    out: Update,
}

impl RingPartial {
    pub fn new(d: usize) -> RingPartial {
        RingPartial {
            d,
            sv: SparseVec::new(d),
            merge: SparseMerge::new(),
            dense: vec![0.0; d],
            any_dense: false,
            out: Update::new_sparse(d),
        }
    }

    /// Start a fold: reset the merge table (O(previous support)) and
    /// clear the accumulator. The dense buffer is re-zeroed only when
    /// the previous fold spilled.
    pub fn begin(&mut self) {
        self.merge.finish(&self.sv);
        self.merge.begin(self.d, &mut self.sv);
        if self.any_dense {
            self.dense.iter_mut().for_each(|v| *v = 0.0);
            self.any_dense = false;
        }
    }

    /// Fold one contribution into the aggregate. Callers fold in a
    /// fixed order (node-id around the ring, lower-id-first in a gossip
    /// pair); per coordinate the additions happen in exactly that
    /// arrival order.
    pub fn fold(&mut self, u: &Update) {
        match u {
            Update::Sparse(sv) => {
                if self.any_dense {
                    for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                        self.dense[j as usize] += vj;
                    }
                } else {
                    for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                        self.merge.add(&mut self.sv, j, vj);
                    }
                }
            }
            Update::Dense(g) => {
                if !self.any_dense {
                    self.any_dense = true;
                    for (&j, &vj) in self.sv.idx.iter().zip(&self.sv.val) {
                        self.dense[j as usize] += vj;
                    }
                }
                for (a, &gj) in self.dense.iter_mut().zip(g) {
                    *a += gj;
                }
            }
        }
    }

    /// Paper-accounted cost of transmitting this aggregate one hop —
    /// the per-hop analog of the PS broadcast accounting (leaf syncs
    /// use their method's own accounting; merged aggregates use the
    /// closed form).
    pub fn cost_bits(&self, idx_bits: u64) -> u64 {
        if self.any_dense {
            32 * self.d as u64
        } else {
            self.sv.idx.len() as u64 * (32 + idx_bits)
        }
    }

    /// Frame the aggregate as an [`Update`] for the payload codec (the
    /// sync server's `bc_update` refill idiom — no per-frame alloc once
    /// warm).
    pub fn fill_update(&mut self) -> &Update {
        if self.any_dense {
            match &mut self.out {
                Update::Dense(g) => {
                    g.clear();
                    g.extend_from_slice(&self.dense);
                }
                other => *other = Update::Dense(self.dense.clone()),
            }
        } else {
            let sv = self.out.sparse_mut(self.d);
            for (&j, &vj) in self.sv.idx.iter().zip(&self.sv.val) {
                sv.push(j, vj);
            }
        }
        &self.out
    }

    /// Apply the scaled aggregate to an iterate: `x[j] -= v[j]·scale`,
    /// one op per touched coordinate — the literal expression a wire
    /// node evaluates on the decoded aggregate
    /// ([`Update::sub_scaled_from`]), so both sides produce identical
    /// iterate bits.
    pub fn apply(&self, scale: f32, x: &mut [f32]) {
        if self.any_dense {
            for (xj, a) in x.iter_mut().zip(&self.dense) {
                *xj -= *a * scale;
            }
        } else {
            for (&j, &vj) in self.sv.idx.iter().zip(&self.sv.val) {
                x[j as usize] -= vj * scale;
            }
        }
    }
}

/// Closed-form transmission cost of one already-materialized update —
/// what [`RingPartial::cost_bits`] reports, computable from a decoded
/// frame (the payload codec preserves the entry list exactly, so both
/// sides of a hop agree).
pub(crate) fn update_cost_bits(u: &Update, d: usize, idx_bits: u64) -> u64 {
    match u {
        Update::Sparse(sv) => sv.idx.len() as u64 * (32 + idx_bits),
        Update::Dense(_) => 32 * d as u64,
    }
}

/// Derive one gossip round's matching into `pairs` (normalized
/// `(low, high)`, folded lower-id-first) and return the unmatched node,
/// if any.
///
/// Invariants the wire engine leans on:
/// * **Fixed draw count per round** — `nodes − 1` draws for
///   [`GossipGraph::Complete`] (Fisher–Yates), exactly 1 for
///   [`GossipGraph::Ring`] (the parity draw) — so every node can replay
///   the full schedule independently from a clone of the topology
///   stream and all nodes agree on every round's matching without any
///   coordination traffic.
/// * The topology stream is `root.split(nodes + 1)`, drawn **after**
///   the worker streams `1..=nodes`, so adding gossip never perturbs
///   the worker trajectories' RNG contract.
pub(crate) fn gossip_matching(
    graph: GossipGraph,
    nodes: usize,
    rng: &mut Prng,
    perm: &mut Vec<usize>,
    pairs: &mut Vec<(usize, usize)>,
) -> Option<usize> {
    pairs.clear();
    match graph {
        GossipGraph::Complete => {
            perm.clear();
            perm.extend(0..nodes);
            for i in (1..nodes).rev() {
                let j = rng.below(i + 1);
                perm.swap(i, j);
            }
            let mut k = 0;
            while k + 1 < nodes {
                let (a, b) = (perm[k], perm[k + 1]);
                pairs.push((a.min(b), a.max(b)));
                k += 2;
            }
            (nodes % 2 == 1).then(|| perm[nodes - 1])
        }
        GossipGraph::Ring => {
            let p = rng.below(2);
            if nodes < 2 {
                return (nodes == 1).then_some(0);
            }
            if nodes % 2 == 0 {
                // Parity p selects the even or odd edge set; the odd
                // set wraps the ring once.
                for m in 0..nodes / 2 {
                    let a = (p + 2 * m) % nodes;
                    let b = (p + 2 * m + 1) % nodes;
                    pairs.push((a.min(b), a.max(b)));
                }
                None
            } else {
                // Odd ring: the selected edge set is a path matching;
                // one endpoint sits out.
                for m in 0..nodes / 2 {
                    pairs.push((p + 2 * m, p + 2 * m + 1));
                }
                Some(if p == 0 { nodes - 1 } else { 0 })
            }
        }
    }
}

/// Simulated ring all-reduce: the `ParamServerSync` schedule (same
/// phases, same RNG streams, same mean-apply) with the server replaced
/// by a ring fold — node `i` folds its sync into the circulating
/// partial and forwards it (`REDUCE`, `n − 1` hops), the last node
/// completes the aggregate, and it circulates back (`GATHER`, `n − 1`
/// hops) so every node applies the mean. `total_bits` is what crosses
/// the ring (closed-form per-hop costs, split into the `reduce_bits` /
/// `gather_bits` extras); the methods' own accounted sync bits land in
/// the `upload_bits` extra. Losses match [`param_server_sync`] exactly
/// (per-coordinate FP fold order is the same node-id order); with one
/// node nothing crosses a wire and the trajectory is
/// [`sequential`]'s (H = 1, no averaging).
pub(crate) fn all_reduce<B: GradBackend>(
    backend: &mut B,
    nodes: usize,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    let mut root_rng = Prng::new(s.seed);

    struct Node {
        ef: ErrorFeedbackStep,
        rng: Prng,
    }
    let mut workers: Vec<Node> = (0..nodes)
        .map(|w| Node {
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
        })
        .collect();

    let mut x = vec![0.0f32; d];
    let mut ws = WorkerScratch::new(d, n, local);
    let mut partial = RingPartial::new(d);
    let idx_bits = crate::compress::sparse::index_bits(d);
    let mut reduce_bits = 0u64;
    let mut gather_bits = 0u64;

    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::AllReduce { nodes }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    let scale = 1.0 / nodes as f32;
    for round in 0..rounds {
        // η held constant within a round, as in the PS-sync engine.
        let etaf = s.schedule.eta(round) as f32;
        partial.begin();
        for (w, worker) in workers.iter_mut().enumerate() {
            ws.phase(backend, &mut worker.ef, &mut worker.rng, &mut x, |_| etaf);
            partial.fold(worker.ef.update());
            // REDUCE hop w → w+1 carries the partial holding nodes
            // 0..=w; the last node completes the fold and forwards
            // nothing.
            if w + 1 < nodes {
                reduce_bits += partial.cost_bits(idx_bits);
            }
        }
        // GATHER: the completed aggregate circulates n − 1 hops.
        gather_bits += (nodes as u64 - 1) * partial.cost_bits(idx_bits);
        partial.apply(scale, &mut x);

        if (round + 1) % eval_every == 0 || round + 1 == rounds {
            record.curve.push(LossPoint {
                t: round + 1,
                bits: reduce_bits + gather_bits,
                loss: backend.full_loss(&x),
            });
        }
    }

    let uploads: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
    record.steps = rounds * nodes * h;
    record.total_bits = reduce_bits + gather_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record.extra.insert("reduce_bits".into(), reduce_bits as f64);
    record.extra.insert("gather_bits".into(), gather_bits as f64);
    annotate_local(&mut record, local, rounds * nodes * h);
    Ok(record)
}

/// Simulated gossip: `nodes` private iterates, one matching per round
/// on the configured graph ([`gossip_matching`] — drawn from the
/// topology's own PRNG stream `root.split(nodes + 1)`). Matched pairs
/// fold lower-id-first through [`RingPartial`] and both apply the pair
/// mean; an unmatched node applies its own sync alone (those bits are
/// accounted in the `self_sync_bits` extra, not in `total_bits` —
/// nothing crossed a wire). The loss curve evaluates the node-mean
/// iterate, folded in node-id order.
pub(crate) fn gossip<B: GradBackend>(
    backend: &mut B,
    nodes: usize,
    graph: GossipGraph,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    let mut root_rng = Prng::new(s.seed);

    struct Node {
        ef: ErrorFeedbackStep,
        rng: Prng,
        x: Vec<f32>,
    }
    let mut workers: Vec<Node> = (0..nodes)
        .map(|w| Node {
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
            x: vec![0.0; d],
        })
        .collect();
    // Topology stream — split AFTER the worker streams so the worker
    // trajectories keep the module's RNG contract unchanged.
    let mut match_rng = root_rng.split(nodes as u64 + 1);

    let mut ws = WorkerScratch::new(d, n, local);
    let mut partial = RingPartial::new(d);
    let mut sync_bits = vec![0u64; nodes];
    let mut perm = Vec::new();
    let mut pairs = Vec::new();
    let mut xbar = vec![0.0f32; d];
    let mut transmitted = 0u64;
    let mut self_bits = 0u64;

    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::Gossip { nodes, graph }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&xbar) });

    for round in 0..rounds {
        let etaf = s.schedule.eta(round) as f32;
        for (w, worker) in workers.iter_mut().enumerate() {
            sync_bits[w] = ws.phase(backend, &mut worker.ef, &mut worker.rng, &mut worker.x, |_| {
                etaf
            });
        }
        let unpaired = gossip_matching(graph, nodes, &mut match_rng, &mut perm, &mut pairs);
        for &(a, b) in &pairs {
            // Fold lower-id-first — the fixed pair fold order every
            // wire node reproduces — and both apply the pair mean.
            partial.begin();
            partial.fold(workers[a].ef.update());
            partial.fold(workers[b].ef.update());
            partial.apply(0.5, &mut workers[a].x);
            partial.apply(0.5, &mut workers[b].x);
            // Each partner transmits its own sync once.
            transmitted += sync_bits[a] + sync_bits[b];
        }
        if let Some(u) = unpaired {
            let wkr = &mut workers[u];
            wkr.ef.update().sub_from(&mut wkr.x);
            self_bits += sync_bits[u];
        }

        if (round + 1) % eval_every == 0 || round + 1 == rounds {
            // Node-mean iterate, folded in node-id order.
            xbar.iter_mut().for_each(|v| *v = 0.0);
            for worker in workers.iter() {
                for (sm, &xi) in xbar.iter_mut().zip(&worker.x) {
                    *sm += xi;
                }
            }
            let ns = 1.0 / nodes as f32;
            xbar.iter_mut().for_each(|v| *v *= ns);
            record.curve.push(LossPoint {
                t: round + 1,
                bits: transmitted,
                loss: backend.full_loss(&xbar),
            });
        }
    }

    let uploads: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
    record.steps = rounds * nodes * h;
    record.total_bits = transmitted;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record.extra.insert("self_sync_bits".into(), self_bits as f64);
    annotate_local(&mut record, local, rounds * nodes * h);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Asynchronous parameter-server engine (§1.1 sparsification + asynchrony)
// ---------------------------------------------------------------------------

/// Pending event: a worker finishing its gradient at `t_ns`.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Finish {
    t_ns: u64,
    worker: usize,
}

pub(crate) fn param_server_async<B: GradBackend>(
    backend: &mut B,
    nodes: usize,
    net: &NetworkModel,
    compute: &ComputeModel,
    hetero: f64,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    // Each server update now absorbs one local phase of H·B gradients;
    // the remainder of the budget is dropped (steps = 0 runs nothing,
    // as before).
    let grads_per_sync = (local.batch.max(1) * h) as f64;
    let total_syncs = s.steps / h;
    // Async fault plans count per-worker *turns* rather than global
    // rounds (a worker owns roughly `total_syncs / nodes` turns), so
    // the plan expands against that per-node shape — the wire twin uses
    // the identical expression.
    let deaths: Vec<Option<u64>> = match &s.faults {
        Some(spec) => spec.plan(nodes, (total_syncs / nodes).max(2))?.sim_deaths(nodes)?,
        None => vec![None; nodes],
    };
    let mut turns = vec![0u64; nodes];
    let mut dead = vec![false; nodes];
    let mut root_rng = Prng::new(s.seed);

    struct AsyncNode {
        ef: ErrorFeedbackStep,
        rng: Prng,
        /// Server update-counter value at this worker's last fetch.
        fetch_version: u64,
        /// Compute-time multiplier ≥ 1.
        slow: f64,
    }
    let mut workers: Vec<AsyncNode> = (0..nodes)
        .map(|w| AsyncNode {
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
            fetch_version: 0,
            slow: 1.0
                + if nodes > 1 {
                    hetero * w as f64 / (nodes - 1) as f64
                } else {
                    0.0
                },
        })
        .collect();

    let mut x = vec![0.0f32; d];
    let mut ws = WorkerScratch::new(d, n, local);

    // Event queue: min-heap over finish time.
    let mut queue: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    let compute_ns = |slow: f64, cm: &ComputeModel| -> u64 {
        (cm.s_per_coord * cm.coords_per_grad * grads_per_sync * slow * 1e9).max(1.0) as u64
    };
    for (i, w) in workers.iter().enumerate() {
        queue.push(Reverse(Finish {
            t_ns: compute_ns(w.slow, compute),
            worker: i,
        }));
    }

    let mut version = 0u64; // server update counter
    let mut link_free_ns = 0u64; // server ingress link busy-until
    let mut link_busy_total = 0u64;
    let mut staleness_sum = 0u64;
    let mut staleness_max = 0u64;
    let mut now_ns = 0u64;

    let eval_every = (total_syncs / s.eval_points.max(1)).max(1);
    let mut record = RunRecord {
        method: record_method_name(
            &s.method,
            &Topology::ParamServerAsync { nodes, net: net.clone() },
        ),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    while version < total_syncs as u64 {
        let Some(Reverse(ev)) = queue.pop() else {
            bail!("server update {version}: every worker is dead before the sync budget completed");
        };
        now_ns = now_ns.max(ev.t_ns);
        if dead[ev.worker] {
            continue;
        }
        if deaths[ev.worker].is_some_and(|at| turns[ev.worker] >= at) {
            // Mirror of the wire cut: the server's recv for this worker's
            // `at`-th turn fails, so the worker completed exactly `at`
            // turns and never requeues.
            match s.policy {
                FailurePolicy::FailFast => bail!("node {}: {PEER_HUNG_UP}", ev.worker),
                _ => {
                    dead[ev.worker] = true;
                    let live = dead.iter().filter(|&&dd| !dd).count();
                    if let FailurePolicy::DropRound { min_quorum } = s.policy {
                        let quorum = min_quorum.max(1);
                        if live < quorum {
                            bail!(
                                "server update {version}: {live} live nodes below the \
                                 drop-round quorum of {quorum}"
                            );
                        }
                    }
                    continue;
                }
            }
        }
        turns[ev.worker] += 1;
        let w = &mut workers[ev.worker];

        // The worker finished its local phase (computed on the x it
        // fetched; staleness-wise the fetch snapshot is what matters —
        // we apply against the *current* x exactly like a real lock-free
        // PS). η is held constant within the phase, indexed by the
        // server update counter as before.
        let eta = s.schedule.eta(version as usize) as f32;
        let bits = ws.phase(backend, &mut w.ef, &mut w.rng, &mut x, |_| eta);

        // Upload queues behind the shared server link. The link is busy
        // for the serialization time only; propagation latency delays the
        // arrival but does not occupy the link.
        let xfer_ns = (net.xfer_s(bits) * 1e9).max(1.0) as u64;
        let latency_ns = (net.latency_s * 1e9) as u64;
        let start_ns = ev.t_ns.max(link_free_ns);
        link_free_ns = start_ns + xfer_ns;
        link_busy_total += xfer_ns;
        let arrive_ns = link_free_ns + latency_ns;
        now_ns = now_ns.max(arrive_ns);

        // Server applies instantly on receipt.
        w.ef.update().sub_from(&mut x);
        version += 1;
        let stale = version - 1 - w.fetch_version;
        staleness_sum += stale;
        staleness_max = staleness_max.max(stale);

        // Worker refetches and starts the next gradient.
        w.fetch_version = version;
        queue.push(Reverse(Finish {
            t_ns: arrive_ns + compute_ns(w.slow, compute),
            worker: ev.worker,
        }));

        if version % eval_every as u64 == 0 || version == total_syncs as u64 {
            let bits: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
            record.curve.push(LossPoint {
                t: version as usize,
                bits,
                loss: backend.full_loss(&x),
            });
        }
    }

    let total_bits: u64 = workers.iter().map(|w| w.ef.bits_sent).sum();
    record.steps = version as usize * h;
    record.total_bits = total_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    let mean_staleness = staleness_sum as f64 / version.max(1) as f64;
    let sim_seconds = now_ns as f64 / 1e9;
    let link_utilization = if now_ns > 0 {
        (link_busy_total as f64 / now_ns as f64).min(1.0)
    } else {
        0.0
    };
    record.extra.insert("mean_staleness".into(), mean_staleness);
    record.extra.insert("max_staleness".into(), staleness_max as f64);
    record.extra.insert("sim_seconds".into(), sim_seconds);
    record.extra.insert("link_utilization".into(), link_utilization);
    record.extra.insert("workers".into(), nodes as f64);
    annotate_local(&mut record, local, version as usize * h);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Wire engines: the parameter-server topologies on real threads, with
// every update serialized through the Elias payload codec and carried
// by a `Transport` channel (see `super::transport` for the format).
// ---------------------------------------------------------------------------

/// Join the wire worker threads, collecting each node's accounted
/// upload bits. `served` (the server protocol's outcome) keeps error
/// priority: a server-side failure is reported even when it also took
/// the workers down with it; worker errors and panics surface next.
fn join_wire_workers(
    handles: Vec<std::thread::ScopedJoinHandle<'_, Result<u64>>>,
    served: Result<()>,
    dead: &[bool],
) -> Result<Vec<u64>> {
    let mut bits = Vec::with_capacity(handles.len());
    let mut worker_err: Option<anyhow::Error> = None;
    for (node, hd) in handles.into_iter().enumerate() {
        // A node the failure policy marked dead is *expected* to come
        // back with an error (its endpoint was cut); its accounted bits
        // live in the server tally instead.
        let tolerated = dead.get(node).copied().unwrap_or(false);
        match hd.join() {
            Ok(Ok(b)) => bits.push(b),
            Ok(Err(e)) => {
                bits.push(0);
                if worker_err.is_none() && !tolerated {
                    worker_err = Some(anyhow::anyhow!("worker {node}: {e:#}"));
                }
            }
            Err(_) => {
                bits.push(0);
                if worker_err.is_none() {
                    worker_err = Some(anyhow::anyhow!("worker {node} panicked"));
                }
            }
        }
    }
    served?;
    if let Some(e) = worker_err {
        return Err(e);
    }
    Ok(bits)
}

/// Cross-check the accounted bits the workers carried in their upload
/// headers (`upload_acc`, the server tally) against what their
/// error-feedback states counted (`worker_bits`, returned at join) —
/// per node, skipping nodes the failure policy marked dead (a dead
/// node's thread died before it could report; the server tally is the
/// ground truth for what it shipped). Returns the total — the record's
/// upload accounting.
fn check_wire_accounting(upload_acc: &[u64], worker_bits: &[u64], dead: &[bool]) -> Result<u64> {
    for (node, (&tallied, &reported)) in upload_acc.iter().zip(worker_bits).enumerate() {
        if dead.get(node).copied().unwrap_or(false) {
            continue;
        }
        if tallied != reported {
            bail!(
                "wire protocol desync: node {node} counted {reported} upload bits, \
                 server tallied {tallied}"
            );
        }
    }
    Ok(upload_acc.iter().sum())
}

/// Per-node state of a wire-engine worker thread: the channel endpoint,
/// a backend replica, the error-feedback state, the node's RNG stream,
/// and the run configuration. Built on the server thread in node-id
/// order (so the RNG split sequence matches the simulated engine) and
/// moved into the worker thread whole. The multi-process cluster
/// runtime ([`super::cluster`]) builds the same state in a worker
/// process — channel backed by a TCP socket, RNG re-derived from the
/// handshake's seed and node id — and runs the same protocol loops.
pub(crate) struct WireWorker<B> {
    pub(crate) ch: Box<dyn Channel>,
    pub(crate) backend: B,
    pub(crate) ef: ErrorFeedbackStep,
    pub(crate) rng: Prng,
    pub(crate) schedule: Schedule,
    pub(crate) local: LocalUpdate,
    pub(crate) node: u32,
    pub(crate) d: usize,
    pub(crate) n: usize,
}

impl<B: GradBackend> WireWorker<B> {
    /// Synchronous protocol: `rounds` barriered iterations of phase →
    /// encoded upload → decoded broadcast, against a private model
    /// replica that stays bit-identical to the server's iterate, then
    /// one final `SHUTDOWN` from the server (the explicit end-of-run
    /// drain). Returns the accounted upload bits (cross-checked by the
    /// server).
    pub(crate) fn run_sync(self, rounds: usize, scale: f32) -> Result<u64> {
        let x = vec![0.0f32; self.d];
        self.run_sync_from(0, rounds, scale, x)
    }

    /// [`WireWorker::run_sync`] resumed mid-run: start at `start_round`
    /// against a caller-supplied replica `x` (a fresh process seeds it
    /// from the server's `SNAPSHOT` frame; the error memory starts
    /// empty, which is exactly the rejoin contract — suppressed mass
    /// that died with the old incarnation is gone, and the analysis
    /// only ever bounded the memory, never required it).
    pub(crate) fn run_sync_from(
        mut self,
        start_round: usize,
        rounds: usize,
        scale: f32,
        mut x: Vec<f32>,
    ) -> Result<u64> {
        let mut ws = WorkerScratch::new(self.d, self.n, self.local);
        let mut w = BitWriter::new();
        for round in start_round..rounds {
            // η is held constant within a round, exactly as in the
            // simulated engine.
            let etaf = self.schedule.eta(round) as f32;
            let bits = ws.phase(&mut self.backend, &mut self.ef, &mut self.rng, &mut x, |_| etaf);
            let node = self.node;
            encode_upload(&mut w, round as u64, node, bits, self.ef.compressor(), self.ef.update());
            self.ch.send(w.as_bytes())?;
            let frame = self.ch.recv()?;
            match decode_msg(&frame, self.d)?.msg {
                WireMsg::Broadcast { round: r, update } if r == round as u64 => {
                    // The simulated server's literal expression
                    // (`x[j] -= v[j]·scale`), in ascending coordinate
                    // order — the decoded aggregate arrives sorted.
                    update.sub_scaled_from(scale, &mut x);
                }
                other => bail!("node {node}: unexpected {other:?} in round {round}"),
            }
        }
        // A premature SHUTDOWN mid-run lands in the round loop above and
        // fails descriptively; the one the server drains after the final
        // round is consumed here, so both sides agree the run is over
        // before either closes its endpoint.
        let frame = self.ch.recv()?;
        match decode_msg(&frame, self.d)?.msg {
            WireMsg::Shutdown => Ok(self.ef.bits_sent),
            other => bail!(
                "node {}: expected SHUTDOWN after the final round, got {other:?}",
                self.node
            ),
        }
    }

    /// Asynchronous protocol: an event loop over `Apply` (keep the
    /// replica current), `Go` (compute one phase at the server-named
    /// version and upload it), and `Shutdown`. Per-channel FIFO
    /// ordering guarantees every update the server applied before a
    /// `Go` has reached the replica when the phase runs — the phase
    /// sees exactly the simulated engine's iterate.
    pub(crate) fn run_async(mut self) -> Result<u64> {
        let mut x = vec![0.0f32; self.d];
        let mut ws = WorkerScratch::new(self.d, self.n, self.local);
        let mut w = BitWriter::new();
        loop {
            let frame = self.ch.recv()?;
            match decode_msg(&frame, self.d)?.msg {
                WireMsg::Apply { update, .. } => update.sub_from(&mut x),
                WireMsg::Go { version } => {
                    let etaf = self.schedule.eta(version as usize) as f32;
                    let bits =
                        ws.phase(&mut self.backend, &mut self.ef, &mut self.rng, &mut x, |_| etaf);
                    encode_upload(
                        &mut w,
                        version,
                        self.node,
                        bits,
                        self.ef.compressor(),
                        self.ef.update(),
                    );
                    self.ch.send(w.as_bytes())?;
                }
                WireMsg::Shutdown => return Ok(self.ef.bits_sent),
                other => bail!("node {}: unexpected {other:?}", self.node),
            }
        }
    }
}

/// Per-run tallies of the synchronous server protocol: the paper
/// accounting carried in upload headers plus the measured wire bits,
/// split by direction. Shared by the in-process threaded engine and
/// the multi-process cluster runtime ([`super::cluster`]).
pub(crate) struct SyncServerTally {
    /// Accounted upload bits per node, from the `UPLOAD` headers.
    pub(crate) upload_acc: Vec<u64>,
    /// Paper-accounted broadcast bits (closed form, as simulated).
    pub(crate) broadcast_bits: u64,
    /// Measured `UPLOAD` payload bits.
    pub(crate) wire_up: u64,
    /// Measured `BROADCAST` payload bits.
    pub(crate) wire_bc: u64,
    /// Measured frame bits, worker → server.
    pub(crate) wire_frames_up: u64,
    /// Measured frame bits, server → workers.
    pub(crate) wire_frames_down: u64,
}

impl SyncServerTally {
    pub(crate) fn new(nodes: usize) -> SyncServerTally {
        SyncServerTally {
            upload_acc: vec![0; nodes],
            broadcast_bits: 0,
            wire_up: 0,
            wire_bc: 0,
            wire_frames_up: 0,
            wire_frames_down: 0,
        }
    }
}

/// Failure-handling state for one synchronous serve: the policy, which
/// nodes are dead, where to resume, and the optional rejoin /
/// checkpoint hooks that only the multi-process runtime wires up. The
/// threaded in-process engine builds it with [`SyncServe::with_policy`];
/// the historical behavior is [`SyncServe::fail_fast`].
pub(crate) struct SyncServe<'a> {
    /// What to do when a node's recv/send fails mid-round.
    pub(crate) policy: FailurePolicy,
    /// First round to serve (> 0 after a checkpoint restart; the server
    /// opens by pushing a `SNAPSHOT` so every replica starts aligned).
    pub(crate) start_round: usize,
    /// Liveness mask by node id: dead nodes are skipped in the fold and
    /// excluded from the quorum mean. Inspected by the caller after the
    /// serve to tolerate the dead nodes' thread errors at join.
    pub(crate) dead: Vec<bool>,
    /// Cluster checkpoint sink: (path, every-N-rounds).
    pub(crate) checkpoint: Option<(std::path::PathBuf, usize)>,
    /// `WaitRejoin` hook: given (node, next_round, model), block until
    /// the node reconnects and return its fresh channel — the serve then
    /// pushes a `SNAPSHOT` before the next round. `Ok(None)` means
    /// nobody came back in time; the node stays dead and the run
    /// continues degraded.
    #[allow(clippy::type_complexity)]
    pub(crate) rejoin:
        Option<&'a mut dyn FnMut(usize, u64, &[f32]) -> Result<Option<Box<dyn Channel>>>>,
}

impl SyncServe<'_> {
    /// Today's default: the first dead peer fails the run.
    pub(crate) fn fail_fast(nodes: usize) -> SyncServe<'static> {
        SyncServe::with_policy(nodes, FailurePolicy::FailFast)
    }

    /// A serve from round 0 with all nodes live under `policy` and no
    /// rejoin/checkpoint hooks.
    pub(crate) fn with_policy(nodes: usize, policy: FailurePolicy) -> SyncServe<'static> {
        SyncServe {
            policy,
            start_round: 0,
            dead: vec![false; nodes],
            checkpoint: None,
            rejoin: None,
        }
    }
}

/// The server half of the synchronous wire protocol: `rounds`
/// node-id-ordered aggregation rounds against one channel per node,
/// then a `SHUTDOWN` drained to every worker. Exactly the simulated
/// engine's floating-point fold and accounting — the threaded engine
/// runs it against loopback/TCP ends with in-process workers, the
/// cluster runtime ([`super::cluster`]) against accepted sockets with
/// worker processes, and both reproduce [`param_server_sync`]
/// bit for bit.
///
/// Failure semantics live in `ctl` ([`SyncServe`]): under
/// [`FailurePolicy::FailFast`] any channel error aborts the serve
/// (historical behavior, bit-identical trajectories); under
/// `DropRound`/`WaitRejoin` the failing node is hung up, swapped for a
/// [`DeadChannel`], and the round completes on the surviving quorum —
/// the broadcast carries the quorum mean (values pre-scaled by
/// `1 / live`, replicas apply scale `1.0`), which with every node live
/// is bit-identical to the historical `1 / nodes` mean.
pub(crate) fn serve_sync_protocol<B: GradBackend>(
    backend: &mut B,
    ends: &mut [Box<dyn Channel>],
    x: &mut [f32],
    rounds: usize,
    eval_every: usize,
    record: &mut RunRecord,
    ctl: &mut SyncServe<'_>,
    tally: &mut SyncServerTally,
) -> Result<()> {
    let d = x.len();
    let idx_bits = crate::compress::sparse::index_bits(d);
    let mut agg: BTreeMap<u32, f32> = BTreeMap::new();
    let mut agg_dense = vec![0.0f32; d];
    let mut bc_update = Update::new_sparse(d);
    let mut w = BitWriter::new();
    // A restarted server re-syncs every replica before serving: the
    // workers' first recv is the SNAPSHOT, then round `start_round`
    // proceeds as usual.
    if ctl.start_round > 0 {
        let snap = Update::Dense(x.to_vec());
        let payload = encode_snapshot(&mut w, ctl.start_round as u64, &snap);
        for (node, ch) in ends.iter_mut().enumerate() {
            if ctl.dead[node] {
                continue;
            }
            ch.send(w.as_bytes())?;
            tally.wire_bc += payload;
            tally.wire_frames_down += w.as_bytes().len() as u64 * 8;
        }
    }
    for round in ctl.start_round..rounds {
        agg.clear();
        let mut any_dense = false;
        let mut lost: Vec<usize> = Vec::new();
        // Node-id-ordered aggregation: one blocking recv per node
        // channel, in id order — the simulated engine's exact
        // floating-point fold order (dead nodes are skipped, which
        // keeps the fold order identical to the simulated twin's
        // live-node iteration).
        for (node, ch) in ends.iter_mut().enumerate() {
            if ctl.dead[node] {
                continue;
            }
            let folded = (|| -> Result<()> {
                let frame = ch.recv()?;
                tally.wire_frames_up += frame.len() as u64 * 8;
                let dec = decode_msg(&frame, d)?;
                match dec.msg {
                    WireMsg::Upload { round: r, node: nid, accounted_bits, update }
                        if r == round as u64 && nid == node as u32 =>
                    {
                        tally.wire_up += dec.payload_bits;
                        tally.upload_acc[node] += accounted_bits;
                        // Mirrors the simulated engine's mixed-variant
                        // merge exactly: spill `agg` into `agg_dense` when
                        // the first dense upload arrives, then fold every
                        // later sparse upload directly into `agg_dense` —
                        // same per-coordinate addition order, bit for bit.
                        match update {
                            Update::Sparse(sv) => {
                                if any_dense {
                                    for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                                        agg_dense[j as usize] += vj;
                                    }
                                } else {
                                    for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                                        *agg.entry(j).or_insert(0.0) += vj;
                                    }
                                }
                            }
                            Update::Dense(g) => {
                                if !any_dense {
                                    any_dense = true;
                                    for (&j, &vj) in agg.iter() {
                                        agg_dense[j as usize] += vj;
                                    }
                                    agg.clear();
                                }
                                for (a, &gj) in agg_dense.iter_mut().zip(&g) {
                                    *a += gj;
                                }
                            }
                        }
                        Ok(())
                    }
                    other => {
                        bail!("server: unexpected {other:?} from node {node} in round {round}")
                    }
                }
            })();
            if let Err(e) = folded {
                match ctl.policy {
                    FailurePolicy::FailFast => {
                        return Err(e.push_context(format!("node {node}")));
                    }
                    _ => {
                        // The node is dead to this run: close our end
                        // (drops a loopback sender, shuts down a TCP
                        // socket — either way the peer unblocks) and
                        // park a DeadChannel in its slot. Its accepted
                        // uploads stay in the aggregate history; the
                        // mass it failed to ship lives on in whatever
                        // error memory survives on its side.
                        ch.hangup();
                        *ch = Box::new(DeadChannel::new(node));
                        ctl.dead[node] = true;
                        lost.push(node);
                    }
                }
            }
        }
        // The round mean is over the *live* quorum. With every node
        // alive `1.0 / live as f32` is bit-identical to the historical
        // `1.0 / nodes as f32`, so fault-free runs are unchanged.
        let live = ctl.dead.iter().filter(|&&dd| !dd).count();
        if live == 0 {
            bail!("round {round}: every node is dead");
        }
        if let FailurePolicy::DropRound { min_quorum } = ctl.policy {
            let quorum = min_quorum.max(1);
            if live < quorum {
                bail!("round {round}: {live} live nodes below the drop-round quorum of {quorum}");
            }
        }
        let scale = 1.0 / live as f32;
        // Frame the aggregate for the replicas, values pre-scaled by
        // the quorum mean — replicas apply scale 1.0, so they need no
        // liveness knowledge (and `v * scale * 1.0` keeps the raw-f32
        // payload bits identical to the historical unscaled frame +
        // `1 / nodes` replica apply when everyone is alive).
        if any_dense {
            match &mut bc_update {
                Update::Dense(g) => {
                    g.clear();
                    g.extend(agg_dense.iter().map(|a| a * scale));
                }
                other => *other = Update::Dense(agg_dense.iter().map(|a| a * scale).collect()),
            }
        } else {
            let sv = bc_update.sparse_mut(d);
            for (&j, &vj) in agg.iter() {
                sv.push(j, vj * scale);
            }
        }
        let payload = encode_broadcast(&mut w, round as u64, &bc_update);
        for (node, ch) in ends.iter_mut().enumerate() {
            if ctl.dead[node] {
                continue;
            }
            if let Err(e) = ch.send(w.as_bytes()) {
                match ctl.policy {
                    FailurePolicy::FailFast => return Err(e.push_context(format!("node {node}"))),
                    _ => {
                        ch.hangup();
                        *ch = Box::new(DeadChannel::new(node));
                        ctl.dead[node] = true;
                        continue;
                    }
                }
            }
            tally.wire_bc += payload;
            tally.wire_frames_down += w.as_bytes().len() as u64 * 8;
        }
        // Apply the mean update to the server iterate with the
        // simulated engine's literal expressions + accounting.
        if any_dense {
            for (xj, a) in x.iter_mut().zip(agg_dense.iter_mut()) {
                *xj -= *a * scale;
                *a = 0.0;
            }
            tally.broadcast_bits += 32 * d as u64;
        } else {
            for (&j, &vj) in agg.iter() {
                x[j as usize] -= vj * scale;
            }
            tally.broadcast_bits += agg.len() as u64 * (32 + idx_bits);
        }
        if (round + 1) % eval_every == 0 || round + 1 == rounds {
            let uploads: u64 = tally.upload_acc.iter().sum();
            record.curve.push(LossPoint {
                t: round + 1,
                bits: uploads + tally.broadcast_bits,
                loss: backend.full_loss(x),
            });
        }
        // Wait-rejoin: give every node lost this round a chance to come
        // back before the next round. The rejoined replica is re-synced
        // with a SNAPSHOT naming the round it resumes at.
        if !lost.is_empty() && matches!(ctl.policy, FailurePolicy::WaitRejoin { .. }) {
            if let Some(rejoin) = ctl.rejoin.as_mut() {
                for node in lost {
                    if let Some(mut ch) = rejoin(node, round as u64 + 1, x)? {
                        let snap = Update::Dense(x.to_vec());
                        let payload = encode_snapshot(&mut w, round as u64 + 1, &snap);
                        ch.send(w.as_bytes())?;
                        tally.wire_bc += payload;
                        tally.wire_frames_down += w.as_bytes().len() as u64 * 8;
                        ends[node] = ch;
                        ctl.dead[node] = false;
                    }
                }
            }
        }
        // Cluster checkpoint: model + round counter + liveness, written
        // atomically so a killed server restarts from here.
        if let Some((path, every)) = &ctl.checkpoint {
            let every = (*every).max(1);
            if (round + 1) % every == 0 || round + 1 == rounds {
                let ckpt = super::checkpoint::ClusterCheckpoint {
                    round: round as u64 + 1,
                    x: x.to_vec(),
                    dead: ctl.dead.clone(),
                };
                ckpt.save(path)?;
            }
        }
    }
    // Clean shutdown: drain a SHUTDOWN to every live worker so both
    // sides agree the run is over before any endpoint closes. Under a
    // lenient policy a node dying this late is recorded, not fatal.
    encode_shutdown(&mut w);
    for (node, ch) in ends.iter_mut().enumerate() {
        if ctl.dead[node] {
            continue;
        }
        match ch.send(w.as_bytes()) {
            Ok(()) => tally.wire_frames_down += w.as_bytes().len() as u64 * 8,
            Err(e) => match ctl.policy {
                FailurePolicy::FailFast => return Err(e.push_context(format!("node {node}"))),
                _ => {
                    ch.hangup();
                    *ch = Box::new(DeadChannel::new(node));
                    ctl.dead[node] = true;
                }
            },
        }
    }
    Ok(())
}

/// Fill a sync wire-engine run record from the server tallies: steps,
/// accounted totals, and the measured `wire_*` extras. Shared by the
/// threaded engine and the cluster runtime so both report identically.
pub(crate) fn finish_sync_wire_record(
    record: &mut RunRecord,
    s: &Settings,
    nodes: usize,
    rounds: usize,
    uploads: u64,
    tally: &SyncServerTally,
    started: Instant,
) {
    let h = s.local.sync_every.max(1);
    record.steps = rounds * nodes * h;
    record.total_bits = uploads + tally.broadcast_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record.extra.insert("broadcast_bits".into(), tally.broadcast_bits as f64);
    record.extra.insert("wire".into(), 1.0);
    record.extra.insert("wire_upload_payload_bits".into(), tally.wire_up as f64);
    record.extra.insert("wire_broadcast_payload_bits".into(), tally.wire_bc as f64);
    record.extra.insert("wire_upload_frame_bits".into(), tally.wire_frames_up as f64);
    record
        .extra
        .insert("wire_broadcast_frame_bits".into(), tally.wire_frames_down as f64);
    record.extra.insert(
        "wire_frame_bits".into(),
        (tally.wire_frames_up + tally.wire_frames_down) as f64,
    );
    annotate_local(record, s.local, rounds * nodes * h);
}

/// Threaded synchronous parameter server: one server (this thread) and
/// `nodes` worker threads exchanging Elias-coded wire messages over
/// `transport`. Barriered rounds with node-id-ordered aggregation keep
/// the floating-point fold — and the whole trajectory, loss curve and
/// accounted bits included — **bit-identical** to [`param_server_sync`]
/// (`tests/wire_protocol.rs`). The measured bytes that actually crossed
/// the channel land in the `wire_*` record extras.
pub(crate) fn param_server_sync_wire<B: GradBackend + Clone + Send>(
    backend: &mut B,
    nodes: usize,
    transport: &mut dyn Transport,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    // The fault plan decorates the *server-side* channel ends, so an
    // injected cut surfaces exactly where a real peer death would: in
    // the server's recv for that node.
    let plan = match &s.faults {
        Some(spec) => Some(spec.plan(nodes, rounds)?),
        None => None,
    };
    let mut root_rng = Prng::new(s.seed);

    // Channels and per-node state, created in node-id order so the RNG
    // split sequence matches the simulated engine exactly.
    let mut server_ends: Vec<Box<dyn Channel>> = Vec::with_capacity(nodes);
    let mut workers: Vec<WireWorker<B>> = Vec::with_capacity(nodes);
    for w in 0..nodes {
        let (se, we) = transport.duplex();
        let se = match &plan {
            Some(p) => p.wrap(w, se),
            None => se,
        };
        server_ends.push(se);
        workers.push(WireWorker {
            ch: we,
            backend: backend.clone(),
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
            schedule: s.schedule.clone(),
            local,
            node: w as u32,
            d,
            n,
        });
    }

    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::ParamServerSync { nodes }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let mut x = vec![0.0f32; d];
    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    let mut tally = SyncServerTally::new(nodes);
    let mut ctl = SyncServe::with_policy(nodes, s.policy);

    let worker_bits = std::thread::scope(|scope| -> Result<Vec<u64>> {
        let mut handles = Vec::with_capacity(nodes);
        for wk in workers {
            // Replicas apply scale 1.0: the broadcast values arrive
            // pre-scaled by the server's quorum mean.
            handles.push(scope.spawn(move || wk.run_sync(rounds, 1.0)));
        }

        // The server protocol. An error falls through to the drop
        // below, which releases the channel ends before the joins —
        // dropped ends turn every blocked worker `recv` into an error,
        // so shutdown can never deadlock.
        let served = serve_sync_protocol(
            backend,
            &mut server_ends,
            &mut x,
            rounds,
            eval_every,
            &mut record,
            &mut ctl,
            &mut tally,
        );
        drop(server_ends);
        join_wire_workers(handles, served, &ctl.dead)
    })?;
    let uploads = check_wire_accounting(&tally.upload_acc, &worker_bits, &ctl.dead)?;

    finish_sync_wire_record(&mut record, s, nodes, rounds, uploads, &tally, started);
    Ok(record)
}

/// Per-run tallies of the asynchronous server protocol: accounted
/// upload bits, measured wire bits split by direction, and the
/// simulated-clock state (version counter, staleness, link busy time).
/// Shared by the threaded engine and the cluster runtime.
pub(crate) struct AsyncServerTally {
    pub(crate) upload_acc: Vec<u64>,
    pub(crate) wire_up: u64,
    pub(crate) wire_apply: u64,
    pub(crate) wire_frames_up: u64,
    pub(crate) wire_frames_down: u64,
    pub(crate) version: u64,
    pub(crate) link_busy_total: u64,
    pub(crate) staleness_sum: u64,
    pub(crate) staleness_max: u64,
    pub(crate) now_ns: u64,
}

impl AsyncServerTally {
    pub(crate) fn new(nodes: usize) -> AsyncServerTally {
        AsyncServerTally {
            upload_acc: vec![0; nodes],
            wire_up: 0,
            wire_apply: 0,
            wire_frames_up: 0,
            wire_frames_down: 0,
            version: 0,
            link_busy_total: 0,
            staleness_sum: 0,
            staleness_max: 0,
            now_ns: 0,
        }
    }
}

/// The server half of the asynchronous wire protocol: the seeded
/// discrete-event heap arbitrates delivery order (`GO` → `UPLOAD` →
/// `APPLY` to every replica), the accounted bits charge the network
/// model exactly as simulated, and a `SHUTDOWN` drains to every worker
/// at the end. Shared by the threaded engine and the cluster runtime;
/// both reproduce [`param_server_async`] bit for bit.
///
/// Failure semantics: under [`FailurePolicy::FailFast`] any channel
/// error aborts the serve; otherwise the failing worker is hung up,
/// swapped for a [`DeadChannel`], removed from the event heap (its turn
/// neither advances the version nor requeues), and the run continues on
/// the survivors. `dead` is caller-owned so the join can tolerate the
/// dead nodes' thread errors.
#[allow(clippy::too_many_arguments)] // the simulated engine's state, spelled out
pub(crate) fn serve_async_protocol<B: GradBackend>(
    backend: &mut B,
    ends: &mut [Box<dyn Channel>],
    x: &mut [f32],
    net: &NetworkModel,
    compute: &ComputeModel,
    slow: &[f64],
    grads_per_sync: f64,
    total_syncs: usize,
    eval_every: usize,
    record: &mut RunRecord,
    policy: FailurePolicy,
    dead: &mut [bool],
    tally: &mut AsyncServerTally,
) -> Result<()> {
    let d = x.len();
    let compute_ns = |slow: f64, cm: &ComputeModel| -> u64 {
        (cm.s_per_coord * cm.coords_per_grad * grads_per_sync * slow * 1e9).max(1.0) as u64
    };
    let mut queue: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    for (i, &sl) in slow.iter().enumerate() {
        queue.push(Reverse(Finish { t_ns: compute_ns(sl, compute), worker: i }));
    }
    let mut fetch_version = vec![0u64; ends.len()];
    let mut link_free_ns = 0u64;
    let mut w = BitWriter::new();

    while tally.version < total_syncs as u64 {
        let Some(Reverse(ev)) = queue.pop() else {
            bail!(
                "server update {}: every worker is dead before the sync budget completed",
                tally.version
            );
        };
        tally.now_ns = tally.now_ns.max(ev.t_ns);
        if dead[ev.worker] {
            // Killed by an APPLY-send failure after its turn was already
            // queued; discard the stale event.
            continue;
        }

        // The heap names the worker; it computes one phase at
        // η(version) against its (current) replica and uploads.
        let turn = (|| -> Result<(u64, Update)> {
            encode_go(&mut w, tally.version);
            ends[ev.worker].send(w.as_bytes())?;
            tally.wire_frames_down += w.as_bytes().len() as u64 * 8;
            let frame = ends[ev.worker].recv()?;
            tally.wire_frames_up += frame.len() as u64 * 8;
            let dec = decode_msg(&frame, d)?;
            match dec.msg {
                WireMsg::Upload { round, node, accounted_bits, update }
                    if round == tally.version && node == ev.worker as u32 =>
                {
                    tally.wire_up += dec.payload_bits;
                    Ok((accounted_bits, update))
                }
                other => bail!(
                    "server: unexpected {other:?} from node {} at version {}",
                    ev.worker,
                    tally.version
                ),
            }
        })();
        let (bits, update) = match turn {
            Ok(v) => v,
            Err(e) => match policy {
                FailurePolicy::FailFast => {
                    return Err(e.push_context(format!("node {}", ev.worker)));
                }
                _ => {
                    let ch = &mut ends[ev.worker];
                    ch.hangup();
                    *ch = Box::new(DeadChannel::new(ev.worker));
                    dead[ev.worker] = true;
                    let live = dead.iter().filter(|&&dd| !dd).count();
                    if let FailurePolicy::DropRound { min_quorum } = policy {
                        let quorum = min_quorum.max(1);
                        if live < quorum {
                            bail!(
                                "server update {}: {live} live nodes below the \
                                 drop-round quorum of {quorum}",
                                tally.version
                            );
                        }
                    }
                    // The dead worker's turn neither advances the
                    // version nor requeues; the heap forgets it.
                    continue;
                }
            },
        };
        tally.upload_acc[ev.worker] += bits;

        // Identical simulated-time arithmetic: the accounted bits (not
        // the wire frame) charge the network model, exactly as in the
        // simulated engine.
        let xfer_ns = (net.xfer_s(bits) * 1e9).max(1.0) as u64;
        let latency_ns = (net.latency_s * 1e9) as u64;
        let start_ns = ev.t_ns.max(link_free_ns);
        link_free_ns = start_ns + xfer_ns;
        tally.link_busy_total += xfer_ns;
        let arrive_ns = link_free_ns + latency_ns;
        tally.now_ns = tally.now_ns.max(arrive_ns);

        // Apply on the server, then replicate to every live worker.
        update.sub_from(x);
        let payload = encode_apply(&mut w, tally.version, &update);
        for (node, ch) in ends.iter_mut().enumerate() {
            if dead[node] {
                continue;
            }
            if let Err(e) = ch.send(w.as_bytes()) {
                match policy {
                    FailurePolicy::FailFast => return Err(e.push_context(format!("node {node}"))),
                    _ => {
                        ch.hangup();
                        *ch = Box::new(DeadChannel::new(node));
                        dead[node] = true;
                        continue;
                    }
                }
            }
            tally.wire_apply += payload;
            tally.wire_frames_down += w.as_bytes().len() as u64 * 8;
        }
        tally.version += 1;
        let stale = tally.version - 1 - fetch_version[ev.worker];
        tally.staleness_sum += stale;
        tally.staleness_max = tally.staleness_max.max(stale);
        fetch_version[ev.worker] = tally.version;
        queue.push(Reverse(Finish {
            t_ns: arrive_ns + compute_ns(slow[ev.worker], compute),
            worker: ev.worker,
        }));

        if tally.version % eval_every as u64 == 0 || tally.version == total_syncs as u64 {
            let bits: u64 = tally.upload_acc.iter().sum();
            record.curve.push(LossPoint {
                t: tally.version as usize,
                bits,
                loss: backend.full_loss(x),
            });
        }
    }
    encode_shutdown(&mut w);
    for (node, ch) in ends.iter_mut().enumerate() {
        if dead[node] {
            continue;
        }
        match ch.send(w.as_bytes()) {
            Ok(()) => tally.wire_frames_down += w.as_bytes().len() as u64 * 8,
            Err(e) => match policy {
                FailurePolicy::FailFast => return Err(e.push_context(format!("node {node}"))),
                _ => {
                    ch.hangup();
                    *ch = Box::new(DeadChannel::new(node));
                    dead[node] = true;
                }
            },
        }
    }
    Ok(())
}

/// Fill an async wire-engine run record from the server tallies —
/// simulated-time metrics included. Shared by the threaded engine and
/// the cluster runtime so both report identically.
pub(crate) fn finish_async_wire_record(
    record: &mut RunRecord,
    s: &Settings,
    nodes: usize,
    total_bits: u64,
    tally: &AsyncServerTally,
    started: Instant,
) {
    let h = s.local.sync_every.max(1);
    record.steps = tally.version as usize * h;
    record.total_bits = total_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    let mean_staleness = tally.staleness_sum as f64 / tally.version.max(1) as f64;
    let sim_seconds = tally.now_ns as f64 / 1e9;
    let link_utilization = if tally.now_ns > 0 {
        (tally.link_busy_total as f64 / tally.now_ns as f64).min(1.0)
    } else {
        0.0
    };
    record.extra.insert("mean_staleness".into(), mean_staleness);
    record.extra.insert("max_staleness".into(), tally.staleness_max as f64);
    record.extra.insert("sim_seconds".into(), sim_seconds);
    record.extra.insert("link_utilization".into(), link_utilization);
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("wire".into(), 1.0);
    record.extra.insert("wire_upload_payload_bits".into(), tally.wire_up as f64);
    record
        .extra
        .insert("wire_broadcast_payload_bits".into(), tally.wire_apply as f64);
    record.extra.insert("wire_upload_frame_bits".into(), tally.wire_frames_up as f64);
    record
        .extra
        .insert("wire_broadcast_frame_bits".into(), tally.wire_frames_down as f64);
    record.extra.insert(
        "wire_frame_bits".into(),
        (tally.wire_frames_up + tally.wire_frames_down) as f64,
    );
    annotate_local(record, s.local, tally.version as usize * h);
}

/// Threaded asynchronous parameter server: the simulated engine's
/// seeded discrete-event heap stays on the server as the
/// delivery-order arbiter — it decides which worker computes next and
/// in what order uploads reach the model — while the compute itself
/// runs on worker threads against replicas kept current by `Apply`
/// messages. Simulated-time results (staleness, link utilization,
/// `sim_seconds`) and the trajectory are **bit-identical** to
/// [`param_server_async`]; the bytes that actually crossed the channel
/// land in the `wire_*` record extras.
#[allow(clippy::too_many_arguments)] // mirrors the simulated engine's signature + transport
pub(crate) fn param_server_async_wire<B: GradBackend + Clone + Send>(
    backend: &mut B,
    nodes: usize,
    net: &NetworkModel,
    compute: &ComputeModel,
    hetero: f64,
    transport: &mut dyn Transport,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let grads_per_sync = (local.batch.max(1) * h) as f64;
    let total_syncs = s.steps / h;
    // Same per-worker-turn plan shape as the simulated twin.
    let plan = match &s.faults {
        Some(spec) => Some(spec.plan(nodes, (total_syncs / nodes).max(2))?),
        None => None,
    };
    let mut root_rng = Prng::new(s.seed);

    let mut server_ends: Vec<Box<dyn Channel>> = Vec::with_capacity(nodes);
    let mut workers: Vec<WireWorker<B>> = Vec::with_capacity(nodes);
    let mut slow = Vec::with_capacity(nodes);
    for w in 0..nodes {
        let (se, we) = transport.duplex();
        let se = match &plan {
            Some(p) => p.wrap(w, se),
            None => se,
        };
        server_ends.push(se);
        workers.push(WireWorker {
            ch: we,
            backend: backend.clone(),
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
            schedule: s.schedule.clone(),
            local,
            node: w as u32,
            d,
            n,
        });
        slow.push(
            1.0 + if nodes > 1 {
                hetero * w as f64 / (nodes - 1) as f64
            } else {
                0.0
            },
        );
    }

    let mut record = RunRecord {
        method: record_method_name(
            &s.method,
            &Topology::ParamServerAsync { nodes, net: net.clone() },
        ),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let mut x = vec![0.0f32; d];
    let eval_every = (total_syncs / s.eval_points.max(1)).max(1);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    let mut tally = AsyncServerTally::new(nodes);
    let mut dead = vec![false; nodes];
    let worker_bits = std::thread::scope(|scope| -> Result<Vec<u64>> {
        let mut handles = Vec::with_capacity(nodes);
        for wk in workers {
            handles.push(scope.spawn(move || wk.run_async()));
        }
        let served = serve_async_protocol(
            backend,
            &mut server_ends,
            &mut x,
            net,
            compute,
            &slow,
            grads_per_sync,
            total_syncs,
            eval_every,
            &mut record,
            s.policy,
            &mut dead,
            &mut tally,
        );
        // Drop the server ends either way so blocked workers error out
        // instead of hanging the join.
        drop(server_ends);
        join_wire_workers(handles, served, &dead)
    })?;
    let total_bits = check_wire_accounting(&tally.upload_acc, &worker_bits, &dead)?;
    finish_async_wire_record(&mut record, s, nodes, total_bits, &tally, started);
    Ok(record)
}

// ---------------------------------------------------------------------------
// Server-free wire engines: threaded ring all-reduce and gossip
// ---------------------------------------------------------------------------

/// Generic join for server-free node threads (the
/// [`join_wire_workers`] contract for outcome types richer than a bit
/// count): `primary` — the driver's own protocol outcome — keeps error
/// priority, then node errors and panics surface with the failing node
/// named. `first_node` offsets the reported ids (the ring driver is
/// node 0, so its thread peers start at 1).
fn join_node_outcomes<T>(
    handles: Vec<std::thread::ScopedJoinHandle<'_, Result<T>>>,
    primary: Result<()>,
    first_node: usize,
) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(handles.len());
    let mut node_err: Option<anyhow::Error> = None;
    for (i, hd) in handles.into_iter().enumerate() {
        let node = first_node + i;
        match hd.join() {
            Ok(Ok(v)) => out.push(v),
            Ok(Err(e)) => {
                if node_err.is_none() {
                    node_err = Some(anyhow::anyhow!("node {node}: {e:#}"));
                }
            }
            Err(_) => {
                if node_err.is_none() {
                    node_err = Some(anyhow::anyhow!("node {node} panicked"));
                }
            }
        }
    }
    primary?;
    if let Some(e) = node_err {
        return Err(e);
    }
    Ok(out)
}

/// Per-node state of a threaded all-reduce ring node (nodes
/// `1..nodes`; node 0 is the recording driver on the engine thread —
/// [`run_ring_driver`]). The multi-process cluster runtime
/// ([`super::cluster`]) builds the same state around accepted/connected
/// TCP sockets and runs the same protocol loop.
pub(crate) struct RingNode<B> {
    /// Recv side: frames from node `node − 1`.
    pub(crate) left: Box<dyn Channel>,
    /// Send side: frames to node `(node + 1) % nodes`.
    pub(crate) right: Box<dyn Channel>,
    pub(crate) backend: B,
    pub(crate) ef: ErrorFeedbackStep,
    pub(crate) rng: Prng,
    pub(crate) schedule: Schedule,
    pub(crate) local: LocalUpdate,
    pub(crate) node: u32,
    pub(crate) nodes: usize,
    pub(crate) d: usize,
    pub(crate) n: usize,
}

/// What a ring node reports at join: its accounted sync bits, the
/// closed-form cost of the hops it sent, and the frame/payload bits it
/// measured — the driver reconciles all of it against the header
/// tallies.
#[derive(Default)]
pub(crate) struct RingOutcome {
    pub(crate) acc_bits: u64,
    pub(crate) hop_bits: u64,
    pub(crate) reduce_frame_bits: u64,
    pub(crate) gather_frame_bits: u64,
    pub(crate) reduce_payload_bits: u64,
    pub(crate) gather_payload_bits: u64,
}

impl<B: GradBackend> RingNode<B> {
    /// The non-driver ring protocol, per round: phase, fold the
    /// incoming `REDUCE` partial with this node's own sync, forward the
    /// partial (or, as the last node, originate the `GATHER`), then
    /// apply the round aggregate. Ring teardown is by endpoint drop —
    /// an error anywhere cascades as "channel closed" along the ring,
    /// so no node can hang on a dead peer.
    pub(crate) fn run(mut self, rounds: usize, scale: f32) -> Result<RingOutcome> {
        let me = self.node as usize;
        let last = me == self.nodes - 1;
        let idx_bits = crate::compress::sparse::index_bits(self.d);
        let mut x = vec![0.0f32; self.d];
        let mut ws = WorkerScratch::new(self.d, self.n, self.local);
        let mut w = BitWriter::new();
        let mut partial = RingPartial::new(self.d);
        let mut out = RingOutcome::default();
        for round in 0..rounds {
            let etaf = self.schedule.eta(round) as f32;
            let bits = ws.phase(&mut self.backend, &mut self.ef, &mut self.rng, &mut x, |_| etaf);
            let frame = self.left.recv()?;
            let dec = decode_msg(&frame, self.d)?;
            let (acc_sum, hops_in) = match dec.msg {
                WireMsg::Reduce { round: r, node, accounted_bits, hop_bits, update }
                    if r == round as u64 && node as usize + 1 == me =>
                {
                    out.reduce_payload_bits += dec.payload_bits;
                    partial.begin();
                    partial.fold(&update);
                    partial.fold(self.ef.update());
                    (accounted_bits + bits, hop_bits)
                }
                other => bail!("node {me}: unexpected {other:?} in round {round}"),
            };
            if last {
                // The fold is complete: originate the GATHER carrying
                // the round's accounted-bit sum and reduce hop total.
                let agg_cost = partial.cost_bits(idx_bits);
                encode_gather(&mut w, round as u64, acc_sum, hops_in, partial.fill_update());
                self.right.send(w.as_bytes())?;
                out.hop_bits += agg_cost;
                out.gather_frame_bits += w.as_bytes().len() as u64 * 8;
                partial.apply(scale, &mut x);
            } else {
                let hop = partial.cost_bits(idx_bits);
                encode_reduce(
                    &mut w,
                    round as u64,
                    self.node,
                    acc_sum,
                    hops_in + hop,
                    partial.fill_update(),
                );
                self.right.send(w.as_bytes())?;
                out.hop_bits += hop;
                out.reduce_frame_bits += w.as_bytes().len() as u64 * 8;
                // Wait for the completed aggregate to come around
                // (origin: node nodes−1, forwarded 0 → 1 → … → nodes−2).
                let frame = self.left.recv()?;
                let dec = decode_msg(&frame, self.d)?;
                match dec.msg {
                    WireMsg::Gather { round: r, update, .. } if r == round as u64 => {
                        out.gather_payload_bits += dec.payload_bits;
                        if me + 2 < self.nodes {
                            // Forward the frame verbatim so every hop
                            // transmits identical bytes.
                            self.right.send(&frame)?;
                            out.hop_bits += update_cost_bits(&update, self.d, idx_bits);
                            out.gather_frame_bits += frame.len() as u64 * 8;
                        }
                        update.sub_scaled_from(scale, &mut x);
                    }
                    other => bail!("node {me}: unexpected {other:?} in round {round}"),
                }
            }
        }
        out.acc_bits = self.ef.bits_sent;
        Ok(out)
    }
}

/// The driver-side tallies of a ring run: header-carried sums (for the
/// loss curve and the join-time reconciliation) plus the driver's own
/// [`RingOutcome`].
pub(crate) struct RingDriverTally {
    /// Σ `GATHER.accounted_bits` over rounds — every node's accounted
    /// sync bits, carried around the ring.
    pub(crate) gather_acc: u64,
    /// Σ `GATHER.hop_bits` — the closed-form reduce-phase cost.
    pub(crate) reduce_bits: u64,
    /// `(nodes − 1) · cost(aggregate)` per round — the gather cost,
    /// recomputed from the decoded aggregate.
    pub(crate) gather_bits: u64,
    /// The driver's own sends/receives.
    pub(crate) own: RingOutcome,
}

impl RingDriverTally {
    pub(crate) fn new() -> RingDriverTally {
        RingDriverTally {
            gather_acc: 0,
            reduce_bits: 0,
            gather_bits: 0,
            own: RingOutcome::default(),
        }
    }
}

/// The driver (node 0) half of the ring protocol: phases like any
/// other node, originates each round's `REDUCE`, receives the `GATHER`
/// from the last node (forwarding it on rings of more than two nodes),
/// applies the mean, and records the loss curve with the simulated
/// engine's exact bit accounting (reconstructed from the header
/// tallies). `ring` is `None` only for a single-node run, where
/// nothing crosses a wire. Shared by the threaded engine and the
/// multi-process cluster runtime.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_ring_driver<B: GradBackend>(
    backend: &mut B,
    mut ring: Option<(&mut dyn Channel, &mut dyn Channel)>,
    ef: &mut ErrorFeedbackStep,
    rng: &mut Prng,
    schedule: &Schedule,
    local: LocalUpdate,
    nodes: usize,
    rounds: usize,
    eval_every: usize,
    x: &mut [f32],
    record: &mut RunRecord,
    tally: &mut RingDriverTally,
) -> Result<()> {
    let d = x.len();
    let idx_bits = crate::compress::sparse::index_bits(d);
    let scale = 1.0 / nodes as f32;
    let mut ws = WorkerScratch::new(d, backend.n(), local);
    let mut w = BitWriter::new();
    let mut partial = RingPartial::new(d);
    for round in 0..rounds {
        let etaf = schedule.eta(round) as f32;
        let bits = ws.phase(backend, ef, rng, x, |_| etaf);
        partial.begin();
        partial.fold(ef.update());
        if let Some((left, right)) = ring.as_mut() {
            let hop = partial.cost_bits(idx_bits);
            encode_reduce(&mut w, round as u64, 0, bits, hop, partial.fill_update());
            right
                .send(w.as_bytes())
                .map_err(|e| e.push_context("driver: REDUCE send to node 1"))?;
            tally.own.hop_bits += hop;
            tally.own.reduce_frame_bits += w.as_bytes().len() as u64 * 8;
            let frame = left
                .recv()
                .map_err(|e| e.push_context(format!("driver: GATHER recv from node {}", nodes - 1)))?;
            let dec = decode_msg(&frame, d)?;
            match dec.msg {
                WireMsg::Gather { round: r, accounted_bits, hop_bits, update }
                    if r == round as u64 =>
                {
                    tally.own.gather_payload_bits += dec.payload_bits;
                    tally.gather_acc += accounted_bits;
                    tally.reduce_bits += hop_bits;
                    let agg_cost = update_cost_bits(&update, d, idx_bits);
                    tally.gather_bits += (nodes as u64 - 1) * agg_cost;
                    if nodes > 2 {
                        right
                            .send(&frame)
                            .map_err(|e| e.push_context("driver: GATHER forward to node 1"))?;
                        tally.own.hop_bits += agg_cost;
                        tally.own.gather_frame_bits += frame.len() as u64 * 8;
                    }
                    update.sub_scaled_from(scale, x);
                }
                other => bail!("driver: unexpected {other:?} in round {round}"),
            }
        } else {
            // Single node: the degenerate ring — nothing transmits.
            tally.gather_acc += bits;
            partial.apply(scale, x);
        }
        if (round + 1) % eval_every == 0 || round + 1 == rounds {
            record.curve.push(LossPoint {
                t: round + 1,
                bits: tally.reduce_bits + tally.gather_bits,
                loss: backend.full_loss(x),
            });
        }
    }
    Ok(())
}

/// Threaded ring all-reduce: node 0 (the recorder) on this thread and
/// `nodes − 1` worker threads, every partial serialized through the
/// payload codec and carried one directed ring edge at a time.
/// Trajectory, loss curve, accounted bits, and every extra are
/// **bit-identical** to [`all_reduce`] (`tests/allreduce_gossip.rs`);
/// measured bytes land in the `wire_*` extras. All ring traffic is
/// sent from the `server` end of each duplex, so a
/// [`super::transport::CountingTransport`] attributes it to its
/// broadcast counter.
pub(crate) fn all_reduce_wire<B: GradBackend + Clone + Send>(
    backend: &mut B,
    nodes: usize,
    transport: &mut dyn Transport,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    let scale = 1.0 / nodes as f32;
    let mut root_rng = Prng::new(s.seed);

    // One duplex per directed ring edge i → (i+1) % nodes, created in
    // edge order; the sender keeps the server end.
    let mut send_to_next: Vec<Option<Box<dyn Channel>>> = (0..nodes).map(|_| None).collect();
    let mut recv_from_prev: Vec<Option<Box<dyn Channel>>> = (0..nodes).map(|_| None).collect();
    if nodes > 1 {
        for i in 0..nodes {
            let (se, we) = transport.duplex();
            send_to_next[i] = Some(se);
            recv_from_prev[(i + 1) % nodes] = Some(we);
        }
    }
    // Node state in node-id order so the RNG split sequence matches the
    // simulated engine exactly (driver = node 0 = split(1)).
    let mut driver_ef = s.method.error_feedback(d);
    let mut driver_rng = root_rng.split(1);
    let mut ring_nodes: Vec<RingNode<B>> = Vec::with_capacity(nodes.saturating_sub(1));
    for w in 1..nodes {
        ring_nodes.push(RingNode {
            left: recv_from_prev[w].take().expect("ring edge"),
            right: send_to_next[w].take().expect("ring edge"),
            backend: backend.clone(),
            ef: s.method.error_feedback(d),
            rng: root_rng.split(w as u64 + 1),
            schedule: s.schedule.clone(),
            local,
            node: w as u32,
            nodes,
            d,
            n,
        });
    }

    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::AllReduce { nodes }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let mut x = vec![0.0f32; d];
    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&x) });

    let mut tally = RingDriverTally::new();
    let outcomes = std::thread::scope(|scope| -> Result<Vec<RingOutcome>> {
        let mut handles = Vec::with_capacity(ring_nodes.len());
        for nd in ring_nodes {
            handles.push(scope.spawn(move || nd.run(rounds, scale)));
        }
        let mut left = recv_from_prev[0].take();
        let mut right = send_to_next[0].take();
        let ring = match (left.as_deref_mut(), right.as_deref_mut()) {
            (Some(l), Some(r)) => Some((l, r)),
            _ => None,
        };
        let served = run_ring_driver(
            backend,
            ring,
            &mut driver_ef,
            &mut driver_rng,
            &s.schedule,
            local,
            nodes,
            rounds,
            eval_every,
            &mut x,
            &mut record,
            &mut tally,
        );
        // Drop the driver's endpoints either way: a failure cascades as
        // "channel closed" around the ring instead of hanging the join.
        drop(left);
        drop(right);
        join_node_outcomes(handles, served, 1)
    })?;

    // Accounted-vs-header reconciliation (the ring analog of
    // `check_wire_accounting`): every node's sync accounting must match
    // what the GATHER headers carried, and every hop's closed-form cost
    // must match what the headers/aggregates tallied.
    let reported_acc = driver_ef.bits_sent + outcomes.iter().map(|o| o.acc_bits).sum::<u64>();
    if tally.gather_acc != reported_acc {
        bail!(
            "wire protocol desync: nodes counted {reported_acc} accounted sync bits, \
             gather headers tallied {}",
            tally.gather_acc
        );
    }
    let sent_hops = tally.own.hop_bits + outcomes.iter().map(|o| o.hop_bits).sum::<u64>();
    if sent_hops != tally.reduce_bits + tally.gather_bits {
        bail!(
            "wire protocol desync: ring hops sent {sent_hops} closed-form bits, \
             headers tallied {}",
            tally.reduce_bits + tally.gather_bits
        );
    }

    let reduce_payload: u64 = outcomes.iter().map(|o| o.reduce_payload_bits).sum();
    let gather_payload: u64 = tally.own.gather_payload_bits
        + outcomes.iter().map(|o| o.gather_payload_bits).sum::<u64>();
    let reduce_frames: u64 =
        tally.own.reduce_frame_bits + outcomes.iter().map(|o| o.reduce_frame_bits).sum::<u64>();
    let gather_frames: u64 =
        tally.own.gather_frame_bits + outcomes.iter().map(|o| o.gather_frame_bits).sum::<u64>();

    record.steps = rounds * nodes * h;
    record.total_bits = tally.reduce_bits + tally.gather_bits;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), reported_acc as f64);
    record.extra.insert("reduce_bits".into(), tally.reduce_bits as f64);
    record.extra.insert("gather_bits".into(), tally.gather_bits as f64);
    record.extra.insert("wire".into(), 1.0);
    record.extra.insert("wire_reduce_payload_bits".into(), reduce_payload as f64);
    record.extra.insert("wire_gather_payload_bits".into(), gather_payload as f64);
    record.extra.insert("wire_reduce_frame_bits".into(), reduce_frames as f64);
    record.extra.insert("wire_gather_frame_bits".into(), gather_frames as f64);
    record
        .extra
        .insert("wire_frame_bits".into(), (reduce_frames + gather_frames) as f64);
    annotate_local(&mut record, local, rounds * nodes * h);
    Ok(record)
}

/// Per-node state of a threaded gossip node. Every node holds one edge
/// channel per potential partner plus a monitor channel to the
/// recording driver; the matching schedule is replayed locally from
/// `match_rng` (a clone of the topology stream), so rounds need no
/// coordination traffic at all.
pub(crate) struct GossipNode<B> {
    /// Edge channels indexed by partner node id (`None` at own index).
    pub(crate) peers: Vec<Option<Box<dyn Channel>>>,
    /// Channel to the recording driver (`REPORT` frames at eval rounds).
    pub(crate) monitor: Box<dyn Channel>,
    pub(crate) backend: B,
    pub(crate) ef: ErrorFeedbackStep,
    pub(crate) rng: Prng,
    pub(crate) match_rng: Prng,
    pub(crate) schedule: Schedule,
    pub(crate) local: LocalUpdate,
    pub(crate) graph: GossipGraph,
    pub(crate) node: u32,
    pub(crate) nodes: usize,
    pub(crate) d: usize,
    pub(crate) n: usize,
}

/// What a gossip node reports at join; the driver reconciles
/// `transmitted_bits` against the node's final `REPORT` header.
#[derive(Default)]
pub(crate) struct GossipOutcome {
    pub(crate) acc_bits: u64,
    pub(crate) transmitted_bits: u64,
    pub(crate) self_sync_bits: u64,
    pub(crate) exchange_payload_bits: u64,
    pub(crate) exchange_frame_bits: u64,
    pub(crate) report_frame_bits: u64,
}

impl<B: GradBackend> GossipNode<B> {
    /// The gossip node protocol, per round: phase, replay the round's
    /// matching, exchange compressed syncs with the matched partner
    /// (both send, then both receive — frames are small and the fabric
    /// buffers, so no deadlock), fold lower-id-first, apply the pair
    /// mean; unmatched rounds apply the own sync alone. At eval rounds
    /// the node `REPORT`s its dense iterate to the driver.
    pub(crate) fn run(mut self, rounds: usize, eval_every: usize) -> Result<GossipOutcome> {
        let me = self.node as usize;
        let mut x = vec![0.0f32; self.d];
        let mut ws = WorkerScratch::new(self.d, self.n, self.local);
        let mut w = BitWriter::new();
        let mut partial = RingPartial::new(self.d);
        let mut perm = Vec::new();
        let mut pairs = Vec::new();
        let mut report = Update::new_dense(self.d);
        let mut out = GossipOutcome::default();
        for round in 0..rounds {
            let etaf = self.schedule.eta(round) as f32;
            let bits = ws.phase(&mut self.backend, &mut self.ef, &mut self.rng, &mut x, |_| etaf);
            let unpaired =
                gossip_matching(self.graph, self.nodes, &mut self.match_rng, &mut perm, &mut pairs);
            if unpaired == Some(me) {
                self.ef.update().sub_from(&mut x);
                out.self_sync_bits += bits;
            } else {
                let &(a, b) = pairs
                    .iter()
                    .find(|&&(a, b)| a == me || b == me)
                    .expect("every non-unpaired node is matched");
                let partner = if a == me { b } else { a };
                encode_exchange(
                    &mut w,
                    round as u64,
                    self.node,
                    bits,
                    self.ef.compressor(),
                    self.ef.update(),
                );
                let ch = self.peers[partner].as_mut().expect("edge channel for partner");
                ch.send(w.as_bytes())
                    .map_err(|e| anyhow::anyhow!("exchange send to node {partner}: {e:#}"))?;
                out.exchange_frame_bits += w.as_bytes().len() as u64 * 8;
                out.transmitted_bits += bits;
                let frame = ch
                    .recv()
                    .map_err(|e| anyhow::anyhow!("exchange recv from node {partner}: {e:#}"))?;
                let dec = decode_msg(&frame, self.d)?;
                match dec.msg {
                    WireMsg::Exchange { round: r, node, update, .. }
                        if r == round as u64 && node as usize == partner =>
                    {
                        out.exchange_payload_bits += dec.payload_bits;
                        partial.begin();
                        if me == a {
                            partial.fold(self.ef.update());
                            partial.fold(&update);
                        } else {
                            partial.fold(&update);
                            partial.fold(self.ef.update());
                        }
                        partial.apply(0.5, &mut x);
                    }
                    other => bail!(
                        "unexpected {other:?} from partner {partner} in round {round}"
                    ),
                }
            }
            if (round + 1) % eval_every == 0 || round + 1 == rounds {
                match &mut report {
                    Update::Dense(g) => {
                        g.clear();
                        g.extend_from_slice(&x);
                    }
                    other => *other = Update::Dense(x.clone()),
                }
                encode_report(&mut w, round as u64, self.node, out.transmitted_bits, &report);
                self.monitor.send(w.as_bytes())?;
                out.report_frame_bits += w.as_bytes().len() as u64 * 8;
            }
        }
        out.acc_bits = self.ef.bits_sent;
        Ok(out)
    }
}

/// Threaded gossip: `nodes` worker threads with private iterates, a
/// driver on this thread that only listens — each node replays the
/// matching schedule locally and `REPORT`s its iterate at eval rounds,
/// where the driver folds the node-mean in node-id order and records
/// the loss. Trajectory, curve, accounted bits, and every extra are
/// **bit-identical** to [`gossip`] on both transports
/// (`tests/allreduce_gossip.rs`).
pub(crate) fn gossip_wire<B: GradBackend + Clone + Send>(
    backend: &mut B,
    nodes: usize,
    graph: GossipGraph,
    transport: &mut dyn Transport,
    s: &Settings,
) -> Result<RunRecord> {
    let nodes = nodes.max(1);
    let d = backend.dim();
    let n = backend.n();
    let local = s.local;
    let h = local.sync_every.max(1);
    let rounds = (s.steps / (nodes * h)).max(1);
    let mut root_rng = Prng::new(s.seed);

    // Edge channels for every pair (a, b), a < b, in lexicographic
    // order — the lower-id node keeps the server end. The matching
    // never needs more than these.
    let mut peer_ends: Vec<Vec<Option<Box<dyn Channel>>>> =
        (0..nodes).map(|_| (0..nodes).map(|_| None).collect()).collect();
    for a in 0..nodes {
        for b in (a + 1)..nodes {
            let (se, we) = transport.duplex();
            peer_ends[a][b] = Some(se);
            peer_ends[b][a] = Some(we);
        }
    }
    // Monitors + per-node RNG streams in node-id order; the topology
    // stream is split after every worker stream (the gossip RNG
    // contract), then cloned into each node for local replay.
    let mut monitors: Vec<Box<dyn Channel>> = Vec::with_capacity(nodes);
    let mut node_parts: Vec<(Vec<Option<Box<dyn Channel>>>, Box<dyn Channel>, Prng)> =
        Vec::with_capacity(nodes);
    for (w_id, peers) in peer_ends.into_iter().enumerate() {
        let (drv_end, node_end) = transport.duplex();
        monitors.push(drv_end);
        node_parts.push((peers, node_end, root_rng.split(w_id as u64 + 1)));
    }
    let match_rng = root_rng.split(nodes as u64 + 1);
    let mut gossip_nodes: Vec<GossipNode<B>> = Vec::with_capacity(nodes);
    for (w_id, (peers, monitor, rng)) in node_parts.into_iter().enumerate() {
        gossip_nodes.push(GossipNode {
            peers,
            monitor,
            backend: backend.clone(),
            ef: s.method.error_feedback(d),
            rng,
            match_rng: match_rng.clone(),
            schedule: s.schedule.clone(),
            local,
            graph,
            node: w_id as u32,
            nodes,
            d,
            n,
        });
    }

    let mut record = RunRecord {
        method: record_method_name(&s.method, &Topology::Gossip { nodes, graph }),
        dataset: s.dataset.clone(),
        schedule: s.schedule.describe(),
        ..Default::default()
    };
    let started = Instant::now();
    let mut xbar = vec![0.0f32; d];
    let eval_every = (rounds / s.eval_points.max(1)).max(1);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: backend.full_loss(&xbar) });

    let mut report_acc = vec![0u64; nodes];
    let mut report_payload = 0u64;
    let outcomes = std::thread::scope(|scope| -> Result<Vec<GossipOutcome>> {
        let mut handles = Vec::with_capacity(nodes);
        for nd in gossip_nodes {
            handles.push(scope.spawn(move || nd.run(rounds, eval_every)));
        }
        // The driver only listens: at every eval round it folds the
        // reported iterates into the node-mean (node-id order — the
        // simulated engine's exact expressions) and records the loss.
        let served = (|| -> Result<()> {
            for round in 0..rounds {
                if (round + 1) % eval_every == 0 || round + 1 == rounds {
                    xbar.iter_mut().for_each(|v| *v = 0.0);
                    for (node, mon) in monitors.iter_mut().enumerate() {
                        let frame = mon.recv().map_err(|e| {
                            e.push_context(format!("driver: REPORT recv from node {node}"))
                        })?;
                        let dec = decode_msg(&frame, d)?;
                        match dec.msg {
                            WireMsg::Report { round: r, node: nid, accounted_bits, update }
                                if r == round as u64 && nid == node as u32 =>
                            {
                                report_payload += dec.payload_bits;
                                report_acc[node] = accounted_bits;
                                match update {
                                    Update::Dense(g) => {
                                        for (sm, &xi) in xbar.iter_mut().zip(&g) {
                                            *sm += xi;
                                        }
                                    }
                                    other => bail!(
                                        "driver: REPORT payload must be dense, got {other:?}"
                                    ),
                                }
                            }
                            other => bail!(
                                "driver: unexpected {other:?} from node {node} in round {round}"
                            ),
                        }
                    }
                    let ns = 1.0 / nodes as f32;
                    xbar.iter_mut().for_each(|v| *v *= ns);
                    record.curve.push(LossPoint {
                        t: round + 1,
                        bits: report_acc.iter().sum(),
                        loss: backend.full_loss(&xbar),
                    });
                }
            }
            Ok(())
        })();
        // Drop the monitor ends either way so a node blocked on a
        // report send errors out instead of hanging the join.
        drop(monitors);
        join_node_outcomes(handles, served, 0)
    })?;

    // Per-node reconciliation: each node's final REPORT header carried
    // its cumulative transmitted accounting — it must equal what the
    // node reported at join.
    for (node, (hdr, o)) in report_acc.iter().zip(&outcomes).enumerate() {
        if *hdr != o.transmitted_bits {
            bail!(
                "wire protocol desync: node {node} reported {} transmitted bits, \
                 report headers tallied {hdr}",
                o.transmitted_bits
            );
        }
    }

    let transmitted: u64 = outcomes.iter().map(|o| o.transmitted_bits).sum();
    let uploads: u64 = outcomes.iter().map(|o| o.acc_bits).sum();
    let self_bits: u64 = outcomes.iter().map(|o| o.self_sync_bits).sum();
    let exch_payload: u64 = outcomes.iter().map(|o| o.exchange_payload_bits).sum();
    let exch_frames: u64 = outcomes.iter().map(|o| o.exchange_frame_bits).sum();
    let report_frames: u64 = outcomes.iter().map(|o| o.report_frame_bits).sum();

    record.steps = rounds * nodes * h;
    record.total_bits = transmitted;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    record.extra.insert("workers".into(), nodes as f64);
    record.extra.insert("upload_bits".into(), uploads as f64);
    record.extra.insert("self_sync_bits".into(), self_bits as f64);
    record.extra.insert("wire".into(), 1.0);
    record.extra.insert("wire_exchange_payload_bits".into(), exch_payload as f64);
    record.extra.insert("wire_report_payload_bits".into(), report_payload as f64);
    record.extra.insert("wire_exchange_frame_bits".into(), exch_frames as f64);
    record.extra.insert("wire_report_frame_bits".into(), report_frames as f64);
    record
        .extra
        .insert("wire_frame_bits".into(), (exch_frames + report_frames) as f64);
    annotate_local(&mut record, local, rounds * nodes * h);
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::models::LogisticModel;

    fn data() -> crate::data::Dataset {
        synthetic::epsilon_like(300, 16, 5)
    }

    #[test]
    fn builder_runs_sequential_by_default() {
        let data = data();
        let rec = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.5))
            .steps(2_000)
            .eval_points(4)
            .seed(7)
            .average(false)
            .run()
            .unwrap();
        assert_eq!(rec.method, "memsgd(top_2)");
        assert_eq!(rec.steps, 2_000);
        assert!(rec.final_loss() < 0.69, "loss {}", rec.final_loss());
        assert!(rec.total_bits > 0);
    }

    #[test]
    fn local_update_schedule_divides_syncs() {
        let data = data();
        let run = |local: LocalUpdate| {
            Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
                .method(MethodSpec::mem_top_k(1))
                .schedule(Schedule::constant(0.5))
                .steps(1_200)
                .eval_points(3)
                .average(false)
                .seed(3)
                .local_update(local)
                .run()
                .unwrap()
        };
        let base = run(LocalUpdate::default());
        let h4 = run(LocalUpdate::new(1, 4).unwrap());
        assert_eq!(base.steps, 1_200);
        assert_eq!(h4.steps, 1_200);
        // top-1 sends exactly one coordinate per sync, so H = 4 means
        // exactly 4x fewer syncs and 4x fewer bits at the same budget.
        assert_eq!(base.total_bits, 4 * h4.total_bits);
        assert_eq!(h4.extra["sync_every"], 4.0);
        assert!(!base.extra.contains_key("sync_every"), "default schedule stays unannotated");
        // Minibatching alone keeps the sync count (and hence the bits).
        let b8 = run(LocalUpdate::new(8, 1).unwrap());
        assert_eq!(base.total_bits, b8.total_bits);
        assert_eq!(b8.extra["batch"], 8.0);
        assert_eq!(b8.extra["grad_samples"], 9_600.0);
        assert!(b8.final_loss().is_finite());
    }

    #[test]
    fn wire_requires_a_message_passing_topology_and_run() {
        let data = data();
        let err = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .topology(Topology::SharedMemory { workers: 2 })
            .wire(true)
            .run()
            .unwrap_err();
        assert!(format!("{err:#}").contains("message-passing"), "{err:#}");
        let err = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .topology(Topology::ParamServerSync { nodes: 2 })
            .wire(true)
            .run_single_threaded()
            .unwrap_err();
        assert!(format!("{err:#}").contains("worker threads"), "{err:#}");
    }

    #[test]
    fn wire_sync_smoke_matches_simulated_record() {
        // The full MethodSpec × LocalUpdate matrix lives in
        // tests/wire_protocol.rs; this is the in-crate canary.
        let data = data();
        let run = |wire: bool| {
            Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
                .method(MethodSpec::mem_top_k(2))
                .schedule(Schedule::constant(0.5))
                .topology(Topology::ParamServerSync { nodes: 3 })
                .steps(600)
                .eval_points(4)
                .seed(11)
                .wire(wire)
                .run()
                .unwrap()
        };
        let sim = run(false);
        let wired = run(true);
        assert_eq!(sim.curve, wired.curve, "trajectory diverged");
        assert_eq!(sim.total_bits, wired.total_bits);
        assert_eq!(sim.steps, wired.steps);
        assert_eq!(wired.extra["wire"], 1.0);
        assert!(wired.extra["wire_frame_bits"] > 0.0);
    }

    #[test]
    fn run_sequential_rejects_multi_worker_topologies() {
        let data = data();
        let err = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .topology(Topology::SharedMemory { workers: 2 })
            .run_sequential()
            .unwrap_err();
        assert!(format!("{err:#}").contains("Sequential"), "{err:#}");
    }

    #[test]
    fn topology_worker_counts() {
        assert_eq!(Topology::Sequential.workers(), 1);
        assert_eq!(Topology::SharedMemory { workers: 4 }.workers(), 4);
        assert_eq!(Topology::ParamServerSync { nodes: 0 }.workers(), 1);
        assert_eq!(
            Topology::ParamServerAsync { nodes: 8, net: NetworkModel::eth_1g() }.workers(),
            8
        );
        assert_eq!(Topology::AllReduce { nodes: 5 }.workers(), 5);
        assert_eq!(Topology::AllReduce { nodes: 0 }.workers(), 1);
        assert_eq!(
            Topology::Gossip { nodes: 6, graph: GossipGraph::Complete }.workers(),
            6
        );
    }

    #[test]
    fn ring_partial_merges_mixed_contributions_in_fold_order() {
        // The PR 7 bug class: a fold mixing sparse and dense
        // contributions must keep every entry, with per-coordinate
        // additions in exactly the caller's fold order no matter where
        // the spill to dense happens.
        let d = 8;
        let mut partial = RingPartial::new(d);
        let mut sv = SparseVec::new(d);
        sv.push(3, 0.5);
        sv.push(7, -0.25);
        let sparse = Update::Sparse(sv);
        let dense: Vec<f32> = (0..d).map(|j| 0.125 * (j as f32) - 0.5).collect();

        // sparse-then-dense: the sparse entries spill, then the dense
        // vector folds on top.
        partial.begin();
        partial.fold(&sparse);
        partial.fold(&Update::Dense(dense.clone()));
        assert_eq!(partial.cost_bits(crate::compress::sparse::index_bits(d)), 32 * d as u64);
        let mut x = vec![0.0f32; d];
        partial.apply(1.0, &mut x);
        for j in 0..d {
            let s = match j {
                3 => 0.5f32,
                7 => -0.25f32,
                _ => 0.0,
            };
            assert_eq!(x[j], -((0.0 + s) + dense[j]), "x[{j}] lost a contribution");
        }

        // begin() resets across rounds: a pure-sparse fold after a
        // dense spill is accounted and applied sparsely again.
        partial.begin();
        partial.fold(&sparse);
        let idx_bits = crate::compress::sparse::index_bits(d);
        assert_eq!(partial.cost_bits(idx_bits), 2 * (32 + idx_bits));
        let mut y = vec![0.0f32; d];
        partial.apply(0.5, &mut y);
        assert_eq!(y[3], -0.25);
        assert_eq!(y[7], 0.125);
        assert_eq!(y.iter().filter(|v| **v != 0.0).count(), 2);

        // The codec frame preserves the entry list, so both sides of a
        // hop compute the same closed-form cost.
        let u = partial.fill_update();
        assert_eq!(update_cost_bits(u, d, idx_bits), 2 * (32 + idx_bits));
    }

    #[test]
    fn gossip_matching_is_deterministic_with_fixed_draws() {
        // Same seed -> same schedule; every round consumes a fixed
        // number of draws, so two clones replaying independently agree
        // round by round (the wire engine's zero-coordination replay).
        for graph in [GossipGraph::Complete, GossipGraph::Ring] {
            for nodes in 1..=5 {
                let mut a = Prng::new(42).split(nodes as u64 + 1);
                let mut b = a.clone();
                let (mut perm_a, mut pairs_a) = (Vec::new(), Vec::new());
                let (mut perm_b, mut pairs_b) = (Vec::new(), Vec::new());
                for round in 0..12 {
                    let ua = gossip_matching(graph, nodes, &mut a, &mut perm_a, &mut pairs_a);
                    let ub = gossip_matching(graph, nodes, &mut b, &mut perm_b, &mut pairs_b);
                    assert_eq!(ua, ub, "{graph:?} n={nodes} round={round}");
                    assert_eq!(pairs_a, pairs_b, "{graph:?} n={nodes} round={round}");
                    // Every node is matched exactly once or unpaired.
                    let mut seen = vec![0u32; nodes];
                    for &(lo, hi) in &pairs_a {
                        assert!(lo < hi && hi < nodes, "non-normalized pair ({lo},{hi})");
                        seen[lo] += 1;
                        seen[hi] += 1;
                    }
                    if let Some(u) = ua {
                        assert_eq!(seen[u], 0, "unpaired node {u} also matched");
                        seen[u] += 1;
                    }
                    assert!(seen.iter().all(|&c| c == 1), "{graph:?} n={nodes}: {seen:?}");
                    if graph == GossipGraph::Ring && nodes >= 2 {
                        for &(lo, hi) in &pairs_a {
                            assert!(
                                hi - lo == 1 || (lo == 0 && hi == nodes - 1),
                                "({lo},{hi}) is not a ring edge of n={nodes}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn all_reduce_sim_matches_param_server_sync_losses() {
        // Same phases, same RNG streams, same node-id fold order, same
        // mean-apply — the ring changes only what the bits are charged
        // to, so the loss trajectory is identical.
        let data = data();
        let run = |topology: Topology| {
            Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
                .method(MethodSpec::mem_top_k(2))
                .schedule(Schedule::constant(0.5))
                .topology(topology)
                .steps(600)
                .eval_points(4)
                .seed(11)
                .run()
                .unwrap()
        };
        let ps = run(Topology::ParamServerSync { nodes: 3 });
        let ring = run(Topology::AllReduce { nodes: 3 });
        assert_eq!(ps.curve.len(), ring.curve.len());
        for (p, r) in ps.curve.iter().zip(&ring.curve) {
            assert_eq!(p.t, r.t);
            assert_eq!(p.loss, r.loss, "loss diverged at t={}", p.t);
        }
        assert_eq!(ps.extra["upload_bits"], ring.extra["upload_bits"]);
        assert!(ring.extra["reduce_bits"] > 0.0);
        assert!(ring.extra["gather_bits"] > 0.0);
        assert_eq!(
            ring.total_bits,
            (ring.extra["reduce_bits"] + ring.extra["gather_bits"]) as u64
        );
    }

    #[test]
    fn all_reduce_single_node_matches_sequential() {
        // n = 1: nothing crosses a wire; the trajectory is the
        // sequential engine's (H = 1, no averaging) bit for bit, and
        // the ring charges zero transmitted bits.
        let data = data();
        let seq = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.5))
            .steps(900)
            .eval_points(3)
            .seed(5)
            .average(false)
            .run()
            .unwrap();
        let ring = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.5))
            .topology(Topology::AllReduce { nodes: 1 })
            .steps(900)
            .eval_points(3)
            .seed(5)
            .run()
            .unwrap();
        assert_eq!(ring.total_bits, 0, "a 1-node ring transmits nothing");
        assert_eq!(seq.curve.len(), ring.curve.len());
        for (sp, rp) in seq.curve.iter().zip(&ring.curve) {
            assert_eq!(sp.t, rp.t);
            assert_eq!(sp.loss, rp.loss, "loss diverged at t={}", sp.t);
        }
    }

    #[test]
    fn gossip_sim_runs_and_accounts_on_both_graphs() {
        let data = data();
        for graph in [GossipGraph::Complete, GossipGraph::Ring] {
            // Odd node count: every round leaves one node unpaired, so
            // self-sync bits must show up in the extras.
            let rec = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
                .method(MethodSpec::mem_top_k(2))
                .schedule(Schedule::constant(0.5))
                .topology(Topology::Gossip { nodes: 3, graph })
                .steps(600)
                .eval_points(4)
                .seed(11)
                .run()
                .unwrap();
            assert_eq!(rec.extra["workers"], 3.0);
            assert!(rec.total_bits > 0, "{graph:?}: paired exchanges transmit");
            assert!(rec.extra["self_sync_bits"] > 0.0, "{graph:?}: odd n leaves one out");
            assert_eq!(
                rec.extra["upload_bits"],
                rec.total_bits as f64 + rec.extra["self_sync_bits"],
                "{graph:?}: every accounted sync is transmitted or self-applied"
            );
            assert!(rec.final_loss() < rec.curve[0].loss, "{graph:?}: no progress");
            // Determinism: the same seed replays bit for bit.
            let again = Experiment::new(LogisticModel::new(&data, 1.0 / 300.0))
                .method(MethodSpec::mem_top_k(2))
                .schedule(Schedule::constant(0.5))
                .topology(Topology::Gossip { nodes: 3, graph })
                .steps(600)
                .eval_points(4)
                .seed(11)
                .run()
                .unwrap();
            assert_eq!(rec.curve, again.curve, "{graph:?}: seeded replay diverged");
        }
    }

    #[test]
    fn mixed_sparse_dense_round_merges_both_contributions() {
        // Regression: a round mixing `Update::Dense` and
        // `Update::Sparse` uploads used to broadcast/apply only the
        // dense aggregate, silently dropping every sparse node's
        // contribution. No current compressor mixes variants within a
        // method, so the mix is injected over hand-built channels —
        // exactly what a remote peer could always send.
        let data = data();
        let mut backend = LogisticModel::new(&data, 1.0 / 300.0);
        let d = backend.dim();
        let dense: Vec<f32> = (0..d).map(|j| 0.125 * (j as f32) - 0.5).collect();

        let mut lb = Loopback;
        let (s0, mut w0) = lb.duplex();
        let (s1, mut w1) = lb.duplex();
        let mut ends = vec![s0, s1];

        let dense_up = dense.clone();
        let script = std::thread::spawn(move || -> Vec<f32> {
            let mut w = BitWriter::new();
            let dense_comp = crate::compress::from_spec("identity").unwrap();
            let sparse_comp = crate::compress::from_spec("top_k:1").unwrap();
            // Node 0 uploads dense, node 1 sparse — one round.
            encode_upload(&mut w, 0, 0, 123, dense_comp.as_ref(), &Update::Dense(dense_up));
            w0.send(w.as_bytes()).unwrap();
            let mut sv = SparseVec::new(d);
            sv.push(3, 0.5);
            sv.push(7, -0.25);
            encode_upload(&mut w, 0, 1, 77, sparse_comp.as_ref(), &Update::Sparse(sv));
            w1.send(w.as_bytes()).unwrap();
            // Drain the broadcast (returned for assertion) and the
            // shutdown on both worker ends.
            let bc = w0.recv().unwrap();
            let g = match decode_msg(&bc, d).unwrap().msg {
                WireMsg::Broadcast { round: 0, update: Update::Dense(g) } => g,
                other => panic!("expected dense broadcast for round 0, got {other:?}"),
            };
            w1.recv().unwrap();
            for ch in [&mut w0, &mut w1] {
                match decode_msg(&ch.recv().unwrap(), d).unwrap().msg {
                    WireMsg::Shutdown => {}
                    other => panic!("expected shutdown, got {other:?}"),
                }
            }
            g
        });

        let mut x = vec![0.0f32; d];
        let mut record = RunRecord::default();
        let mut tally = SyncServerTally::new(2);
        let mut ctl = SyncServe::fail_fast(2);
        serve_sync_protocol(&mut backend, &mut ends, &mut x, 1, 1, &mut record, &mut ctl, &mut tally)
            .unwrap();
        let broadcast = script.join().unwrap();

        // Expected aggregate, folded in the server's node-id order:
        // node 0's dense vector first, then node 1's two coordinates.
        let mut expected = vec![0.0f32; d];
        for (e, &v) in expected.iter_mut().zip(&dense) {
            *e += v;
        }
        expected[3] += 0.5;
        expected[7] += -0.25;
        // The broadcast carries the quorum mean (values pre-scaled by
        // 1/live); replicas apply it at scale 1.0.
        let scale = 1.0 / 2.0f32;
        let scaled: Vec<f32> = expected.iter().map(|v| v * scale).collect();
        assert_eq!(broadcast, scaled, "broadcast dropped the sparse contribution");
        for j in 0..d {
            assert_eq!(x[j], -(expected[j] * scale), "x[{j}] dropped the sparse contribution");
        }
        assert_eq!(tally.upload_acc, vec![123, 77]);
        // Mixed round accounts the broadcast densely.
        assert_eq!(tally.broadcast_bits, 32 * d as u64);
    }

    #[test]
    fn record_names_follow_legacy_format() {
        let m = MethodSpec::mem_top_k(1);
        assert_eq!(record_method_name(&m, &Topology::Sequential), "memsgd(top_1)");
        assert_eq!(
            record_method_name(&m, &Topology::SharedMemory { workers: 4 }),
            "parallel_memsgd(top_k:1,W=4)"
        );
        assert_eq!(
            record_method_name(&m, &Topology::ParamServerSync { nodes: 8 }),
            "dist_memsgd(top_k:1,W=8)"
        );
        assert_eq!(
            record_method_name(
                &m,
                &Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_1g() }
            ),
            "async_memsgd(top_k:1,W=2,1GbE)"
        );
        assert_eq!(
            record_method_name(&MethodSpec::Sgd, &Topology::ParamServerSync { nodes: 2 }),
            "dist_sgd(W=2)"
        );
        assert_eq!(
            record_method_name(&m, &Topology::AllReduce { nodes: 4 }),
            "allreduce_memsgd(top_k:1,W=4)"
        );
        assert_eq!(
            record_method_name(&MethodSpec::Sgd, &Topology::AllReduce { nodes: 3 }),
            "allreduce_sgd(W=3)"
        );
        assert_eq!(
            record_method_name(
                &m,
                &Topology::Gossip { nodes: 4, graph: GossipGraph::Complete }
            ),
            "gossip_memsgd(top_k:1,W=4,complete)"
        );
        assert_eq!(
            record_method_name(
                &MethodSpec::Sgd,
                &Topology::Gossip { nodes: 5, graph: GossipGraph::Ring }
            ),
            "gossip_sgd(W=5,ring)"
        );
    }
}
