//! Method specifications: which optimizer + compressor combination runs.
//!
//! [`MethodSpec`] is the **typed** form — operator parameters live here
//! as numbers, parsed once at the CLI/JSON edge by [`MethodSpec::parse`].
//! Everything downstream (naming, contraction parameters, optimizer
//! construction) is infallible: no re-parsing, no `expect()` on user
//! input inside a driver.
//!
//! Spec grammar (used by the CLI and config files):
//!
//! ```text
//! memsgd:<compressor-spec>     Algorithm 1 with any compress operator,
//!                              e.g. memsgd:top_k:1
//! sgd                          vanilla SGD (dense transmission)
//! sgd:qsgd:<levels>[:<eff_d>]  QSGD baseline (Section 4.3)
//! sgd:unbiased_rand_k:<k>      the d/k-scaled unbiased baseline (§2.2)
//! ```
//!
//! Parsing is strict: unconsumed spec components (`memsgd:top_k:1:junk`)
//! are rejected with a clear error.

use anyhow::{bail, Result};

use crate::compress::CompressorSpec;
use crate::optim::{ErrorFeedbackStep, MemSgd, Schedule, Sgd};

/// Local-update schedule: how much local computation happens between
/// communication events (the Qsparse-local-SGD axis; Basu et al. 2019).
///
/// * `batch` — minibatch size `B`: each stochastic gradient averages
///   `B` samples, `∇ = (1/B)·Σ_{i∈batch} ∇f_i(x)`.
/// * `sync_every` — sync interval `H`: a worker takes `H`
///   error-compensated local steps, accumulating the raw updates
///   `Σ_h η_h·∇_h` on a worker-local iterate, and only then compresses
///   the aggregate (against its worker-local error memory) and
///   communicates — dividing the number of transmissions, and hence the
///   communicated bits, by a factor of `H`.
///
/// `B = 1, H = 1` (the default) is the paper's per-sample schedule; the
/// golden-trajectory suite (`tests/local_update_equivalence.rs`) pins
/// that this case reproduces the classic engines bit for bit.
///
/// Construct through [`LocalUpdate::new`], the strict parse edge: zero
/// and overflowing values are rejected there, and re-checked via
/// [`LocalUpdate::validate`] by every schedule-accepting API
/// (`Experiment::run*`, the train shims, `run_resumable`,
/// `grid::search_local`, `figure6_network`) — never `panic!`ed on deep
/// inside a driver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LocalUpdate {
    /// Minibatch size `B ≥ 1` (samples averaged per gradient).
    pub batch: usize,
    /// Sync interval `H ≥ 1` (local steps per communication).
    pub sync_every: usize,
}

impl Default for LocalUpdate {
    fn default() -> Self {
        LocalUpdate { batch: 1, sync_every: 1 }
    }
}

impl LocalUpdate {
    /// Validated constructor — the `--batch`/`--local-steps` parse edge.
    pub fn new(batch: usize, sync_every: usize) -> Result<LocalUpdate> {
        let lu = LocalUpdate { batch, sync_every };
        lu.validate()?;
        Ok(lu)
    }

    /// Re-check a (possibly literally constructed) schedule: `batch` and
    /// `sync_every` must be ≥ 1 and their product — the samples consumed
    /// per sync — must not overflow.
    pub fn validate(&self) -> Result<()> {
        if self.batch == 0 {
            bail!("--batch must be >= 1 (a zero-sample minibatch has no gradient)");
        }
        if self.sync_every == 0 {
            bail!("--local-steps must be >= 1 (a sync interval of zero never communicates)");
        }
        if self.batch.checked_mul(self.sync_every).is_none() {
            bail!(
                "--batch {} x --local-steps {} overflows the per-sync sample count",
                self.batch,
                self.sync_every
            );
        }
        Ok(())
    }

    /// Whether this is the paper's per-sample schedule (`B = 1, H = 1`).
    pub fn is_default(&self) -> bool {
        self.batch == 1 && self.sync_every == 1
    }
}

/// A parsed, fully-typed method specification.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// Algorithm 1 with the given compression operator. Contraction
    /// operators carry an error memory; non-contractions (QSGD) run
    /// memory-free, as in the paper's §4.3 baseline.
    MemSgd { comp: CompressorSpec },
    /// Vanilla SGD.
    Sgd,
    /// QSGD (levels, optional effective dimension for bit accounting).
    SgdQsgd { levels: u32, eff: Option<usize> },
    /// Section 2.2's unbiased rand-k with d/k scaling.
    SgdUnbiasedRandK { k: usize },
}

/// Deprecated name of [`MethodSpec`], kept for source compatibility.
#[deprecated(note = "use MethodSpec; Method's stringly `comp` field is gone")]
pub type Method = MethodSpec;

impl MethodSpec {
    /// Mem-SGD with a typed operator — the programmatic constructor.
    pub fn mem(comp: CompressorSpec) -> MethodSpec {
        MethodSpec::MemSgd { comp }
    }

    /// Mem-SGD with top-k sparsification (the paper's best performer).
    pub fn mem_top_k(k: usize) -> MethodSpec {
        MethodSpec::MemSgd { comp: CompressorSpec::TopK { k } }
    }

    /// Mem-SGD with rand-k sparsification.
    pub fn mem_rand_k(k: usize) -> MethodSpec {
        MethodSpec::MemSgd { comp: CompressorSpec::RandK { k } }
    }

    /// Parse a spec string (the CLI/JSON edge). Strict: every
    /// `:`-separated component must be consumed.
    pub fn parse(spec: &str) -> Result<MethodSpec> {
        let (head, rest) = match spec.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (spec, None),
        };
        Ok(match (head, rest) {
            ("memsgd", Some(comp)) => MethodSpec::MemSgd { comp: CompressorSpec::parse(comp)? },
            ("memsgd", None) => bail!("memsgd requires a compressor, e.g. 'memsgd:top_k:1'"),
            ("sgd", None) => MethodSpec::Sgd,
            ("sgd", Some(r)) => {
                let mut parts = r.split(':');
                let variant = parts.next();
                let no_trailing = |parts: &mut std::str::Split<'_, char>| -> Result<()> {
                    match parts.next() {
                        Some(extra) => bail!("trailing component '{extra}' in '{spec}'"),
                        None => Ok(()),
                    }
                };
                match variant {
                    Some("qsgd") => {
                        let levels: u32 = match parts.next() {
                            Some(v) => v
                                .parse()
                                .map_err(|e| anyhow::anyhow!("qsgd levels '{v}': {e}"))?,
                            None => bail!("sgd:qsgd requires levels, e.g. 'sgd:qsgd:16'"),
                        };
                        if levels == 0 {
                            bail!("sgd:qsgd requires levels >= 1");
                        }
                        let eff = match parts.next() {
                            Some(v) => Some(
                                v.parse::<usize>()
                                    .map_err(|e| anyhow::anyhow!("qsgd effective dim '{v}': {e}"))?,
                            ),
                            None => None,
                        };
                        no_trailing(&mut parts)?;
                        MethodSpec::SgdQsgd { levels, eff }
                    }
                    Some("unbiased_rand_k") => {
                        let k: usize = match parts.next() {
                            Some(v) => v
                                .parse()
                                .map_err(|e| anyhow::anyhow!("unbiased_rand_k '{v}': {e}"))?,
                            None => bail!("sgd:unbiased_rand_k requires k"),
                        };
                        if k == 0 {
                            bail!("sgd:unbiased_rand_k requires k >= 1");
                        }
                        no_trailing(&mut parts)?;
                        MethodSpec::SgdUnbiasedRandK { k }
                    }
                    other => bail!("unknown sgd variant {other:?} in '{spec}'"),
                }
            }
            _ => bail!("unknown method spec '{spec}'"),
        })
    }

    /// Display name used in records and plots. Infallible — the typed
    /// spec holds its parameters, nothing is re-parsed.
    pub fn name(&self) -> String {
        match self {
            MethodSpec::MemSgd { comp } => format!("memsgd({})", comp.name()),
            MethodSpec::Sgd => "sgd".into(),
            MethodSpec::SgdQsgd { levels, .. } => {
                format!("sgd_qsgd_{}", crate::compress::qsgd::level_suffix(*levels))
            }
            MethodSpec::SgdUnbiasedRandK { k } => format!("sgd_unbiased_rand_{k}"),
        }
    }

    /// Canonical spec string — parses back to `self`.
    pub fn spec_string(&self) -> String {
        match self {
            MethodSpec::MemSgd { comp } => format!("memsgd:{}", comp.spec_string()),
            MethodSpec::Sgd => "sgd".into(),
            MethodSpec::SgdQsgd { levels, eff } => match eff {
                Some(e) => format!("sgd:qsgd:{levels}:{e}"),
                None => format!("sgd:qsgd:{levels}"),
            },
            MethodSpec::SgdUnbiasedRandK { k } => format!("sgd:unbiased_rand_k:{k}"),
        }
    }

    /// Contraction parameter of the underlying operator (drives the
    /// paper's stepsize shift `a ∝ d/k`); `d` for vanilla, `None` for
    /// non-contractive QSGD. Infallible.
    pub fn contraction_k(&self, d: usize) -> Option<f64> {
        match self {
            MethodSpec::MemSgd { comp } => comp.contraction_k(d),
            MethodSpec::Sgd => Some(d as f64),
            MethodSpec::SgdQsgd { .. } => None,
            MethodSpec::SgdUnbiasedRandK { k } => Some(*k as f64),
        }
    }

    /// The paper's theoretical schedule (Table 2) for this method on a
    /// `d`-dimensional, `n`-sample problem: `η_t = γ/(λ(t+a))` with
    /// `a = multiplier·d/k` and `λ` defaulting to `1/n`.
    pub fn paper_schedule(
        &self,
        d: usize,
        n: usize,
        gamma: f64,
        shift_multiplier: f64,
        lam: Option<f64>,
    ) -> Schedule {
        let k = self.contraction_k(d).unwrap_or(d as f64);
        let lam = lam.unwrap_or(1.0 / n as f64);
        Schedule::inv_t(gamma, lam, Schedule::paper_shift(d, k, shift_multiplier))
    }

    /// Per-worker error-feedback state for the topology engines: the
    /// compressor, memory policy, and unbiasing scale this method implies.
    ///
    /// Memory policy (uniform across all four topologies and
    /// [`MethodSpec::build`]): `MemSgd` carries an error memory only for
    /// contraction operators; non-contractions (QSGD) run memory-free as
    /// in the paper's §4.3 baseline — accumulating unbiased quantization
    /// noise would amplify it instead of correcting it.
    pub fn error_feedback(&self, d: usize) -> ErrorFeedbackStep {
        match self {
            MethodSpec::MemSgd { comp } => ErrorFeedbackStep::new(d, comp.build()),
            MethodSpec::Sgd => {
                ErrorFeedbackStep::memory_free(d, Box::new(crate::compress::Identity), 1.0)
            }
            MethodSpec::SgdQsgd { levels, eff } => ErrorFeedbackStep::memory_free(
                d,
                Box::new(crate::compress::Qsgd::with_effective_dim(*levels, *eff)),
                1.0,
            ),
            MethodSpec::SgdUnbiasedRandK { k } => ErrorFeedbackStep::memory_free(
                d,
                Box::new(crate::compress::RandK::new(*k)),
                d as f32 / *k as f32,
            ),
        }
    }

    /// Instantiate the legacy stepping interface at `x0`. Infallible.
    ///
    /// Matches [`MethodSpec::error_feedback`]'s memory policy exactly:
    /// `MemSgd` with a non-contraction operator (QSGD) steps memory-free,
    /// so the same spec runs the same algorithm through every entry point.
    pub fn build(&self, x0: Vec<f32>) -> Optimizer {
        match self {
            MethodSpec::MemSgd { comp } => {
                if comp.contraction_k(x0.len()).is_some() {
                    Optimizer::Mem(MemSgd::new(x0, comp.build()))
                } else {
                    Optimizer::Plain(Sgd::with_compressor(x0, comp.build(), 1.0))
                }
            }
            MethodSpec::Sgd => Optimizer::Plain(Sgd::vanilla(x0)),
            MethodSpec::SgdQsgd { levels, eff } => Optimizer::Plain(Sgd::qsgd(x0, *levels, *eff)),
            MethodSpec::SgdUnbiasedRandK { k } => Optimizer::Plain(Sgd::unbiased_rand_k(x0, *k)),
        }
    }
}

/// Either optimizer behind one stepping interface.
pub enum Optimizer {
    Mem(MemSgd),
    Plain(Sgd),
}

impl Optimizer {
    #[inline]
    pub fn step(&mut self, grad: &[f32], eta: f64, rng: &mut crate::util::prng::Prng) {
        match self {
            Optimizer::Mem(o) => {
                o.step(grad, eta, rng);
            }
            Optimizer::Plain(o) => o.step(grad, eta, rng),
        }
    }

    #[inline]
    pub fn x(&self) -> &[f32] {
        match self {
            Optimizer::Mem(o) => &o.x,
            Optimizer::Plain(o) => &o.x,
        }
    }

    pub fn bits_sent(&self) -> u64 {
        match self {
            Optimizer::Mem(o) => o.bits_sent,
            Optimizer::Plain(o) => o.bits_sent,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_method_kinds() {
        assert_eq!(
            MethodSpec::parse("memsgd:top_k:1").unwrap(),
            MethodSpec::MemSgd { comp: CompressorSpec::TopK { k: 1 } }
        );
        assert_eq!(MethodSpec::parse("sgd").unwrap(), MethodSpec::Sgd);
        assert_eq!(
            MethodSpec::parse("sgd:qsgd:16").unwrap(),
            MethodSpec::SgdQsgd { levels: 16, eff: None }
        );
        assert_eq!(
            MethodSpec::parse("sgd:qsgd:16:71").unwrap(),
            MethodSpec::SgdQsgd { levels: 16, eff: Some(71) }
        );
        assert_eq!(
            MethodSpec::parse("sgd:unbiased_rand_k:10").unwrap(),
            MethodSpec::SgdUnbiasedRandK { k: 10 }
        );
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(MethodSpec::parse("memsgd").is_err());
        assert!(MethodSpec::parse("memsgd:bogus:1").is_err());
        assert!(MethodSpec::parse("sgd:bogus").is_err());
        assert!(MethodSpec::parse("adam").is_err());
        assert!(MethodSpec::parse("sgd:qsgd").is_err());
    }

    #[test]
    fn rejects_trailing_components() {
        assert!(MethodSpec::parse("memsgd:top_k:1:junk").is_err());
        assert!(MethodSpec::parse("sgd:qsgd:16:71:junk").is_err());
        assert!(MethodSpec::parse("sgd:unbiased_rand_k:10:junk").is_err());
        assert!(MethodSpec::parse("memsgd:identity:junk").is_err());
    }

    #[test]
    fn names_are_infallible() {
        assert_eq!(MethodSpec::parse("memsgd:top_k:1").unwrap().name(), "memsgd(top_1)");
        assert_eq!(MethodSpec::parse("sgd:qsgd:256").unwrap().name(), "sgd_qsgd_8bit");
        // Non-power-of-two levels keep exact names (no log2 rounding).
        assert_eq!(MethodSpec::parse("sgd:qsgd:6").unwrap().name(), "sgd_qsgd_s6");
        assert_eq!(MethodSpec::parse("sgd").unwrap().name(), "sgd");
        assert_eq!(MethodSpec::mem_top_k(3).name(), "memsgd(top_3)");
        assert_eq!(
            MethodSpec::parse("memsgd:qsgd:16(top_k:100)").unwrap().name(),
            "memsgd(qsgd_4bit(top_100))"
        );
        assert_eq!(
            MethodSpec::parse("memsgd:adaptive:100").unwrap().name(),
            "memsgd(adaptive_100)"
        );
    }

    #[test]
    fn spec_string_round_trips() {
        for spec in [
            "memsgd:top_k:1",
            "memsgd:random_p:0.5",
            "sgd",
            "sgd:qsgd:16",
            "sgd:qsgd:16:71",
            "sgd:unbiased_rand_k:10",
            "memsgd:adaptive:100",
            "memsgd:qsgd:16(top_k:100)",
        ] {
            let m = MethodSpec::parse(spec).unwrap();
            assert_eq!(MethodSpec::parse(&m.spec_string()).unwrap(), m, "{spec}");
        }
    }

    #[test]
    fn contraction_parameters() {
        assert_eq!(MethodSpec::parse("memsgd:top_k:3").unwrap().contraction_k(100), Some(3.0));
        assert_eq!(
            MethodSpec::parse("memsgd:random_p:0.5").unwrap().contraction_k(100),
            Some(0.5)
        );
        assert_eq!(MethodSpec::parse("sgd").unwrap().contraction_k(100), Some(100.0));
        assert_eq!(MethodSpec::parse("sgd:qsgd:16").unwrap().contraction_k(100), None);
    }

    #[test]
    fn paper_schedule_uses_contraction_shift() {
        let m = MethodSpec::mem_top_k(2);
        match m.paper_schedule(64, 1000, 2.0, 1.0, None) {
            Schedule::InvT { shift, lambda, .. } => {
                assert_eq!(shift, 32.0); // d/k = 64/2
                assert!((lambda - 1e-3).abs() < 1e-12);
            }
            _ => panic!("expected InvT"),
        }
    }

    #[test]
    fn build_and_step() {
        let mut rng = crate::util::prng::Prng::new(0);
        for spec in ["memsgd:top_k:1", "sgd", "sgd:qsgd:16", "sgd:unbiased_rand_k:2"] {
            let mut opt = MethodSpec::parse(spec).unwrap().build(vec![0.0; 8]);
            opt.step(&[1.0; 8], 0.1, &mut rng);
            assert!(opt.bits_sent() > 0, "{spec}");
            assert_eq!(opt.x().len(), 8);
        }
    }

    #[test]
    fn error_feedback_policy_per_method() {
        assert!(MethodSpec::mem_top_k(1).error_feedback(8).uses_memory());
        assert!(!MethodSpec::Sgd.error_feedback(8).uses_memory()); // identity needs no memory
        assert!(!MethodSpec::SgdQsgd { levels: 16, eff: None }.error_feedback(8).uses_memory());
        assert!(!MethodSpec::SgdUnbiasedRandK { k: 2 }.error_feedback(8).uses_memory());
        // memsgd with a non-contraction runs memory-free too (§4.3).
        assert!(!MethodSpec::parse("memsgd:qsgd:16").unwrap().error_feedback(8).uses_memory());
    }

    #[test]
    fn local_update_parse_edge_is_strict() {
        assert!(LocalUpdate::new(0, 1).is_err());
        assert!(LocalUpdate::new(1, 0).is_err());
        assert!(LocalUpdate::new(0, 0).is_err());
        assert!(LocalUpdate::new(usize::MAX, 2).is_err()); // B·H overflows
        let lu = LocalUpdate::new(1, 1).unwrap();
        assert!(lu.is_default());
        assert_eq!(lu, LocalUpdate::default());
        let lu = LocalUpdate::new(8, 4).unwrap();
        assert!(!lu.is_default());
        // Literal construction bypasses new(); validate() re-rejects.
        assert!(LocalUpdate { batch: 0, sync_every: 3 }.validate().is_err());
        assert!(LocalUpdate { batch: 3, sync_every: 0 }.validate().is_err());
    }

    #[test]
    fn build_memory_policy_matches_error_feedback() {
        // The legacy Optimizer interface and the engines must agree on
        // when an error memory exists — same spec, same algorithm.
        match MethodSpec::mem_top_k(1).build(vec![0.0; 8]) {
            Optimizer::Mem(_) => {}
            Optimizer::Plain(_) => panic!("top_k must carry memory"),
        }
        match MethodSpec::parse("memsgd:qsgd:16").unwrap().build(vec![0.0; 8]) {
            Optimizer::Plain(_) => {}
            Optimizer::Mem(_) => panic!("memsgd:qsgd must run memory-free"),
        }
    }
}
