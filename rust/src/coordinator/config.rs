//! Method specifications: which optimizer + compressor combination runs.
//!
//! Spec grammar (used by the CLI, config files and all drivers):
//!
//! ```text
//! memsgd:<compressor-spec>     Algorithm 1 with any compress::from_spec
//!                              operator, e.g. memsgd:top_k:1
//! sgd                          vanilla SGD (dense transmission)
//! sgd:qsgd:<levels>[:<eff_d>]  QSGD baseline (Section 4.3)
//! sgd:unbiased_rand_k:<k>      the d/k-scaled unbiased baseline (§2.2)
//! ```

use anyhow::{bail, Result};

use crate::compress;
use crate::optim::{MemSgd, Sgd};

/// A parsed method specification.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Algorithm 1 with the given compressor spec.
    MemSgd { comp: String },
    /// Vanilla SGD.
    Sgd,
    /// QSGD (levels, optional effective dimension for bit accounting).
    SgdQsgd { levels: u32, eff: Option<usize> },
    /// Section 2.2's unbiased rand-k with d/k scaling.
    SgdUnbiasedRandK { k: usize },
}

impl Method {
    pub fn parse(spec: &str) -> Result<Method> {
        let (head, rest) = match spec.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (spec, None),
        };
        Ok(match (head, rest) {
            ("memsgd", Some(comp)) => {
                compress::from_spec(comp)?; // validate eagerly
                Method::MemSgd { comp: comp.to_string() }
            }
            ("memsgd", None) => bail!("memsgd requires a compressor, e.g. 'memsgd:top_k:1'"),
            ("sgd", None) => Method::Sgd,
            ("sgd", Some(r)) => {
                let mut parts = r.split(':');
                match parts.next() {
                    Some("qsgd") => {
                        let levels: u32 = match parts.next() {
                            Some(v) => v.parse()?,
                            None => bail!("sgd:qsgd requires levels, e.g. 'sgd:qsgd:16'"),
                        };
                        let eff = match parts.next() {
                            Some(v) => Some(v.parse::<usize>()?),
                            None => None,
                        };
                        Method::SgdQsgd { levels, eff }
                    }
                    Some("unbiased_rand_k") => {
                        let k: usize = match parts.next() {
                            Some(v) => v.parse()?,
                            None => bail!("sgd:unbiased_rand_k requires k"),
                        };
                        Method::SgdUnbiasedRandK { k }
                    }
                    other => bail!("unknown sgd variant {other:?} in '{spec}'"),
                }
            }
            _ => bail!("unknown method spec '{spec}'"),
        })
    }

    /// Display name used in records and plots.
    pub fn name(&self) -> String {
        match self {
            Method::MemSgd { comp } => {
                let c = compress::from_spec(comp).expect("validated at parse");
                format!("memsgd({})", c.name())
            }
            Method::Sgd => "sgd".into(),
            Method::SgdQsgd { levels, .. } => {
                format!("sgd_qsgd_{}bit", (*levels as f64).log2().round() as u32)
            }
            Method::SgdUnbiasedRandK { k } => format!("sgd_unbiased_rand_{k}"),
        }
    }

    /// Contraction parameter of the underlying operator (drives the
    /// paper's stepsize shift `a ∝ d/k`); `d` for vanilla, `None` for
    /// non-contractive QSGD.
    pub fn contraction_k(&self, d: usize) -> Option<f64> {
        match self {
            Method::MemSgd { comp } => compress::from_spec(comp)
                .expect("validated at parse")
                .contraction_k(d),
            Method::Sgd => Some(d as f64),
            Method::SgdQsgd { .. } => None,
            Method::SgdUnbiasedRandK { k } => Some(*k as f64),
        }
    }

    /// Instantiate the optimizer at `x0`.
    pub fn build(&self, x0: Vec<f32>) -> Result<Optimizer> {
        Ok(match self {
            Method::MemSgd { comp } => Optimizer::Mem(MemSgd::new(x0, compress::from_spec(comp)?)),
            Method::Sgd => Optimizer::Plain(Sgd::vanilla(x0)),
            Method::SgdQsgd { levels, eff } => Optimizer::Plain(Sgd::qsgd(x0, *levels, *eff)),
            Method::SgdUnbiasedRandK { k } => Optimizer::Plain(Sgd::unbiased_rand_k(x0, *k)),
        })
    }
}

/// Either optimizer behind one stepping interface.
pub enum Optimizer {
    Mem(MemSgd),
    Plain(Sgd),
}

impl Optimizer {
    #[inline]
    pub fn step(&mut self, grad: &[f32], eta: f64, rng: &mut crate::util::prng::Prng) {
        match self {
            Optimizer::Mem(o) => {
                o.step(grad, eta, rng);
            }
            Optimizer::Plain(o) => o.step(grad, eta, rng),
        }
    }

    #[inline]
    pub fn x(&self) -> &[f32] {
        match self {
            Optimizer::Mem(o) => &o.x,
            Optimizer::Plain(o) => &o.x,
        }
    }

    pub fn bits_sent(&self) -> u64 {
        match self {
            Optimizer::Mem(o) => o.bits_sent,
            Optimizer::Plain(o) => o.bits_sent,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_method_kinds() {
        assert_eq!(
            Method::parse("memsgd:top_k:1").unwrap(),
            Method::MemSgd { comp: "top_k:1".into() }
        );
        assert_eq!(Method::parse("sgd").unwrap(), Method::Sgd);
        assert_eq!(
            Method::parse("sgd:qsgd:16").unwrap(),
            Method::SgdQsgd { levels: 16, eff: None }
        );
        assert_eq!(
            Method::parse("sgd:qsgd:16:71").unwrap(),
            Method::SgdQsgd { levels: 16, eff: Some(71) }
        );
        assert_eq!(
            Method::parse("sgd:unbiased_rand_k:10").unwrap(),
            Method::SgdUnbiasedRandK { k: 10 }
        );
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(Method::parse("memsgd").is_err());
        assert!(Method::parse("memsgd:bogus:1").is_err());
        assert!(Method::parse("sgd:bogus").is_err());
        assert!(Method::parse("adam").is_err());
        assert!(Method::parse("sgd:qsgd").is_err());
    }

    #[test]
    fn names() {
        assert_eq!(Method::parse("memsgd:top_k:1").unwrap().name(), "memsgd(top_1)");
        assert_eq!(Method::parse("sgd:qsgd:256").unwrap().name(), "sgd_qsgd_8bit");
        assert_eq!(Method::parse("sgd").unwrap().name(), "sgd");
    }

    #[test]
    fn contraction_parameters() {
        assert_eq!(Method::parse("memsgd:top_k:3").unwrap().contraction_k(100), Some(3.0));
        assert_eq!(Method::parse("memsgd:random_p:0.5").unwrap().contraction_k(100), Some(0.5));
        assert_eq!(Method::parse("sgd").unwrap().contraction_k(100), Some(100.0));
        assert_eq!(Method::parse("sgd:qsgd:16").unwrap().contraction_k(100), None);
    }

    #[test]
    fn build_and_step() {
        let mut rng = crate::util::prng::Prng::new(0);
        for spec in ["memsgd:top_k:1", "sgd", "sgd:qsgd:16", "sgd:unbiased_rand_k:2"] {
            let mut opt = Method::parse(spec).unwrap().build(vec![0.0; 8]).unwrap();
            opt.step(&[1.0; 8], 0.1, &mut rng);
            assert!(opt.bits_sent() > 0, "{spec}");
            assert_eq!(opt.x().len(), 8);
        }
    }
}
