//! Datasets: dense and CSR-sparse feature matrices with ±1 labels.
//!
//! The paper evaluates on *epsilon* (400k × 2000, 100% dense) and
//! *RCV1-test* (677k × 47236, 0.15% dense). Real downloads are not
//! available in this environment, so [`synthetic`] provides generators
//! matched on every property the experiments depend on (d, density,
//! feature-magnitude decay, label noise); [`libsvm`] parses the real
//! files when present so they can be dropped in (DESIGN.md §3).

pub mod libsvm;
pub mod synthetic;

/// Feature storage: row-major dense or CSR sparse.
#[derive(Clone, Debug)]
pub enum Features {
    /// Row-major `n × d`.
    Dense { x: Vec<f32>, d: usize },
    /// Compressed sparse rows over dimension `d`.
    Csr {
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
        d: usize,
    },
}

/// A view of one sample's features.
#[derive(Clone, Copy, Debug)]
pub enum RowView<'a> {
    Dense(&'a [f32]),
    Sparse { idx: &'a [u32], val: &'a [f32] },
}

/// A labeled binary-classification dataset (labels in {−1, +1}).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Features,
    pub labels: Vec<f32>,
    /// Provenance string for metric records ("epsilon-like(n=..,d=..)").
    pub name: String,
}

/// Table-1 style dataset statistics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetStats {
    pub n: usize,
    pub d: usize,
    pub nnz: usize,
    pub density: f64,
}

impl Dataset {
    pub fn dense(name: impl Into<String>, x: Vec<f32>, d: usize, labels: Vec<f32>) -> Dataset {
        assert_eq!(x.len(), labels.len() * d, "dense shape mismatch");
        assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
        Dataset {
            features: Features::Dense { x, d },
            labels,
            name: name.into(),
        }
    }

    pub fn csr(
        name: impl Into<String>,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
        d: usize,
        labels: Vec<f32>,
    ) -> Dataset {
        assert_eq!(indptr.len(), labels.len() + 1, "indptr length mismatch");
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        debug_assert!(indices.iter().all(|&j| (j as usize) < d));
        assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
        Dataset {
            features: Features::Csr {
                indptr,
                indices,
                values,
                d,
            },
            labels,
            name: name.into(),
        }
    }

    /// Number of samples.
    #[inline]
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    /// Feature dimension.
    #[inline]
    pub fn d(&self) -> usize {
        match &self.features {
            Features::Dense { d, .. } | Features::Csr { d, .. } => *d,
        }
    }

    /// Label of sample `i`.
    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.labels[i]
    }

    /// Feature view of sample `i`.
    #[inline]
    pub fn row(&self, i: usize) -> RowView<'_> {
        match &self.features {
            Features::Dense { x, d } => RowView::Dense(&x[i * d..(i + 1) * d]),
            Features::Csr {
                indptr,
                indices,
                values,
                ..
            } => {
                let (lo, hi) = (indptr[i], indptr[i + 1]);
                RowView::Sparse {
                    idx: &indices[lo..hi],
                    val: &values[lo..hi],
                }
            }
        }
    }

    /// `⟨a_i, x⟩` — the margin's inner product.
    #[inline]
    pub fn dot_row(&self, i: usize, x: &[f32]) -> f32 {
        match self.row(i) {
            RowView::Dense(row) => dot(row, x),
            RowView::Sparse { idx, val } => {
                let mut acc = 0.0f32;
                for (&j, &v) in idx.iter().zip(val) {
                    acc += v * x[j as usize];
                }
                acc
            }
        }
    }

    /// `out += coef · a_i`.
    #[inline]
    pub fn add_scaled_row(&self, i: usize, coef: f32, out: &mut [f32]) {
        match self.row(i) {
            RowView::Dense(row) => {
                for (o, &v) in out.iter_mut().zip(row) {
                    *o += coef * v;
                }
            }
            RowView::Sparse { idx, val } => {
                for (&j, &v) in idx.iter().zip(val) {
                    out[j as usize] += coef * v;
                }
            }
        }
    }

    /// Nonzeros stored for sample `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        match self.row(i) {
            RowView::Dense(row) => row.len(),
            RowView::Sparse { idx, .. } => idx.len(),
        }
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        match &self.features {
            Features::Dense { x, .. } => x.len(),
            Features::Csr { values, .. } => values.len(),
        }
    }

    /// Table-1 statistics.
    pub fn stats(&self) -> DatasetStats {
        let n = self.n();
        let d = self.d();
        let nnz = self.nnz();
        DatasetStats {
            n,
            d,
            nnz,
            density: nnz as f64 / (n as f64 * d as f64),
        }
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive loop
    // on the d=2000 hot path and keeps f32 rounding deterministic.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dense() -> Dataset {
        Dataset::dense(
            "tiny",
            vec![1.0, 2.0, /*row1*/ 3.0, 4.0, /*row2*/ -1.0, 0.5],
            2,
            vec![1.0, -1.0, 1.0],
        )
    }

    fn tiny_csr() -> Dataset {
        // rows: [ (0,1.0) ], [ (1,2.0), (2,-3.0) ], [ ]
        Dataset::csr(
            "tiny-sparse",
            vec![0, 1, 3, 3],
            vec![0, 1, 2],
            vec![1.0, 2.0, -3.0],
            4,
            vec![1.0, -1.0, 1.0],
        )
    }

    #[test]
    fn dense_accessors() {
        let ds = tiny_dense();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.label(1), -1.0);
        match ds.row(1) {
            RowView::Dense(r) => assert_eq!(r, &[3.0, 4.0]),
            _ => panic!("expected dense row"),
        }
        assert_eq!(ds.dot_row(1, &[1.0, 1.0]), 7.0);
        let mut out = vec![0.0f32; 2];
        ds.add_scaled_row(2, 2.0, &mut out);
        assert_eq!(out, vec![-2.0, 1.0]);
        assert_eq!(ds.stats().density, 1.0);
    }

    #[test]
    fn csr_accessors() {
        let ds = tiny_csr();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 4);
        assert_eq!(ds.row_nnz(0), 1);
        assert_eq!(ds.row_nnz(2), 0);
        assert_eq!(ds.dot_row(1, &[1.0, 1.0, 1.0, 1.0]), -1.0);
        let mut out = vec![0.0f32; 4];
        ds.add_scaled_row(1, 0.5, &mut out);
        assert_eq!(out, vec![0.0, 1.0, -1.5, 0.0]);
        let st = ds.stats();
        assert_eq!(st.nnz, 3);
        assert!((st.density - 3.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32 * 0.71).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    #[should_panic(expected = "dense shape mismatch")]
    fn dense_shape_checked() {
        Dataset::dense("bad", vec![1.0; 5], 2, vec![1.0, -1.0]);
    }

    #[test]
    #[should_panic]
    fn labels_must_be_plus_minus_one() {
        Dataset::dense("bad", vec![1.0; 4], 2, vec![1.0, 0.5]);
    }
}
