//! Synthetic dataset generators matched to the paper's datasets.
//!
//! The real *epsilon* and *RCV1-test* files require network downloads
//! that this environment does not have, so we generate surrogates that
//! match every property the experiments are sensitive to (DESIGN.md §3):
//!
//! * `epsilon_like`  — dense Gaussian features, L2-normalized rows,
//!   planted separator with label noise: same d = 2000, density 100%,
//!   same margin structure class (PASCAL epsilon is a synthetic
//!   Gaussian-mixture dataset itself).
//! * `rcv1_like`     — sparse rows with power-law feature frequencies
//!   (Zipfian document-term statistics), tf-idf-like positive values,
//!   L2-normalized rows, planted separator on the frequent features:
//!   same d = 47236, density ≈ 0.15%, heavy-tailed coordinate
//!   importance (what makes top-k beat rand-k).
//!
//! Generators are deterministic in the seed.

use super::Dataset;
use crate::util::prng::Prng;

/// Dense epsilon-like data: `n` rows, `d` features, unit-norm rows,
/// labels from a planted Gaussian separator with 8% flip noise.
pub fn epsilon_like(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed);
    // Planted separator.
    let w_star: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let w_norm = crate::util::stats::l2_norm(&w_star) as f32;

    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    let mut row = vec![0.0f32; d];
    for _ in 0..n {
        let mut norm_sq = 0.0f32;
        for r in row.iter_mut() {
            let v = rng.normal_f32();
            *r = v;
            norm_sq += v * v;
        }
        let inv = 1.0 / norm_sq.sqrt().max(1e-12);
        let mut margin = 0.0f32;
        for (r, &ws) in row.iter_mut().zip(&w_star) {
            *r *= inv;
            margin += *r * ws;
        }
        margin /= w_norm;
        // Label noise: flip with probability shrinking in |margin|
        // (logistic link), floor 8%.
        let p_flip = 0.08 + 0.42 * (-8.0 * margin.abs() as f64).exp();
        let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.bernoulli(p_flip) {
            y = -y;
        }
        labels.push(y);
        x.extend_from_slice(&row);
    }
    Dataset::dense(format!("epsilon-like(n={n},d={d})"), x, d, labels)
}

/// Sparse RCV1-like data: power-law feature frequencies, about
/// `density · d` nonzeros per row, unit-norm rows, planted separator
/// supported on the frequent features.
pub fn rcv1_like(n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    assert!(density > 0.0 && density <= 1.0);
    let mut rng = Prng::new(seed);
    let nnz_per_row = ((density * d as f64).round() as usize).max(1);

    // Zipf(1.1) over features: cumulative table for inverse-CDF sampling.
    let mut cdf = Vec::with_capacity(d);
    let mut acc = 0.0f64;
    for j in 0..d {
        acc += 1.0 / ((j + 1) as f64).powf(1.1);
        cdf.push(acc);
    }
    let total = acc;

    // Separator weights decay with feature rank — frequent features are
    // informative, mirroring the heavy-tailed importance of text data.
    let w_star: Vec<f32> = (0..d)
        .map(|j| rng.normal_f32() / ((j + 1) as f32).powf(0.3))
        .collect();

    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices: Vec<u32> = Vec::with_capacity(n * nnz_per_row);
    let mut values: Vec<f32> = Vec::with_capacity(n * nnz_per_row);
    let mut labels = Vec::with_capacity(n);
    indptr.push(0);

    let mut row_idx: Vec<u32> = Vec::with_capacity(nnz_per_row * 2);
    for _ in 0..n {
        // Draw distinct features by inverse-CDF + dedup.
        row_idx.clear();
        while row_idx.len() < nnz_per_row {
            let u = rng.f64() * total;
            let j = match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                Ok(j) | Err(j) => j.min(d - 1),
            } as u32;
            if !row_idx.contains(&j) {
                row_idx.push(j);
            }
        }
        row_idx.sort_unstable();
        // tf-idf-like positive magnitudes, then L2-normalize the row.
        let mut vals: Vec<f32> = row_idx
            .iter()
            .map(|_| (0.2 + rng.f32()) * (1.0 + rng.f32()))
            .collect();
        let norm: f32 = vals.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
        let mut margin = 0.0f32;
        for (v, &j) in vals.iter_mut().zip(&row_idx) {
            *v /= norm;
            margin += *v * w_star[j as usize];
        }
        let p_flip = 0.08 + 0.42 * (-4.0 * margin.abs() as f64).exp();
        let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.bernoulli(p_flip) {
            y = -y;
        }
        labels.push(y);
        indices.extend_from_slice(&row_idx);
        values.extend_from_slice(&vals);
        indptr.push(indices.len());
    }
    Dataset::csr(
        format!("rcv1-like(n={n},d={d},density={density})"),
        indptr,
        indices,
        values,
        d,
        labels,
    )
}

/// Paper-scale epsilon surrogate, scaled down by `scale` (1 = full 400k
/// rows; the figure drivers default to scale 20 → n = 20k).
pub fn epsilon_paper(scale: usize, seed: u64) -> Dataset {
    epsilon_like(400_000 / scale.max(1), 2000, seed)
}

/// Paper-scale RCV1-test surrogate, scaled down by `scale`.
pub fn rcv1_paper(scale: usize, seed: u64) -> Dataset {
    rcv1_like(677_399 / scale.max(1), 47_236, 0.0015, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RowView;

    #[test]
    fn epsilon_like_shape_and_normalization() {
        let ds = epsilon_like(200, 50, 1);
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.d(), 50);
        assert_eq!(ds.stats().density, 1.0);
        for i in 0..ds.n() {
            if let RowView::Dense(row) = ds.row(i) {
                let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
                assert!((norm - 1.0).abs() < 1e-4, "row {i} norm {norm}");
            }
        }
    }

    #[test]
    fn epsilon_like_is_roughly_balanced_and_learnable() {
        let ds = epsilon_like(2000, 20, 2);
        let pos = ds.labels.iter().filter(|&&y| y == 1.0).count();
        assert!((600..1400).contains(&pos), "pos={pos}");
    }

    #[test]
    fn rcv1_like_density_and_norms() {
        let ds = rcv1_like(300, 1000, 0.01, 3);
        let st = ds.stats();
        assert_eq!(st.n, 300);
        assert_eq!(st.d, 1000);
        assert!((st.density - 0.01).abs() < 0.002, "density={}", st.density);
        for i in 0..ds.n() {
            if let RowView::Sparse { idx, val } = ds.row(i) {
                assert_eq!(idx.len(), val.len());
                let norm: f32 = val.iter().map(|v| v * v).sum::<f32>().sqrt();
                assert!((norm - 1.0).abs() < 1e-4);
                // indices sorted strictly increasing (CSR convention)
                assert!(idx.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn rcv1_like_feature_frequencies_are_heavy_tailed() {
        let d = 500;
        let ds = rcv1_like(2000, d, 0.02, 4);
        let mut counts = vec![0usize; d];
        if let crate::data::Features::Csr { indices, .. } = &ds.features {
            for &j in indices {
                counts[j as usize] += 1;
            }
        }
        // The most frequent decile must carry several times the load of
        // the least frequent half (Zipf law signature).
        let head: usize = counts[..d / 10].iter().sum();
        let tail: usize = counts[d / 2..].iter().sum();
        assert!(head > 3 * tail.max(1), "head={head} tail={tail}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = epsilon_like(50, 10, 7);
        let b = epsilon_like(50, 10, 7);
        let c = epsilon_like(50, 10, 8);
        assert_eq!(a.labels, b.labels);
        if let (crate::data::Features::Dense { x: xa, .. }, crate::data::Features::Dense { x: xb, .. }) =
            (&a.features, &b.features)
        {
            assert_eq!(xa, xb);
        }
        assert_ne!(a.labels, c.labels);
    }
}
