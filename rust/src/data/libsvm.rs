//! LIBSVM/SVMlight text format parser.
//!
//! The paper's datasets (*epsilon_normalized*, *rcv1_test.binary*) ship
//! in this format; when the real files are available they can be loaded
//! with [`load`] and passed to the same drivers as the synthetic
//! surrogates. Format, one sample per line:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based in the file and converted to 0-based; labels are
//! mapped to {−1, +1} (`0` and `-1` both map to −1).

use std::io::BufRead;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

/// Parse a LIBSVM file into a CSR [`Dataset`]. `dim` forces the feature
/// dimension (use the documented d of the dataset); pass `None` to infer
/// it as the maximum index seen.
pub fn load(path: impl AsRef<Path>, dim: Option<usize>) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("cannot open LIBSVM file {}", path.display()))?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    parse(reader, dim, name)
}

/// Parse from any reader (unit tests feed strings).
pub fn parse<R: BufRead>(reader: R, dim: Option<usize>, name: String) -> Result<Dataset> {
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_index = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f32 = label_tok
            .parse::<f32>()
            .with_context(|| format!("line {}: bad label '{label_tok}'", lineno + 1))?;
        let label = if label > 0.0 { 1.0 } else { -1.0 };

        let mut last_index: Option<usize> = None;
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx1: usize = idx_s
                .parse()
                .with_context(|| format!("line {}: bad index '{idx_s}'", lineno + 1))?;
            if idx1 == 0 {
                bail!("line {}: LIBSVM indices are 1-based, found 0", lineno + 1);
            }
            let idx = idx1 - 1;
            if let Some(prev) = last_index {
                if idx <= prev {
                    bail!("line {}: indices must be strictly increasing", lineno + 1);
                }
            }
            last_index = Some(idx);
            let val: f32 = val_s
                .parse()
                .with_context(|| format!("line {}: bad value '{val_s}'", lineno + 1))?;
            max_index = max_index.max(idx);
            indices.push(idx as u32);
            values.push(val);
        }
        labels.push(label);
        indptr.push(indices.len());
    }
    if labels.is_empty() {
        bail!("empty LIBSVM input");
    }
    let inferred = max_index + 1;
    let d = match dim {
        Some(d) => {
            if d < inferred {
                bail!("given dim {d} is smaller than max index {inferred}");
            }
            d
        }
        None => inferred,
    };
    Ok(Dataset::csr(name, indptr, indices, values, d, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RowView;
    use std::io::Cursor;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.5
-1 2:2.0
0 1:1.0 2:1.0 4:1.0  # trailing comment
";

    #[test]
    fn parses_basic_file() {
        let ds = parse(Cursor::new(SAMPLE), None, "t".into()).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 4);
        assert_eq!(ds.labels, vec![1.0, -1.0, -1.0]); // 0 → −1
        match ds.row(0) {
            RowView::Sparse { idx, val } => {
                assert_eq!(idx, &[0, 2]);
                assert_eq!(val, &[0.5, 1.5]);
            }
            _ => panic!(),
        }
        assert_eq!(ds.row_nnz(2), 3);
    }

    #[test]
    fn forced_dimension() {
        let ds = parse(Cursor::new(SAMPLE), Some(100), "t".into()).unwrap();
        assert_eq!(ds.d(), 100);
        assert!(parse(Cursor::new(SAMPLE), Some(2), "t".into()).is_err());
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse(Cursor::new("x 1:1.0\n"), None, "t".into()).is_err());
        assert!(parse(Cursor::new("+1 0:1.0\n"), None, "t".into()).is_err()); // 0-based index
        assert!(parse(Cursor::new("+1 5:1.0 2:1.0\n"), None, "t".into()).is_err()); // not increasing
        assert!(parse(Cursor::new("+1 a:1.0\n"), None, "t".into()).is_err());
        assert!(parse(Cursor::new("+1 1:zz\n"), None, "t".into()).is_err());
        assert!(parse(Cursor::new(""), None, "t".into()).is_err());
    }

    #[test]
    fn blank_lines_and_comments_skipped() {
        let src = "\n# full comment\n+1 1:1.0\n\n-1 2:1.0\n";
        let ds = parse(Cursor::new(src), None, "t".into()).unwrap();
        assert_eq!(ds.n(), 2);
    }

    #[test]
    fn load_from_tempfile() {
        let dir = std::env::temp_dir();
        let path = dir.join("memsgd_libsvm_test.txt");
        std::fs::write(&path, SAMPLE).unwrap();
        let ds = load(&path, None).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.name, "memsgd_libsvm_test.txt");
        std::fs::remove_file(&path).ok();
    }
}
