//! Measurement harness for `rust/benches/` (criterion replacement).
//!
//! Usage pattern inside a `harness = false` bench binary:
//!
//! ```ignore
//! let mut b = Bench::new("compressors");
//! b.run("top_k d=2000 k=1", || top_k(&x, 1, &mut out));
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed over adaptive repetitions until a
//! target measuring window is filled; mean / p50 / p95 and throughput are
//! printed in a fixed-width table and optionally appended as JSON lines
//! for the EXPERIMENTS.md tooling.
//!
//! ## Perf-trajectory JSON (`BENCH_hot_path.json` row schema)
//!
//! [`Bench::write_json`] maintains a JSON-lines file of one object per
//! measured case. Keys are emitted in a stable (alphabetical) order and
//! rows are sorted by `(bench, case)`, so repeated runs produce readable
//! diffs. Fields:
//!
//! | field      | type   | meaning                                        |
//! |------------|--------|------------------------------------------------|
//! | `bench`    | string | bench binary title (e.g. `"hot_path"`)         |
//! | `case`     | string | case name — the `(bench, case)` pair is the row key |
//! | `mean_ns`  | number | mean ns/iteration over all samples             |
//! | `p50_ns`   | number | median ns/iteration (what the CI gate compares) |
//! | `p95_ns`   | number | 95th-percentile ns/iteration                   |
//! | `iters`    | number | total timed iterations                         |
//! | `estimated`| bool   | *optional*; `true` marks hand-seeded baseline rows that were never measured — the CI gate widens its tolerance on them (see `util::gate`) |
//!
//! The file is **deduplicated by `(bench, case)`**: writing a case that
//! already has a row replaces it (latest wins), so repeated local runs
//! don't bloat the file; rows from other benches are preserved. The
//! committed `BENCH_hot_path.json` doubles as the CI performance
//! baseline (`.github/workflows/ci.yml`, `bench-gate` job — compared via
//! the `memsgd bench-gate` subcommand).
//!
//! Gate-relevant case names are exported from [`crate::util::gate`] so
//! the bench and the policy cannot drift apart: the calibration case
//! (`gate::CAL_CASE`, `"grad only           dense d=2000"`), the
//! local-step invariant pair (`gate::local_step_dense_case` /
//! `gate::local_step_sparse_case`), and the phase-sync cases of the
//! active-set path (`gate::phase_sync_dense_case`,
//! `"phase sync dense    top_10 d=47236"`, vs
//! `gate::phase_sync_active_case(a)` for `a ∈ {100, 1000, 10000}`,
//! `"phase sync active   top_10 d=47236 a=..."` — the rows whose p50s
//! pin sync cost to the active-set size rather than d), and the
//! wire-codec throughput cases (`gate::wire_encode_sparse_case` /
//! `gate::wire_decode_sparse_case` / `gate::wire_encode_qsgd_case` /
//! `gate::wire_decode_qsgd_case` — the threaded engines' per-message
//! serialization cost, regression-gated like every other row), and the
//! TCP round-trip cases (`gate::tcp_roundtrip_sparse_case` /
//! `gate::tcp_roundtrip_qsgd_case` — the cluster runtime's full
//! encode → length-framed localhost socket hop → decode cost; the delta
//! against the matching codec rows isolates framing + syscall overhead).

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// One measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

/// Bench harness: collects measurements, prints a table, optionally dumps
/// JSON (set `MEMSGD_BENCH_JSON=/path/file.json`).
pub struct Bench {
    pub title: String,
    pub warmup: Duration,
    pub window: Duration,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        println!("\n=== bench: {title} ===");
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            "case", "mean", "p50", "p95", "iters"
        );
        Bench {
            title: title.to_string(),
            warmup: Duration::from_millis(80),
            window: Duration::from_millis(400),
            results: Vec::new(),
        }
    }

    /// Fast harness for long-running cases (convergence benches): one
    /// warmup-free sample per repetition.
    pub fn slow(title: &str) -> Bench {
        let mut b = Bench::new(title);
        b.warmup = Duration::ZERO;
        b.window = Duration::ZERO;
        b
    }

    /// Time `f` adaptively and record under `name`. Returns mean ns/iter.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Estimate a batch size so each sample is >= ~50us (amortizes timer
        // overhead) and collect samples until the window is filled.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(30));
        let batch = (Duration::from_micros(50).as_nanos() / once.as_nanos()).max(1) as usize;
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0usize;
        let started = Instant::now();
        loop {
            let s = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if started.elapsed() >= self.window && samples.len() >= 5 {
                break;
            }
            if samples.len() >= 2_000 {
                break;
            }
        }
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
            min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            m.iters
        );
        let mean = m.mean_ns;
        self.results.push(m);
        mean
    }

    /// Record an externally measured duration (for end-to-end drivers that
    /// cannot be re-run in a closure cheaply).
    pub fn record(&mut self, name: &str, elapsed: Duration, iters: usize) {
        let per_iter = elapsed.as_nanos() as f64 / iters.max(1) as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: per_iter,
            p50_ns: per_iter,
            p95_ns: per_iter,
            min_ns: per_iter,
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            m.name,
            fmt_ns(m.mean_ns),
            "-",
            "-",
            m.iters
        );
        self.results.push(m);
    }

    /// Print the footer and dump JSON if requested via env var.
    pub fn finish(&self) {
        if let Ok(path) = std::env::var("MEMSGD_BENCH_JSON") {
            let _ = self.write_json(&path);
        }
        println!("=== bench: {} done ({} cases) ===", self.title, self.results.len());
    }

    /// Merge this bench's rows into the JSON-lines file at `path` (the
    /// same format the `MEMSGD_BENCH_JSON` env hook writes; full schema
    /// in the module docs). Rows are **deduplicated by `(bench, case)`
    /// keeping the latest measurement**, sorted by that key, and emitted
    /// with a stable field order — so perf-trajectory files like
    /// `BENCH_hot_path.json` stay small and diff cleanly no matter how
    /// often the bench reruns. Unparseable lines in an existing file are
    /// dropped with a warning rather than aborting the run.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        // (bench, case) → row; the BTreeMap both dedupes (later entries,
        // including this run's, overwrite earlier ones) and sorts the
        // final write by key.
        let mut rows = std::collections::BTreeMap::new();
        let field = |row: &Json, key: &str| -> String {
            row.get(key).and_then(|v| v.as_str().ok()).unwrap_or("").to_string()
        };
        if let Ok(existing) = std::fs::read_to_string(path) {
            for line in existing.lines().filter(|l| !l.trim().is_empty()) {
                match Json::parse(line) {
                    Ok(row) => {
                        let key = (field(&row, "bench"), field(&row, "case"));
                        rows.insert(key, row);
                    }
                    Err(e) => eprintln!("{path}: dropping unparseable row ({e:#}): {line}"),
                }
            }
        }
        for m in &self.results {
            let row = Json::obj(vec![
                ("bench", Json::str(&self.title)),
                ("case", Json::str(&m.name)),
                ("mean_ns", Json::Num(m.mean_ns)),
                ("p50_ns", Json::Num(m.p50_ns)),
                ("p95_ns", Json::Num(m.p95_ns)),
                ("iters", Json::Num(m.iters as f64)),
            ]);
            rows.insert((self.title.clone(), m.name.clone()), row);
        }
        let mut text = String::new();
        for row in rows.values() {
            text.push_str(&row.to_string());
            text.push('\n');
        }
        std::fs::write(path, text)
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("self-test");
        let mut acc = 0u64;
        let mean = b.run("wrapping-add-loop", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(mean > 0.0);
        assert_eq!(b.results.len(), 1);
        b.finish();
    }

    #[test]
    fn write_json_dedupes_by_bench_and_case_keeping_latest() {
        let path = std::env::temp_dir().join("memsgd_bench_json_test.json");
        std::fs::remove_file(&path).ok();

        let mut b = Bench::new("json-test");
        b.record("case-a", Duration::from_millis(1), 10);
        b.record("case-b", Duration::from_millis(2), 10);
        b.write_json(path.to_str().unwrap()).unwrap();
        // Rerunning must replace, not append.
        let mut b2 = Bench::new("json-test");
        b2.record("case-a", Duration::from_millis(5), 10);
        b2.write_json(path.to_str().unwrap()).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "one row per (bench, case):\n{text}");
        let row_a = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(row_a.req("case").unwrap().as_str().unwrap(), "case-a");
        // Latest measurement won: 5ms/10 iters = 500_000 ns.
        assert_eq!(row_a.req("p50_ns").unwrap().as_f64().unwrap(), 500_000.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_json_preserves_other_benches_and_sorts_rows() {
        let path = std::env::temp_dir().join("memsgd_bench_json_sort_test.json");
        std::fs::remove_file(&path).ok();
        let mut zz = Bench::new("zz-later");
        zz.record("z-case", Duration::from_millis(1), 1);
        zz.write_json(path.to_str().unwrap()).unwrap();
        let mut aa = Bench::new("aa-early");
        aa.record("a-case", Duration::from_millis(1), 1);
        aa.write_json(path.to_str().unwrap()).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let benches: Vec<String> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().req("bench").unwrap().as_str().unwrap().to_string())
            .collect();
        // Other benches' rows survive, and output is sorted by (bench, case).
        assert_eq!(benches, vec!["aa-early", "zz-later"]);
        // Stable field order within a row (alphabetical via BTreeMap).
        assert!(text.lines().next().unwrap().starts_with("{\"bench\":"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}
