//! Measurement harness for `rust/benches/` (criterion replacement).
//!
//! Usage pattern inside a `harness = false` bench binary:
//!
//! ```ignore
//! let mut b = Bench::new("compressors");
//! b.run("top_k d=2000 k=1", || top_k(&x, 1, &mut out));
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed over adaptive repetitions until a
//! target measuring window is filled; mean / p50 / p95 and throughput are
//! printed in a fixed-width table and optionally appended as JSON lines
//! for the EXPERIMENTS.md tooling.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// One measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

/// Bench harness: collects measurements, prints a table, optionally dumps
/// JSON (set `MEMSGD_BENCH_JSON=/path/file.json`).
pub struct Bench {
    pub title: String,
    pub warmup: Duration,
    pub window: Duration,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        println!("\n=== bench: {title} ===");
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            "case", "mean", "p50", "p95", "iters"
        );
        Bench {
            title: title.to_string(),
            warmup: Duration::from_millis(80),
            window: Duration::from_millis(400),
            results: Vec::new(),
        }
    }

    /// Fast harness for long-running cases (convergence benches): one
    /// warmup-free sample per repetition.
    pub fn slow(title: &str) -> Bench {
        let mut b = Bench::new(title);
        b.warmup = Duration::ZERO;
        b.window = Duration::ZERO;
        b
    }

    /// Time `f` adaptively and record under `name`. Returns mean ns/iter.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Estimate a batch size so each sample is >= ~50us (amortizes timer
        // overhead) and collect samples until the window is filled.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(30));
        let batch = (Duration::from_micros(50).as_nanos() / once.as_nanos()).max(1) as usize;
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0usize;
        let started = Instant::now();
        loop {
            let s = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if started.elapsed() >= self.window && samples.len() >= 5 {
                break;
            }
            if samples.len() >= 2_000 {
                break;
            }
        }
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
            min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            m.iters
        );
        let mean = m.mean_ns;
        self.results.push(m);
        mean
    }

    /// Record an externally measured duration (for end-to-end drivers that
    /// cannot be re-run in a closure cheaply).
    pub fn record(&mut self, name: &str, elapsed: Duration, iters: usize) {
        let per_iter = elapsed.as_nanos() as f64 / iters.max(1) as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: per_iter,
            p50_ns: per_iter,
            p95_ns: per_iter,
            min_ns: per_iter,
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            m.name,
            fmt_ns(m.mean_ns),
            "-",
            "-",
            m.iters
        );
        self.results.push(m);
    }

    /// Print the footer and dump JSON if requested via env var.
    pub fn finish(&self) {
        if let Ok(path) = std::env::var("MEMSGD_BENCH_JSON") {
            let _ = self.write_json(&path);
        }
        println!("=== bench: {} done ({} cases) ===", self.title, self.results.len());
    }

    /// Append this bench's rows as JSON lines to `path` — the same
    /// format the `MEMSGD_BENCH_JSON` env hook writes. Benches that
    /// track a perf trajectory (e.g. `hot_path` →
    /// `BENCH_hot_path.json`) call this unconditionally so every run
    /// accumulates a record.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut text = String::new();
        for m in &self.results {
            let row = Json::obj(vec![
                ("bench", Json::str(&self.title)),
                ("case", Json::str(&m.name)),
                ("mean_ns", Json::Num(m.mean_ns)),
                ("p50_ns", Json::Num(m.p50_ns)),
                ("p95_ns", Json::Num(m.p95_ns)),
                ("iters", Json::Num(m.iters as f64)),
            ]);
            text.push_str(&row.to_string());
            text.push('\n');
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(text.as_bytes())
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("self-test");
        let mut acc = 0u64;
        let mean = b.run("wrapping-add-loop", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(mean > 0.0);
        assert_eq!(b.results.len(), 1);
        b.finish();
    }

    #[test]
    fn write_json_appends_one_line_per_case() {
        let mut b = Bench::new("json-test");
        b.record("case-a", Duration::from_millis(1), 10);
        b.record("case-b", Duration::from_millis(2), 10);
        let path = std::env::temp_dir().join("memsgd_bench_json_test.json");
        std::fs::remove_file(&path).ok();
        b.write_json(path.to_str().unwrap()).unwrap();
        b.write_json(path.to_str().unwrap()).unwrap(); // appends
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.contains("\"case-a\""));
        assert!(text.contains("json-test"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}
