//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`), experiment configuration files, and the
//! metric records the drivers emit. Covers the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are kept as `f64` (adequate: our manifests carry
/// dims < 2^31 and metric floats).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ----- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 * 4.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(xs) => Ok(xs),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    // ----- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num_arr<I: IntoIterator<Item = f64>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(Json::Num).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; emit null (matches python json.dumps(allow_nan=False) policy).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at offset {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u{code:04x}"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"entries":[{"dims":[256,2000],"dtype":"f32","ok":true}],"format":1}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v, Json::Str("éA".into()));
    }

    #[test]
    fn string_escaping_round_trips() {
        let s = "line1\nline2\t\"quoted\" \\ end";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "x");
        assert!(!v.req("b").unwrap().as_bool().unwrap());
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.req("missing").is_err());
        assert!(v.req("s").unwrap().as_f64().is_err());
    }
}
